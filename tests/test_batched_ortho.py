"""Batched optimizer-step orthogonalization: shape-class routing and
batched-vs-leafwise parity.

The load-bearing claims:

  * a step's 2-D matrices partition into shape classes and the dispatch
    count is O(classes), not O(leaves) — asserted on the pure
    ``plan_batched_ortho`` query;
  * the batched answer IS the leafwise answer: same pytree through
    ``batched_orthogonalize`` and per-matrix ``qr_orthogonalize_2d``
    matches within the conformance tolerance rule (100 * eps * max(m, n)
    — sign-fixed thin Q is unique for full-rank input, so the two
    dispatch schedules target the same matrix), and BITWISE where the
    batched path falls back to the identical leafwise function
    (singleton classes);
  * ``muon_update(batched_ortho=True)`` is a drop-in: same params/state
    out (to tolerance), same tree structure, jit-compatible.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim import (
    DEFAULT_ORTHO_POLICY, muon_init, muon_update, plan_batched_ortho,
    qr_orthogonalize_2d,
)
from repro.optim.batched_ortho import batched_orthogonalize
from repro.serving.bucketing import BucketingPolicy

KEY = jax.random.PRNGKey(7)


def _tol(shape):
    return 100.0 * float(jnp.finfo(jnp.float32).eps) * max(shape[-2:])


def _leafwise(leaf, **kw):
    stack = leaf.reshape((-1,) + leaf.shape[-2:])
    qs = [qr_orthogonalize_2d(stack[i], **kw) for i in range(stack.shape[0])]
    return jnp.stack(qs).reshape(leaf.shape)


def _assert_parity(leaves, outs, **kw):
    for leaf, o in zip(leaves, outs):
        assert o.shape == leaf.shape and o.dtype == leaf.dtype
        ref = _leafwise(leaf, **kw)
        err = float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        assert err <= _tol(leaf.shape), (leaf.shape, err, _tol(leaf.shape))


def _mk(shapes, key=KEY, dtype=jnp.float32):
    ks = jax.random.split(key, len(shapes))
    return [jax.random.normal(k, s, jnp.float32).astype(dtype)
            for k, s in zip(ks, shapes)]


# ------------------------------------------------------------- planning


def test_plan_dispatch_count_is_classes_not_leaves():
    """The headline: 5 leaves / 13 matrices over 2 repeated shapes plan
    to 2 batched dispatches (plus the singleton's leafwise fallback)."""
    shapes = [((3, 48, 48), np.float32), ((3, 48, 48), np.float32),
              ((3, 96, 48), np.float32), ((3, 48, 96), np.float32),
              ((40, 24), np.float32)]
    plan = plan_batched_ortho(shapes)
    assert plan.n_leaves == 5 and plan.n_matrices == 13
    routes = {(c.key.m, c.key.n): c.route for c in plan.classes}
    # wide 48x96 orients tall into the 96x48 class
    assert routes == {(48, 48): "batched", (96, 48): "batched",
                      (48, 32): "leafwise"}
    assert plan.dispatches == 3          # 2 batched + 1 singleton
    assert plan.batched_matrices == 12 and plan.leafwise_matrices == 1
    # every matrix is owned by exactly one class
    owned = sorted(i for c in plan.classes for i in c.members)
    assert owned == list(range(13))


def test_plan_singleton_class_routes_leafwise():
    plan = plan_batched_ortho([((64, 32), np.float32)])
    (cls,) = plan.classes
    assert cls.route == "leafwise" and "singleton" in cls.reason
    assert plan.dispatches == 1


def test_plan_batched_class_carries_explain_trail():
    """Batched classes keep the planner's full decision trail (the
    explain contract: every routing choice is auditable)."""
    plan = plan_batched_ortho([((48, 48), np.float32)] * 3)
    (cls,) = plan.classes
    assert cls.route == "batched" and cls.method is not None
    assert cls.explain is not None
    sel = cls.explain.selected
    assert sel is not None and sel.rule in cls.reason


def test_plan_rejects_vector_leaves():
    with pytest.raises(ValueError):
        plan_batched_ortho([((64,), np.float32)])


def test_plan_merges_ragged_shapes_at_tile_granularity():
    """Off-tile shapes tile-round into the class of their rounded-up
    neighbors, so near-miss raggedness still batches."""
    plan = plan_batched_ortho([((45, 30), np.float32),
                               ((48, 32), np.float32)])
    (cls,) = plan.classes       # both land in the padded 48x32 class
    assert (cls.key.m, cls.key.n) == (48, 32)
    assert len(cls.members) == 2 and cls.route == "batched"


# --------------------------------------------------------------- parity


def test_parity_ragged_mix():
    """Ragged shape mix — square, tall, wide, off-tile, stacked — through
    both schedules: every member matches within the conformance rule."""
    shapes = [(48, 48), (96, 48), (48, 96), (45, 30), (3, 48, 48),
              (2, 2, 48, 48)]
    leaves = _mk(shapes)
    outs = batched_orthogonalize(leaves)
    _assert_parity(leaves, outs)


def test_parity_singleton_fallback_is_bitwise():
    """A singleton class runs the very same qr_orthogonalize_2d the
    leafwise path runs — bitwise equality, not just tolerance."""
    (leaf,) = _mk([(56, 24)])
    (out,) = batched_orthogonalize([leaf])
    ref = qr_orthogonalize_2d(leaf)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_parity_bf16_storage():
    """bf16 leaves: batched classes accumulate in fp32 (class compute
    dtype = promote_types) and return bf16, same as the leafwise path."""
    leaves = _mk([(48, 48), (48, 48), (96, 48)], dtype=jnp.bfloat16)
    outs = batched_orthogonalize(leaves)
    assert all(o.dtype == jnp.bfloat16 for o in outs)
    _assert_parity(leaves, outs)


def test_parity_inside_jit():
    """The executor's routing is static over shapes — the whole thing
    traces under jit and matches the eager result."""
    leaves = _mk([(3, 48, 48), (96, 48), (40, 24)])
    eager = batched_orthogonalize(leaves)
    jitted = jax.jit(lambda ls: batched_orthogonalize(ls))(leaves)
    for a, b in zip(eager, jitted):
        assert float(jnp.max(jnp.abs(a - b))) <= _tol(a.shape)


def test_precomputed_plan_reuse():
    """A plan built from the shapes alone drives the executor (what the
    bench does: count dispatches without running, then run)."""
    leaves = _mk([(48, 48), (48, 48), (96, 48), (96, 48)])
    plan = plan_batched_ortho([(tuple(l.shape), l.dtype) for l in leaves])
    outs = batched_orthogonalize(leaves, ortho_plan=plan)
    _assert_parity(leaves, outs)
    assert plan.dispatches == 2


def test_custom_policy_changes_classes():
    """A coarser policy merges shapes into fewer classes (tile-48 pads
    both 40x40 and 48x48 to 48x48; tile-8 keeps them apart) — routing
    follows the policy."""
    shapes = [((40, 40), np.float32), ((48, 48), np.float32)]
    fine = plan_batched_ortho(
        shapes, policy=BucketingPolicy(tile=8, max_waste=0.0))
    coarse = plan_batched_ortho(
        shapes, policy=BucketingPolicy(tile=48, max_waste=0.25))
    assert len(fine.classes) == 2 and len(coarse.classes) == 1
    assert coarse.dispatches == 1


# ---------------------------------------------------------- muon_update


def _lm_like():
    ks = jax.random.split(KEY, 9)
    mk = lambda s, k: 0.02 * jax.random.normal(k, s, jnp.float32)  # noqa
    params = {
        "embed": {"table": mk((128, 48), ks[0])},
        "layers": {
            "wq": mk((3, 48, 48), ks[1]), "wk": mk((3, 48, 48), ks[2]),
            "wv": mk((3, 48, 48), ks[3]), "wo": mk((3, 48, 48), ks[4]),
            "w_in": mk((3, 96, 48), ks[5]), "w_out": mk((3, 48, 96), ks[6]),
            "g": mk((3, 48), ks[7]),
        },
    }
    grads = jax.tree.map(
        lambda p: 0.1 * jax.random.normal(ks[8], p.shape, p.dtype), params)
    return params, grads


def test_muon_update_batched_matches_leafwise():
    params, grads = _lm_like()
    state = muon_init(params)
    p_ref, s_ref = muon_update(grads, state, params, lr=0.02)
    p_bat, s_bat = muon_update(grads, state, params, lr=0.02,
                               batched_ortho=True)
    assert jax.tree_util.tree_structure(p_ref) == \
        jax.tree_util.tree_structure(p_bat)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_bat)):
        assert float(jnp.max(jnp.abs(a - b))) <= _tol(
            a.shape if a.ndim >= 2 else (1, 1))
    # momentum/second-moment state is orthogonalization-free: bitwise
    for a, b in zip(jax.tree.leaves(s_ref.mu), jax.tree.leaves(s_bat.mu)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(s_ref.nu), jax.tree.leaves(s_bat.nu)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_muon_update_batched_under_jit_two_steps():
    import functools

    params, grads = _lm_like()
    state = muon_init(params)
    step = jax.jit(functools.partial(muon_update, lr=0.02,
                                     batched_ortho=True))
    p1, s1 = step(grads, state, params)
    p2, s2 = step(grads, s1, p1)
    assert int(s2.step) == 2
    for leaf in jax.tree.leaves(p2):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_muon_update_batched_emits_dispatch_metrics():
    """The optim.* counters record the dispatch economy at trace time."""
    from repro.observability import metrics as obs

    params, grads = _lm_like()
    state = muon_init(params)
    d0 = obs.counter_value("optim.ortho_dispatches", route="batched")
    muon_update(grads, state, params, lr=0.02, batched_ortho=True)
    assert obs.counter_value("optim.ortho_dispatches",
                             route="batched") > d0


def test_default_policy_pads_at_tile_granularity():
    """The optimizer policy pads to tile multiples ONLY (max_waste=0):
    parameter shapes are a static set whose classes form from exact
    repeats, so pow2-ish coarsening would buy no merging while costing
    cubic flops (serving's edges pad 576 -> 768, ~2.4x the QR work)."""
    assert DEFAULT_ORTHO_POLICY.tile == 16
    assert DEFAULT_ORTHO_POLICY.max_waste == 0.0
    from repro.serving.bucketing import pad_dim

    kw = dict(tile=DEFAULT_ORTHO_POLICY.tile,
              max_waste=DEFAULT_ORTHO_POLICY.max_waste)
    for d in (48, 96, 576, 1536):     # LM widths pad to themselves
        assert pad_dim(d, **kw) == d
    assert pad_dim(45, **kw) == 48    # ragged shapes still merge
    assert pad_dim(576, tile=32, max_waste=0.25) == 768  # what we avoid
