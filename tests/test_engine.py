"""Wavefront macro-op engine tests (repro.core.engine).

The engine's contract is *bitwise* equivalence between its lowerings of
the static wavefront schedule:

  * ``use_kernel=True, dispatch_mode="wavefront"`` — one in-place Pallas
    dispatch per (wavefront, kind) task batch over the tile workspace
    (interpret mode on CPU);
  * ``use_kernel=True, dispatch_mode="megakernel"`` — the whole schedule
    as ONE persistent pallas_call walking a scalar-prefetched task table
    with double-buffered tile DMA;
  * ``use_kernel=False`` — the vmapped pure-jnp oracle of the same
    macro-op bodies.

Covered here: per-(wavefront, kind) dispatch vs the jnp lowering from
identical pre-state (the per-macro-op bitwise property), end-to-end
``factor_tiles`` / ``tiled_qr`` bitwise equality per dispatch mode, the
megakernel task-table census / prefetch-safety invariants / one-dispatch
lowering assertion / budget-driven auto fallback, macro-op bodies vs the
independent ``kernels/ref`` oracles, the schedule batch census, the
workspace-donation contract, and the VMEM/shape guards.  The
registry-wide engine hook lives in tests/test_conformance.py.
"""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from hypothesis_compat import given, settings, st

from repro.core import engine
from repro.core.tilegraph import tiled_qr, wavefronts
from repro.kernels import macro_ops, ref


def _workspace(p, q, nb, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((p, q, nb, nb)), jnp.float32)


def _assert_state_bitwise(a: engine.FactorState, b: engine.FactorState):
    for name, xa, xb in zip(a._fields, a, b):
        assert bool((xa == xb).all()), (
            f"{name} differs: max |delta| = "
            f"{float(jnp.abs(xa - xb).max()):.3e}")


# ---------------------------------------------------------------- schedule

@pytest.mark.parametrize("p,q", [(1, 1), (3, 3), (5, 2), (2, 4)])
def test_wavefront_batches_cover_schedule(p, q):
    """The dispatchable batches are exactly the levelized task DAG."""
    batches = engine.wavefront_task_arrays(p, q)
    levels = wavefronts(p, q)
    assert len(batches) == len(levels)
    for by_kind, level in zip(batches, levels):
        tasks = {(t.kind, t.k, t.i, t.j) for t in level}
        batched = {(kind, int(k), int(i), int(j))
                   for kind, idx in by_kind.items()
                   for k, i, j in idx}
        assert batched == tasks


# ------------------------------------------------- per-macro-op bitwise

@pytest.mark.parametrize("p,q", [(3, 3), (4, 2), (2, 3)])
def test_each_wavefront_kind_bitwise(p, q):
    """Every (wavefront, kind) Pallas dispatch matches the jnp lowering
    bitwise when started from the identical pre-wavefront state — the
    per-macro-op property, with realistic (mid-factorization) inputs."""
    nb = 8
    r = min(p, q)
    dt = jnp.float32
    state = engine.FactorState(
        _workspace(p, q, nb, seed=p * 10 + q),
        jnp.zeros((r, nb, nb), dt), jnp.zeros((r, nb), dt),
        jnp.zeros((p, r, nb, nb), dt), jnp.zeros((p, r, nb), dt))
    seen = set()
    for by_kind in engine.wavefront_task_arrays(p, q):
        for kind, idx in by_kind.items():
            seen.add(kind)
            jnp_next = engine._jnp_wavefront(state, {kind: idx})
            pls_next = engine._DISPATCH[kind](state, idx, nb, True)
            _assert_state_bitwise(jnp_next, pls_next)
        # advance on the oracle path so later levels see factored state
        state = engine._jnp_wavefront(state, by_kind)
    if p > 1 and q > 1:
        assert seen == {"GEQRT", "LARFB", "TSQRT", "SSRFB"}


# ------------------------------------------------------ end-to-end bitwise

@pytest.mark.parametrize("p,q", [(1, 1), (2, 2), (4, 4), (5, 2), (2, 4)])
def test_factor_tiles_bitwise(p, q):
    nb = 8
    ws = _workspace(p, q, nb, seed=42)
    f_jnp = engine.factor_tiles(ws.copy(), p=p, q=q, nb=nb, use_kernel=False)
    f_pls = engine.factor_tiles(ws.copy(), p=p, q=q, nb=nb, use_kernel=True)
    _assert_state_bitwise(f_jnp, f_pls)


@settings(max_examples=8, deadline=None)
@given(p=st.integers(1, 4), q=st.integers(1, 4), seed=st.integers(0, 1000))
def test_property_factor_tiles_bitwise(p, q, seed):
    nb = 4
    ws = _workspace(p, q, nb, seed=seed)
    f_jnp = engine.factor_tiles(ws.copy(), p=p, q=q, nb=nb, use_kernel=False)
    f_pls = engine.factor_tiles(ws.copy(), p=p, q=q, nb=nb, use_kernel=True)
    _assert_state_bitwise(f_jnp, f_pls)


@pytest.mark.parametrize("m,n", [(64, 64), (96, 48), (48, 96), (70, 50)])
def test_tiled_qr_engine_bitwise(m, n):
    """tiled_qr's kernel path (engine Pallas dispatch) is bitwise equal
    to its jnp-oracle path, through padding, Q formation and all."""
    rng = np.random.default_rng(m + n)
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    qk, rk = tiled_qr(a, tile=16, use_kernel=True)
    qj, rj = tiled_qr(a, tile=16, use_kernel=False)
    assert bool((qk == qj).all()) and bool((rk == rj).all())


# -------------------------------------------------- megakernel dispatch

@pytest.mark.parametrize("p,q", [(1, 1), (3, 3), (5, 2), (2, 4), (4, 4)])
def test_megakernel_table_census(p, q):
    """Every levelized DAG task appears exactly once in the flattened
    task table, slots are level-grouped with NOOP padding only as a
    level suffix, and the census matches the wavefront batches."""
    table, nlevels, nslots = engine.megakernel_task_table(p, q)
    levels = wavefronts(p, q)
    assert nlevels == len(levels)
    assert table.shape == (nlevels * nslots, engine._NCOLS)
    kind_names = dict(enumerate(engine._KIND_ORDER))
    seen = []
    for lv in range(nlevels):
        rows = table[lv * nslots:(lv + 1) * nslots]
        kinds = rows[:, engine._COL_KIND]
        valid = kinds != engine._NOOP
        # NOOP padding is a suffix: valid slots are contiguous from 0
        assert bool((~valid[int(valid.sum()):]).all())
        got = {(kind_names[int(kd)], int(k), int(i), int(j))
               for kd, k, i, j in rows[valid][:, :4]}
        want = {(t.kind, t.k, t.i, t.j) for t in levels[lv]}
        assert got == want
        seen.extend(got)
    assert len(seen) == len(set(seen)) == engine.task_count(p, q)


@pytest.mark.parametrize("p,q", [(4, 4), (6, 3), (3, 6)])
def test_megakernel_table_prefetch_invariants(p, q):
    """The static flags behind the double buffering: prefetch never
    crosses a level boundary (the wavefront barrier), FETCHED mirrors the
    predecessor's PREFETCH, and every reuse flag marks a genuine repeat
    read of a tile the current task does not write."""
    table, nlevels, nslots = engine.megakernel_task_table(p, q)
    kind_names = dict(enumerate(engine._KIND_ORDER))

    def task(row):
        return (kind_names[int(row[engine._COL_KIND])],
                int(row[engine._COL_K]), int(row[engine._COL_I]),
                int(row[engine._COL_J]))

    for t in range(table.shape[0]):
        row = table[t]
        if row[engine._COL_PREFETCH]:
            # successor exists, is valid, and sits in the same level
            assert (t + 1) // nslots == t // nslots
            assert table[t + 1, engine._COL_KIND] != engine._NOOP
            assert table[t + 1, engine._COL_FETCHED] == 1
            cur, nxt = task(row), task(table[t + 1])
            cw = engine._task_writes(*cur)
            cr = engine._task_reads(*cur)
            nr = engine._task_reads(*nxt)
            # the level-local safety invariant: prefetch (issued before
            # the current task's write-back) never reads a stale tile
            assert not (set(nr) & cw)
            for b in range(3):
                if table[t + 1, engine._COL_REUSE0 + b]:
                    assert b < min(len(cr), len(nr)) and nr[b] == cr[b]
            if table[t + 1, engine._COL_REUSET]:
                assert (engine._task_t_source(*cur)
                        == engine._task_t_source(*nxt) is not None)
        else:
            if t + 1 < table.shape[0]:
                assert table[t + 1, engine._COL_FETCHED] == 0
        if row[engine._COL_KIND] == engine._NOOP:
            assert not row[engine._COL_PREFETCH] \
                and not row[engine._COL_FETCHED]


@pytest.mark.parametrize("p,q", [(1, 1), (2, 2), (4, 4), (5, 2), (2, 4)])
def test_factor_tiles_megakernel_bitwise(p, q):
    """The single-dispatch megakernel lowering is bitwise equal to the
    jnp oracle AND to the per-level wavefront lowering."""
    nb = 8
    ws = _workspace(p, q, nb, seed=42)
    f_jnp = engine.factor_tiles(ws.copy(), p=p, q=q, nb=nb, use_kernel=False)
    f_meg = engine.factor_tiles(ws.copy(), p=p, q=q, nb=nb, use_kernel=True,
                                dispatch_mode="megakernel")
    f_wav = engine.factor_tiles(ws.copy(), p=p, q=q, nb=nb, use_kernel=True,
                                dispatch_mode="wavefront")
    _assert_state_bitwise(f_jnp, f_meg)
    _assert_state_bitwise(f_wav, f_meg)


@pytest.mark.parametrize("m,n", [(64, 64), (96, 48), (48, 96), (70, 50)])
def test_tiled_qr_megakernel_bitwise(m, n):
    """End-to-end tiled_qr on the megakernel dispatch mode is bitwise
    equal to the jnp oracle, through padding, Q formation and all."""
    rng = np.random.default_rng(m + n)
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    qm, rm = tiled_qr(a, tile=16, use_kernel=True,
                      dispatch_mode="megakernel")
    qj, rj = tiled_qr(a, tile=16, use_kernel=False)
    assert bool((qm == qj).all()) and bool((rm == rj).all())


def _pallas_call_count(jaxpr) -> int:
    """Count pallas_call equations anywhere in a (closed) jaxpr, walking
    nested jaxprs through the public eqn-params surface."""
    jx = getattr(jaxpr, "jaxpr", jaxpr)  # ClosedJaxpr -> Jaxpr
    n = 0
    for eqn in jx.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for val in eqn.params.values():
            for sub in val if isinstance(val, (list, tuple)) else (val,):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    n += _pallas_call_count(sub)
    return n


@pytest.mark.parametrize("p,q", [(3, 3), (4, 2)])
def test_megakernel_issues_single_pallas_call(p, q):
    """The acceptance property: megakernel mode lowers the entire
    factorization to exactly ONE pallas_call; wavefront mode issues one
    per (wavefront, kind) batch — exactly schedule_stats' counts."""
    nb = 8
    ws = jax.ShapeDtypeStruct((p, q, nb, nb), jnp.float32)
    stats = engine.schedule_stats(p, q, nb)

    def counted(mode):
        jaxpr = jax.make_jaxpr(
            lambda w: engine._factor_impl(w, p, q, nb, True, True, mode))(ws)
        return _pallas_call_count(jaxpr)

    assert counted("megakernel") == stats["megakernel"]["dispatches"] == 1
    assert counted("wavefront") == stats["wavefront"]["dispatches"]


def test_observability_leaves_megakernel_jaxpr_pinned():
    """The observability layer's zero-cost guarantee at the IR level:
    the public ``factor_tiles`` megakernel path lowers to the IDENTICAL
    jaxpr whether observability is disabled (the default) or fully
    enabled — profiler annotations are ``jax.named_scope`` metadata and
    span/metric emission is host-side, so neither adds an equation —
    and it stays exactly one pallas_call either way."""
    from repro import observability as obs

    p, q, nb = 3, 3, 8
    ws = jax.ShapeDtypeStruct((p, q, nb, nb), jnp.float32)

    def lower():
        return jax.make_jaxpr(
            lambda w: engine.factor_tiles(
                w, p=p, q=q, nb=nb, use_kernel=True, interpret=True,
                dispatch_mode="megakernel"))(ws)

    disabled = lower()
    with obs.enabled_scope():
        enabled = lower()
    assert str(disabled) == str(enabled)
    assert _pallas_call_count(disabled) == _pallas_call_count(enabled) == 1


@pytest.mark.parametrize("batch", [2, 4])
def test_batched_megakernel_issues_single_pallas_call(batch):
    """The serving acceptance property: a whole bucket — B stacked
    workspaces — still lowers to exactly ONE pallas_call in megakernel
    mode (the batch rides the grid's outer axis, sharing one task
    table, not the dispatch count)."""
    p, q, nb = 3, 3, 8
    ws = jax.ShapeDtypeStruct((batch, p, q, nb, nb), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda w: engine._factor_batched_impl(w, p, q, nb, True, True,
                                              "megakernel"))(ws)
    assert _pallas_call_count(jaxpr) == 1


def test_schedule_stats_reports_both_modes():
    stats = engine.schedule_stats(8, 8, nb=64)
    assert stats["megakernel"]["dispatches"] == 1
    assert stats["wavefront"]["dispatches"] == sum(
        len(b) for b in engine.wavefront_task_arrays(8, 8))
    assert stats["tasks"] == engine.task_count(8, 8)
    assert stats["megakernel"]["table_bytes"] > 0
    assert stats["megakernel"]["reused_tile_fetches"] > 0
    assert stats["auto"] in engine.DISPATCH_MODES
    # memoized construction: the same (p, q) returns the same array
    t1, _, _ = engine.megakernel_task_table(8, 8)
    t2, _, _ = engine.megakernel_task_table(8, 8)
    assert t1 is t2


def test_dispatch_mode_auto_rule():
    """Auto picks megakernel inside both budgets and falls back to
    wavefront when either the VMEM working set or the scalar-prefetch
    task table outgrows its budget."""
    from repro.core.plan import _KERNEL_POLICIES, register_kernel_policy

    assert engine.resolve_dispatch_mode(8, 8, 64) == "megakernel"
    # VMEM side: the double-buffered working set of a huge tile
    assert engine.resolve_dispatch_mode(2, 2, 2048) == "wavefront"
    # table side: shrink the policy budget under the 8x8 table
    pol = _KERNEL_POLICIES["macro_ops"]
    table_bytes = engine.schedule_stats(8, 8)["megakernel"]["table_bytes"]
    try:
        register_kernel_policy(
            dataclasses.replace(pol, table_budget=table_bytes - 1))
        assert engine.resolve_dispatch_mode(8, 8, 64) == "wavefront"
    finally:
        register_kernel_policy(pol)
    # and the closed-form early-out rejects huge grids without building
    # the table (the lru cache must not gain an entry)
    info0 = engine.megakernel_task_table.cache_info()
    assert engine.resolve_dispatch_mode(400, 400, 16) == "wavefront"
    assert engine.megakernel_task_table.cache_info().misses == info0.misses


@pytest.mark.parametrize("p,q", [(8, 8), (16, 4), (16, 16)])
def test_megakernel_traffic_at_most_wavefront(p, q):
    """The acceptance property behind bench_kernel_traffic's megakernel
    row: per-task tile DMA in megakernel mode (double-buffer reuse) is
    <= the wavefront mode's (every operand re-fetched per level) on
    every level, and strictly less in total on >= 8x8 grids."""
    reused = engine.megakernel_reused_reads(p, q)
    per_level_dma = []
    for lvl, by_kind in enumerate(engine.wavefront_task_arrays(p, q)):
        tiles_moved = sum(
            idx.shape[0] * (macro_ops.MACRO_OPS[kind].tile_reads
                            + macro_ops.MACRO_OPS[kind].tile_writes)
            for kind, idx in by_kind.items())
        assert 0 <= int(reused[lvl]) <= tiles_moved
        per_level_dma.append((tiles_moved - int(reused[lvl]), tiles_moved))
    total_mega = sum(m_ for m_, _ in per_level_dma)
    total_wave = sum(w for _, w in per_level_dma)
    assert total_mega < total_wave


def test_factor_tiles_megakernel_vmem_guard():
    """Forcing dispatch_mode="megakernel" past the VMEM budget is an
    error (auto would have fallen back to wavefront instead)."""
    nb = 512  # 15 tiles * 512^2 * 4 bytes > the shared 8 MiB budget...
    assert macro_ops.megakernel_vmem_bytes(nb) > macro_ops._POLICY.vmem_budget
    # ...while the per-level wavefront working set (7 tiles) still fits —
    # exactly the window where auto falls back instead of failing
    assert macro_ops.engine_vmem_bytes(nb) <= macro_ops._POLICY.vmem_budget
    assert engine.resolve_dispatch_mode(1, 1, nb) == "wavefront"
    ws = jnp.zeros((1, 1, nb, nb), jnp.float32)
    with pytest.raises(ValueError, match="megakernel VMEM"):
        engine.factor_tiles(ws, p=1, q=1, nb=nb, use_kernel=True,
                            dispatch_mode="megakernel")


def test_factor_tiles_megakernel_table_guard():
    """Forcing megakernel on a grid whose task table exceeds the
    scalar-prefetch budget is refused up front (via the closed-form
    task-count bound — no giant table is built just to error)."""
    p = q = 100  # task_count * 64 B ~= 21.7 MB >> the 512 KiB budget
    from repro.core.plan import kernel_table_budget

    assert engine.task_count(p, q) * engine._NCOLS * 4 \
        > kernel_table_budget("macro_ops")
    ws = jnp.zeros((p, q, 2, 2), jnp.float32)
    info0 = engine.megakernel_task_table.cache_info()
    with pytest.raises(ValueError, match="task table"):
        engine.factor_tiles(ws, p=p, q=q, nb=2, use_kernel=True,
                            dispatch_mode="megakernel")
    assert engine.megakernel_task_table.cache_info().misses == info0.misses


def test_factor_tiles_dispatch_mode_guard():
    ws = _workspace(2, 2, 8)
    with pytest.raises(ValueError, match="dispatch_mode"):
        engine.factor_tiles(ws, p=2, q=2, nb=8, use_kernel=True,
                            dispatch_mode="warpspeed")


def test_factor_tiles_matches_dense_qr():
    """The engine's R (joined from the workspace) matches jnp.linalg.qr
    up to column signs — anchoring the bitwise pair to ground truth."""
    m = n = 64
    nb = 16
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    q, r = tiled_qr(a, tile=nb, use_kernel=True)
    rn = jnp.linalg.qr(a)[1]
    s = jnp.sign(jnp.diagonal(r)) * jnp.sign(jnp.diagonal(rn))
    np.testing.assert_allclose(np.asarray(r * s[:, None]), np.asarray(rn),
                               atol=5e-4)


# -------------------------------------------------- macro-op body oracles

def test_geqrt_body_matches_ref():
    tile = _workspace(1, 1, 16, seed=1)[0, 0]
    pk, tk, tauk = macro_ops.geqrt_body(tile)
    pr, tr, taur = ref.geqrt_ref(tile)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), atol=3e-5)
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr), atol=3e-5)
    np.testing.assert_allclose(np.asarray(tauk), np.asarray(taur), atol=3e-5)


def test_larfb_body_matches_ref():
    tile = _workspace(1, 1, 16, seed=2)[0, 0]
    packed, t, _ = macro_ops.geqrt_body(tile)
    c = _workspace(1, 1, 16, seed=3)[0, 0]
    np.testing.assert_allclose(
        np.asarray(macro_ops.larfb_body(packed, t, c)),
        np.asarray(ref.larfb_ref(packed, t, c)), atol=3e-5)


def test_tsqrt_body_matches_ref():
    nb = 16
    diag = jnp.triu(_workspace(1, 1, nb, seed=4)[0, 0])
    sub = _workspace(1, 1, nb, seed=5)[0, 0]
    mk, vk, tk, tauk = macro_ops.tsqrt_body(diag, sub)
    rr, vr, taur = ref.tsqrt_ref(diag, sub)
    np.testing.assert_allclose(np.asarray(jnp.triu(mk)), np.asarray(rr),
                               atol=3e-5)
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr), atol=3e-5)
    np.testing.assert_allclose(np.asarray(tauk), np.asarray(taur), atol=3e-5)
    np.testing.assert_allclose(
        np.asarray(tk), np.asarray(macro_ops.stacked_larft(vr, taur)),
        atol=3e-5)


def test_tsqrt_body_passes_packed_subdiagonal_through():
    """The diagonal tile carries V1 below its diagonal — TSQRT must
    factor the upper triangle only and keep the packed V1 bit-for-bit."""
    nb = 8
    diag = _workspace(1, 1, nb, seed=6)[0, 0]  # dense: lower part is "V1"
    sub = _workspace(1, 1, nb, seed=7)[0, 0]
    merged, _, _, _ = macro_ops.tsqrt_body(diag, sub)
    lower = jnp.tril(jnp.ones((nb, nb), bool), -1)
    assert bool(jnp.where(lower, merged == diag, True).all())


def test_ssrfb_body_matches_ref():
    nb = 16
    diag = jnp.triu(_workspace(1, 1, nb, seed=8)[0, 0])
    sub = _workspace(1, 1, nb, seed=9)[0, 0]
    _, v2, t, _ = macro_ops.tsqrt_body(diag, sub)
    ck = _workspace(1, 1, nb, seed=10)[0, 0]
    ci = _workspace(1, 1, nb, seed=11)[0, 0]
    got = macro_ops.ssrfb_body(v2, t, ck, ci)
    want = ref.ssrfb_ref(v2, t, ck, ci)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=3e-5)


# --------------------------------------------------------------- donation

@pytest.mark.parametrize("use_kernel,dispatch_mode",
                         [(False, None), (True, "wavefront"),
                          (True, "megakernel")])
def test_factor_tiles_donates_workspace(use_kernel, dispatch_mode):
    """The factor loop consumes the caller's workspace buffer — the hot
    path must not retain a second copy of the input tile array."""
    ws = _workspace(3, 3, 8, seed=12)
    out = engine.factor_tiles(ws, p=3, q=3, nb=8, use_kernel=use_kernel,
                              dispatch_mode=dispatch_mode)
    jax.block_until_ready(out.tiles)
    assert ws.is_deleted(), "input workspace was retained, not donated"


def test_tiled_qr_does_not_consume_user_input():
    """Donation is an engine-internal contract: the public tiled_qr
    caller's matrix survives (the workspace is built from a fresh
    split/pad, never the user's buffer)."""
    a = _workspace(1, 1, 64, seed=13)[0, 0]
    tiled_qr(a, tile=16, use_kernel=False)
    assert not a.is_deleted()
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a))  # readable


# ----------------------------------------------------------------- guards

def test_factor_tiles_shape_guard():
    ws = _workspace(2, 2, 8)
    with pytest.raises(ValueError, match="workspace"):
        engine.factor_tiles(ws, p=2, q=3, nb=8)


def test_factor_tiles_vmem_guard():
    """Tiles past the kernel-policy budget are refused on the kernel
    path (same number the planner uses), and allowed on the jnp path."""
    nb = 2048  # 7 * 2048^2 * 4 bytes > the shared 8 MiB budget
    need = macro_ops.engine_vmem_bytes(nb)
    assert need > macro_ops._POLICY.vmem_budget
    ws = jnp.zeros((1, 1, nb, nb), jnp.float32)
    with pytest.raises(ValueError, match="VMEM"):
        engine.factor_tiles(ws, p=1, q=1, nb=nb, use_kernel=True)


def test_engine_vmem_estimator_is_worst_case():
    for kind in macro_ops.MACRO_OPS:
        assert macro_ops.vmem_bytes(kind, 32) <= macro_ops.engine_vmem_bytes(32)
    # SSRFB holds the most tiles resident
    assert macro_ops.engine_vmem_bytes(32) == macro_ops.vmem_bytes("SSRFB", 32)


# ------------------------------------------ budget staleness (PR-8 bugfix)

def test_dispatch_budgets_read_at_call_time():
    """Re-registering the "macro_ops" policy changes the auto-dispatch
    verdict IMMEDIATELY — no helper may have cached a verdict keyed on
    the old budget.  (The schedule helpers stay lru-cached; only the
    pure structural parts are.)"""
    import importlib

    # repro.core re-exports the plan() function under the same name, so
    # attribute import would shadow the module
    plan_mod = importlib.import_module("repro.core.plan")

    p, q, nb = 3, 3, 8
    orig = plan_mod._KERNEL_POLICIES["macro_ops"]
    assert engine.resolve_dispatch_mode(p, q, nb) == "megakernel"
    try:
        plan_mod.register_kernel_policy(
            dataclasses.replace(orig, table_budget=16))
        mode, why = engine.explain_dispatch_mode(p, q, nb)
        assert mode == "wavefront"
        assert "scalar-prefetch budget 16" in why
        assert engine.resolve_dispatch_mode(p, q, nb) == "wavefront"
        assert engine.schedule_stats(p, q, nb)["auto"] == "wavefront"
        # explicit overrides bypass the registry entirely
        assert engine.resolve_dispatch_mode(
            p, q, nb, table_budget=orig.table_budget) == "megakernel"
    finally:
        plan_mod.register_kernel_policy(orig)
    assert engine.resolve_dispatch_mode(p, q, nb) == "megakernel"


def test_schedule_stats_reports_budgets():
    """schedule_stats carries the budgets its auto verdict used, and
    explicit overrides flow through to both the fields and the verdict."""
    from repro.core.plan import kernel_table_budget, kernel_vmem_budget

    st = engine.schedule_stats(3, 3, 8)
    assert st["vmem_budget"] == kernel_vmem_budget("macro_ops")
    assert st["table_budget"] == kernel_table_budget("macro_ops")
    assert st["auto"] == "megakernel"
    st2 = engine.schedule_stats(3, 3, 8, table_budget=16)
    assert st2["table_budget"] == 16 and st2["auto"] == "wavefront"


def test_lru_cached_helpers_are_budget_free():
    """The purity contract documented above wavefront_task_arrays: the
    cached helpers take only grid ints; every budget-reading function is
    deliberately un-cached."""
    import inspect

    for fn in (engine.wavefront_task_arrays, engine.megakernel_task_table,
               engine.modeled_dma_bytes):
        params = inspect.signature(fn).parameters
        assert "vmem_budget" not in params and "table_budget" not in params
        assert hasattr(fn, "cache_info")
    for fn in (engine.explain_dispatch_mode, engine.resolve_dispatch_mode,
               engine.schedule_stats):
        assert not hasattr(fn, "cache_info")
