"""Wavefront macro-op engine tests (repro.core.engine).

The engine's contract is *bitwise* equivalence between its two
lowerings of the static wavefront schedule:

  * ``use_kernel=True``  — one in-place Pallas dispatch per
    (wavefront, kind) task batch over the tile workspace (interpret
    mode on CPU);
  * ``use_kernel=False`` — the vmapped pure-jnp oracle of the same
    macro-op bodies.

Covered here: per-(wavefront, kind) dispatch vs the jnp lowering from
identical pre-state (the per-macro-op bitwise property), end-to-end
``factor_tiles`` / ``tiled_qr`` bitwise equality, macro-op bodies vs the
independent ``kernels/ref`` oracles, the schedule batch census, the
workspace-donation contract, and the VMEM/shape guards.  The
registry-wide engine hook lives in tests/test_conformance.py.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from hypothesis_compat import given, settings, st

from repro.core import engine
from repro.core.tilegraph import tiled_qr, wavefronts
from repro.kernels import macro_ops, ref


def _workspace(p, q, nb, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((p, q, nb, nb)), jnp.float32)


def _assert_state_bitwise(a: engine.FactorState, b: engine.FactorState):
    for name, xa, xb in zip(a._fields, a, b):
        assert bool((xa == xb).all()), (
            f"{name} differs: max |delta| = "
            f"{float(jnp.abs(xa - xb).max()):.3e}")


# ---------------------------------------------------------------- schedule

@pytest.mark.parametrize("p,q", [(1, 1), (3, 3), (5, 2), (2, 4)])
def test_wavefront_batches_cover_schedule(p, q):
    """The dispatchable batches are exactly the levelized task DAG."""
    batches = engine.wavefront_task_arrays(p, q)
    levels = wavefronts(p, q)
    assert len(batches) == len(levels)
    for by_kind, level in zip(batches, levels):
        tasks = {(t.kind, t.k, t.i, t.j) for t in level}
        batched = {(kind, int(k), int(i), int(j))
                   for kind, idx in by_kind.items()
                   for k, i, j in idx}
        assert batched == tasks


# ------------------------------------------------- per-macro-op bitwise

@pytest.mark.parametrize("p,q", [(3, 3), (4, 2), (2, 3)])
def test_each_wavefront_kind_bitwise(p, q):
    """Every (wavefront, kind) Pallas dispatch matches the jnp lowering
    bitwise when started from the identical pre-wavefront state — the
    per-macro-op property, with realistic (mid-factorization) inputs."""
    nb = 8
    r = min(p, q)
    dt = jnp.float32
    state = engine.FactorState(
        _workspace(p, q, nb, seed=p * 10 + q),
        jnp.zeros((r, nb, nb), dt), jnp.zeros((r, nb), dt),
        jnp.zeros((p, r, nb, nb), dt), jnp.zeros((p, r, nb), dt))
    seen = set()
    for by_kind in engine.wavefront_task_arrays(p, q):
        for kind, idx in by_kind.items():
            seen.add(kind)
            jnp_next = engine._jnp_wavefront(state, {kind: idx})
            pls_next = engine._DISPATCH[kind](state, idx, nb, True)
            _assert_state_bitwise(jnp_next, pls_next)
        # advance on the oracle path so later levels see factored state
        state = engine._jnp_wavefront(state, by_kind)
    if p > 1 and q > 1:
        assert seen == {"GEQRT", "LARFB", "TSQRT", "SSRFB"}


# ------------------------------------------------------ end-to-end bitwise

@pytest.mark.parametrize("p,q", [(1, 1), (2, 2), (4, 4), (5, 2), (2, 4)])
def test_factor_tiles_bitwise(p, q):
    nb = 8
    ws = _workspace(p, q, nb, seed=42)
    f_jnp = engine.factor_tiles(ws.copy(), p=p, q=q, nb=nb, use_kernel=False)
    f_pls = engine.factor_tiles(ws.copy(), p=p, q=q, nb=nb, use_kernel=True)
    _assert_state_bitwise(f_jnp, f_pls)


@settings(max_examples=8, deadline=None)
@given(p=st.integers(1, 4), q=st.integers(1, 4), seed=st.integers(0, 1000))
def test_property_factor_tiles_bitwise(p, q, seed):
    nb = 4
    ws = _workspace(p, q, nb, seed=seed)
    f_jnp = engine.factor_tiles(ws.copy(), p=p, q=q, nb=nb, use_kernel=False)
    f_pls = engine.factor_tiles(ws.copy(), p=p, q=q, nb=nb, use_kernel=True)
    _assert_state_bitwise(f_jnp, f_pls)


@pytest.mark.parametrize("m,n", [(64, 64), (96, 48), (48, 96), (70, 50)])
def test_tiled_qr_engine_bitwise(m, n):
    """tiled_qr's kernel path (engine Pallas dispatch) is bitwise equal
    to its jnp-oracle path, through padding, Q formation and all."""
    rng = np.random.default_rng(m + n)
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    qk, rk = tiled_qr(a, tile=16, use_kernel=True)
    qj, rj = tiled_qr(a, tile=16, use_kernel=False)
    assert bool((qk == qj).all()) and bool((rk == rj).all())


def test_factor_tiles_matches_dense_qr():
    """The engine's R (joined from the workspace) matches jnp.linalg.qr
    up to column signs — anchoring the bitwise pair to ground truth."""
    m = n = 64
    nb = 16
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    q, r = tiled_qr(a, tile=nb, use_kernel=True)
    rn = jnp.linalg.qr(a)[1]
    s = jnp.sign(jnp.diagonal(r)) * jnp.sign(jnp.diagonal(rn))
    np.testing.assert_allclose(np.asarray(r * s[:, None]), np.asarray(rn),
                               atol=5e-4)


# -------------------------------------------------- macro-op body oracles

def test_geqrt_body_matches_ref():
    tile = _workspace(1, 1, 16, seed=1)[0, 0]
    pk, tk, tauk = macro_ops.geqrt_body(tile)
    pr, tr, taur = ref.geqrt_ref(tile)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), atol=3e-5)
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr), atol=3e-5)
    np.testing.assert_allclose(np.asarray(tauk), np.asarray(taur), atol=3e-5)


def test_larfb_body_matches_ref():
    tile = _workspace(1, 1, 16, seed=2)[0, 0]
    packed, t, _ = macro_ops.geqrt_body(tile)
    c = _workspace(1, 1, 16, seed=3)[0, 0]
    np.testing.assert_allclose(
        np.asarray(macro_ops.larfb_body(packed, t, c)),
        np.asarray(ref.larfb_ref(packed, t, c)), atol=3e-5)


def test_tsqrt_body_matches_ref():
    nb = 16
    diag = jnp.triu(_workspace(1, 1, nb, seed=4)[0, 0])
    sub = _workspace(1, 1, nb, seed=5)[0, 0]
    mk, vk, tk, tauk = macro_ops.tsqrt_body(diag, sub)
    rr, vr, taur = ref.tsqrt_ref(diag, sub)
    np.testing.assert_allclose(np.asarray(jnp.triu(mk)), np.asarray(rr),
                               atol=3e-5)
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr), atol=3e-5)
    np.testing.assert_allclose(np.asarray(tauk), np.asarray(taur), atol=3e-5)
    np.testing.assert_allclose(
        np.asarray(tk), np.asarray(macro_ops.stacked_larft(vr, taur)),
        atol=3e-5)


def test_tsqrt_body_passes_packed_subdiagonal_through():
    """The diagonal tile carries V1 below its diagonal — TSQRT must
    factor the upper triangle only and keep the packed V1 bit-for-bit."""
    nb = 8
    diag = _workspace(1, 1, nb, seed=6)[0, 0]  # dense: lower part is "V1"
    sub = _workspace(1, 1, nb, seed=7)[0, 0]
    merged, _, _, _ = macro_ops.tsqrt_body(diag, sub)
    lower = jnp.tril(jnp.ones((nb, nb), bool), -1)
    assert bool(jnp.where(lower, merged == diag, True).all())


def test_ssrfb_body_matches_ref():
    nb = 16
    diag = jnp.triu(_workspace(1, 1, nb, seed=8)[0, 0])
    sub = _workspace(1, 1, nb, seed=9)[0, 0]
    _, v2, t, _ = macro_ops.tsqrt_body(diag, sub)
    ck = _workspace(1, 1, nb, seed=10)[0, 0]
    ci = _workspace(1, 1, nb, seed=11)[0, 0]
    got = macro_ops.ssrfb_body(v2, t, ck, ci)
    want = ref.ssrfb_ref(v2, t, ck, ci)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=3e-5)


# --------------------------------------------------------------- donation

@pytest.mark.parametrize("use_kernel", [False, True])
def test_factor_tiles_donates_workspace(use_kernel):
    """The factor loop consumes the caller's workspace buffer — the hot
    path must not retain a second copy of the input tile array."""
    ws = _workspace(3, 3, 8, seed=12)
    out = engine.factor_tiles(ws, p=3, q=3, nb=8, use_kernel=use_kernel)
    jax.block_until_ready(out.tiles)
    assert ws.is_deleted(), "input workspace was retained, not donated"


def test_tiled_qr_does_not_consume_user_input():
    """Donation is an engine-internal contract: the public tiled_qr
    caller's matrix survives (the workspace is built from a fresh
    split/pad, never the user's buffer)."""
    a = _workspace(1, 1, 64, seed=13)[0, 0]
    tiled_qr(a, tile=16, use_kernel=False)
    assert not a.is_deleted()
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a))  # readable


# ----------------------------------------------------------------- guards

def test_factor_tiles_shape_guard():
    ws = _workspace(2, 2, 8)
    with pytest.raises(ValueError, match="workspace"):
        engine.factor_tiles(ws, p=2, q=3, nb=8)


def test_factor_tiles_vmem_guard():
    """Tiles past the kernel-policy budget are refused on the kernel
    path (same number the planner uses), and allowed on the jnp path."""
    nb = 2048  # 7 * 2048^2 * 4 bytes > the shared 8 MiB budget
    need = macro_ops.engine_vmem_bytes(nb)
    assert need > macro_ops._POLICY.vmem_budget
    ws = jnp.zeros((1, 1, nb, nb), jnp.float32)
    with pytest.raises(ValueError, match="VMEM"):
        engine.factor_tiles(ws, p=1, q=1, nb=nb, use_kernel=True)


def test_engine_vmem_estimator_is_worst_case():
    for kind in macro_ops.MACRO_OPS:
        assert macro_ops.vmem_bytes(kind, 32) <= macro_ops.engine_vmem_bytes(32)
    # SSRFB holds the most tiles resident
    assert macro_ops.engine_vmem_bytes(32) == macro_ops.vmem_bytes("SSRFB", 32)
