"""Chaos suite for the robustness layer: every injectable fault class
fired against every consumer (serving, batched-ortho, plain ``qr()``),
plus the contracts the layer promises when OFF (verify-off is
jaxpr-identical to an unchecked solve) and the satellite fixes that
ride with it (flush atomicity, true watchdog median, the train_lm
fault-tolerance drill).

The acceptance scenario from the PR issue is the end-to-end test at the
bottom: one flush carrying (a) a NaN request in a mixed bucket, (b) a
compile failure on one bucket, and (c) a failed health check on a
dispatch — every uncorrupted request must come back
conformance-correct, the corrupted one quarantined with a named reason,
and the expected ``robustness.escalations`` counters fired.
"""

import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import gaussian
from repro.core.api import qr, QRConfig, plan
from repro.observability import metrics
from repro.robustness import escalate, guards, inject, verify
from repro.serving.bucketing import BucketingPolicy
from repro.serving.qr_service import QRService


@pytest.fixture(autouse=True)
def _disarm():
    """No fault leaks across tests."""
    inject.reset()
    yield
    inject.reset()


def _svc(**kw):
    kw.setdefault("policy", BucketingPolicy(tile=16, max_batch=4))
    kw.setdefault("use_kernel", False)
    return QRService(**kw)


def _randn(m, n, seed=0):
    return np.asarray(
        np.random.default_rng(seed).standard_normal((m, n)), np.float32)


def _resid(a, q, r):
    a, q, r = map(np.asarray, (a, q, r))
    return np.linalg.norm(a - q @ r) / max(np.linalg.norm(a), 1e-30)


def _tol(a):
    return verify.tolerance(np.asarray(a).dtype, *np.asarray(a).shape)


# ------------------------------------------------------------- admission

class TestAdmission:
    def test_rejects_nonfinite_with_named_reason(self):
        a = _randn(8, 4)
        a[2, 1] = np.nan
        with pytest.raises(guards.AdmissionError) as ei:
            guards.admit(a)
        assert ei.value.reason == "nonfinite_input"

    def test_rejects_bad_ndim_and_dtype(self):
        with pytest.raises(guards.AdmissionError) as ei:
            guards.admit(np.zeros(3, np.float32))
        assert ei.value.reason == "bad_ndim"
        with pytest.raises(guards.AdmissionError) as ei:
            guards.admit(np.zeros((3, 3), np.int32))
        assert ei.value.reason == "non_float_dtype"

    def test_condition_guard_is_opt_in(self):
        a = np.eye(4, dtype=np.float32)
        a[3, 3] = 1e-12                       # cond ~ 1e12
        guards.admit(a)                       # default: no cond check
        with pytest.raises(guards.AdmissionError) as ei:
            guards.admit(a, policy=guards.AdmissionPolicy(max_cond=1e6))
        assert ei.value.reason == "ill_conditioned"
        assert guards.estimate_condition(np.eye(3)) == pytest.approx(1.0)

    def test_service_quarantines_bad_request_in_mixed_bucket(self):
        svc = _svc(verify=True)
        good = [_randn(24, 12, seed=s) for s in range(3)]
        bad = good[1].copy()
        bad[0, 0] = np.inf
        rids = [svc.submit(good[0]), svc.submit(bad), svc.submit(good[2])]
        res = svc.flush()
        assert res[rids[1]].error == "quarantined:nonfinite_input"
        assert res[rids[1]].q is None and not res[rids[1]].ok
        for rid, a in ((rids[0], good[0]), (rids[2], good[2])):
            assert res[rid].ok
            assert _resid(a, res[rid].q, res[rid].r) < _tol(a)
        assert svc.stats()["quarantined"] == 1

    def test_flush_with_only_quarantined_requests(self):
        svc = _svc()
        bad = _randn(8, 4)
        bad[:] = np.nan
        rid = svc.submit(bad)
        res = svc.flush()
        assert set(res) == {rid} and not res[rid].ok
        assert svc.flush() == {}              # delivered exactly once


# ---------------------------------------------------------------- verify

class TestVerify:
    def test_tolerance_matches_conformance_rule(self):
        from test_conformance import _tol as conf_tol
        for dtype in (np.float32, np.float64):
            for m, n in ((64, 32), (8, 128)):
                assert verify.tolerance(dtype, m, n) == conf_tol(dtype, m, n)

    def test_healthy_factorization_passes(self):
        a = gaussian(32, 16, seed=3)
        q, r = jnp.linalg.qr(a)
        rep = verify.check_qr(a, q, r)
        assert rep.ok and rep.reason is None

    def test_corrupt_q_fails_with_reason(self):
        a = gaussian(32, 16, seed=3)
        q, r = jnp.linalg.qr(a)
        rep = verify.check_qr(a, q.at[0, 0].set(jnp.nan), r)
        assert not rep.ok and rep.reason == "nonfinite_output"
        rep = verify.check_qr(a, 2.0 * q, r)
        assert not rep.ok and rep.reason in ("residual_exceeds_tol",
                                             "ortho_defect_exceeds_tol")

    def test_r_only_gram_check(self):
        a = gaussian(32, 16, seed=4)
        r = jnp.linalg.qr(a, mode="r")
        assert verify.check_r(a, r).ok
        bad = verify.check_r(a, 1.5 * r)
        assert not bad.ok and bad.reason == "gram_residual_exceeds_tol"

    def test_batch_identifies_single_bad_slice(self):
        a = jnp.stack([gaussian(16, 8, seed=s) for s in range(4)])
        q, r = jax.vmap(jnp.linalg.qr)(a)
        q = q.at[2].set(jnp.nan)
        reports = verify.check_batch(a, q, r)
        assert [rep.ok for rep in reports] == [True, True, False, True]
        assert reports[2].reason == "nonfinite_output"

    def test_env_default_resolution(self, monkeypatch):
        monkeypatch.delenv("REPRO_VERIFY", raising=False)
        assert verify.verify_enabled(None) is False
        monkeypatch.setenv("REPRO_VERIFY", "1")
        assert verify.verify_enabled(None) is True
        assert verify.verify_enabled(False) is False   # explicit wins
        monkeypatch.setenv("REPRO_VERIFY", "off")
        assert verify.verify_enabled(None) is False

    def test_qrconfig_verify_validation(self):
        with pytest.raises(ValueError, match="verify"):
            QRConfig(verify="yes")
        assert QRConfig(verify=True).verify is True


# ------------------------------------------------------------ escalation

class TestEscalation:
    def test_ladder_is_monotone(self):
        assert escalate.ladder_below("megakernel") == (
            "wavefront", "oracle", "lapack")
        assert escalate.ladder_below("lapack") == ()
        # unknown pseudo-rungs land on the safe kernel-free tail
        assert escalate.ladder_below("planned") == ("oracle", "lapack")

    def test_classify_keeps_injected_site(self):
        assert escalate.classify(
            inject.InjectedFault("compile", "x"), "compile") \
            == "injected_compile"
        assert escalate.classify(ValueError("x"), "dispatch") \
            == "dispatch_failed"

    def test_record_fires_counter(self):
        before = metrics.counter_value(
            "robustness.escalations",
            **{"from": "megakernel", "to": "wavefront", "reason": "t"})
        esc = escalate.record("megakernel", "wavefront", "t", "detail")
        assert esc.rule == "t" and esc.reason == "detail"
        assert metrics.counter_value(
            "robustness.escalations",
            **{"from": "megakernel", "to": "wavefront",
               "reason": "t"}) == before + 1

    def test_solve_below_recovers_and_exhausts(self):
        a = _randn(20, 10, seed=5)
        q, r, rung, escs = escalate.solve_below(a, start="megakernel")
        assert rung in ("oracle", "lapack") and escs == []
        assert _resid(a, q, r) < _tol(a)
        # every remaining rung faulted -> exhausted, hops preserved
        with inject.active(inject.Fault(site="dispatch", times=None)):
            with pytest.raises(escalate.EscalationExhausted) as ei:
                escalate.solve_below(a, start="megakernel")
        assert len(ei.value.escalations) == 2   # oracle, lapack both raise

    def test_lapack_verify_failure_returns_factors(self):
        # a pathological input: lapack is the last word even if the
        # health check dislikes the answer (the input is the suspect)
        a = _randn(12, 6, seed=6)
        q, r, rung, _ = escalate.solve_below(a, start="oracle")
        assert rung == "lapack" or rung == "oracle"


# ------------------------------------------------------------- injection

class TestInjection:
    def test_poison_is_deterministic(self):
        a = _randn(16, 16, seed=7)
        p1 = inject.poison(a, kind="nan", frac=0.1, seed=3)
        p2 = inject.poison(a, kind="nan", frac=0.1, seed=3)
        assert np.array_equal(np.isnan(p1), np.isnan(p2))
        assert np.isnan(p1).sum() == max(1, int(0.1 * a.size))

    def test_times_gating_and_scoping(self):
        f = inject.Fault(site="compile", times=2)
        with inject.active(f):
            assert inject.enabled()
            for _ in range(2):
                with pytest.raises(inject.InjectedFault):
                    inject.check("compile", "anything")
            inject.check("compile", "anything")   # disarmed after 2
        assert not inject.enabled()
        inject.check("compile", "anything")       # out of scope: no-op

    def test_match_is_substring_on_tag(self):
        with inject.active(inject.Fault(site="dispatch", match="64x64")):
            inject.check("dispatch", "32x32:oracle")      # no match
            with pytest.raises(inject.InjectedFault) as ei:
                inject.check("dispatch", "64x64:megakernel")
        assert ei.value.site == "dispatch"

    def test_input_corruption_exercises_admission(self):
        svc = _svc()
        with inject.active(inject.Fault(site="input", match="24x12")):
            rid = svc.submit(_randn(24, 12, seed=8))
        res = svc.flush()
        assert res[rid].error == "quarantined:nonfinite_input"
        assert metrics.counter_value("robustness.faults_injected",
                                     site="input") >= 1


# -------------------------------------------------- service chaos matrix

class TestServiceChaos:
    def test_compile_fault_escalates_to_working_rung(self):
        svc = _svc(verify=True)
        arrs = [_randn(24, 12, seed=s) for s in range(3)]
        with inject.active(inject.Fault(site="compile", match="32x16")):
            outs = svc.submit_many(arrs)
        assert all(o.ok for o in outs)
        for a, o in zip(arrs, outs):
            assert _resid(a, o.q, o.r) < _tol(a)
        rules = [e.rule for e in svc.escalations]
        assert "injected_compile" in rules

    def test_dispatch_fault_recovers_per_request(self):
        svc = _svc(verify=True)
        arrs = [_randn(24, 12, seed=s) for s in range(3)]
        with inject.active(inject.Fault(site="dispatch", match="32x16")):
            outs = svc.submit_many(arrs)
        assert all(o.ok for o in outs)
        for a, o in zip(arrs, outs):
            assert _resid(a, o.q, o.r) < _tol(a)

    def test_output_corruption_caught_and_healed_per_slice(self):
        svc = _svc(verify=True)
        arrs = [_randn(24, 12, seed=s) for s in range(3)]
        with inject.active(inject.Fault(site="output", match="32x16",
                                        slice_index=1)):
            outs = svc.submit_many(arrs)
        assert all(o.ok for o in outs)
        for a, o in zip(arrs, outs):
            assert np.isfinite(np.asarray(o.q)).all()
            assert _resid(a, o.q, o.r) < _tol(a)
        assert svc.stats()["health_check_failures"] >= 1
        assert any(e.rule == "health_check_failed"
                   for e in svc.escalations)

    def test_vmem_fault_walks_megakernel_to_wavefront(self):
        svc = QRService(policy=BucketingPolicy(tile=8, max_batch=2),
                        use_kernel=True, interpret=True,
                        dispatch_mode="megakernel", verify=True)
        arrs = [_randn(16, 8, seed=s) for s in range(2)]
        with inject.active(inject.Fault(site="vmem", match="megakernel")):
            outs = svc.submit_many(arrs)
        assert all(o.ok for o in outs)
        for a, o in zip(arrs, outs):
            assert _resid(a, o.q, o.r) < _tol(a)
        hops = [(e.rung_from, e.rung_to) for e in svc.escalations]
        assert ("megakernel", "wavefront") in hops

    def test_latency_fault_only_slows(self):
        svc = _svc()
        with inject.active(inject.Fault(site="latency", delay_s=0.05)):
            outs = svc.submit_many([_randn(12, 6, seed=9)])
        assert outs[0].ok

    def test_mode_r_verify_and_recovery(self):
        svc = _svc(verify=True)
        arrs = [_randn(24, 12, seed=s) for s in range(2)]
        with inject.active(inject.Fault(site="output", match="32x16",
                                        slice_index=0)):
            outs = svc.submit_many(arrs, mode="r")
        assert all(o.ok and o.q is None for o in outs)
        for a, o in zip(arrs, outs):
            r = np.asarray(o.r)
            gram = np.linalg.norm(a.T @ a - r.T @ r) \
                / np.linalg.norm(a) ** 2
            assert gram < _tol(a)


# -------------------------------------------------------- circuit breaker

class TestCircuitBreaker:
    def test_trips_evicts_and_pins(self):
        svc = _svc(verify=True, breaker_threshold=2)
        fault = inject.Fault(site="dispatch", match="32x16", times=None)
        with inject.active(fault):
            for s in range(2):
                svc.submit_many([_randn(24, 12, seed=s)])
        st = svc.stats()
        assert st["breaker_trips"] == 1 and st["breaker_open"] == 1
        assert not any(ck[0].m == 32 and ck[0].n == 16
                       for ck in svc._plans)   # plans evicted
        # pinned: lapack serves the bucket even with the fault still armed
        with inject.active(inject.Fault(site="dispatch", match="32x16",
                                        times=None)):
            outs = svc.submit_many([_randn(24, 12, seed=11)])
        assert outs[0].ok
        assert svc.stats()["breaker_open"] == 1

    def test_resets_on_tuning_fingerprint_change(self):
        from repro.tuning.cache import TuningCache, active_cache, \
            set_active_cache
        svc = _svc(verify=True, breaker_threshold=1)
        with inject.active(inject.Fault(site="dispatch", match="32x16")):
            svc.submit_many([_randn(24, 12, seed=12)])
        assert svc.stats()["breaker_open"] == 1
        prev = active_cache()
        try:
            set_active_cache(TuningCache(source="test:breaker-reset"))
            svc.submit_many([_randn(24, 12, seed=13)])
            assert svc.stats()["breaker_open"] == 0
        finally:
            set_active_cache(prev)


# -------------------------------------------------------- flush atomicity

class TestFlushAtomicity:
    def test_error_restores_unprocessed_requests(self):
        svc = _svc(escalate=False)             # failures raise through
        arrs = [_randn(24, 12, seed=s) for s in range(3)]
        rids = [svc.submit(a) for a in arrs]
        with inject.active(inject.Fault(site="dispatch", match="32x16")):
            with pytest.raises(inject.InjectedFault):
                svc.flush()
        assert len(svc._pending) == 3          # nothing dropped
        res = svc.flush()                      # fault disarmed: succeeds
        for rid, a in zip(rids, arrs):
            assert res[rid].ok
            assert _resid(a, res[rid].q, res[rid].r) < _tol(a)

    def test_compile_error_restores_requests(self):
        svc = _svc(escalate=False)
        rid = svc.submit(_randn(24, 12, seed=14))
        with inject.active(inject.Fault(site="compile", match="32x16")):
            with pytest.raises(inject.InjectedFault):
                svc.flush()
        assert [r.rid for r in svc._pending] == [rid]
        assert svc.flush()[rid].ok


# ------------------------------------------------------------- plain qr()

class TestCheckedQr:
    def test_output_corruption_recovered(self):
        a = gaussian(20, 10, seed=15)
        with inject.active(inject.Fault(site="output", match="qr:20x10")):
            q, r = qr(a, config=QRConfig(verify=True))
        assert np.isfinite(np.asarray(q)).all()
        assert _resid(a, q, r) < _tol(a)

    def test_mode_r_recovery(self):
        a = gaussian(20, 10, seed=16)
        with inject.active(inject.Fault(site="output", match="qr:20x10")):
            r = qr(a, config=QRConfig(mode="r", verify=True))
        rr = np.asarray(r)
        assert np.isfinite(rr).all()

    def test_batched_input_heals_only_bad_slice(self):
        a = jnp.stack([gaussian(16, 8, seed=s) for s in range(3)])
        with inject.active(inject.Fault(site="output", match="qr:3x16x8",
                                        slice_index=2)):
            q, r = qr(a, config=QRConfig(verify=True))
        q, r = np.asarray(q), np.asarray(r)
        assert np.isfinite(q).all() and np.isfinite(r).all()
        for i in range(3):
            ai = np.asarray(a[i])
            assert _resid(ai, q[i], r[i]) < _tol(ai)

    def test_verify_off_is_jaxpr_identical(self):
        """The pin: the verify knob must not touch the traced program.
        Off, on, and no-knob all trace to the direct solver.solve jaxpr
        (under a trace the input is abstract, so the host-side check
        never fires)."""
        a = gaussian(32, 16, seed=17)

        def traced(cfg):
            return str(jax.make_jaxpr(
                lambda x: qr(x, config=cfg))(a))

        base = str(jax.make_jaxpr(
            plan(a.shape, a.dtype, QRConfig()).solve)(a))
        assert traced(QRConfig(verify=False)) == base
        assert traced(QRConfig(verify=True)) == base
        assert traced(QRConfig()) == base

    def test_verify_off_adds_zero_equations_eager_path(self):
        """Off-knob eager calls never import/resolve the checker into
        the compute: result is bitwise-identical to solver.solve."""
        a = gaussian(16, 8, seed=18)
        cfg = QRConfig(verify=False)
        q1, r1 = qr(a, config=cfg)
        q2, r2 = plan(a.shape, a.dtype, cfg).solve(a)
        assert np.array_equal(np.asarray(q1), np.asarray(q2))
        assert np.array_equal(np.asarray(r1), np.asarray(r2))


# ------------------------------------------------------ batched ortho path

class TestBatchedOrthoChaos:
    def test_corrupt_slice_escalates_to_leafwise(self):
        from repro.optim.batched_ortho import batched_orthogonalize
        leaves = [jnp.asarray(np.random.default_rng(19)
                              .standard_normal((3, 32, 16)), jnp.float32)]
        before = metrics.counter_total("optim.ortho_escalations")
        with inject.active(inject.Fault(site="output", match="ortho:32x16",
                                        slice_index=1)):
            outs = batched_orthogonalize(
                leaves, config=QRConfig(use_kernel=False, verify=True))
        q = np.asarray(outs[0])
        assert np.isfinite(q).all()
        for i in range(3):
            defect = np.linalg.norm(q[i].T @ q[i] - np.eye(16))
            assert defect < verify.tolerance(np.float32, 32, 16)
        assert metrics.counter_total("optim.ortho_escalations") \
            == before + 1

    def test_verify_off_matches_baseline(self):
        from repro.optim.batched_ortho import batched_orthogonalize
        leaves = [jnp.asarray(np.random.default_rng(20)
                              .standard_normal((2, 24, 8)), jnp.float32)]
        a = batched_orthogonalize(leaves,
                                  config=QRConfig(use_kernel=False))
        b = batched_orthogonalize(
            leaves, config=QRConfig(use_kernel=False, verify=False))
        assert np.array_equal(np.asarray(a[0]), np.asarray(b[0]))


# ------------------------------------------------------ watchdog satellite

class TestWatchdogMedian:
    def test_even_window_uses_true_median(self):
        from repro.distributed.fault_tolerance import StepWatchdog, _median
        assert _median([1.0, 2.0, 3.0, 10.0]) == 2.5   # not 3.0
        assert _median([1.0, 2.0, 3.0]) == 2.0
        wd = StepWatchdog()
        wd._times = [1.0, 1.0, 1.0, 9.0]
        assert wd.median == 1.0

    def test_straggler_counter_fires(self):
        from repro.distributed.fault_tolerance import StepWatchdog
        wd = StepWatchdog(threshold=2.0)
        before = metrics.counter_value("fault.straggler_steps")
        wd._times = [0.1] * 6
        wd._t0 = __import__("time").monotonic() - 1.0   # 1s step vs 0.1 median
        assert wd.stop(step=7) > 0.5
        assert wd.straggler_steps == [7]
        assert metrics.counter_value("fault.straggler_steps") == before + 1


# ----------------------------------------------- end-to-end acceptance

class TestAcceptance:
    def test_three_simultaneous_fault_classes_one_flush(self):
        """(a) NaN request in a mixed bucket, (b) compile failure on one
        bucket, (c) health-check failure on a dispatch — all armed at
        once; one flush must quarantine (a), escalate (b) and (c), and
        return conformance-correct results for every clean request."""
        svc = _svc(verify=True)
        small = [_randn(24, 12, seed=s) for s in range(3)]     # 32x16
        large = [_randn(40, 24, seed=s + 10) for s in range(2)]  # 48x32
        poisoned = inject.poison(small[1], kind="nan", seed=0)
        esc_before = metrics.counter_total("robustness.escalations")
        with inject.active(
                inject.Fault(site="compile", match="48x32"),       # (b)
                inject.Fault(site="output", match="32x16",
                             slice_index=0)):                      # (c)
            rids_small = [svc.submit(small[0]), svc.submit(poisoned),
                          svc.submit(small[2])]                    # (a)
            rids_large = [svc.submit(a) for a in large]
            res = svc.flush()
        # (a) quarantined, named
        assert res[rids_small[1]].error == "quarantined:nonfinite_input"
        # every clean request conformance-correct
        clean = [(rids_small[0], small[0]), (rids_small[2], small[2]),
                 (rids_large[0], large[0]), (rids_large[1], large[1])]
        for rid, a in clean:
            assert res[rid].ok, res[rid].error
            assert np.isfinite(np.asarray(res[rid].q)).all()
            assert _resid(a, res[rid].q, res[rid].r) < _tol(a)
        # (b) + (c) each fired a named escalation counter
        rules = {e.rule for e in svc.escalations}
        assert "injected_compile" in rules
        assert "health_check_failed" in rules
        assert metrics.counter_total("robustness.escalations") \
            > esc_before
        st = svc.stats()
        assert st["quarantined"] == 1 and st["escalations"] >= 2


# ------------------------------------------- train_lm FT drill (slow)

# The straggler lands at step 11: the post-restore watchdog needs its
# five-step warm-up (restore at 6 -> steps 6..10 recorded) before the
# straggler rule may fire.
_FT_SCRIPT_ARGS = [
    "examples/train_lm.py", "--smoke", "--steps", "12", "--seq", "16",
    "--batch", "2", "--optimizer", "adamw", "--fault-tolerance",
    "--checkpoint-every", "4", "--crash-at", "6",
    "--inject-straggler-at", "11", "--watchdog-threshold", "2.0",
]


@pytest.mark.slow
def test_train_lm_fault_tolerance_drill(tmp_path):
    """The ROADMAP item: watchdog + checkpoint-restore wired into the
    example driver.  Injects a synthetic straggler and a simulated
    crash/restore; asserts the sentinels."""
    res = subprocess.run(
        [sys.executable] + _FT_SCRIPT_ARGS
        + ["--checkpoint-dir", str(tmp_path / "ckpt")],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
             "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd=__file__.rsplit("/", 2)[0])
    out = res.stdout
    assert "CRASH_SIMULATED step=6" in out, res.stderr[-3000:]
    assert "[trainer] restored step 6" in out, out
    assert "[watchdog] straggler step 11" in out, out
    assert "STRAGGLERS=[11]" in out, out
    assert "FT_OK" in out, out
