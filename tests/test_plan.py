"""QRPlan / solver-registry API tests.

Covers the planner redesign: registry round-trip, QRConfig hashability
under jit static args, the method="auto" routing table, batched solve vs
the jnp.linalg.qr oracle, the config-only API surface (the PR-1 legacy
string-kwarg shim is removed), and the mode="full" regression.
"""

import functools

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import QRConfig, lstsq, orthogonalize, qr
from repro.core.plan import (
    MethodSpec,
    available_methods,
    get_method,
    plan,
    register_method,
    select_method,
    unregister_method,
)


def _rand(*shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ------------------------------------------------------------------ registry

def test_registry_roundtrip():
    spec = MethodSpec(name="_dummy_qr", factor=lambda a, cfg: (a, a[0]),
                      description="test stub")
    register_method(spec)
    try:
        assert get_method("_dummy_qr") is spec
        assert "_dummy_qr" in available_methods()
    finally:
        unregister_method("_dummy_qr")
    assert "_dummy_qr" not in available_methods()


def test_unknown_method_errors():
    with pytest.raises(ValueError, match="unknown method"):
        get_method("nope")
    with pytest.raises(ValueError, match="unknown method"):
        plan((8, 8), jnp.float32, QRConfig(method="nope"))
    with pytest.raises(ValueError, match="unknown method"):
        qr(_rand(8, 8), config=QRConfig(method="nope"))


def test_builtins_registered():
    methods = available_methods()
    for name in ("geqr2", "geqr2_ht", "geqrf", "geqrf_ht", "geqrf_fori",
                 "tsqr", "tiled"):
        assert name in methods
    assert get_method("tsqr").min_aspect == 4.0
    assert not get_method("tsqr").supports_full_q
    assert get_method("geqrf_ht").kernel_backed
    assert get_method("tiled").kernel_backed


# ------------------------------------------------------------------ QRConfig

def test_qrconfig_hashable_and_value_semantics():
    a = QRConfig(method="geqrf_ht", block=16)
    b = QRConfig(method="geqrf_ht", block=16)
    assert a == b and hash(a) == hash(b)
    assert len({a, b}) == 1
    assert a.replace(block=32) != a


def test_qrconfig_validation():
    with pytest.raises(ValueError, match="mode"):
        QRConfig(mode="banana")
    with pytest.raises(ValueError, match="q_method"):
        QRConfig(q_method="banana")
    with pytest.raises(ValueError, match="block"):
        QRConfig(block=0)
    with pytest.raises(ValueError, match="dispatch_mode"):
        QRConfig(dispatch_mode="warpspeed")


def test_plan_resolves_engine_dispatch_mode():
    """Engine-backed methods resolve dispatch_mode=None on the kernel
    path (megakernel within budgets, honoring an explicit override) and
    leave it None on the jnp-oracle path."""
    shape = (96, 64)
    resolved = plan(shape, jnp.float32,
                    QRConfig(method="tiled", block=16, use_kernel=True))
    assert resolved.config.dispatch_mode == "megakernel"
    forced = plan(shape, jnp.float32,
                  QRConfig(method="tiled", block=16, use_kernel=True,
                           dispatch_mode="wavefront"))
    assert forced.config.dispatch_mode == "wavefront"
    oracle = plan(shape, jnp.float32,
                  QRConfig(method="tiled", block=16, use_kernel=False))
    assert oracle.config.dispatch_mode is None


def test_plan_dispatch_mode_accounts_for_dtype():
    """The auto rule resolves at the planned element width: a tile whose
    double-buffered megakernel set fits in fp32 but not fp64 must pin
    wavefront for fp64 input (else solve() would hit the runtime VMEM
    guard instead of falling back)."""
    from repro.kernels import macro_ops
    from repro.core.plan import kernel_vmem_budget

    nb = 288
    budget = kernel_vmem_budget("macro_ops")
    assert macro_ops.megakernel_vmem_bytes(nb, 4) <= budget \
        < macro_ops.megakernel_vmem_bytes(nb, 8)
    shape = (4 * nb, 2 * nb)
    cfg = QRConfig(method="tiled", block=nb, use_kernel=True)
    assert plan(shape, jnp.float32, cfg).config.dispatch_mode == "megakernel"
    assert plan(shape, jnp.float64, cfg).config.dispatch_mode == "wavefront"
    # the precision override wins over the input dtype
    assert plan(shape, jnp.float64,
                cfg.replace(precision="float32")
                ).config.dispatch_mode == "megakernel"


def test_kernel_fits_gate_prices_wavefront_floor():
    """The planner's fits-in-VMEM gate prices the kernel path at its
    wavefront floor: an fp64 shape whose wavefront set fits must keep
    use_kernel on TPU even though the megakernel set would not (auto
    then pins the wavefront lowering) — the megakernel is an opt-in
    upgrade, never a reason to lose the kernel path."""
    nb = 288
    shape = (4 * nb, 2 * nb)
    s64 = plan(shape, jnp.float64, QRConfig(method="tiled", block=nb),
               backend="tpu")
    assert s64.config.use_kernel is True
    assert s64.config.dispatch_mode == "wavefront"
    s32 = plan(shape, jnp.float32, QRConfig(method="tiled", block=nb),
               backend="tpu")
    assert s32.config.use_kernel is True
    assert s32.config.dispatch_mode == "megakernel"


def test_qrconfig_as_jit_static_arg():
    @functools.partial(jax.jit, static_argnames=("cfg",))
    def f(a, cfg: QRConfig):
        return plan(a.shape, a.dtype, cfg).solve(a)

    a = _rand(24, 12, seed=1)
    q1, r1 = f(a, QRConfig(method="geqrf_ht", block=8))
    q2, r2 = f(a, QRConfig(method="geqrf_ht", block=8))  # cache hit
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    qn, rn = jnp.linalg.qr(a)
    s = jnp.sign(jnp.diagonal(r1)) * jnp.sign(jnp.diagonal(rn))
    np.testing.assert_allclose(np.asarray(q1 * s[None, :]), np.asarray(qn),
                               atol=3e-5)


# ------------------------------------------------------------- auto routing

def test_auto_picks_tsqr_for_tall_skinny():
    solver = plan((1024, 32), jnp.float32, QRConfig())
    assert solver.config.method == "tsqr"
    assert solver.config.nblocks == 8  # planner-chosen divisor of m
    assert 1024 % solver.config.nblocks == 0


def test_auto_picks_kernel_geqrf_ht_on_tpu_when_panel_fits():
    # aspect < 4 so TSQR is out; panel (256 x 32) easily fits VMEM
    solver = plan((256, 128), jnp.float32, QRConfig(), backend="tpu")
    assert solver.config.method == "geqrf_ht"
    assert solver.config.use_kernel is True


def test_auto_skips_kernel_when_panel_exceeds_vmem():
    # 2 * 40000 * 32 * 4 bytes > the 8 MiB budget
    solver = plan((40000, 16384), jnp.float32, QRConfig(), backend="tpu")
    assert solver.config.method == "geqrf_ht"
    assert solver.config.use_kernel is False


# The full auto routing table in one place: (shape, backend, ndevices)
# -> method.  ndevices=1 is the single-device column; the >1 columns
# exercise the device-count-aware sharded_tiled routing.
#
# These rows document the HEURISTIC rules, so they pin
# use_tuning_cache=False (_HEUR): with the committed measured cache
# active, swept shape classes (256^2..512^2 squares on CPU — including
# (255,255) and (511,500), which pad into those classes) route via the
# "tuned" rule instead.  tests/test_tuning.py covers that layer.
_HEUR = QRConfig(use_tuning_cache=False)

_ROUTING_TABLE = [
    ((1024, 32), "cpu", 1, "tsqr"),        # tall-skinny beats everything
    ((1024, 256), "cpu", 1, "tsqr"),       # exactly 4:1 is still TSQR
    ((512, 512), "cpu", 1, "tiled"),       # large near-square -> task graph
    ((512, 512), "tpu", 1, "tiled"),
    ((1023, 256), "cpu", 1, "geqrf_ht"),   # under the raised CPU floor
    ((1023, 512), "cpu", 1, "tiled"),      # at the CPU floor
    ((1023, 256), "tpu", 1, "tiled"),      # TPU keeps the 256 floor
    ((300, 280), "cpu", 1, "geqrf_ht"),    # LAPACK geqrf wins small squares
    ((300, 280), "tpu", 1, "tiled"),
    ((2048, 1024), "cpu", 1, "tiled"),     # at the tiled ceiling
    ((2049, 1024), "cpu", 1, "geqrf_ht"),  # past it: DAG would be too big
    ((40000, 16384), "tpu", 1, "geqrf_ht"),
    ((256, 128), "tpu", 1, "geqrf_ht"),    # min dim below the tiled floor
    ((256, 128), "cpu", 1, "geqrf_ht"),
    ((255, 255), "cpu", 1, "geqrf_ht"),    # one short of the (TPU) floor
    ((511, 500), "cpu", 1, "geqrf_ht"),    # one short of the CPU floor
    ((256, 256), "tpu", 1, "tiled"),       # TPU floor unchanged at 256
    ((256, 40000), "cpu", 1, "geqrf_ht"),  # wide but far from square
    ((24, 16), "cpu", 1, "geqr2_ht"),      # single panel
    # -- device-count-aware rows: past the tiled ceiling, near-square --
    ((512, 512), "cpu", 8, "tiled"),         # one device's budget: stay tiled
    ((2049, 1024), "cpu", 8, "sharded_tiled"),  # too big for one device
    ((4096, 4096), "cpu", 8, "sharded_tiled"),
    ((4096, 2048), "cpu", 2, "sharded_tiled"),  # within 2x the ceiling
    ((8192, 4096), "cpu", 2, "geqrf_ht"),    # past d * ceiling: blocked
    ((2049, 1024), "cpu", 1, "geqrf_ht"),    # no second device, no sharding
    ((1024, 2049), "cpu", 8, "geqrf_ht"),    # wide: row-sharding won't help
    ((40000, 16384), "cpu", 8, "geqrf_ht"),  # past the 8-device ceiling too
]


@pytest.mark.parametrize("shape,backend,ndevices,expected", _ROUTING_TABLE)
def test_auto_routing_table(shape, backend, ndevices, expected):
    assert select_method(shape, jnp.float32, _HEUR,
                         backend=backend, ndevices=ndevices) == expected


@pytest.mark.parametrize("shape,backend,ndevices,expected", _ROUTING_TABLE)
def test_auto_routing_table_explain(shape, backend, ndevices, expected):
    """Every routing-table decision is explainable: ``plan(explain=True)``
    attaches a PlanExplain whose selected decision names the winning rule
    with a non-empty machine-readable reason, and whose decision trail
    records why each earlier candidate was rejected."""
    solver = plan(shape, jnp.float32, _HEUR, backend=backend,
                  ndevices=ndevices, explain=True)
    ex = solver.explain
    assert ex is not None
    assert ex.method == expected == solver.config.method
    assert ex.shape == shape and ex.backend == backend
    assert ex.ndevices == ndevices
    sel = ex.selected
    assert sel is not None and sel.outcome == "selected" and sel.reason
    # Every decision in the trail is machine-readable: a stable rule
    # slug plus a human reason, never empty.
    for d in ex.decisions:
        assert d.rule and d.outcome in ("selected", "rejected",
                                        "fallback", "resolved")
        assert d.reason
    # The trail ends at the winner: no decisions after the selection.
    kinds = [d.outcome for d in ex.decisions]
    assert "selected" in kinds
    # fallback_reasons mirrors the fallback decisions exactly.
    assert ex.fallback_reasons == tuple(
        d.rule for d in ex.decisions if d.outcome == "fallback")


def test_plan_explain_default_off_and_identity_preserving():
    """explain=False (default) leaves solver.explain None, and the
    explain field never perturbs solver equality/hash (jit-static id)."""
    s0 = plan((512, 512), jnp.float32, QRConfig(), backend="cpu")
    s1 = plan((512, 512), jnp.float32, QRConfig(), backend="cpu",
              explain=True)
    assert s0.explain is None and s1.explain is not None
    assert s0 == s1 and hash(s0) == hash(s1)


def test_plan_explain_cpu_floor_fallback_reason():
    """The silent small-square degradation on CPU — near-square inside
    the tiled band but under the raised CPU floor — now carries a
    structured fallback reason."""
    solver = plan((300, 280), jnp.float32, QRConfig(), backend="cpu",
                  explain=True)
    assert solver.config.method == "geqrf_ht"
    assert "tiled_min_dim_cpu_floor" in solver.explain.fallback_reasons
    d = solver.explain.decision("tiled_min_dim_cpu_floor")
    assert d.outcome == "fallback" and "cpu" in d.reason.lower()


def test_plan_explain_sharded_degraded_reason():
    """Past the tiled ceiling with only one device: the sharded route is
    rejected with a machine-readable reason, not silently skipped."""
    solver = plan((2049, 1024), jnp.float32, QRConfig(), backend="cpu",
                  ndevices=1, explain=True)
    assert solver.config.method == "geqrf_ht"
    rules = [d.rule for d in solver.explain.decisions]
    assert "sharded_past_ceiling" in rules


def test_auto_sharded_routing_respects_full_mode():
    """Full Q is not a sharded capability -> auto must not route there."""
    assert select_method((2049, 1024), jnp.float32, QRConfig(mode="full"),
                         backend="cpu", ndevices=8) != "sharded_tiled"


def test_auto_sharded_routing_respects_batched():
    """Batched stacks are not a sharded capability either — auto must
    keep them plannable (blocked path), not raise downstream."""
    assert select_method((4, 2049, 1024), jnp.float32, QRConfig(),
                         backend="cpu", ndevices=8) == "geqrf_ht"
    solver = plan((4, 2049, 1024), jnp.float32, QRConfig(), ndevices=8)
    assert solver.config.method == "geqrf_ht"


def test_auto_picks_tiled_for_large_near_square():
    # heuristic rule under test — pin the cache off (the measured CPU
    # cache routes 512^2 to geqrf_ht, which is the point of PR 8)
    solver = plan((512, 512), jnp.float32, _HEUR, backend="cpu")
    assert solver.config.method == "tiled"
    assert solver.config.use_kernel is False  # jnp path off-TPU
    solver_tpu = plan((512, 512), jnp.float32, _HEUR, backend="tpu")
    assert solver_tpu.config.method == "tiled"
    assert solver_tpu.config.use_kernel is True  # tile pair fits VMEM


def test_auto_small_problems_use_unblocked_mht():
    assert select_method((24, 16), jnp.float32, QRConfig()) == "geqr2_ht"


def test_auto_default_is_blocked_mht_on_cpu():
    solver = plan((256, 128), jnp.float32, QRConfig(), backend="cpu")
    assert solver.config.method == "geqrf_ht"
    assert solver.config.use_kernel is False


def test_auto_never_picks_tsqr_for_full_mode():
    solver = plan((1024, 32), jnp.float32, QRConfig(mode="full"))
    assert solver.config.method != "tsqr"
    q, r = solver.solve(_rand(1024, 32, seed=3))
    assert q.shape == (1024, 1024) and r.shape == (1024, 32)


def test_kernel_policy_single_vmem_budget():
    """Planner decisions and kernel runtime guards read one budget."""
    from repro.core.plan import DEFAULT_VMEM_BUDGET, kernel_vmem_budget
    from repro.kernels import ops, tile_ops

    assert kernel_vmem_budget() == DEFAULT_VMEM_BUDGET
    assert kernel_vmem_budget("mht_panel") == ops._POLICY.vmem_budget
    assert kernel_vmem_budget("tile_ops") == tile_ops._POLICY.vmem_budget
    assert ops._POLICY.vmem_budget == tile_ops._POLICY.vmem_budget
    # unknown policies fall back to the shared default
    assert kernel_vmem_budget("nope") == DEFAULT_VMEM_BUDGET


def test_capability_checks():
    with pytest.raises(ValueError, match="tall-skinny"):
        plan((64, 32), jnp.float32, QRConfig(method="tsqr"))
    with pytest.raises(ValueError, match="thin Q"):
        plan((256, 16), jnp.float32, QRConfig(method="tsqr", mode="full"))
    with pytest.raises(ValueError, match="kernel"):
        plan((64, 32), jnp.float32, QRConfig(method="geqr2", use_kernel=True))


def test_auto_tsqr_matches_oracle():
    a = _rand(1024, 32, seed=4)
    q, r = qr(a, config=QRConfig())
    rn = jnp.linalg.qr(a)[1]
    s = jnp.sign(jnp.diagonal(r)) * jnp.sign(jnp.diagonal(rn))
    np.testing.assert_allclose(np.asarray(r * s[:, None]), np.asarray(rn),
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), atol=1e-4)


# ------------------------------------------------------- batched + jit/vmap

def test_batched_qr_matches_oracle():
    a = _rand(3, 32, 16, seed=5)
    qb, rb = qr(a, config=QRConfig(method="geqrf_ht", block=8))
    assert qb.shape == (3, 32, 16) and rb.shape == (3, 16, 16)
    for i in range(3):
        qn, rn = jnp.linalg.qr(a[i])
        s = jnp.sign(jnp.diagonal(rb[i])) * jnp.sign(jnp.diagonal(rn))
        np.testing.assert_allclose(np.asarray(qb[i] * s[None, :]),
                                   np.asarray(qn), atol=3e-5)
        np.testing.assert_allclose(np.asarray(rb[i] * s[:, None]),
                                   np.asarray(rn), atol=3e-5)


def test_batched_solver_under_jit_and_vmap():
    a = _rand(4, 48, 12, seed=6)
    solver = plan(a.shape, a.dtype, QRConfig(method="geqrf_ht", block=4))
    out_solver = solver.solve(a)  # internal vmap rule
    f = jax.jit(jax.vmap(plan((48, 12), a.dtype,
                              QRConfig(method="geqrf_ht", block=4)).solve))
    out_jit = f(a)  # external jit+vmap over a 2-D solver
    np.testing.assert_allclose(np.asarray(out_solver[0]),
                               np.asarray(out_jit[0]), atol=1e-6)
    rec = jnp.einsum("bmk,bkn->bmn", out_jit[0], out_jit[1])
    np.testing.assert_allclose(np.asarray(rec), np.asarray(a), atol=1e-4)


def test_batched_auto_tsqr():
    a = _rand(2, 256, 16, seed=7)
    solver = plan(a.shape, a.dtype, QRConfig())
    assert solver.config.method == "tsqr"
    q, r = solver.solve(a)
    assert q.shape == (2, 256, 16) and r.shape == (2, 16, 16)
    rec = jnp.einsum("bmk,bkn->bmn", q, r)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(a), atol=1e-4)


# ---------------------------------------------------- post-shim API surface

def test_legacy_string_kwargs_removed():
    """The PR-1 deprecation shim is gone: string kwargs are a TypeError,
    not a DeprecationWarning."""
    a = _rand(16, 8, seed=10)
    with pytest.raises(TypeError):
        qr(a, method="geqrf_ht")
    with pytest.raises(TypeError):
        qr(a, block=8)
    with pytest.raises(TypeError):
        orthogonalize(a, method="geqr2_ht")
    with pytest.raises(TypeError):
        lstsq(a, a[:, 0], method="geqrf")


def test_qr_default_config_is_auto_planner():
    """qr(a) with no config plans with QRConfig() — the auto route."""
    a = _rand(32, 12, seed=9)
    q1, r1 = qr(a)
    q2, r2 = plan(a.shape, a.dtype, QRConfig()).solve(a)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


# ------------------------------------------------ wrappers through planner

def test_orthogonalize_auto_routes_tall_skinny_through_tsqr():
    cfg = QRConfig()
    assert select_method((256, 16), jnp.float32,
                         cfg.replace(sign_fix=True)) == "tsqr"
    o = orthogonalize(_rand(256, 16, seed=12), config=cfg)
    np.testing.assert_allclose(np.asarray(o.T @ o), np.eye(16), atol=1e-4)
    # wide input factorizes the transpose — also tall-skinny, also TSQR
    ow = orthogonalize(_rand(16, 256, seed=13), config=cfg)
    assert ow.shape == (16, 256)
    np.testing.assert_allclose(np.asarray(ow @ ow.T), np.eye(16), atol=1e-4)


def test_lstsq_auto_routes_tall_skinny_through_tsqr():
    a = _rand(256, 8, seed=14)
    x_true = _rand(8, seed=15)
    b = a @ x_true
    x = lstsq(a, b, config=QRConfig())
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_true), atol=1e-3)


# ------------------------------------------------- degenerate (zero-dim)

@pytest.mark.parametrize("shape", [(0, 5), (5, 0), (0, 0)])
def test_degenerate_routing_zero_dims(shape):
    """Zero-dim inputs route to the trivial method on every path — the
    PR-8 bugfix for the planner crashing where jnp.linalg.qr succeeds."""
    assert select_method(shape, jnp.float32, QRConfig()) == "degenerate"
    solver = plan(shape, jnp.float32, QRConfig(), explain=True)
    assert solver.config.method == "degenerate"
    sel = solver.explain.selected
    assert sel.rule == "degenerate_empty" and "zero-dim" in sel.reason


def test_degenerate_overrides_explicit_method():
    """An explicit method cannot factor an empty matrix — the override
    is applied and recorded in the decision reason, not raised."""
    solver = plan((0, 5), jnp.float32, QRConfig(method="tiled"),
                  explain=True)
    assert solver.config.method == "degenerate"
    assert "overrides config.method='tiled'" in solver.explain.selected.reason


def test_degenerate_method_rejects_nonempty():
    with pytest.raises(ValueError, match="zero-dim"):
        plan((8, 8), jnp.float32, QRConfig(method="degenerate"))


def test_degenerate_batched_solve():
    a = jnp.zeros((3, 0, 5), jnp.float32)
    q, r = plan(a.shape, a.dtype, QRConfig()).solve(a)
    assert q.shape == (3, 0, 0) and r.shape == (3, 0, 5)


# ------------------------------------------- explain-trail completeness

def test_route_trail_is_complete_prefix():
    """PR-8 bugfix: every core rule evaluated before the winner records
    a decision on EVERY path (sharded_past_ceiling used to vanish from
    the trail for near-square under-ceiling single-device shapes).  The
    recorded core-rule decisions must be exactly the contiguous run of
    ``plan._ROUTE_RULES`` from "tuned" through the selected rule."""
    from repro.core.plan import _ROUTE_RULES

    for shape, backend, ndevices, expected in _ROUTING_TABLE:
        solver = plan(shape, jnp.float32, _HEUR, backend=backend,
                      ndevices=ndevices, explain=True)
        core = [d for d in solver.explain.decisions if d.rule in _ROUTE_RULES]
        assert core[-1].outcome == "selected", (shape, backend, ndevices)
        assert all(d.outcome == "rejected" for d in core[:-1])
        got = tuple(d.rule for d in core)
        start = _ROUTE_RULES.index("tuned")
        stop = _ROUTE_RULES.index(core[-1].rule) + 1
        assert got == _ROUTE_RULES[start:stop], (shape, backend, ndevices)


def test_trail_records_sharded_rejection_under_the_ceiling():
    """The specific shape class the incomplete-trail bug dropped: the
    rejected branch used to be recorded only when ``near_square and
    max(m, n) > _TILED_MAX_DIM``, so any shape that fell through tiled
    *below* the ceiling lost its sharded decision entirely."""
    solver = plan((300, 280), jnp.float32, _HEUR, backend="cpu",
                  ndevices=1, explain=True)
    d = solver.explain.decision("sharded_past_ceiling")
    assert d is not None and d.outcome == "rejected"
    assert "not near-square" in d.reason


# ------------------------------------------- fallback-counter hygiene

def test_select_method_is_pure_query_no_counters():
    """PR-8 bugfix: ``select_method`` / ``_route`` are pure queries —
    only ``plan()`` emits planner.fallbacks, exactly once per plan, so
    ``plan(explain=True)`` cannot double-count against an earlier
    ``select_method`` probe of the same shape."""
    from repro.observability import metrics

    before = metrics.counter_value("planner.fallbacks",
                                   reason="tiled_min_dim_cpu_floor")
    select_method((300, 280), jnp.float32, QRConfig(), backend="cpu")
    select_method((300, 280), jnp.float32, QRConfig(), backend="cpu")
    assert metrics.counter_value(
        "planner.fallbacks", reason="tiled_min_dim_cpu_floor") == before
    plan((300, 280), jnp.float32, QRConfig(), backend="cpu", explain=True)
    assert metrics.counter_value(
        "planner.fallbacks", reason="tiled_min_dim_cpu_floor") == before + 1


def test_solver_q_method_solve_matches_formq():
    a = _rand(96, 24, seed=16)
    q1, _ = plan(a.shape, a.dtype,
                 QRConfig(method="geqrf_ht", q_method="formq")).solve(a)
    q2, _ = plan(a.shape, a.dtype,
                 QRConfig(method="geqrf_ht", q_method="solve")).solve(a)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-4)
