"""Property-testing shim: real `hypothesis` when installed, else a
deterministic fallback so tier-1 collects and runs without the dev extra.

Shared example budget: tier-1 must stay fast, so BOTH paths cap
``max_examples`` through one profile knob — the ``REPRO_MAX_EXAMPLES``
environment variable (default 8).  Test modules keep their historical
``@settings(max_examples=N)`` annotations as *upper bounds*; the
effective count is ``min(N, REPRO_MAX_EXAMPLES)``.  The full-suite CI
job raises the knob to run the complete sweeps.

The fallback implements just the surface this suite uses —
``@settings(max_examples=..., deadline=...)`` stacked on
``@given(name=st.integers(...)/st.floats(...)/...)`` — by drawing a fixed
number of examples from a seeded NumPy generator.  It does no shrinking
and no edge-case targeting; install ``hypothesis`` (the ``dev`` extra in
pyproject.toml) for the real engine.

Usage in test modules:

    from hypothesis_compat import given, settings, st
"""

import os

# One shared example budget for the whole suite (tier-1 speed knob).
MAX_EXAMPLES_CAP = int(os.environ.get("REPRO_MAX_EXAMPLES", "8"))

try:
    import hypothesis
    from hypothesis import given, strategies as st

    HAVE_HYPOTHESIS = True

    # The shared profile: every test without explicit @settings draws at
    # most the cap; deadline off (jit compile times dwarf any deadline).
    hypothesis.settings.register_profile(
        "repro", max_examples=MAX_EXAMPLES_CAP, deadline=None)
    hypothesis.settings.load_profile("repro")

    def settings(*, max_examples=None, **kwargs):
        """`hypothesis.settings` with the module-level count capped by the
        shared profile budget (explicit counts are upper bounds)."""
        if max_examples is not None:
            max_examples = min(max_examples, MAX_EXAMPLES_CAP)
        else:
            max_examples = MAX_EXAMPLES_CAP
        kwargs.setdefault("deadline", None)
        return hypothesis.settings(max_examples=max_examples, **kwargs)

except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect

    import numpy as _np

    # Keep fallback runs cheap: property bodies here re-jit per drawn shape,
    # so a handful of deterministic examples is the right CI trade.
    _FALLBACK_MAX_EXAMPLES = min(5, MAX_EXAMPLES_CAP)

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 — mimics `hypothesis.strategies` namespace
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(
                lambda rng: elems[int(rng.integers(0, len(elems)))])

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = _np.random.default_rng(0xC0DE)
                for _ in range(wrapper._max_examples):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper._max_examples = _FALLBACK_MAX_EXAMPLES
            wrapper.is_hypothesis_fallback = True
            # pytest must not see the drawn parameters as fixtures: hide the
            # wrapped signature (functools.wraps exposes it via __wrapped__).
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco

    def settings(*, max_examples=None, **_ignored):
        def deco(fn):
            if max_examples is not None and hasattr(fn, "_max_examples"):
                fn._max_examples = min(max_examples, _FALLBACK_MAX_EXAMPLES)
            return fn

        return deco
