"""End-to-end fault-tolerance simulation: train on an 8-device mesh,
"lose" half the devices, elastically re-mesh to 4 and resume from the
checkpoint with resharded state.  Runs in a subprocess (device-count
isolation per the dry-run rule)."""

import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.data import DataConfig
    from repro.distributed import MeshRules
    from repro.distributed.fault_tolerance import plan_elastic_mesh
    from repro.distributed.sharding import activation_policy
    from repro.training import RunConfig, TrainConfig, Trainer

    cfg = get_smoke_config("olmo-1b")
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    tc = TrainConfig(optimizer="adamw", lr=1e-3)

    with tempfile.TemporaryDirectory() as td:
        rc = RunConfig(total_steps=10, warmup_steps=0, log_every=1,
                       checkpoint_every=3, checkpoint_dir=td)
        # phase 1: 4x2 mesh over 8 devices
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = MeshRules(mesh=mesh, data_axes=("data",))
        t1 = Trainer(cfg, tc, rc, data, mesh=mesh, rules=rules,
                     log_fn=lambda s: None)
        with mesh, activation_policy(rules):
            t1.run(stop_at=6)   # "crash" after step 6 (ckpt at 3 and 6)
        losses1 = {m["step"]: m["loss"] for m in t1.metrics_history}

        # phase 2: devices 4..7 "fail"; re-mesh to 2x2 over survivors
        plan = plan_elastic_mesh(jax.devices(),
                                 failed=[d.id for d in jax.devices()[4:]],
                                 prefer_model=2)
        assert plan.mesh.size == 4, plan
        rules2 = MeshRules(mesh=plan.mesh, data_axes=("data",))
        t2 = Trainer(cfg, tc, rc, data, mesh=plan.mesh, rules=rules2,
                     log_fn=lambda s: None)
        with plan.mesh, activation_policy(rules2):
            t2.run()            # restores step 6, resharded; runs to 10
        assert t2.step_idx == 10
        assert t2.pipeline.step == 10
        losses2 = {m["step"]: m["loss"] for m in t2.metrics_history}
        # loss continuity across the re-mesh (same data, same state)
        assert np.isfinite(list(losses2.values())).all()
        print("ELASTIC_OK", losses1.get(6), losses2.get(7))
""")


@pytest.mark.slow
def test_elastic_restart_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd=__file__.rsplit("/", 2)[0])
    assert "ELASTIC_OK" in res.stdout, res.stderr[-3000:]
