"""Parallelism quantification tests — paper §4 eq. 6-10, fig 9."""

from repro.core.dag import (analyze_ht, analyze_mht, analyze_sharded_tiled,
                            analyze_tiled, phase_model_theta, sharded_curve,
                            theta_curve, tiled_curve)


def test_mht_dag_is_strictly_shallower():
    """The fused macro-op removes the P-materialization levels (C2)."""
    for n in (4, 8, 16, 32):
        ht = analyze_ht(n)
        mht = analyze_mht(n)
        assert mht.depth < ht.depth, (n, mht.depth, ht.depth)


def test_mht_has_fewer_ops_same_math():
    """Explicit-P classical HT does O(L^2 w) work per column; MHT O(L w)."""
    ht, mht = analyze_ht(16), analyze_mht(16)
    assert mht.ops < ht.ops


def test_theta_below_one_and_saturating():
    rows = theta_curve((8, 16, 32, 64))["rows"]
    thetas = [r["theta_levels"] for r in rows]
    assert all(0.5 < t < 1.0 for t in thetas)
    # equal-ops parallelism gain (paper eq 9/10) is > 1 for all sizes
    assert all(r["beta_gain_equal_ops"] > 1.0 for r in rows)


def test_width4_phase_model_matches_paper_constant():
    """Under the paper's 4-wide RDP model, theta saturates at ~0.75
    (paper fig 9 reports 0.749) and the parallelism gain at ~1.33x."""
    big = phase_model_theta(512)
    assert abs(big["theta"] - 0.75) < 0.02
    assert abs(big["parallelism_gain"] - 4.0 / 3.0) < 0.04
    # monotone approach to the asymptote
    t = [phase_model_theta(n)["theta"] for n in (8, 32, 128, 512)]
    assert all(a > b for a, b in zip(t, t[1:]))


def test_phase_model_levels_positive_and_ordered():
    pm = phase_model_theta(64)
    assert 0 < pm["levels_mht"] < pm["levels_ht"]


def test_tiled_beta_extends_the_metric():
    """The tile DAG exposes (far) more scalar work per level than MHT,
    and its level count is the closed-form wavefront count."""
    rows = tiled_curve((64, 128), tile=16)["rows"]
    assert all(r["beta_gain_tiled"] > 1.0 for r in rows)
    tl = analyze_tiled(64, 16)
    assert tl.depth == 10  # 4x4 grid: p + 2q - 2
    assert tl.ops > analyze_mht(64).ops / 2  # same O(n^3) work regime


def test_sharded_beta_extends_the_metric_across_devices():
    """Domain sharding collapses levels (p/d + 2q + log d wavefronts)
    while ops only gain the merge nodes -> beta grows with d."""
    from repro.core.tilegraph import sharded_wavefront_count, tile_grid

    for n, tile, d in [(128, 16, 4), (256, 16, 8), (256, 32, 2)]:
        tl = analyze_tiled(n, tile)
        sh = analyze_sharded_tiled(n, tile, d)
        p, q = tile_grid(n, n, tile)
        assert sh.depth == sharded_wavefront_count(p, q, d)
        assert sh.depth < tl.depth
        assert sh.ops > tl.ops  # merge tree adds work...
        assert sh.beta > tl.beta  # ...but levels shrink faster


def test_sharded_d1_is_tiled():
    """One domain: identical DagStats to the single-device analysis."""
    tl, sh = analyze_tiled(128, 16), analyze_sharded_tiled(128, 16, 1)
    assert (sh.ops, sh.depth) == (tl.ops, tl.depth)


def test_sharded_curve_rows():
    rows = sharded_curve((128, 256), tile=16, ndomains=4)["rows"]
    assert all(r["beta_gain_sharded"] > 1.0 for r in rows)
    assert all(r["level_gain"] > 1.0 for r in rows)
