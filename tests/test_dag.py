"""Parallelism quantification tests — paper §4 eq. 6-10, fig 9."""

from repro.core.dag import (analyze_ht, analyze_mht, analyze_tiled,
                            phase_model_theta, theta_curve, tiled_curve)


def test_mht_dag_is_strictly_shallower():
    """The fused macro-op removes the P-materialization levels (C2)."""
    for n in (4, 8, 16, 32):
        ht = analyze_ht(n)
        mht = analyze_mht(n)
        assert mht.depth < ht.depth, (n, mht.depth, ht.depth)


def test_mht_has_fewer_ops_same_math():
    """Explicit-P classical HT does O(L^2 w) work per column; MHT O(L w)."""
    ht, mht = analyze_ht(16), analyze_mht(16)
    assert mht.ops < ht.ops


def test_theta_below_one_and_saturating():
    rows = theta_curve((8, 16, 32, 64))["rows"]
    thetas = [r["theta_levels"] for r in rows]
    assert all(0.5 < t < 1.0 for t in thetas)
    # equal-ops parallelism gain (paper eq 9/10) is > 1 for all sizes
    assert all(r["beta_gain_equal_ops"] > 1.0 for r in rows)


def test_width4_phase_model_matches_paper_constant():
    """Under the paper's 4-wide RDP model, theta saturates at ~0.75
    (paper fig 9 reports 0.749) and the parallelism gain at ~1.33x."""
    big = phase_model_theta(512)
    assert abs(big["theta"] - 0.75) < 0.02
    assert abs(big["parallelism_gain"] - 4.0 / 3.0) < 0.04
    # monotone approach to the asymptote
    t = [phase_model_theta(n)["theta"] for n in (8, 32, 128, 512)]
    assert all(a > b for a, b in zip(t, t[1:]))


def test_phase_model_levels_positive_and_ordered():
    pm = phase_model_theta(64)
    assert 0 < pm["levels_mht"] < pm["levels_ht"]


def test_tiled_beta_extends_the_metric():
    """The tile DAG exposes (far) more scalar work per level than MHT,
    and its level count is the closed-form wavefront count."""
    rows = tiled_curve((64, 128), tile=16)["rows"]
    assert all(r["beta_gain_tiled"] > 1.0 for r in rows)
    tl = analyze_tiled(64, 16)
    assert tl.depth == 10  # 4x4 grid: p + 2q - 2
    assert tl.ops > analyze_mht(64).ops / 2  # same O(n^3) work regime
