"""Observability layer tests (repro.observability).

The layer's contract has two halves, and both are load-bearing:

  * **Disabled (the default) is free.**  ``span()`` hands back one
    shared no-op singleton — no clock reads, no allocation, no
    ``block_until_ready`` — and the per-call cost is held to < 1% of
    even a small (256²) tiled solve by an explicit budget assertion.
    The jaxpr-pin twin of this guarantee (annotations add zero
    equations to the megakernel lowering) lives in tests/test_engine.py.
  * **Enabled is truthful.**  Spans nest correctly across the
    thread-local stack, ``sync`` blocks on device values so durations
    cover execution rather than dispatch, the Chrome-trace export
    round-trips through JSON with the schema chrome://tracing loads,
    and the metrics registry stays exact under concurrent writers —
    including real ``QRService.submit_many`` traffic from threads.
"""

import json
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import observability as obs
from repro.core import QRConfig, plan
from repro.observability import instrument, metrics, trace


@pytest.fixture(autouse=True)
def _clean_observability():
    """Each test starts disabled with an empty registry/span buffer and
    leaves the process the same way (the layer is process-global)."""
    instrument.disable()
    metrics.reset()
    trace.clear()
    yield
    instrument.disable()
    metrics.reset()
    trace.clear()


# ------------------------------------------------------------------ metrics

def test_counter_labels_and_totals():
    metrics.counter("t.requests", route="a").inc()
    metrics.counter("t.requests", route="a").inc(2)
    metrics.counter("t.requests", route="b").inc(5)
    assert metrics.counter_value("t.requests", route="a") == 3
    assert metrics.counter_value("t.requests", route="b") == 5
    assert metrics.counter_value("t.requests", route="zzz") == 0
    assert metrics.counter_total("t.requests") == 8


def test_counter_rejects_negative():
    with pytest.raises(ValueError):
        metrics.counter("t.bad").inc(-1)


def test_gauge_set_inc_dec():
    g = metrics.gauge("t.depth", tree="x")
    g.set(4)
    g.inc()
    g.dec(2)
    assert metrics.snapshot()["gauges"]["t.depth"][0]["value"] == 3


def test_histogram_percentiles_and_snapshot():
    h = metrics.histogram("t.lat")
    for v in [1.0] * 90 + [100.0] * 10:
        h.observe(v)
    snap = metrics.snapshot()["histograms"]["t.lat"][0]
    assert snap["count"] == 100
    assert snap["min"] == 1.0 and snap["max"] == 100.0
    # log-bucketed CDF: p50 lands in the 1.0 bucket, p99 near the top
    assert h.percentile(50) < 5.0
    assert h.percentile(99) > 50.0
    assert 1.0 < h.mean < 100.0


def test_prometheus_export_format():
    metrics.counter("serve.reqs", route="a").inc(3)
    metrics.histogram("serve.lat").observe(0.5)
    text = metrics.to_prometheus()
    assert '# TYPE serve_reqs_total counter' in text
    assert 'serve_reqs_total{route="a"} 3' in text
    assert '# TYPE serve_lat histogram' in text
    assert 'serve_lat_bucket{le="+Inf"} 1' in text
    assert "serve_lat_count 1" in text


def test_registry_thread_safety_raw_counters():
    n_threads, n_incs = 8, 5000

    def worker():
        for _ in range(n_incs):
            metrics.counter("t.contended", shared="yes").inc()

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert metrics.counter_value("t.contended",
                                 shared="yes") == n_threads * n_incs


def test_registry_thread_safety_under_submit_many():
    """Concurrent serving traffic from threads keeps every service's
    registry-backed stats exact (the counters behind ``stats()`` share
    one process-global registry)."""
    from repro.serving import BucketingPolicy, QRService

    rng = np.random.default_rng(0)
    waves = [[rng.standard_normal((12, 12), dtype=np.float32)
              for _ in range(6)] for _ in range(4)]
    services = [QRService(policy=BucketingPolicy(tile=16, max_batch=4),
                          use_kernel=False) for _ in range(4)]
    errs = []

    def worker(svc, wave):
        try:
            svc.submit_many(wave)
        except Exception as e:  # pragma: no cover - surfaced below
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(svc, wave))
               for svc, wave in zip(services, waves)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    for svc in services:
        s = svc.stats()
        assert s["requests"] == s["matrices_served"] == 6
    assert metrics.counter_total("serving.requests") >= 24


def test_fresh_service_instances_start_at_zero():
    from repro.serving import QRService

    a = np.eye(8, dtype=np.float32)
    s1 = QRService(use_kernel=False)
    s1.submit_many([a])
    s2 = QRService(use_kernel=False)
    assert s1.stats()["requests"] == 1
    assert s2.stats()["requests"] == 0


# ------------------------------------------------------------------- tracer

def test_span_disabled_is_shared_noop_singleton():
    s1, s2 = trace.span("a"), trace.span("b", k=1)
    assert s1 is s2  # no allocation on the disabled path
    with s1 as sp:
        sp.set(more="labels")
    assert trace.spans() == []


class _SyncProbe:
    """Duck-typed array: records whether block_until_ready ran."""

    def __init__(self):
        self.blocked = False

    def block_until_ready(self):
        self.blocked = True
        return self


def test_sync_noop_when_disabled_blocks_when_enabled():
    probe = _SyncProbe()
    out = trace.span("x").sync(probe)
    assert out is probe and not probe.blocked  # disabled: never syncs
    with obs.enabled_scope():
        with trace.span("x") as sp:
            assert sp.sync(probe) is probe
    assert probe.blocked  # enabled: span waits for the device


def test_sync_skips_abstract_tracers():
    with obs.enabled_scope():
        def f(x):
            with trace.span("inside.jit") as sp:
                return sp.sync(x * 2.0)

        out = jax.jit(f)(jnp.ones((4,)))
    np.testing.assert_allclose(np.asarray(out), 2.0)


def test_span_nesting_and_ordering():
    with obs.enabled_scope():
        with trace.span("outer", wave=0) as outer:
            with trace.span("inner.a") as a:
                pass
            with trace.span("inner.b") as b:
                pass
    done = trace.spans()
    assert [s.name for s in done] == ["inner.a", "inner.b", "outer"]
    assert a.parent_sid == outer.sid and b.parent_sid == outer.sid
    assert a.depth == b.depth == 1 and outer.depth == 0
    assert outer.t_start <= a.t_start <= a.t_end <= b.t_start <= outer.t_end
    assert "outer" in trace.tree() and "  inner.a" in trace.tree()


def test_traced_decorator():
    @trace.traced("deco.name", kind="unit")
    def work():
        return 7

    assert work() == 7  # disabled: plain call
    with obs.enabled_scope():
        assert work() == 7
    (sp,) = trace.spans()
    assert sp.name == "deco.name" and sp.labels == {"kind": "unit"}


def test_chrome_trace_round_trip(tmp_path):
    with obs.enabled_scope():
        with trace.span("parent", bucket="64x64"):
            with trace.span("child"):
                time.sleep(0.001)
    path = trace.export_chrome_trace(str(tmp_path / "trace.json"))
    doc = json.loads(open(path).read())
    events = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    assert [e["name"] for e in events] == ["parent", "child"]  # ts-sorted
    for e in events:
        assert e["ph"] == "X"
        assert set(e) >= {"name", "ph", "ts", "dur", "pid", "tid", "args"}
        assert e["dur"] >= 0
    assert events[0]["args"] == {"bucket": "64x64"}
    child, parent = events[1], events[0]
    assert parent["ts"] <= child["ts"]
    assert child["ts"] + child["dur"] <= parent["ts"] + parent["dur"] + 1e-3


def test_enabled_scope_restores_prior_state():
    assert not instrument.tracing_enabled()
    with obs.enabled_scope():
        assert instrument.tracing_enabled()
        assert instrument.annotations_enabled()
    assert not instrument.tracing_enabled()
    instrument.enable(tracing=False, annotations=True)
    with obs.enabled_scope():
        pass
    assert instrument.annotations_enabled()
    assert not instrument.tracing_enabled()


# ----------------------------------------------------------------- overhead

def test_disabled_overhead_budget():
    """The disabled-mode budget: one span + sync (what a hot serving /
    engine call adds) must cost < 1% of even a small tiled 256² solve.
    Generous on both sides — the null path is ~1 µs, the solve is ms."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((256, 256), dtype=np.float32))
    solver = plan(a.shape, a.dtype,
                  QRConfig(method="tiled", mode="r", block=64,
                           use_kernel=False))
    jax.block_until_ready(solver.solve(a))  # warm the jit cache
    t0 = time.perf_counter()
    jax.block_until_ready(solver.solve(a))
    solve_s = time.perf_counter() - t0

    n = 2000
    t0 = time.perf_counter()
    for _ in range(n):
        with trace.span("overhead.probe", mode="megakernel") as sp:
            sp.sync(None)
    per_call_s = (time.perf_counter() - t0) / n
    assert per_call_s < 0.01 * solve_s, (
        f"disabled span costs {per_call_s * 1e6:.2f} us/call, "
        f"> 1% of the {solve_s * 1e3:.2f} ms tiled 256^2 solve")


# ------------------------------------------------------- planner / pipeline

def test_planner_emits_plan_and_fallback_counters():
    # use_tuning_cache=False pins the heuristic table — this test asserts
    # the heuristic pick's counter labels, not the measured cache's.
    plan((512, 512), jnp.float32, QRConfig(use_tuning_cache=False),
         backend="cpu")
    assert metrics.counter_value("planner.plans", method="tiled") == 1
    plan((300, 280), jnp.float32, QRConfig(), backend="cpu")
    assert metrics.counter_value(
        "planner.fallbacks", reason="tiled_min_dim_cpu_floor") == 1


def test_engine_emits_dispatch_and_dma_series():
    from repro.core import engine

    p = q = 3
    nb = 8
    rng = np.random.default_rng(1)
    tiles = jnp.asarray(
        rng.standard_normal((p, q, nb, nb), dtype=np.float32))
    jax.block_until_ready(engine.factor_tiles(
        tiles, p=p, q=q, nb=nb, use_kernel=True, interpret=True,
        dispatch_mode="megakernel").tiles)
    assert metrics.counter_value("engine.dispatches", mode="megakernel",
                                 phase="execute") == 1
    st = engine.schedule_stats(p, q, nb)
    assert metrics.counter_value(
        "engine.modeled_dma_bytes", mode="megakernel",
        phase="execute") == st["megakernel"]["modeled_dma_bytes"]


def test_end_to_end_capture_covers_serving_pipeline(tmp_path):
    """A traced serving run yields Chrome-trace spans covering the full
    bucketize -> plan -> dispatch -> unpad pipeline plus the serving
    histograms — the acceptance shape of the observability PR."""
    from repro.serving import BucketingPolicy, QRService

    rng = np.random.default_rng(2)
    svc = QRService(policy=BucketingPolicy(tile=16, max_batch=4),
                    use_kernel=False)
    with obs.enabled_scope():
        svc.submit_many([rng.standard_normal((12, 10), dtype=np.float32)
                         for _ in range(3)])
    names = {s.name for s in trace.spans()}
    assert {"serving.bucketize", "serving.plan", "serving.dispatch",
            "serving.unpad"} <= names
    doc = trace.chrome_trace()
    assert len(doc["traceEvents"]) == len(trace.spans())
    snap = metrics.snapshot()
    for h in ("serving.queue_wait_seconds", "serving.latency_seconds",
              "serving.bucket_fill", "serving.padding_waste"):
        assert h in snap["histograms"], h
    assert metrics.counter_value("serving.dispatches",
                                 service=svc._sid) == 1
