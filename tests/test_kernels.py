"""Pallas kernel tests: shape/dtype sweeps + property tests vs. ref.py.

Kernels run in interpret mode on CPU (the body executes exactly as it
would on TPU, minus the Mosaic lowering).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.core import geqr2_ht, geqrf
from repro.core.blocked import larft, panel_factor, unpack_v_panel
from repro.kernels import ops, ref, tile_ops


# Shared deterministic matrix factory (tests/conftest.py).
from conftest import randn as _rand  # noqa: E402


# ---------------------------------------------------------------- mht_panel

PANEL_SHAPES = [(8, 4), (32, 8), (64, 16), (128, 32), (256, 64), (128, 128),
                (512, 16), (96, 24)]


@pytest.mark.parametrize("m,b", PANEL_SHAPES)
def test_mht_panel_matches_ref_f32(m, b):
    p = _rand((m, b), seed=m + b)
    pk, tk = ops.mht_panel(p)
    pr, tr = ref.mht_panel_ref(p)
    # fp32 accumulation-order differences grow with factorization depth b.
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr), atol=1e-7 * b + 2e-6)


@pytest.mark.parametrize("m,b", [(64, 16), (128, 32)])
@pytest.mark.parametrize("row0", [0, 8, 32])
def test_mht_panel_row_offsets(m, b, row0):
    p = _rand((m, b), seed=row0)
    pk, tk = ops.mht_panel(p, row0=row0)
    pr, tr = ref.mht_panel_ref(p, row0=row0)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr), atol=2e-6)
    # rows above the pivot band must be bit-identical to the input
    np.testing.assert_array_equal(np.asarray(pk[:row0]), np.asarray(p[:row0]))


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5), (jnp.bfloat16, 5e-2)])
def test_mht_panel_dtypes(dtype, atol):
    p = _rand((64, 16), dtype=dtype, seed=5)
    pk, tk = ops.mht_panel(p)
    pr, tr = ref.mht_panel_ref(p)
    np.testing.assert_allclose(
        np.asarray(pk, np.float32), np.asarray(pr, np.float32), atol=atol)
    np.testing.assert_allclose(
        np.asarray(tk, np.float32), np.asarray(tr, np.float32), atol=atol)


def test_mht_panel_vmem_guard():
    with pytest.raises(ValueError, match="VMEM"):
        ops.mht_panel(jnp.zeros((8192, 256), jnp.float32))


def test_mht_panel_degenerate_column():
    """A column that is already zero below the pivot must give tau=0."""
    p = _rand((32, 4), seed=1)
    p = p.at[1:, 0].set(0.0)
    pk, tk = ops.mht_panel(p)
    pr, tr = ref.mht_panel_ref(p)
    assert float(tk[0]) == 0.0
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(4, 128), b=st.integers(2, 32), seed=st.integers(0, 10_000),
       scale=st.floats(1e-2, 1e2))
def test_property_mht_panel(m, b, seed, scale):
    b = min(b, m)
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.standard_normal((m, b)) * scale, jnp.float32)
    pk, tk = ops.mht_panel(p)
    pr, tr = ref.mht_panel_ref(p)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr),
                               atol=3e-5 * max(scale, 1.0))
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr), atol=3e-5)


# -------------------------------------------------------------- wy_trailing

WY_SHAPES = [(32, 8, 16), (64, 16, 40), (128, 32, 128), (256, 32, 300),
             (512, 64, 96), (128, 128, 256)]


def _make_vt(m, k, seed):
    a = _rand((m, k), seed=seed)
    pf, taus = panel_factor(a, 0)
    v = unpack_v_panel(pf, 0)
    return v, larft(v, taus)


@pytest.mark.parametrize("m,k,n", WY_SHAPES)
def test_wy_trailing_matches_ref_f32(m, k, n):
    v, t = _make_vt(m, k, seed=m + k + n)
    c = _rand((m, n), seed=n)
    np.testing.assert_allclose(
        np.asarray(ops.wy_trailing(v, t, c)),
        np.asarray(ref.wy_trailing_ref(v, t, c)), atol=3e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 3e-5), (jnp.bfloat16, 1e-1)])
def test_wy_trailing_dtypes(dtype, atol):
    v, t = _make_vt(128, 32, seed=2)
    c = _rand((128, 100), dtype=dtype, seed=3)
    out_k = ops.wy_trailing(v.astype(dtype), t.astype(dtype), c)
    out_r = ref.wy_trailing_ref(v.astype(dtype), t.astype(dtype), c)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=atol)


def test_wy_trailing_applies_qt():
    """Kernel output must equal applying Q^T from the packed factors."""
    from repro.core import apply_q

    m, k, n = 96, 16, 24
    a = _rand((m, k), seed=9)
    pf, taus = panel_factor(a, 0)
    v = unpack_v_panel(pf, 0)
    t = larft(v, taus)
    c = _rand((m, n), seed=10)
    out = ops.wy_trailing(v, t, c)
    expected = apply_q(pf, taus, c, transpose=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(8, 128), k=st.integers(2, 32), n=st.integers(1, 200),
       seed=st.integers(0, 10_000))
def test_property_wy_trailing(m, k, n, seed):
    k = min(k, m)
    v, t = _make_vt(m, k, seed=seed)
    c = _rand((m, n), seed=seed + 1)
    np.testing.assert_allclose(
        np.asarray(ops.wy_trailing(v, t, c)),
        np.asarray(ref.wy_trailing_ref(v, t, c)), atol=5e-5)


# ------------------------------------------------ tile ops (TSQRT / SSRFB)

def _tsqrt_inputs(nb, seed):
    r = jnp.triu(_rand((nb, nb), seed=seed))
    a = _rand((nb, nb), seed=seed + 1)
    return r, a


@pytest.mark.parametrize("nb", [4, 8, 16, 32])
def test_tsqrt_matches_ref(nb):
    r, a = _tsqrt_inputs(nb, seed=nb)
    rk, vk, tk = tile_ops.tsqrt(r, a)
    rr, vr, tr = ref.tsqrt_ref(r, a)
    np.testing.assert_allclose(np.asarray(rk), np.asarray(rr), atol=3e-5)
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr), atol=3e-5)
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr), atol=3e-5)
    # structured output: the updated R stays exactly upper triangular
    assert float(jnp.linalg.norm(jnp.tril(rk, -1))) == 0.0


def test_tsqrt_reduces_stacked_pair():
    """[R; A] = Q [R'; 0]: R' must match the QR of the stacked pair."""
    nb = 16
    r, a = _tsqrt_inputs(nb, seed=3)
    rk, _, _ = tile_ops.tsqrt(r, a)
    rn = jnp.linalg.qr(jnp.concatenate([r, a], axis=0))[1]
    s = jnp.sign(jnp.diagonal(rk)) * jnp.sign(jnp.diagonal(rn))
    np.testing.assert_allclose(np.asarray(rk * s[:, None]), np.asarray(rn),
                               atol=3e-5)


def test_tsqrt_degenerate_zero_tail():
    """A zero A-tile must pass R through untouched (all tau = 0)."""
    nb = 8
    r = jnp.triu(_rand((nb, nb), seed=4))
    rk, vk, tk = tile_ops.tsqrt(r, jnp.zeros((nb, nb), jnp.float32))
    np.testing.assert_allclose(np.asarray(tk), np.zeros(nb), atol=0)
    np.testing.assert_allclose(np.asarray(vk), np.zeros((nb, nb)), atol=0)
    np.testing.assert_allclose(np.asarray(rk), np.asarray(r), atol=1e-6)


@pytest.mark.parametrize("nb", [4, 8, 16, 32])
def test_ssrfb_matches_ref(nb):
    from repro.kernels.macro_ops import stacked_larft

    r, a = _tsqrt_inputs(nb, seed=nb + 7)
    _, v2, taus = ref.tsqrt_ref(r, a)
    t = stacked_larft(v2, taus)
    ck, ci = _rand((nb, nb), seed=1), _rand((nb, nb), seed=2)
    ck_k, ci_k = tile_ops.ssrfb(v2, t, ck, ci)
    ck_r, ci_r = ref.ssrfb_ref(v2, t, ck, ci)
    np.testing.assert_allclose(np.asarray(ck_k), np.asarray(ck_r), atol=3e-5)
    np.testing.assert_allclose(np.asarray(ci_k), np.asarray(ci_r), atol=3e-5)


def test_tile_ops_vmem_guards():
    big = 2048  # 6 * 2048^2 * 4 bytes > the shared 8 MiB budget
    z = jnp.zeros((big, big), jnp.float32)
    with pytest.raises(ValueError, match="VMEM"):
        tile_ops.tsqrt(z, z)
    with pytest.raises(ValueError, match="VMEM"):
        tile_ops.ssrfb(z, z, z, z)


@settings(max_examples=10, deadline=None)
@given(nb=st.integers(2, 24), seed=st.integers(0, 10_000))
def test_property_tsqrt_ssrfb(nb, seed):
    from repro.kernels.macro_ops import stacked_larft

    rng = np.random.default_rng(seed)
    r = jnp.triu(jnp.asarray(rng.standard_normal((nb, nb)), jnp.float32))
    a = jnp.asarray(rng.standard_normal((nb, nb)), jnp.float32)
    rk, vk, tk = tile_ops.tsqrt(r, a)
    rr, vr, tr = ref.tsqrt_ref(r, a)
    np.testing.assert_allclose(np.asarray(rk), np.asarray(rr), atol=5e-5)
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr), atol=5e-5)
    t = stacked_larft(vr, tr)
    c = jnp.asarray(rng.standard_normal((2, nb, nb)), jnp.float32)
    out_k = tile_ops.ssrfb(vr, t, c[0], c[1])
    out_r = ref.ssrfb_ref(vr, t, c[0], c[1])
    for ok, orf in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(ok), np.asarray(orf), atol=5e-5)


# ------------------------------------------------- end-to-end kernel geqrf

@pytest.mark.parametrize("m,n,block", [(64, 32, 8), (96, 64, 16), (128, 128, 32)])
def test_geqrf_kernel_path_matches_unblocked(m, n, block):
    a = _rand((m, n), seed=m)
    pk, tk = geqrf(a, block=block, use_kernel=True)
    pu, tu = geqr2_ht(a)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pu), atol=5e-4)
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tu), atol=5e-5)
