"""TSQR / distributed QR tests (paper §5.2 parallel realization).

The shard_map paths need >1 device; those run in a subprocess with
``--xla_force_host_platform_device_count`` so the rest of the suite keeps
the single real CPU device (per the dry-run isolation rule).
"""

import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.core import tsqr_qr, tsqr_r
from repro.core.tsqr import triangular_inverse_apply


def _rand(m, n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((m, n)), jnp.float32)


@pytest.mark.parametrize("nblocks", [2, 3, 4, 8])
def test_tsqr_r_matches_linalg(nblocks):
    a = _rand(240, 12, seed=nblocks)
    r = tsqr_r(a, nblocks=nblocks)
    rn = jnp.linalg.qr(a)[1]
    s = jnp.sign(jnp.diagonal(r)) * jnp.sign(jnp.diagonal(rn))
    np.testing.assert_allclose(np.asarray(r * s[:, None]), np.asarray(rn), atol=1e-4)


def test_tsqr_qr_reconstruction_and_orthogonality():
    a = _rand(512, 24, seed=1)
    q, r = tsqr_qr(a, nblocks=8)
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), atol=1e-4)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(24), atol=1e-5)


def test_tsqr_qr_ill_conditioned_refinement():
    """CQR2-style refinement keeps Q orthonormal for cond ~ 1e4 inputs."""
    rng = np.random.default_rng(2)
    u, _ = np.linalg.qr(rng.standard_normal((256, 16)))
    v, _ = np.linalg.qr(rng.standard_normal((16, 16)))
    s = np.logspace(0, -4, 16)
    a = jnp.asarray(u @ np.diag(s) @ v.T, jnp.float32)
    q, r = tsqr_qr(a, nblocks=4, refine=True)
    assert float(jnp.linalg.norm(q.T @ q - jnp.eye(16))) < 1e-3
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), atol=1e-4)


def test_triangular_inverse_apply_clamps_rank_deficiency():
    a = _rand(64, 8, seed=3)
    r = jnp.linalg.qr(a)[1]
    r = r.at[4, 4].set(0.0)  # kill a pivot
    out = triangular_inverse_apply(a, r)
    assert bool(jnp.all(jnp.isfinite(out)))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 16))
def test_property_tsqr_gram_identity(seed, n):
    """R from TSQR satisfies R^T R == A^T A regardless of tree shape."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((128, n)), jnp.float32)
    r = tsqr_r(a, nblocks=4)
    np.testing.assert_allclose(
        np.asarray(r.T @ r), np.asarray(a.T @ a), atol=5e-3 * n
    )


_SHARDED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core.tsqr import distributed_qr, tsqr_tree_sharded

    from repro.compat import shard_map
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((256, 16)), jnp.float32)

    f = jax.jit(
        shard_map(
            lambda x: distributed_qr(x, "data"),
            mesh=mesh,
            in_specs=P("data", None),
            out_specs=(P("data", None), P()),
        )
    )
    q, r = f(a)
    assert np.linalg.norm(np.asarray(q) @ np.asarray(r) - np.asarray(a)) < 1e-3
    assert np.linalg.norm(np.asarray(q).T @ np.asarray(q) - np.eye(16)) < 1e-3

    g = jax.jit(
        shard_map(
            lambda x: tsqr_tree_sharded(x, "data"),
            mesh=mesh,
            in_specs=P("data", None),
            out_specs=P(),
        )
    )
    r2 = np.asarray(g(a))
    rn = np.linalg.qr(np.asarray(a))[1]
    s = np.sign(np.diagonal(r2)) * np.sign(np.diagonal(rn))
    assert np.abs(r2 * s[:, None] - rn).max() < 1e-3
    print("SHARDED_TSQR_OK")
    """
)


def test_sharded_tsqr_subprocess():
    """Butterfly-tree TSQR + distributed thin-QR on an 8-way mesh."""
    res = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd=__file__.rsplit("/", 2)[0],
    )
    assert "SHARDED_TSQR_OK" in res.stdout, res.stderr[-3000:]
