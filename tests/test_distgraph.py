"""Multi-device sharded tiled QR tests.

Two layers:
  * symbolic domain metadata + single-device degeneracies run in-process
    (the suite keeps the single real CPU device, per the dry-run
    isolation rule);
  * the real shard_map paths run in a subprocess with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — including
    the PR acceptance check (512x512 and 1024x512 vs ``jnp.linalg.qr``
    within conformance-suite tolerances) and the multi-device edge
    cases (grid smaller than the device count, p not divisible by d).

Under the CI multi-device job this whole module ALSO runs with 8
in-process devices, so the in-process tests exercise d > 1 there.
"""

import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import gaussian
from repro.core import QRConfig, plan
from repro.core.distgraph import effective_domains, sharded_tiled_qr
from repro.core.tilegraph import (
    domain_rows,
    domain_wavefronts,
    merge_levels,
    sharded_wavefront_count,
    tiled_qr,
    wavefront_count,
)


# ----------------------------------------------------- symbolic domain DAG

def test_domain_rows_balanced_and_uneven():
    assert domain_rows(8, 4) == ((0, 2), (2, 4), (4, 6), (6, 8))
    # p = 7 over d = 3: first p % d domains carry the extra row
    assert domain_rows(7, 3) == ((0, 3), (3, 5), (5, 7))
    assert domain_rows(5, 5) == ((0, 1), (1, 2), (2, 3), (3, 4), (4, 5))
    with pytest.raises(ValueError):
        domain_rows(4, 5)
    with pytest.raises(ValueError):
        domain_rows(4, 0)


def test_domain_wavefronts_are_local_dags():
    """Each domain's schedule is exactly the tile DAG of its sub-grid."""
    wfs = domain_wavefronts(8, 4, 4)
    assert len(wfs) == 4
    for dom in wfs:
        # every domain owns 2 tile rows x 4 cols -> wavefront_count(2, 4)
        assert len(dom) == wavefront_count(2, 4)


def test_sharded_wavefront_count_closed_form():
    """Critical path = tallest local schedule + merge-tree depth."""
    for p, q in [(8, 8), (16, 4), (5, 3), (32, 8)]:
        for d in (1, 2, 4, 8):
            got = sharded_wavefront_count(p, q, d)
            if d == 1:
                assert got == wavefront_count(p, q)
            else:
                p_dom = -(-p // d)
                assert got == wavefront_count(p_dom, q) + merge_levels(d)


def test_sharded_critical_path_shrinks_with_domains():
    """The point of the backend: O(p/d + 2q + log d) beats O(p + 2q)."""
    p, q = 32, 8
    counts = [sharded_wavefront_count(p, q, d) for d in (1, 2, 4, 8)]
    assert counts == sorted(counts, reverse=True)
    assert counts[-1] < counts[0]


def test_merge_levels():
    assert [merge_levels(d) for d in (1, 2, 3, 4, 8)] == [0, 1, 2, 2, 3]


# ------------------------------------------------- degeneracies, in-process

def test_effective_domains_caps_and_rounds():
    # grid smaller than the device count: cap at the tile-row count
    assert effective_domains(32, 32, 16, requested=8, device_count=8) == 2
    # non-power-of-two rounds down (butterfly needs 2^k participants)
    assert effective_domains(512, 64, 16, requested=7, device_count=8) == 4
    # wide input: row-sharding degenerates
    assert effective_domains(16, 64, 16, requested=8, device_count=8) == 1
    # never more than the devices that exist
    assert effective_domains(512, 64, 16, requested=8, device_count=2) == 2


def test_d1_degenerates_to_tiled_bit_for_bit():
    """ndomains=1 must be the tiled backend's result, bit for bit."""
    a = gaussian(96, 64, seed=3)
    qt, rt = tiled_qr(a, tile=16)
    qs, rs = sharded_tiled_qr(a, tile=16, ndomains=1)
    np.testing.assert_array_equal(np.asarray(qs), np.asarray(qt))
    np.testing.assert_array_equal(np.asarray(rs), np.asarray(rt))


def test_solver_d1_degenerates_bit_for_bit():
    """Through the planner too (solve hooks share the tiled path)."""
    a = gaussian(80, 48, seed=4)
    cfg_t = QRConfig(method="tiled", block=16)
    cfg_s = QRConfig(method="sharded_tiled", block=16, ndomains=1)
    qt, rt = plan(a.shape, a.dtype, cfg_t).solve(a)
    qs, rs = plan(a.shape, a.dtype, cfg_s).solve(a)
    np.testing.assert_array_equal(np.asarray(qs), np.asarray(qt))
    np.testing.assert_array_equal(np.asarray(rs), np.asarray(rt))


def test_sharded_mode_validation():
    with pytest.raises(ValueError):
        sharded_tiled_qr(gaussian(32, 16, seed=0), tile=16, mode="full")


def test_plan_resolves_ndomains_and_tile():
    solver = plan((256, 128), jnp.float32,
                  QRConfig(method="sharded_tiled", block=32))
    assert solver.config.ndomains == effective_domains(256, 128, 32)
    assert solver.config.ndomains >= 1
    # huge request caps at the device count (and stays a power of two)
    solver = plan((512, 256), jnp.float32,
                  QRConfig(method="sharded_tiled", block=32, ndomains=64))
    d = solver.config.ndomains
    assert d <= jax.local_device_count() and (d & (d - 1)) == 0


def test_plan_rejects_full_mode():
    with pytest.raises(ValueError):
        plan((256, 128), jnp.float32,
             QRConfig(method="sharded_tiled", mode="full"))


def test_sharded_correct_at_any_local_device_count():
    """Whatever d the current process resolves to (1 in the default
    suite, 8 under the CI multi-device job), results meet the bar."""
    a = gaussian(160, 96, seed=9)
    solver = plan(a.shape, a.dtype, QRConfig(method="sharded_tiled", block=16))
    q, r = solver.solve(a)
    assert float(jnp.linalg.norm(q @ r - a) / jnp.linalg.norm(a)) < 1e-5
    assert float(jnp.abs(q.T @ q - jnp.eye(96)).max()) < 1e-5
    r_only = plan(a.shape, a.dtype,
                  QRConfig(method="sharded_tiled", block=16, mode="r")).solve(a)
    assert r_only.shape == (96, 96)
    assert float(jnp.abs(jnp.tril(r_only, -1)).max()) == 0.0


# ------------------------------------------------ shard_map paths (8 devs)

_SUBPROCESS_PRELUDE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    assert jax.local_device_count() == 8, jax.local_device_count()
    from repro.core import QRConfig, plan
    from repro.core.distgraph import effective_domains, sharded_tiled_qr
    from repro.core.tilegraph import tiled_qr

    def tol(m, n):
        return 100.0 * float(jnp.finfo(jnp.float32).eps) * max(m, n)

    def check(a, q, r):
        m, n = a.shape
        k = min(m, n)
        t = tol(m, n)
        rec = float(jnp.linalg.norm(q @ r - a) / jnp.linalg.norm(a))
        orth = float(jnp.abs(q.T @ q - jnp.eye(k, dtype=a.dtype)).max())
        assert rec <= t, (a.shape, rec, t)
        assert orth <= t, (a.shape, orth, t)
        assert float(jnp.abs(jnp.tril(r[:, :k], -1)).max()) == 0.0
        # against the jnp.linalg.qr oracle, up to column signs
        rn = jnp.linalg.qr(a)[1]
        s = jnp.sign(jnp.diagonal(r[:k, :k])) * jnp.sign(jnp.diagonal(rn))
        err = float(jnp.abs(r * s[:, None] - rn).max())
        assert err <= t * float(jnp.abs(rn).max()), (a.shape, err)
    """
)

_ACCEPTANCE_SCRIPT = _SUBPROCESS_PRELUDE + textwrap.dedent(
    """
    rng = np.random.default_rng(0)
    for shape in [(512, 512), (1024, 512)]:
        a = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        solver = plan(a.shape, a.dtype,
                      QRConfig(method="sharded_tiled", block=64))
        assert solver.config.ndomains == 8, solver.config
        q, r = solver.solve(a)
        check(a, q, r)
        print("ACCEPT_OK", shape)
    print("SHARDED_TILED_OK")
    """
)

_EDGE_SCRIPT = _SUBPROCESS_PRELUDE + textwrap.dedent(
    """
    rng = np.random.default_rng(1)

    # (1) tile grid smaller than the device count: 2 tile rows, 8 devices
    a = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    assert effective_domains(64, 48, 32) == 2
    q, r = sharded_tiled_qr(a, tile=32)
    check(a, q, r)

    # (2) uneven split: p = 10 tile rows over 8 domains (pads to 16)
    a = jnp.asarray(rng.standard_normal((160, 64)), jnp.float32)
    q, r = sharded_tiled_qr(a, tile=16)
    check(a, q, r)

    # (3) p = 5 over requested d = 4, non-divisible + off-tile shape
    a = jnp.asarray(rng.standard_normal((74, 40)), jnp.float32)
    q, r = sharded_tiled_qr(a, tile=16, ndomains=4)
    check(a, q, r)

    # (4) d = 1 on an 8-device process is still bit-for-bit tiled
    a = jnp.asarray(rng.standard_normal((96, 64)), jnp.float32)
    qt, rt = tiled_qr(a, tile=16)
    qs, rs = sharded_tiled_qr(a, tile=16, ndomains=1)
    assert (np.asarray(qs) == np.asarray(qt)).all()
    assert (np.asarray(rs) == np.asarray(rt)).all()

    # (5) r-only mode + sign_fix through the planner
    a = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    cfg = QRConfig(method="sharded_tiled", block=32, sign_fix=True)
    q, r = plan(a.shape, a.dtype, cfg).solve(a)
    assert bool((jnp.diagonal(r) >= 0).all())
    check(a, q, r)
    print("SHARDED_EDGES_OK")
    """
)


_MEGAKERNEL_SCRIPT = _SUBPROCESS_PRELUDE + textwrap.dedent(
    """
    # The engine's dispatch modes inside shard_map: with d > 1 every
    # device runs its domain-local sweep through the requested lowering
    # (wavefront = per-level dispatches, megakernel = ONE persistent
    # dispatch per domain sweep) — and the two kernel paths stay bitwise
    # identical to each other and to the jnp-oracle lowering.
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
    d = effective_domains(128, 64, 16)
    assert d == 8, d
    outs = {}
    for mode in (None, "wavefront", "megakernel"):
        use_kernel = mode is not None
        q, r = sharded_tiled_qr(a, tile=16, use_kernel=use_kernel,
                                dispatch_mode=mode)
        check(a, q, r)
        outs[mode] = (np.asarray(q), np.asarray(r))
    for mode in ("wavefront", "megakernel"):
        assert (outs[mode][0] == outs[None][0]).all(), mode
        assert (outs[mode][1] == outs[None][1]).all(), mode
    print("SHARDED_MEGAKERNEL_OK")
    """
)


def _run_sub(script, timeout=600):
    return subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=timeout,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd=__file__.rsplit("/", 2)[0],
    )


# Marked slow: several minutes each, and the CI multi-device job (which
# runs `-m "slow or not slow"`) exercises the same 8-device paths
# in-process on every push — tier-1 keeps the fast d=1 coverage above.

@pytest.mark.slow
def test_sharded_tiled_acceptance_subprocess():
    """PR acceptance: 512x512 and 1024x512 on an 8-device CPU mesh match
    jnp.linalg.qr within the conformance tolerances."""
    res = _run_sub(_ACCEPTANCE_SCRIPT)
    assert "SHARDED_TILED_OK" in res.stdout, res.stderr[-3000:]


@pytest.mark.slow
def test_sharded_tiled_edge_cases_subprocess():
    """Small grids, uneven splits, d=1 bitwise, sign_fix — on 8 devices."""
    res = _run_sub(_EDGE_SCRIPT)
    assert "SHARDED_EDGES_OK" in res.stdout, res.stderr[-3000:]


@pytest.mark.slow
def test_sharded_dispatch_modes_subprocess():
    """Both engine dispatch modes run domain-locally under shard_map
    (d=8) and stay bitwise equal to the jnp-oracle lowering."""
    res = _run_sub(_MEGAKERNEL_SCRIPT)
    assert "SHARDED_MEGAKERNEL_OK" in res.stdout, res.stderr[-3000:]
