"""Distributed QR-Muon: orthogonalize FSDP-sharded momentum with the
butterfly-tree TSQR (paper §5.2 as a production optimizer path)."""

import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.tsqr import distributed_qr
    from repro.optim import muon_init, muon_update, qr_orthogonalize_2d

    from repro.compat import shard_map
    mesh = jax.make_mesh((8,), ("data",))

    # the distributed orthogonalizer: rows sharded over "data", thin Q out
    def tsqr_orth(m2d):
        rows = m2d.shape[0]
        transpose = m2d.shape[0] < m2d.shape[1]
        a = m2d.T if transpose else m2d
        f = shard_map(lambda x: distributed_qr(x, "data"),
                      mesh=mesh, in_specs=P("data", None),
                      out_specs=(P("data", None), P()))
        q, r = f(a)
        signs = jnp.where(jnp.diagonal(r) >= 0, 1.0, -1.0)
        q = q * signs[None, :]
        return q.T if transpose else q

    params = {"w": jax.device_put(
        jax.random.normal(jax.random.PRNGKey(0), (512, 64), jnp.float32),
        NamedSharding(mesh, P("data", None)))}
    grads = {"w": jax.device_put(
        jax.random.normal(jax.random.PRNGKey(1), (512, 64), jnp.float32),
        NamedSharding(mesh, P("data", None)))}
    state = muon_init(params)

    with mesh:
        step = jax.jit(lambda g, s, p: muon_update(
            g, s, p, lr=1.0, momentum=0.0, nesterov=False,
            orthogonalize_fn=tsqr_orth))
        new_params, _ = step(grads, state, params)

    delta = np.asarray(params["w"] - new_params["w"]) / np.sqrt(512 / 64)
    err = np.abs(delta.T @ delta - np.eye(64)).max()
    assert err < 1e-3, err
    # matches the single-device QR orthogonalizer
    ref = np.asarray(qr_orthogonalize_2d(grads["w"]))
    assert np.abs(delta - ref).max() < 1e-3
    print("DIST_MUON_OK", err)
""")


@pytest.mark.slow
def test_distributed_qr_muon_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "JAX_PLATFORMS": "cpu"},  # never probe a TPU from the test
        cwd=__file__.rsplit("/", 2)[0])
    assert "DIST_MUON_OK" in res.stdout, res.stderr[-3000:]
