"""Integration tests: trainer end-to-end, checkpoint/restart continuity,
microbatch-accumulation equivalence, compression training, serving."""

import tempfile

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import DataConfig
from repro.models import init_params
from repro.serving import ServeEngine
from repro.training import (
    RunConfig, TrainConfig, Trainer, init_train_state, make_train_step,
)

KEY = jax.random.PRNGKey(0)


def _data_cfg(cfg, batch=8, seq=64):
    return DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=batch, embedding_input=cfg.embedding_input,
                      d_model=cfg.d_model)


@pytest.mark.slow
def test_trainer_loss_decreases():
    cfg = get_smoke_config("smollm-135m")
    tr = Trainer(cfg, TrainConfig(optimizer="muon-qr", lr=0.02),
                 RunConfig(total_steps=15, warmup_steps=2, log_every=1),
                 _data_cfg(cfg), log_fn=lambda s: None)
    res = tr.run()
    losses = [m["loss"] for m in res["history"]]
    assert losses[-1] < losses[0] - 1.0


@pytest.mark.slow
def test_trainer_restart_is_bitexact_continuation():
    """Crash/restart: resumed run must produce the same next batches and
    continue from the checkpointed state."""
    cfg = get_smoke_config("olmo-1b")
    with tempfile.TemporaryDirectory() as td:
        mk = lambda steps: Trainer(
            cfg, TrainConfig(optimizer="adamw", lr=1e-3),
            RunConfig(total_steps=steps, warmup_steps=0, log_every=1,
                      checkpoint_every=5, checkpoint_dir=td),
            _data_cfg(cfg, batch=4), log_fn=lambda s: None)
        t1 = mk(12)
        r1 = t1.run(stop_at=10)    # "crash" at step 10
        t1._save(blocking=True)
        t1.ckpt.wait_until_finished()
        # fresh process equivalent: restore at 10 and continue to 12
        t2 = mk(12)
        r2 = t2.run()
        assert r2["final_step"] == 12
        assert t2.pipeline.step == 12  # data cursor restored + advanced

        # uninterrupted reference run to 12
        t3 = Trainer(cfg, TrainConfig(optimizer="adamw", lr=1e-3),
                     RunConfig(total_steps=12, warmup_steps=0, log_every=1),
                     _data_cfg(cfg, batch=4), log_fn=lambda s: None)
        r3 = t3.run()
        # same final loss up to numeric noise -> same trajectory
        l2 = [m for m in r2["history"] if m["step"] == 12][0]["loss"]
        l3 = [m for m in r3["history"] if m["step"] == 12][0]["loss"]
        assert abs(l2 - l3) < 1e-3, (l2, l3)


def test_microbatch_equivalence():
    """Gradient accumulation must match the monolithic batch step."""
    cfg = get_smoke_config("olmo-1b").scaled(dtype="float32")
    params = init_params(KEY, cfg)
    batch = {
        "tokens": jax.random.randint(KEY, (8, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(KEY, 1), (8, 32), 0,
                                     cfg.vocab_size),
    }
    lr = jnp.float32(1e-3)
    outs = {}
    for mb in (0, 2, 4):
        tc = TrainConfig(optimizer="adamw", lr=1e-3, microbatch=mb)
        state = init_train_state(params, tc)
        step = jax.jit(make_train_step(cfg, tc))
        new_state, metrics = step(state, batch, lr)
        outs[mb] = (new_state.params, float(metrics["loss"]))
    for mb in (2, 4):
        assert abs(outs[mb][1] - outs[0][1]) < 1e-4
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                             outs[mb][0], outs[0][0])
        assert max(jax.tree.leaves(diffs)) < 1e-4


@pytest.mark.slow
def test_training_with_compression_converges():
    cfg = get_smoke_config("smollm-135m")
    tr = Trainer(cfg, TrainConfig(optimizer="adamw", lr=2e-3,
                                  grad_compression=True),
                 RunConfig(total_steps=12, warmup_steps=2, log_every=1),
                 _data_cfg(cfg), log_fn=lambda s: None)
    res = tr.run()
    losses = [m["loss"] for m in res["history"]]
    assert losses[-1] < losses[0] - 0.5
    assert np.isfinite(losses).all()


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "xlstm-1.3b"])
def test_trainer_runs_recurrent_archs(arch):
    cfg = get_smoke_config(arch)
    tr = Trainer(cfg, TrainConfig(optimizer="muon-qr", lr=0.01),
                 RunConfig(total_steps=4, warmup_steps=1, log_every=1),
                 _data_cfg(cfg, batch=4, seq=32), log_fn=lambda s: None)
    res = tr.run()
    assert np.isfinite([m["loss"] for m in res["history"]]).all()


def test_embedding_input_arch_trains():
    cfg = get_smoke_config("musicgen-large")
    tr = Trainer(cfg, TrainConfig(optimizer="adamw", lr=1e-3),
                 RunConfig(total_steps=4, warmup_steps=1, log_every=1),
                 _data_cfg(cfg, batch=4, seq=32), log_fn=lambda s: None)
    res = tr.run()
    assert np.isfinite([m["loss"] for m in res["history"]]).all()


def test_serving_greedy_reproducible_and_batched():
    cfg = get_smoke_config("gemma2-9b")
    params = init_params(KEY, cfg)
    eng = ServeEngine(params, cfg, batch=3, max_len=64)
    prompts = jax.random.randint(KEY, (3, 16), 0, cfg.vocab_size)
    a = eng.generate(prompts, 8)
    b = eng.generate(prompts, 8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (3, 8)
    # per-request independence: row 0 result does not depend on row 2 prompt
    prompts2 = prompts.at[2].set((prompts[2] + 1) % cfg.vocab_size)
    c = eng.generate(prompts2, 8)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(c[0]))
