"""Launch-layer tests: mesh construction, input specs, a reduced-mesh
dry-run (lower+compile+roofline terms) in a subprocess, HLO collective
parsing, and the analytic cost model."""

import json
import subprocess
import sys
import tempfile
import textwrap

import pytest

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.roofline import analytic_cell_cost
from repro.launch.specs import cell_is_skipped


def test_long_500k_skip_policy():
    runs = {a for a in ARCHS if cell_is_skipped(a, "long_500k") is None}
    assert runs == {"jamba-v0.1-52b", "xlstm-1.3b"}
    for a in ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert cell_is_skipped(a, s) is None


@pytest.mark.parametrize("arch", ARCHS)
def test_analytic_cost_sane(arch):
    cfg = get_config(arch)
    train = analytic_cell_cost(cfg, SHAPES["train_4k"], "train")
    dec = analytic_cell_cost(cfg, SHAPES["decode_32k"], "decode")
    assert train.flops > train.model_flops > 0
    assert 0.03 < train.model_flops / train.flops < 1.0
    assert dec.flops < train.flops
    assert train.params_active <= train.params_total
    if cfg.moe is None:
        assert train.params_active == train.params_total


_DRYRUN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    from repro.distributed.sharding import MeshRules, activation_policy, \\
        tree_shardings
    from repro.launch.specs import input_specs
    from repro.launch.dryrun import collective_bytes, _memory_analysis_dict

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = MeshRules(mesh=mesh, data_axes=("data",))
    cell = input_specs("smollm-135m", "train_4k", rules,
                       overrides=dict(n_layers=2, seq_chunk=256))
    shardings = tuple(tree_shardings(s, mesh) for s in cell.in_specs)
    with mesh, activation_policy(rules):
        lowered = jax.jit(cell.step_fn, in_shardings=shardings).lower(
            *cell.args_sds)
        compiled = lowered.compile()
        mem = _memory_analysis_dict(compiled)
        coll = collective_bytes(compiled.as_text())
    assert coll["total_weighted_bytes"] >= coll["total_bytes"] > 0
    assert mem.get("temp_size_in_bytes", 1) > 0
    print("DRYRUN_SMALL_OK", json.dumps(
        {"weighted": coll["total_weighted_bytes"],
         "static": coll["total_bytes"]}))
""")


@pytest.mark.slow
def test_reduced_mesh_dryrun_subprocess():
    """lower + compile + memory/cost/collective extraction on a small mesh
    — exercises the exact dryrun.py code path used for the 512-chip run."""
    res = subprocess.run(
        [sys.executable, "-c", _DRYRUN_SCRIPT], capture_output=True,
        text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd=__file__.rsplit("/", 2)[0])
    assert "DRYRUN_SMALL_OK" in res.stdout, res.stderr[-3000:]


def test_collective_parser_units():
    from repro.launch.dryrun import _shape_bytes, collective_bytes

    assert _shape_bytes("bf16[16,128]") == 16 * 128 * 2
    assert _shape_bytes("(f32[8], s8[4])") == 36
    hlo = textwrap.dedent("""
        HloModule test

        %body.1 (p: (s32[], f32[4])) -> (s32[], f32[4]) {
          %p = (s32[], f32[4]) parameter(0)
          %ar = f32[4]{0} all-reduce(%gte), to_apply=%add.1
          ROOT %t = (s32[], f32[4]) tuple(%i, %ar)
        }

        %cond.1 (p2: (s32[], f32[4])) -> pred[] {
          %p2 = (s32[], f32[4]) parameter(0)
          %c = s32[] constant(7)
          %i2 = s32[] get-tuple-element(%p2), index=0
          ROOT %cmp = pred[] compare(%i2, %c), direction=LT
        }

        ENTRY %main (a: f32[4]) -> f32[4] {
          %a = f32[4]{0} parameter(0)
          %ag = f32[16]{0} all-gather(%a), dimensions={0}
          %w = (s32[], f32[4]) while(%init), condition=%cond.1, body=%body.1
          ROOT %out = f32[4]{0} get-tuple-element(%w), index=1
        }
    """)
    c = collective_bytes(hlo)
    assert c["bytes"]["all-gather"] == 64
    assert c["bytes"]["all-reduce"] == 16
    # weighted: the loop body all-reduce executes 7x
    assert c["weighted_bytes"]["all-reduce"] == 7 * 16
    assert c["weighted_bytes"]["all-gather"] == 64


def test_make_production_mesh_requires_512():
    """On the 1-device test process the production mesh must refuse —
    proving tests never see the forced 512-device config."""
    import jax

    from repro.launch.mesh import make_production_mesh

    if len(jax.devices()) >= 512:  # pragma: no cover
        pytest.skip("running inside a dry-run environment")
    with pytest.raises(ValueError):
        make_production_mesh()
