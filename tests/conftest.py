"""Shared test fixtures: deterministic matrix generation for the suite.

One seeded generator family instead of per-module ``_rand`` helpers and
ad-hoc ``jax.random.PRNGKey(0)`` calls: every generator derives from
``np.random.default_rng(seed)`` so a test's inputs are bit-identical
across runs, machines, and jax versions (jax.random keys are *not*
stable across jax upgrades; numpy Generator streams are).

Module-level functions (importable as ``from conftest import randn``)
keep legacy ``_rand`` call sites working verbatim; the ``matrices``
fixture hands structured generators (well-conditioned /
graded-singular-value / rank-deficient) to tests that care about
conditioning — the conformance suite above all.
"""

import zlib

import numpy as np
import pytest
import jax.numpy as jnp


def randn(shape, dtype=jnp.float32, seed=0):
    """Deterministic standard-normal array (the canonical test matrix)."""
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def gaussian(m, n, seed=0, dtype=jnp.float32):
    """Two-dim convenience wrapper over :func:`randn`."""
    return randn((m, n), dtype=dtype, seed=seed)


class MatrixFactory:
    """Deterministic generators for numerically *shaped* test matrices.

    All generators build A = U diag(s) V^T from seeded Haar-ish factors
    (QR of Gaussians), so the singular spectrum — what QR accuracy
    actually depends on — is exact and chosen, not luck of the draw.
    """

    def __init__(self, base_seed: int = 0):
        self.base_seed = base_seed

    def _rng(self, seed):
        return np.random.default_rng(
            self.base_seed if seed is None else (self.base_seed, seed))

    def gaussian(self, m, n, seed=None, dtype=jnp.float32):
        return jnp.asarray(self._rng(seed).standard_normal((m, n)), dtype)

    def _svd_matrix(self, m, n, s, seed, dtype):
        rng = self._rng(seed)
        k = len(s)
        u, _ = np.linalg.qr(rng.standard_normal((m, k)))
        v, _ = np.linalg.qr(rng.standard_normal((n, k)))
        return jnp.asarray(u @ np.diag(s) @ v.T, dtype)

    def well_conditioned(self, m, n, cond=100.0, seed=None,
                         dtype=jnp.float32):
        """Full-rank with log-spaced singular values in [1/cond, 1]."""
        k = min(m, n)
        s = np.logspace(0.0, -np.log10(cond), k) if k > 1 else np.ones(1)
        return self._svd_matrix(m, n, s, seed, dtype)

    def graded(self, m, n, cond=1e3, seed=None, dtype=jnp.float32):
        """Geometrically graded spectrum — the moderate-conditioning
        stress case (CQR2-style refinement territory)."""
        return self.well_conditioned(m, n, cond=cond, seed=seed, dtype=dtype)

    def rank_deficient(self, m, n, rank=None, seed=None, dtype=jnp.float32):
        """Exact rank deficiency: min(m, n) - rank singular values are 0."""
        k = min(m, n)
        rank = k // 2 if rank is None else rank
        s = np.zeros(k)
        s[:rank] = np.logspace(0.0, -1.0, max(rank, 1))[:rank]
        return self._svd_matrix(m, n, s, seed, dtype)


@pytest.fixture
def matrices(request):
    """Per-test :class:`MatrixFactory`, seeded from the test's node id —
    deterministic for a given test, decorrelated across tests."""
    return MatrixFactory(zlib.adler32(request.node.nodeid.encode()))
