"""QR serving layer: bucketing properties, service correctness, and
plan-cache behavior.

The load-bearing claims, each pinned by a test here or in the
conformance suite:

  * every request lands in exactly ONE bucket, and the per-dimension
    waste cap is honored whenever achievable at tile granularity
    (property tests over random request mixes);
  * serving answers equal the per-request path's answers — batched
    bitwise parity lives in test_conformance.py; here the end-to-end
    service (pad -> batch -> dispatch -> unpad) meets the numerical bar
    on heterogeneous mixes, both modes, both lowerings;
  * steady-state serving performs ZERO recompilations (the plan cache's
    compile counter is flat across repeated identical traffic);
  * the plan cache is a real LRU: hits refresh recency, evictions hit
    the least-recently-used plan, counters expose all of it.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis_compat import given, settings, st
from repro.serving import (
    BucketKey, BucketingPolicy, QRService, bucket_key, bucketize, pad_batch,
    pad_dim, pow2ish_edges)

# ------------------------------------------------------------- bucketing


@given(tile=st.sampled_from([8, 16, 32, 64]), d=st.integers(1, 5000),
       waste=st.floats(0.05, 0.5))
def test_pad_dim_properties(tile, d, waste):
    """Padded extent is a tile multiple >= max(d, tile); it is either a
    pow2-ish edge within the waste cap or the tile-granularity fallback;
    and the cap is honored whenever tile granularity can honor it."""
    e = pad_dim(d, tile=tile, max_waste=waste)
    assert e >= d and e >= tile and e % tile == 0
    tiled_up = -(-d // tile) * tile
    assert e == tiled_up or ((e - d) / e <= waste
                             and e in pow2ish_edges(tile, d))
    if (tiled_up - d) / tiled_up <= waste:
        assert (e - d) / e <= waste, \
            f"cap achievable at tile granularity but violated: {e} for {d}"


def test_pad_dim_monotone():
    """Bucket edges never cross: a larger matrix never gets a smaller
    bucket (required for the bucket count to stay logarithmic)."""
    for tile, waste in [(16, 0.25), (32, 0.25), (8, 0.1)]:
        pads = [pad_dim(d, tile=tile, max_waste=waste)
                for d in range(1, 700)]
        assert all(a <= b for a, b in zip(pads, pads[1:]))


def test_pow2ish_edges_ladder():
    assert pow2ish_edges(32, 200) == (32, 64, 96, 128, 192, 256)
    # consecutive ratio <= 1.5 from the third edge on
    edges = pow2ish_edges(16, 10000)
    ratios = [b / a for a, b in zip(edges[2:], edges[3:])]
    assert max(ratios) <= 1.5


def test_pad_batch_pow2_capped():
    assert [pad_batch(b, max_batch=8) for b in (1, 2, 3, 4, 5, 8, 9, 100)] \
        == [1, 2, 4, 4, 8, 8, 8, 8]
    with pytest.raises(ValueError):
        pad_batch(0, max_batch=8)


def test_policy_and_key_validation():
    with pytest.raises(ValueError):
        BucketingPolicy(tile=0)
    with pytest.raises(ValueError):
        BucketingPolicy(max_waste=1.0)
    with pytest.raises(ValueError):
        BucketKey(m=32, n=32, dtype="float32", mode="full")


@dataclasses.dataclass
class _Req:
    shape: tuple
    dtype: str
    mode: str


@given(seed=st.integers(0, 10_000), nreq=st.integers(1, 40))
def test_every_request_lands_in_exactly_one_bucket(seed, nreq):
    """bucketize partitions the request stream: every request appears
    exactly once, in the bucket bucket_key maps it to."""
    rng = np.random.default_rng(seed)
    policy = BucketingPolicy(tile=16, max_waste=0.3, max_batch=8)
    reqs = [_Req(shape=(int(rng.integers(1, 400)), int(rng.integers(1, 400))),
                 dtype=str(rng.choice(["float32", "float64"])),
                 mode=str(rng.choice(["reduced", "r"])))
            for _ in range(nreq)]
    buckets = bucketize(reqs, policy)
    seen = []
    for key, members in buckets.items():
        for r in members:
            assert bucket_key(*r.shape, r.dtype, r.mode, policy) == key
            assert key.m >= r.shape[0] and key.n >= r.shape[1]
            seen.append(id(r))
    assert sorted(seen) == sorted(id(r) for r in reqs)


# ------------------------------------------------------------ the service


def _check_qr(a, q, r, tol=2e-4):
    m, n = a.shape
    k = min(m, n)
    q, r = np.asarray(q), np.asarray(r)
    assert q.shape == (m, k) and r.shape == (k, n)
    assert np.abs(q @ r - a).max() <= tol
    assert np.abs(q.T @ q - np.eye(k, dtype=a.dtype)).max() <= tol
    assert np.abs(np.tril(r[:, :k], -1)).max() == 0.0


@pytest.fixture
def service():
    return QRService(policy=BucketingPolicy(tile=16, max_batch=4),
                     use_kernel=False)


def test_heterogeneous_mix_reduced(service):
    """Square / tall / wide / off-tile requests through one flush; every
    answer is the unpadded factorization of ITS matrix."""
    rng = np.random.default_rng(0)
    shapes = [(48, 48), (96, 32), (20, 50), (37, 23), (48, 48), (45, 45)]
    arrs = [rng.standard_normal(s).astype(np.float32) for s in shapes]
    results = service.submit_many(arrs)
    assert len(results) == len(arrs)
    for a, res in zip(arrs, results):
        _check_qr(a, res.q, res.r)
    stats = service.stats()
    assert stats["matrices_served"] == len(arrs)
    assert stats["requests"] == len(arrs)
    assert stats["dispatches"] >= 1


def test_r_mode(service):
    rng = np.random.default_rng(1)
    arrs = [rng.standard_normal((40, 24)).astype(np.float32)
            for _ in range(3)]
    results = service.submit_many(arrs, mode="r")
    for a, res in zip(arrs, results):
        assert res.q is None
        r = np.asarray(res.r)
        assert r.shape == (24, 24)
        assert np.abs(np.tril(r, -1)).max() == 0.0
        assert np.abs(r.T @ r - a.T @ a).max() <= 2e-3 * np.abs(a.T @ a).max()


def test_submit_flush_rids(service):
    """flush keys results by rid; interleaved modes coexist."""
    rng = np.random.default_rng(2)
    a, b = (rng.standard_normal((32, 32)).astype(np.float32)
            for _ in range(2))
    ra = service.submit(a)
    rb = service.submit(b, mode="r")
    out = service.flush()
    assert set(out) == {ra, rb}
    _check_qr(a, out[ra].q, out[ra].r)
    assert out[rb].q is None
    assert service.flush() == {}  # queue drained


def test_ragged_bucket_padding(service):
    """Different true shapes sharing one bucket: each slice's answer is
    its own unpadded factorization (zero padding is numerically free)."""
    rng = np.random.default_rng(3)
    shapes = [(64, 48), (60, 40), (57, 33)]  # all bucket to (64, 48)
    arrs = [rng.standard_normal(s).astype(np.float32) for s in shapes]
    results = service.submit_many(arrs)
    for a, res in zip(arrs, results):
        _check_qr(a, res.q, res.r)
    stats = service.stats()
    assert stats["dispatches"] == 1, "one bucket must mean one dispatch"
    assert stats["padded_slots"] == 1  # batch 3 -> padded batch 4
    assert stats["bucket_fill_ratio"] == pytest.approx(3 / 4)


def test_max_batch_chunking(service):
    """A bucket larger than max_batch splits into full chunks."""
    rng = np.random.default_rng(4)
    arrs = [rng.standard_normal((32, 32)).astype(np.float32)
            for _ in range(6)]  # max_batch=4 -> chunks of 4 and 2
    results = service.submit_many(arrs)
    for a, res in zip(arrs, results):
        _check_qr(a, res.q, res.r)
    assert service.stats()["dispatches"] == 2
    assert service.stats()["padded_slots"] == 0  # 4 and 2 both pow2


def test_kernel_megakernel_serving_path():
    """The Pallas serving path (interpret on CPU): one bucket, batched
    megakernel dispatch, same numerical bar."""
    rng = np.random.default_rng(5)
    svc = QRService(policy=BucketingPolicy(tile=16, max_batch=4),
                    use_kernel=True, dispatch_mode="megakernel")
    arrs = [rng.standard_normal((48, 32)).astype(np.float32)
            for _ in range(2)]
    for a, res in zip(arrs, svc.submit_many(arrs)):
        _check_qr(a, res.q, res.r)
    assert svc.stats()["dispatches"] == 1


def test_submit_validation(service):
    with pytest.raises(ValueError):
        service.submit(np.zeros((3, 3, 3), np.float32))
    with pytest.raises(ValueError):
        service.submit(np.zeros((3, 3), np.float32), mode="full")
    with pytest.raises(ValueError):
        QRService(cache_size=0)


# ------------------------------------------------------------- plan cache


def test_zero_recompiles_steady_state(service):
    """THE serving acceptance property: once the cache is warm, repeated
    traffic with the same shape mix compiles NOTHING new."""
    rng = np.random.default_rng(6)
    shapes = [(48, 48), (96, 32), (37, 23)]

    def mix():
        return [rng.standard_normal(s).astype(np.float32) for s in shapes]

    service.submit_many(mix())          # cold: compiles happen here
    warm = service.stats()["compiles"]
    assert warm > 0
    for _ in range(3):                  # steady state
        for a, res in zip(*(lambda m: (m, service.submit_many(m)))(mix())):
            _check_qr(a, res.q, res.r)
    stats = service.stats()
    assert stats["compiles"] == warm, \
        f"steady-state recompilation: {stats['compiles']} != {warm}"
    assert stats["cache_hits"] >= 3 * len(shapes)
    assert stats["cache_hit_rate"] > 0.5


def test_plan_cache_lru_eviction():
    """cache_size bounds resident plans; eviction is least-recently-USED
    (a hit refreshes recency), and the counters say so."""
    rng = np.random.default_rng(7)
    svc = QRService(policy=BucketingPolicy(tile=16, max_batch=4),
                    use_kernel=False, cache_size=2)

    def go(shape):
        svc.submit_many([rng.standard_normal(shape).astype(np.float32)])

    go((32, 32))   # miss, compile  -> cache [A]
    go((64, 32))   # miss, compile  -> cache [A, B]
    go((32, 32))   # hit            -> cache [B, A] (A refreshed)
    go((96, 32))   # miss, compile  -> evicts B -> cache [A, C]
    s = svc.stats()
    assert (s["compiles"], s["cache_hits"], s["cache_evictions"]) == (3, 1, 1)
    assert s["plans_cached"] == 2
    go((32, 32))   # A survived the eviction (it was refreshed)
    assert svc.stats()["cache_hits"] == 2
    go((64, 32))   # B was the LRU victim -> miss, recompile
    s = svc.stats()
    assert s["compiles"] == 4 and s["cache_evictions"] == 2


def test_tuning_refresh_invalidates_plans():
    """A tuning-cache swap must invalidate every resident bucket plan:
    compiled plans bake in routing/dispatch decisions the old cache
    informed, so serving a stale plan under a new cache silently ignores
    the measurements.  The service fingerprints the active cache and
    drops its LRU on change, counting ``plan_invalidations``."""
    from repro.tuning.cache import TuningCache, active_cache, set_active_cache

    rng = np.random.default_rng(11)
    svc = QRService(policy=BucketingPolicy(tile=16, max_batch=4),
                    use_kernel=False)

    def go(shape):
        svc.submit_many([rng.standard_normal(shape).astype(np.float32)])

    prev = active_cache()
    try:
        go((48, 48))
        s = svc.stats()
        assert s["plans_cached"] > 0 and s["plan_invalidations"] == 0
        compiles = s["compiles"]

        go((48, 48))    # same cache: steady state, no invalidation
        assert svc.stats()["compiles"] == compiles
        assert svc.stats()["plan_invalidations"] == 0

        set_active_cache(TuningCache(source="test:refresh"))
        go((48, 48))    # new fingerprint: plans dropped, recompile
        s = svc.stats()
        assert s["plan_invalidations"] == 1
        assert s["compiles"] == compiles + 1

        go((48, 48))    # new cache is now the steady state
        assert svc.stats()["plan_invalidations"] == 1
        assert svc.stats()["compiles"] == compiles + 1
    finally:
        set_active_cache(prev)
