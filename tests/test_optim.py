"""Optimizer tests: QR-Muon (paper technique), Newton-Schulz, AdamW."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.models import forward_train, init_params
from repro.optim import (
    adamw_init, adamw_update, is_muon_param, muon_init, muon_update,
    newton_schulz_orthogonalize, qr_orthogonalize_2d, warmup_cosine,
)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("shape", [(64, 64), (256, 64), (64, 256), (96, 40),
                                   (40, 96), (130, 50)])
def test_qr_orthogonalize_exact(shape):
    m = jax.random.normal(KEY, shape, jnp.float32)
    q = qr_orthogonalize_2d(m)
    assert q.shape == shape
    k = min(shape)
    gram = q.T @ q if shape[0] >= shape[1] else q @ q.T
    np.testing.assert_allclose(np.asarray(gram), np.eye(k), atol=2e-4)


def test_qr_vs_ns_same_column_space():
    """Both orthogonalizers target the momentum's column-space projector
    — and the QR factor is EXACT where Newton-Schulz only approximates
    (singular values ~[0.7, 1.2]): the QR-Muon selling point."""
    m = jax.random.normal(KEY, (128, 32), jnp.float32)
    qq = qr_orthogonalize_2d(m)
    qn = newton_schulz_orthogonalize(m, steps=12)
    u, _, _ = np.linalg.svd(np.asarray(m), full_matrices=False)
    proj = u @ u.T
    err_qr = np.abs(np.asarray(qq @ qq.T) - proj).max()
    err_ns = np.abs(np.asarray(qn @ qn.T) - proj).max()
    assert err_qr < 1e-5
    assert err_ns < 0.2
    assert err_qr < err_ns / 100


def test_ns_orthogonality_approximate():
    m = jax.random.normal(KEY, (256, 64), jnp.float32)
    q = newton_schulz_orthogonalize(m)
    # NS5 with Muon coefficients is approximately orthogonal by design
    s = jnp.linalg.svd(q, compute_uv=False)
    assert float(jnp.max(s)) < 1.3 and float(jnp.min(s)) > 0.3


def test_is_muon_param_routing():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    params = init_params(KEY, cfg)
    kinds = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        names = tuple(str(getattr(k, "key", k)) for k in path)
        kinds[names] = is_muon_param(path, leaf)
    # embeddings and router are excluded, expert stacks included
    assert not any(v for k, v in kinds.items() if "table" in k)
    assert not any(v for k, v in kinds.items() if "router" in k)
    assert any(v for k, v in kinds.items() if "gate_w" in k)
    assert any(v for k, v in kinds.items() if "wq" in k)
    # norms and biases excluded (ndim < 2)
    assert not any(v for k, v in kinds.items() if k[-1] == "g")


@pytest.mark.parametrize("opt", ["muon-qr", "muon-ns", "adamw"])
def test_optimizers_reduce_loss(opt):
    cfg = get_smoke_config("olmo-1b")
    params = init_params(KEY, cfg)
    batch = {
        "tokens": jax.random.randint(KEY, (2, 64), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(KEY, 1), (2, 64), 0,
                                     cfg.vocab_size),
    }

    def loss_fn(p):
        lg, aux = forward_train(p, batch, cfg)
        lp = jax.nn.log_softmax(lg.astype(jnp.float32), -1)
        return -jnp.take_along_axis(lp, batch["labels"][..., None], -1).mean() + aux

    if opt == "adamw":
        state = adamw_init(params)
        upd = lambda g, s, p: adamw_update(g, s, p, lr=1e-3)
    else:
        state = muon_init(params)
        method = opt.split("-")[1]
        upd = lambda g, s, p: muon_update(g, s, p, lr=0.02, method=method)
    stepf = jax.jit(lambda p, s: upd(jax.grad(loss_fn)(p), s, p))
    l0 = float(loss_fn(params))
    for _ in range(5):
        params, state = stepf(params, state)
    l1 = float(loss_fn(params))
    assert l1 < l0 - 0.5, (opt, l0, l1)


def test_muon_update_is_orthogonal_direction():
    """The applied muon update direction must be (scaled) orthonormal."""
    params = {"w": jax.random.normal(KEY, (64, 32), jnp.float32)}
    grads = {"w": jax.random.normal(jax.random.fold_in(KEY, 1), (64, 32),
                                    jnp.float32)}
    state = muon_init(params)
    new_params, _ = muon_update(grads, state, params, lr=1.0, momentum=0.0,
                                nesterov=False, method="qr")
    delta = (params["w"] - new_params["w"])  # lr * scale * O
    scale = np.sqrt(max(1.0, 64 / 32))
    o = np.asarray(delta) / scale
    np.testing.assert_allclose(o.T @ o, np.eye(32), atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_qr_orthogonalize_respects_param_dtype(dtype):
    """Regression: the orthogonalizer used to hardcode an fp32 plan, so
    low-precision storage params silently changed dtype through it.  It
    must return Q in the param dtype while ACCUMULATING in fp32 — the
    result must match the fp32 factorization of the fp32-cast input to
    storage-rounding error, not fp16/bf16-accumulation error."""
    m = jax.random.normal(KEY, (96, 40), jnp.float32).astype(dtype)
    q = qr_orthogonalize_2d(m)
    assert q.dtype == dtype
    # fp32 accumulation: q is the fp32 result rounded ONCE to storage.
    q_ref = qr_orthogonalize_2d(m.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(q, np.float32), np.asarray(q_ref.astype(dtype),
                                              np.float32), rtol=0, atol=0)
    # Orthogonality at storage precision.
    g = np.asarray(q.astype(jnp.float32))
    eps = float(jnp.finfo(dtype).eps)
    assert np.abs(g.T @ g - np.eye(40)).max() < 10 * eps


def test_qr_orthogonalize_f64_keeps_f64():
    """promote_types(f64, f32) = f64: double-precision params must not
    round-trip through fp32 (x64 off: jnp silently yields f32 arrays, so
    the assert still checks dtype-in == dtype-out)."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal((64, 24)))
    q = qr_orthogonalize_2d(x)
    assert q.dtype == x.dtype
    gram = np.asarray(q.astype(jnp.float64)).T @ np.asarray(
        q.astype(jnp.float64))
    assert np.abs(gram - np.eye(24)).max() < 1e-6


def test_warmup_cosine_schedule():
    lr = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10,
                              total_steps=100)) for s in range(101)]
    assert lr[0] == 0.0 and abs(lr[10] - 1.0) < 1e-6
    assert lr[100] == pytest.approx(0.1, abs=1e-6)
    assert all(a >= b - 1e-9 for a, b in zip(lr[10:], lr[11:]))  # decays


@settings(max_examples=10, deadline=None)
@given(m=st.integers(16, 96), n=st.integers(8, 48), seed=st.integers(0, 999))
def test_property_qr_orthogonalize(m, n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, n), jnp.float32)
    q = qr_orthogonalize_2d(x)
    k = min(m, n)
    gram = q.T @ q if m >= n else q @ q.T
    assert float(jnp.linalg.norm(gram - jnp.eye(k))) < 1e-3
