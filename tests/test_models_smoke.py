"""Per-architecture smoke tests (assignment deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED config
of the same family, run one forward/train step on CPU, assert output
shapes and no NaNs.  Plus cross-mode consistency: teacher-forced forward,
prefill, and token-by-token decode must agree (fp32, capacity-unconstrained
MoE).
"""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import (
    forward_decode, forward_prefill, forward_train, init_caches, init_params,
    param_count,
)
from repro.models.layers import embed

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b, s, key=KEY, dtype=jnp.bfloat16):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0,
                                cfg.vocab_size)
    if cfg.embedding_input:
        emb = jax.random.normal(jax.random.fold_in(key, 2),
                                (b, s, cfg.d_model), dtype)
        return {"embeds": emb, "labels": labels}
    return {"tokens": toks, "labels": labels}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected
    moe_expected = {
        "jamba-v0.1-52b": (16, 2), "qwen2-moe-a2.7b": (60, 4),
        "phi3.5-moe-42b-a6.6b": (16, 2),
    }
    if arch in moe_expected:
        assert (cfg.moe.num_experts, cfg.moe.top_k) == moe_expected[arch]
    else:
        assert cfg.moe is None


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: forward + one grad step, shapes + finiteness."""
    cfg = get_smoke_config(arch)
    b, s = 2, 64
    params = init_params(KEY, cfg)
    batch = _batch(cfg, b, s)

    logits, aux = jax.jit(lambda p, bt: forward_train(p, bt, cfg))(params, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))

    def loss_fn(p):
        lg, aux = forward_train(p, batch, cfg)
        lp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, batch["labels"][..., None], axis=-1).mean()
        return nll + aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    b, s_max = 2, 64
    params = init_params(KEY, cfg)
    caches = init_caches(cfg, b, s_max)
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, new_caches = jax.jit(
        lambda p, t, c: forward_decode(p, t, cfg, c, jnp.int32(3))
    )(params, tok, caches)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_prefill_decode_consistency(arch):
    """Teacher-forced forward == prefill + step-by-step decode (fp32)."""
    b, s, s0 = 1, 32, 24
    cfg = get_smoke_config(arch).scaled(dtype="float32")
    if cfg.moe is not None:
        cfg = cfg.scaled(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    if cfg.embedding_input:
        embeds = embed(params["embed"], toks, dtype=jnp.float32)
        full_batch, pre_batch = {"embeds": embeds}, {"embeds": embeds[:, :s0]}
    else:
        full_batch, pre_batch = {"tokens": toks}, {"tokens": toks[:, :s0]}

    full_logits, _ = forward_train(params, full_batch, cfg)
    plog, caches = forward_prefill(params, pre_batch, cfg)
    np.testing.assert_allclose(np.asarray(plog[:, -1]),
                               np.asarray(full_logits[:, s0 - 1]), atol=2e-4)

    def pad(entry):
        if "k" not in entry:
            return entry
        f = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, s - a.shape[2]),
                                  (0, 0), (0, 0)))
        return {"k": f(entry["k"]), "v": f(entry["v"])}

    cur = tuple(pad(e) for e in caches)
    for t in range(s0, s):
        dlog, cur = forward_decode(params, toks[:, t:t + 1], cfg, cur,
                                   jnp.int32(t))
        np.testing.assert_allclose(np.asarray(dlog[:, 0]),
                                   np.asarray(full_logits[:, t]), atol=2e-4)


def test_local_window_masks_long_range():
    """gemma2 local layers must not see past the window."""
    cfg = get_smoke_config("gemma2-9b").scaled(dtype="float32", window=8,
                                               n_layers=2)
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 40), 0, cfg.vocab_size)
    base, _ = forward_train(params, {"tokens": toks}, cfg)
    # perturb a token far outside every window of the final position
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    pert, _ = forward_train(params, {"tokens": toks2}, cfg)
    # global layer still sees it; but positions within the first window
    # after it change, later-position *local-only* information flow is
    # bounded: verify causality instead for the shared stack:
    np.testing.assert_allclose(np.asarray(base[:, 0] != pert[:, 0]).any(), True)


@pytest.mark.parametrize("arch", ARCHS)
def test_causality(arch):
    """Perturbing a future token never changes past logits."""
    cfg = get_smoke_config(arch).scaled(dtype="float32")
    params = init_params(KEY, cfg)
    b, s, t_cut = 1, 32, 16
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    toks2 = toks.at[0, t_cut + 4].set((toks[0, t_cut + 4] + 7) % cfg.vocab_size)
    if cfg.embedding_input:
        params_e = params
        mk = lambda tk: {"embeds": embed(params_e["embed"], tk, dtype=jnp.float32)}
    else:
        mk = lambda tk: {"tokens": tk}
    a, _ = forward_train(params, mk(toks), cfg)
    c, _ = forward_train(params, mk(toks2), cfg)
    np.testing.assert_allclose(np.asarray(a[:, :t_cut]), np.asarray(c[:, :t_cut]),
                               atol=1e-5)


def test_param_counts_full_configs_in_class():
    """Full configs land in the advertised parameter class (structural
    check via analytic counting — no allocation)."""
    import repro.models.transformer as tr

    expected_range = {
        "olmo-1b": (0.9e9, 1.6e9),
        "smollm-135m": (0.10e9, 0.17e9),
        "qwen2.5-32b": (28e9, 36e9),
        "gemma2-9b": (8e9, 11e9),
        "jamba-v0.1-52b": (45e9, 58e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 45e9),
        "chameleon-34b": (30e9, 38e9),
        "musicgen-large": (1.5e9, 2.6e9),
        "qwen2-moe-a2.7b": (12e9, 16e9),
        "xlstm-1.3b": (1.0e9, 2.4e9),
    }
    for arch, (lo, hi) in expected_range.items():
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda k: tr.init_params(k, cfg),
                                jax.random.PRNGKey(0))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]"
