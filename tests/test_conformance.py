"""Registry-wide numerical conformance suite.

Every method in the planner registry — current and future — is held to
the SAME numerical bar, with no per-method tolerance carve-outs:

    * ||Q^T Q - I||_max        <= tol(dtype, shape)
    * ||A - Q R||_F / ||A||_F  <= tol(dtype, shape)
    * R strictly upper triangular (exact zeros below the diagonal)
    * sign-fix convention: cfg.sign_fix=True  =>  diag(R) >= 0

across square / tall / wide / non-multiple-of-block shapes and
float32/float64, plus the kernel paths (use_kernel=True, interpret mode
on CPU) of every kernel-backed method.  The method list is read from the
registry at collection time, so a newly registered backend inherits the
bar for free.

Shape skips are *capability* skips only (the planner's own checks:
TSQR's 4:1 aspect, geqrf_fori's divisibility, thin-Q-only methods in
full mode) — never looser tolerances.

Under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
multi-device job) the identical assertions exercise ``sharded_tiled``'s
real shard_map path; on one device it degenerates to the tiled backend.
"""

import pytest
import jax
import jax.numpy as jnp

from repro.core.plan import QRConfig, available_methods, plan

METHODS = available_methods()
BLOCK = 8

# (label, (m, n)) — square / tall (TSQR-eligible) / wide / off-block.
SHAPES = [
    ("square", (32, 32)),
    ("tall", (96, 16)),
    ("wide", (16, 40)),
    ("offblock", (37, 23)),
]
DTYPES = ["float32", "float64"]


def _tol(dtype, m, n) -> float:
    """One tolerance rule for every method: 100 eps max(m, n)."""
    return 100.0 * float(jnp.finfo(dtype).eps) * max(m, n)


def _plan_or_skip(shape, dtype, cfg):
    """Planner capability checks double as the conformance skip rule."""
    try:
        return plan(shape, dtype, cfg)
    except ValueError as e:
        pytest.skip(f"capability: {e}")


def _x64():
    return jax.experimental.enable_x64()


class _nullctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def _ctx(dtype):
    return _x64() if dtype == "float64" else _nullctx()


def _assert_conformance(a, q, r, tol):
    m, n = a.shape
    k = min(m, n)
    assert q.shape[-1] == r.shape[-2]
    orth = float(jnp.abs(q.T @ q - jnp.eye(q.shape[1], dtype=a.dtype)).max())
    rec = float(jnp.linalg.norm(q @ r - a) / max(float(jnp.linalg.norm(a)), 1e-30))
    assert orth <= tol, f"||Q^T Q - I|| = {orth} > {tol}"
    assert rec <= tol, f"||A - QR||/||A|| = {rec} > {tol}"
    assert float(jnp.abs(jnp.tril(r[:, :k], -1)).max()) == 0.0, \
        "R not strictly upper triangular"


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("label,shape", SHAPES, ids=[s[0] for s in SHAPES])
@pytest.mark.parametrize("method", METHODS)
def test_reduced_conformance(method, label, shape, dtype, matrices):
    """(Q, R) in reduced mode meets the shared bar for every method."""
    m, n = shape
    with _ctx(dtype):
        a = matrices.well_conditioned(m, n, cond=100.0, dtype=dtype)
        solver = _plan_or_skip(a.shape, a.dtype,
                               QRConfig(method=method, block=BLOCK))
        q, r = solver.solve(a)
        assert q.shape == (m, min(m, n)) and r.shape == (min(m, n), n)
        _assert_conformance(a, q, r, _tol(dtype, m, n))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("label,shape", SHAPES, ids=[s[0] for s in SHAPES])
@pytest.mark.parametrize("method", METHODS)
def test_r_mode_conformance(method, label, shape, dtype, matrices):
    """R-only mode: triangular, and R^T R recovers the Gram matrix."""
    m, n = shape
    with _ctx(dtype):
        a = matrices.well_conditioned(m, n, cond=100.0, dtype=dtype)
        solver = _plan_or_skip(a.shape, a.dtype,
                               QRConfig(method=method, block=BLOCK, mode="r"))
        r = solver.solve(a)
        k = min(m, n)
        assert r.shape == (k, n)
        assert float(jnp.abs(jnp.tril(r[:, :k], -1)).max()) == 0.0
        gram = float(jnp.linalg.norm(r.T @ r - a.T @ a)
                     / max(float(jnp.linalg.norm(a.T @ a)), 1e-30))
        assert gram <= _tol(dtype, m, n), gram


@pytest.mark.parametrize("label,shape", SHAPES, ids=[s[0] for s in SHAPES])
@pytest.mark.parametrize("method", METHODS)
def test_full_mode_conformance(method, label, shape, matrices):
    """Full (m x m) Q where the method supports it — same bar."""
    m, n = shape
    a = matrices.well_conditioned(m, n, cond=100.0)
    solver = _plan_or_skip(
        a.shape, a.dtype, QRConfig(method=method, block=BLOCK, mode="full"))
    q, r = solver.solve(a)
    assert q.shape == (m, m) and r.shape == (m, n)
    _assert_conformance(a, q, r, _tol("float32", m, n))


@pytest.mark.parametrize("method", METHODS)
def test_sign_fix_convention(method, matrices):
    """sign_fix=True => diag(R) >= 0, with Q R unchanged as a product."""
    a = matrices.well_conditioned(48, 24, cond=50.0)
    solver = _plan_or_skip(a.shape, a.dtype,
                           QRConfig(method=method, block=BLOCK, sign_fix=True))
    q, r = solver.solve(a)
    assert bool((jnp.diagonal(r) >= 0).all()), "sign-fix convention violated"
    _assert_conformance(a, q, r, _tol("float32", 48, 24))


@pytest.mark.parametrize("method", METHODS)
def test_graded_spectrum_conformance(method, matrices):
    """cond = 1e3 graded singular values: same tolerances still hold
    (refinement/formq must absorb moderate ill-conditioning)."""
    a = matrices.graded(64, 32, cond=1e3)
    solver = _plan_or_skip(a.shape, a.dtype,
                           QRConfig(method=method, block=BLOCK))
    q, r = solver.solve(a)
    _assert_conformance(a, q, r, _tol("float32", 64, 32))


@pytest.mark.parametrize("method", METHODS)
def test_rank_deficient_finite_and_triangular(method, matrices):
    """Exactly rank-deficient input: every method must stay finite and
    keep R triangular (Q orthogonality is method-defined here — solve-
    based thin-Q paths clamp the singular pivots)."""
    a = matrices.rank_deficient(48, 16, rank=8)
    solver = _plan_or_skip(a.shape, a.dtype,
                           QRConfig(method=method, block=BLOCK))
    q, r = solver.solve(a)
    assert bool(jnp.isfinite(q).all()) and bool(jnp.isfinite(r).all())
    assert float(jnp.abs(jnp.tril(r[:, :16], -1)).max()) == 0.0


from repro.core.plan import get_method  # noqa: E402

_KERNEL_METHODS = [m for m in METHODS if get_method(m).kernel_backed]


@pytest.mark.parametrize("method", _KERNEL_METHODS)
def test_kernel_path_conformance(method, matrices):
    """use_kernel=True (Pallas, interpret mode on CPU) meets the same
    bar as the jnp path for every kernel-backed method."""
    a = matrices.well_conditioned(64, 32, cond=100.0)
    solver = _plan_or_skip(
        a.shape, a.dtype,
        QRConfig(method=method, block=BLOCK, use_kernel=True))
    q, r = solver.solve(a)
    _assert_conformance(a, q, r, _tol("float32", 64, 32))


@pytest.mark.parametrize("dispatch_mode", ["wavefront", "megakernel"])
@pytest.mark.parametrize("method", METHODS)
def test_engine_path_bitwise_vs_oracle(method, dispatch_mode, matrices):
    """Every registry method executing through the wavefront macro-op
    engine (kernel_policy == "macro_ops" — today `tiled` and
    `sharded_tiled`, plus any future engine-backed backend for free)
    must produce BITWISE-identical (Q, R) on BOTH kernel dispatch modes
    (per-level wavefront dispatches AND the single-call megakernel over
    the scalar-prefetched task table; interpret mode on CPU) and its
    ``use_kernel=False`` jnp-oracle lowering.  Not a tolerance —
    equality."""
    if get_method(method).kernel_policy != "macro_ops":
        pytest.skip("capability: method does not execute through "
                    "repro.core.engine")
    a = matrices.well_conditioned(48, 32, cond=100.0)
    sk = _plan_or_skip(a.shape, a.dtype,
                       QRConfig(method=method, block=BLOCK, use_kernel=True,
                                dispatch_mode=dispatch_mode))
    sj = _plan_or_skip(a.shape, a.dtype,
                       QRConfig(method=method, block=BLOCK, use_kernel=False))
    qk, rk = sk.solve(a)
    qj, rj = sj.solve(a)
    assert bool((qk == qj).all()), \
        f"{dispatch_mode} engine Q != oracle Q (bitwise)"
    assert bool((rk == rj).all()), \
        f"{dispatch_mode} engine R != oracle R (bitwise)"


# --------------------------------------------- batched engine (serving hook)

from repro.core import engine  # noqa: E402
from repro.core.tilegraph import _split_tiles  # noqa: E402


@pytest.mark.parametrize("dispatch_mode", [None, "wavefront", "megakernel"],
                         ids=["jnp", "wavefront", "megakernel"])
@pytest.mark.parametrize("batch", [1, 3])
@pytest.mark.parametrize("grid", [(3, 3), (3, 2), (2, 3)],
                         ids=["square", "tall", "wide"])
def test_factor_tiles_batched_bitwise_per_slice(dispatch_mode, batch, grid,
                                                matrices):
    """The serving contract: ``factor_tiles_batched`` over a stacked
    workspace is BITWISE-identical per slice to B independent
    ``factor_tiles`` runs — on the jnp oracle, the wavefront kernels,
    and the batched megakernel (interpret on CPU).  Slices include
    ragged bucket padding (odd slices carry a smaller matrix zero-padded
    to the bucket shape, exactly what QRService stages) and the B=1
    degeneracy.  Not a tolerance — equality."""
    p, q = grid
    nb = BLOCK
    use_kernel = dispatch_mode is not None
    mats = []
    for b in range(batch):
        mr = p * nb - (b % 2) * (nb // 2)  # ragged rows/cols on odd slices
        nr = q * nb - (b % 2) * (nb // 2)
        a = matrices.well_conditioned(mr, nr, cond=100.0)
        mats.append(jnp.zeros((p * nb, q * nb), a.dtype).at[:mr, :nr].set(a))
    tiles = jnp.stack([_split_tiles(a, p, q, nb) for a in mats])
    singles = [engine.factor_tiles(tiles[b], p=p, q=q, nb=nb,
                                   use_kernel=use_kernel,
                                   dispatch_mode=dispatch_mode)
               for b in range(batch)]
    batched = engine.factor_tiles_batched(tiles, p=p, q=q, nb=nb,
                                          use_kernel=use_kernel,
                                          dispatch_mode=dispatch_mode)
    for b, single in enumerate(singles):
        for field, bat, ref in zip(engine.FactorState._fields, batched,
                                   single):
            assert bool((bat[b] == ref).all()), \
                f"slice {b} field {field} differs from independent run " \
                f"(dispatch_mode={dispatch_mode})"


def test_registry_has_all_expected_methods():
    """The suite is only meaningful if it sweeps the full registry."""
    for name in ("geqr2", "geqr2_ht", "geqrf", "geqrf_ht", "tsqr", "tiled",
                 "sharded_tiled", "degenerate"):
        assert name in METHODS, f"{name} missing from registry"


# --------------------------------------------- degenerate (zero-dim) parity

_DEGENERATE_SHAPES = [(0, 5), (5, 0), (0, 0)]


@pytest.mark.parametrize("mode", ["reduced", "r", "full"])
@pytest.mark.parametrize("shape", _DEGENERATE_SHAPES,
                         ids=[f"{m}x{n}" for m, n in _DEGENERATE_SHAPES])
def test_degenerate_shapes_match_linalg_qr(shape, mode):
    """PR-8 bugfix: zero-dim inputs used to crash the planner where
    ``jnp.linalg.qr`` succeeds.  The trivial route must match the oracle
    exactly (shapes AND values — identity Q, zero R)."""
    a = jnp.zeros(shape, jnp.float32)
    solver = plan(a.shape, a.dtype, QRConfig(mode=mode))
    assert solver.config.method == "degenerate"
    oracle_mode = {"reduced": "reduced", "r": "r", "full": "complete"}[mode]
    ref = jnp.linalg.qr(a, mode=oracle_mode)
    if mode == "r":
        r = solver.solve(a)
        assert r.shape == ref.shape and bool((r == ref).all())
    else:
        q, r = solver.solve(a)
        assert q.shape == ref[0].shape and r.shape == ref[1].shape
        assert bool((q == ref[0]).all()) and bool((r == ref[1]).all())


def test_degenerate_capability_guard_skips_nonempty():
    """Explicit method='degenerate' on a nonempty shape is a capability
    error (so the registry-wide suites above skip it, same as tsqr's
    aspect guard)."""
    with pytest.raises(ValueError):
        plan((32, 32), jnp.float32, QRConfig(method="degenerate"))
