"""Correctness of the HT/MHT/blocked QR core against LAPACK semantics.

Paper claims under test:
  C1: MHT is numerically identical to classical HT (same reflectors, same
      R) — only the update dataflow changes (§4).
  C4: blocked (WY) variants produce the same factorization as unblocked.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.core import (
    QRConfig,
    apply_q,
    form_q,
    geqr2,
    geqr2_ht,
    geqrf,
    house_vector,
    lstsq,
    orthogonalize,
    qr,
    qr_algorithm_eig,
    unpack_r,
)
from repro.core.householder import geqr2_explicit_p

SHAPES = [(8, 8), (16, 8), (12, 5), (33, 17), (32, 32), (64, 48), (48, 64)]

# Shared deterministic matrix factory (tests/conftest.py).
from conftest import gaussian as _rand  # noqa: E402


def _check_qr(a, packed, taus, rtol=3e-5):
    m, n = a.shape
    k = min(m, n)
    q = form_q(packed, taus)
    r = unpack_r(packed, n)
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), atol=rtol * np.linalg.norm(a))
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(k), atol=1e-4)
    assert float(jnp.linalg.norm(jnp.tril(r[:, :k], -1))) == 0.0


@pytest.mark.parametrize("m,n", SHAPES)
@pytest.mark.parametrize("factor", ["geqr2", "geqr2_ht", "explicit_p"])
def test_unblocked_reconstruction(m, n, factor):
    a = _rand(m, n, seed=m * 100 + n)
    fn = {"geqr2": geqr2, "geqr2_ht": geqr2_ht, "explicit_p": geqr2_explicit_p}[factor]
    packed, taus = fn(a)
    _check_qr(a, packed, taus)


@pytest.mark.parametrize("m,n", SHAPES)
def test_mht_identical_to_ht(m, n):
    """C1: the MHT re-arrangement changes the DAG, not the numbers."""
    a = _rand(m, n, seed=m + n)
    p1, t1 = geqr2(a)
    p2, t2 = geqr2_ht(a)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


@pytest.mark.parametrize("m,n", SHAPES)
@pytest.mark.parametrize("block", [4, 8, 32])
@pytest.mark.parametrize("panel_method", ["ht", "mht"])
def test_blocked_matches_unblocked(m, n, block, panel_method):
    a = _rand(m, n, seed=block)
    pb, tb = geqrf(a, block=block, panel_method=panel_method)
    pu, tu = geqr2(a)
    np.testing.assert_allclose(np.asarray(pb), np.asarray(pu), atol=2e-4)
    np.testing.assert_allclose(np.asarray(tb), np.asarray(tu), atol=2e-5)
    _check_qr(a, pb, tb)


@pytest.mark.parametrize("m,n", [(16, 8), (32, 32)])
def test_matches_jnp_linalg_qr(m, n):
    a = _rand(m, n, seed=7)
    q, r = qr(a, config=QRConfig(method="geqrf_ht", block=8))
    qn, rn = jnp.linalg.qr(a)
    s = jnp.sign(jnp.diagonal(r)) * jnp.sign(jnp.diagonal(rn))
    np.testing.assert_allclose(np.asarray(r * s[:, None]), np.asarray(rn), atol=3e-5)
    np.testing.assert_allclose(np.asarray(q * s[None, :]), np.asarray(qn), atol=3e-5)


def test_house_vector_annihilates():
    x = jnp.asarray([3.0, 4.0, 0.0, 12.0], jnp.float32)
    v, tau, beta = house_vector(x, 0)
    h = jnp.eye(4) - tau * jnp.outer(v, v)
    hx = h @ x
    assert abs(float(hx[0]) - float(beta)) < 1e-5
    np.testing.assert_allclose(np.asarray(hx[1:]), 0.0, atol=1e-5)
    assert abs(float(beta)) == pytest.approx(13.0, rel=1e-5)
    assert float(beta) == pytest.approx(-13.0, rel=1e-5)  # -sign(x0)*||x||


def test_house_vector_offset_and_degenerate():
    x = jnp.asarray([5.0, 2.0, 0.0, 0.0], jnp.float32)
    v, tau, beta = house_vector(x, 1)
    assert float(v[0]) == 0.0 and float(v[1]) == 1.0
    # degenerate: nothing to annihilate below offset 1
    assert float(tau) == 0.0
    assert float(beta) == pytest.approx(2.0)


def test_apply_q_transpose_roundtrip():
    a = _rand(24, 10, seed=3)
    packed, taus = geqr2_ht(a)
    c = _rand(24, 6, seed=4)
    back = apply_q(packed, taus, apply_q(packed, taus, c, transpose=True))
    np.testing.assert_allclose(np.asarray(back), np.asarray(c), atol=1e-4)


@pytest.mark.parametrize("m,n", [(8, 8), (16, 8), (12, 5), (32, 32)])
def test_qr_full_mode(m, n):
    """Regression: mode="full" used to return (q, (q, r)) when m == k
    (the ternary bound to the tuple's second element)."""
    from repro.core import QRConfig, plan

    a = _rand(m, n, seed=m * 7 + n)
    out = qr(a, config=QRConfig(method="geqrf_ht", mode="full"))
    assert isinstance(out, tuple) and len(out) == 2
    q, r = out
    assert q.shape == (m, m), "full Q must be m x m"
    assert r.shape == (m, n), "full R must be m x n"
    assert isinstance(r, jnp.ndarray), "R must be an array, not a nested tuple"
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), atol=1e-4)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(m), atol=1e-4)
    # config path produces the identical full factorization
    q2, r2 = plan(a.shape, a.dtype, QRConfig(method="geqrf_ht", mode="full")
                  ).solve(a)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(r2))


def test_orthogonalize_tall_and_wide():
    a = _rand(40, 16, seed=9)
    o = orthogonalize(a)
    np.testing.assert_allclose(np.asarray(o.T @ o), np.eye(16), atol=1e-4)
    ow = orthogonalize(a.T)
    assert ow.shape == (16, 40)
    np.testing.assert_allclose(np.asarray(ow @ ow.T), np.eye(16), atol=1e-4)


def test_orthogonalize_is_deterministic_sign():
    """diag(R)-sign fixing makes the factor continuous in the input."""
    a = _rand(20, 8, seed=11)
    o1 = orthogonalize(a)
    o2 = orthogonalize(a * 1.0001)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-2  # no sign flips


def test_lstsq():
    a = _rand(30, 6, seed=5)
    x_true = _rand(6, 1, seed=6)[:, 0]
    b = a @ x_true
    x = lstsq(a, b)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_true), atol=1e-3)


def test_qr_algorithm_eigenvalues():
    """Paper §1 Application 2: eigenvalues via the QR algorithm."""
    rng = np.random.default_rng(12)
    q, _ = np.linalg.qr(rng.standard_normal((8, 8)))
    lam = np.array([9.0, 7.5, 5.0, 3.2, 2.0, 1.0, 0.5, 0.1])
    a = jnp.asarray(q @ np.diag(lam) @ q.T, jnp.float32)
    ev = qr_algorithm_eig(a, iters=300)
    np.testing.assert_allclose(np.asarray(ev), lam, rtol=2e-3)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(2, 48),
    n=st.integers(2, 32),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-3, 1e3),
)
def test_property_qr_invariants(m, n, seed, scale):
    """Property: for any well-scaled matrix, all methods yield Q R = A with
    orthonormal Q and upper-triangular R, and HT == MHT exactly."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, n)) * scale, jnp.float32)
    p1, t1 = geqr2(a)
    p2, t2 = geqr2_ht(a)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    q = form_q(p2, t2)
    r = unpack_r(p2, n)
    norm = max(float(jnp.linalg.norm(a)), 1e-6)
    assert float(jnp.linalg.norm(q @ r - a)) / norm < 5e-5
    assert float(jnp.linalg.norm(q.T @ q - jnp.eye(min(m, n)))) < 5e-4


@settings(max_examples=15, deadline=None)
@given(m=st.integers(8, 64), n=st.integers(4, 24), block=st.integers(2, 16),
       seed=st.integers(0, 1000))
def test_property_blocked_equals_unblocked(m, n, block, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    pb, tb = geqrf(a, block=block, panel_method="mht")
    pu, tu = geqr2_ht(a)
    np.testing.assert_allclose(np.asarray(pb), np.asarray(pu), atol=5e-4)
