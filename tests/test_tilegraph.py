"""Tiled QR task-graph runtime tests.

Covers the symbolic tile DAG (level counts vs the closed-form wavefront
formula, dependency sanity), the wavefront executor against the
``jnp.linalg.qr`` oracle (including non-multiple-of-tile shapes, wide
inputs and every mode), the Pallas tile-kernel path in interpret mode,
the planner integration, and the extended beta parallelism metric.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import QRConfig, plan, qr
from repro.core.dag import analyze_mht, analyze_tiled
from repro.core.tilegraph import (
    build_tasks,
    levelize,
    task_deps,
    tile_grid,
    tiled_qr,
    wavefront_count,
    wavefronts,
)


# Shared deterministic matrix factory (tests/conftest.py).
from conftest import gaussian as _rand  # noqa: E402


def _check(a, q, r, atol=1e-5):
    m, n = a.shape
    k = min(m, n)
    rec = float(jnp.linalg.norm(q @ r - a) / jnp.linalg.norm(a))
    orth = float(jnp.abs(q.T @ q - jnp.eye(q.shape[1], dtype=a.dtype)).max())
    assert rec <= atol, f"reconstruction {rec} > {atol}"
    assert orth <= atol, f"orthogonality {orth} > {atol}"
    assert float(jnp.linalg.norm(jnp.tril(r[:, :k], -1))) == 0.0


# ------------------------------------------------------------- symbolic DAG

def test_wavefront_count_matches_levelization():
    """Closed form p + 2q - 2 (p >= q) / 3p - 1 (p < q) vs the DAG."""
    for p in range(1, 9):
        for q in range(1, 9):
            assert len(wavefronts(p, q)) == wavefront_count(p, q), (p, q)


def test_task_counts():
    """Task census: r GEQRT, per-step trailing LARFB/TSQRT/SSRFB blocks."""
    for p, q in [(1, 1), (4, 4), (6, 3), (3, 6)]:
        tasks = build_tasks(p, q)
        r = min(p, q)
        by_kind = {}
        for t in tasks:
            by_kind[t.kind] = by_kind.get(t.kind, 0) + 1
        assert by_kind.get("GEQRT", 0) == r
        assert by_kind.get("LARFB", 0) == sum(q - 1 - k for k in range(r))
        assert by_kind.get("TSQRT", 0) == sum(p - 1 - k for k in range(r))
        assert by_kind.get("SSRFB", 0) == sum(
            (p - 1 - k) * (q - 1 - k) for k in range(r))


def test_levels_respect_dependencies():
    """Every task fires strictly after all of its dependencies."""
    for p, q in [(4, 4), (5, 3), (3, 5)]:
        levels = levelize(p, q)
        for t in build_tasks(p, q):
            for d in task_deps(t):
                assert levels[d] < levels[t], (t, d)


def test_wavefront_parallelism_exceeds_one():
    """The DAG must actually expose cross-panel parallelism: some
    wavefront carries tasks from more than one panel step k."""
    wfs = wavefronts(4, 4)
    assert any(len({t.k for t in wf}) > 1 for wf in wfs)
    assert max(len(wf) for wf in wfs) >= 4


def test_tile_grid():
    assert tile_grid(64, 64, 16) == (4, 4)
    assert tile_grid(65, 33, 16) == (5, 3)
    with pytest.raises(ValueError):
        tile_grid(8, 8, 0)
    with pytest.raises(ValueError):
        wavefront_count(0, 3)


# ------------------------------------------------------ executor vs oracle

TILED_SHAPES = [(16, 16, 16), (48, 48, 16), (64, 32, 16), (32, 64, 16),
                (50, 34, 16), (37, 23, 8), (96, 96, 32)]


@pytest.mark.parametrize("m,n,tile", TILED_SHAPES)
def test_tiled_qr_matches_oracle(m, n, tile):
    a = _rand(m, n, seed=m * 100 + n)
    q, r = tiled_qr(a, tile=tile)
    k = min(m, n)
    assert q.shape == (m, k) and r.shape == (k, n)
    _check(a, q, r)
    # R matches LAPACK up to column signs
    rn = jnp.linalg.qr(a)[1]
    s = jnp.sign(jnp.diagonal(r[:k, :k])) * jnp.sign(jnp.diagonal(rn[:k, :k]))
    np.testing.assert_allclose(np.asarray(r * s[:, None]), np.asarray(rn),
                               atol=5e-5 * np.sqrt(m))


def test_tiled_qr_r_mode_and_full_mode():
    a = _rand(40, 24, seed=3)
    r_only = tiled_qr(a, tile=16, mode="r")
    _, r_red = tiled_qr(a, tile=16, mode="reduced")
    np.testing.assert_array_equal(np.asarray(r_only), np.asarray(r_red))
    qf, rf = tiled_qr(a, tile=16, mode="full")
    assert qf.shape == (40, 40) and rf.shape == (40, 24)
    _check(a, qf, rf, atol=2e-5)


def test_tiled_qr_kernel_path_matches_jnp_path():
    """tile_ops Pallas kernels (interpret on CPU) vs the pure-jnp path."""
    a = _rand(64, 48, seed=7)
    qk, rk = tiled_qr(a, tile=16, use_kernel=True)
    qj, rj = tiled_qr(a, tile=16, use_kernel=False)
    np.testing.assert_allclose(np.asarray(qk), np.asarray(qj), atol=3e-5)
    np.testing.assert_allclose(np.asarray(rk), np.asarray(rj), atol=3e-5)


def test_tiled_qr_degenerate_rank_deficient():
    """Zero and rank-1 inputs: reflector application keeps Q exactly
    orthonormal where LAPACK semantics allow (tau=0 degenerate columns)."""
    a = jnp.zeros((32, 32), jnp.float32)
    q, r = tiled_qr(a, tile=16)
    assert float(jnp.linalg.norm(r)) == 0.0
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(32), atol=1e-6)


# --------------------------------------------------- acceptance (512 x 512)

def test_tiled_qr_512_acceptance():
    """PR acceptance: 512x512 f32 via QRConfig(method="tiled") with
    relative reconstruction and orthogonality error <= 1e-5 on CPU."""
    a = _rand(512, 512, seed=11)
    q, r = qr(a, config=QRConfig(method="tiled", block=128))
    _check(a, q, r, atol=1e-5)


@pytest.mark.slow
def test_tiled_qr_512_default_block():
    """Same acceptance with the planner-default tile size (32)."""
    a = _rand(512, 512, seed=12)
    q, r = qr(a, config=QRConfig(method="tiled"))
    _check(a, q, r, atol=1e-5)


# ------------------------------------------------------ planner integration

def test_plan_tiled_resolves_and_solves():
    a = _rand(96, 64, seed=5)
    solver = plan(a.shape, a.dtype, QRConfig(method="tiled", block=32))
    assert solver.config.method == "tiled"
    q, r = solver.solve(a)
    _check(a, q, r)


def test_plan_tiled_caps_tile_at_matrix():
    solver = plan((24, 16), jnp.float32, QRConfig(method="tiled", block=64))
    assert solver.config.block == 16  # resolve hook: tile <= min(m, n)
    a = _rand(24, 16, seed=6)
    q, r = solver.solve(a)
    _check(a, q, r)


def test_tiled_batched_solve():
    a = jnp.stack([_rand(48, 32, seed=s) for s in (1, 2, 3)])
    solver = plan(a.shape, a.dtype, QRConfig(method="tiled", block=16))
    qb, rb = solver.solve(a)
    assert qb.shape == (3, 48, 32) and rb.shape == (3, 32, 32)
    for i in range(3):
        _check(a[i], qb[i], rb[i])


def test_tiled_sign_fix_and_q_method_solve():
    a = _rand(64, 48, seed=8)
    q1, r1 = plan(a.shape, a.dtype,
                  QRConfig(method="tiled", block=16, sign_fix=True)).solve(a)
    assert bool((jnp.diagonal(r1) >= 0).all())
    _check(a, q1, r1)
    q2, _ = plan(a.shape, a.dtype,
                 QRConfig(method="tiled", block=16, q_method="solve")).solve(a)
    np.testing.assert_allclose(np.asarray(q2.T @ q2), np.eye(48), atol=1e-4)


# --------------------------------------------------- beta metric extension

def test_analyze_tiled_beats_mht_beta():
    """Acceptance: strictly more ops per DAG level than unblocked MHT for
    n >= 64 with >= 4x4 tile grids."""
    for n, tile in [(64, 16), (128, 16), (128, 32), (256, 32)]:
        p = -(-n // tile)
        assert p >= 4
        tl = analyze_tiled(n, tile)
        mht = analyze_mht(n)
        assert tl.beta > mht.beta, (n, tile, tl.beta, mht.beta)


def test_analyze_tiled_depth_is_wavefront_count():
    assert analyze_tiled(64, 16).depth == wavefront_count(4, 4)
    assert analyze_tiled(100, 16).depth == wavefront_count(7, 7)
