"""Substrate tests: data pipeline, checkpointing, compression,
fault tolerance, sharding rules."""

import os
import tempfile
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLM
from repro.distributed import (
    StepWatchdog, dequantize, ef_compress_tree, init_error_state, quantize,
)
from repro.distributed.fault_tolerance import plan_elastic_mesh


# ----------------------------------------------------------------- data

def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4, seed=7)
    p1 = SyntheticLM(cfg)
    it = iter(p1)
    batches = [next(it) for _ in range(5)]
    # resume from step 3
    p2 = SyntheticLM(cfg)
    p2.load_state_dict({"step": 3, "seed": 7})
    b3 = next(iter(p2))
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])
    np.testing.assert_array_equal(b3["labels"], batches[3]["labels"])


def test_pipeline_labels_are_shifted_stream():
    cfg = DataConfig(vocab_size=50, seq_len=16, global_batch=2, seed=1)
    b = SyntheticLM(cfg).peek(0)
    # labels[t] is the next token of tokens[t] by construction
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_embedding_input_stub():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2, seed=0,
                     embedding_input=True, d_model=32)
    b = SyntheticLM(cfg).peek(0)
    assert "tokens" not in b and b["embeds"].shape == (2, 8, 32)
    assert np.isfinite(b["embeds"]).all()


def test_pipeline_seed_mismatch_raises():
    cfg = DataConfig(vocab_size=10, seq_len=4, global_batch=1, seed=1)
    p = SyntheticLM(cfg)
    with pytest.raises(AssertionError):
        p.load_state_dict({"step": 0, "seed": 2})


# ----------------------------------------------------------- checkpoint

def _tree(key):
    return {"a": jax.random.normal(key, (8, 4)),
            "b": {"c": jnp.arange(5), "d": jnp.float32(3.5)}}


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, keep_n=2)
        for step in (1, 2, 3, 4):
            mgr.save(step, _tree(jax.random.PRNGKey(step)))
        assert mgr.all_steps() == [3, 4]  # gc keeps 2
        restored = mgr.restore(4, _tree(jax.random.PRNGKey(0)))
        expect = _tree(jax.random.PRNGKey(4))
        np.testing.assert_allclose(np.asarray(restored["a"]),
                                   np.asarray(expect["a"]))


def test_checkpoint_async_and_metadata():
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td)
        mgr.save(10, _tree(jax.random.PRNGKey(1)),
                 metadata={"data": {"step": 10, "seed": 0}}, blocking=False)
        mgr.wait_until_finished()
        assert mgr.latest_step() == 10
        assert mgr.metadata(10)["data"]["step"] == 10


def test_checkpoint_ignores_uncommitted():
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td)
        mgr.save(1, _tree(jax.random.PRNGKey(1)))
        # simulate a crash mid-save: directory without COMMITTED
        os.makedirs(os.path.join(td, "step_00000002"))
        assert mgr.latest_step() == 1


def test_checkpoint_structure_mismatch_raises():
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td)
        mgr.save(1, _tree(jax.random.PRNGKey(1)))
        with pytest.raises(ValueError):
            mgr.restore(1, {"a": jnp.zeros((8, 4))})  # missing leaves


# ---------------------------------------------------------- compression

@pytest.mark.parametrize("shape", [(100,), (64, 64), (3, 5, 7)])
def test_quantize_roundtrip_bound(shape):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32) * 10
    codes, scales = quantize(x)
    back = dequantize(codes, scales, shape)
    # int8 symmetric quantization: error <= scale/2 per element
    err = np.abs(np.asarray(back - x))
    bound = np.asarray(scales).max() / 2 + 1e-6
    assert err.max() <= bound
    assert codes.dtype == jnp.int8


def test_error_feedback_accumulates_to_unbiased():
    """Sum of decoded updates converges to sum of true grads (EF property)."""
    key = jax.random.PRNGKey(3)
    g = {"w": jax.random.normal(key, (256,), jnp.float32)}
    err = init_error_state(g)
    total_dec = jnp.zeros((256,))
    steps = 50
    for i in range(steps):
        dec, err = ef_compress_tree(g, err)
        total_dec = total_dec + dec["w"]
    # mean decoded ~= true grad: residual bounded by one quantization step
    diff = np.abs(np.asarray(total_dec / steps - g["w"]))
    assert diff.max() < np.abs(np.asarray(g["w"])).max() / 100


def test_compressed_psum_subprocess():
    import subprocess, sys, textwrap

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum, init_error_state

        from repro.compat import shard_map
        mesh = jax.make_mesh((4,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (4, 256), jnp.float32)
        err = jnp.zeros((4, 256), jnp.float32)
        f = jax.jit(shard_map(
            lambda gg, ee: compressed_psum({"g": gg}, "data", {"g": ee}),
            mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=({"g": P()}, {"g": P("data")})))
        red, new_err = f(g, err)
        true_mean = np.asarray(g).mean(0)
        got = np.asarray(red["g"])[0]
        assert np.abs(got - true_mean).max() < 0.05, np.abs(got - true_mean).max()
        print("COMPRESSED_PSUM_OK")
    """)
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src",
                              "PATH": "/usr/bin:/bin:/usr/local/bin",
                              "JAX_PLATFORMS": "cpu"},
                         cwd=__file__.rsplit("/", 2)[0])
    assert "COMPRESSED_PSUM_OK" in res.stdout, res.stderr[-2000:]


# ------------------------------------------------------ fault tolerance

def test_watchdog_flags_stragglers():
    flagged = []
    wd = StepWatchdog(threshold=3.0,
                      on_straggler=lambda s, dt, med: flagged.append(s))
    for step in range(10):
        wd.start()
        time.sleep(0.01 if step != 7 else 0.2)
        wd.stop(step)
    assert flagged == [7]


def test_elastic_mesh_shrinks_after_failure():
    devices = jax.devices()
    plan = plan_elastic_mesh(devices, failed=[], prefer_model=1)
    assert plan.mesh.size >= 1
    # simulate loss of all but one device
    if len(devices) > 1:
        plan2 = plan_elastic_mesh(devices, failed=[d.id for d in devices[1:]],
                                  prefer_model=1)
        assert plan2.mesh.size == 1


# --------------------------------------------------------- sharding rules

def test_sharding_rules_divisibility_fallbacks():
    import subprocess, sys, textwrap

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import MeshRules, param_specs, batch_specs
        from repro.configs import get_config
        from repro.models import init_params

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = MeshRules(mesh=mesh, data_axes=("data",))
        # smollm: 9 heads (not div by 4) must fall back, never crash
        cfg = get_config("smollm-135m")
        sds = jax.eval_shape(lambda k: init_params(k, cfg),
                             jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = param_specs(sds, rules)
        flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat) > 0
        # every spec must be consistent with its leaf's divisibility
        for (path, leaf), spec in zip(
                jax.tree_util.tree_leaves_with_path(sds),
                flat):
            for dim, ax in zip(leaf.shape, spec):
                if ax is None: continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes: size *= mesh.shape[a]
                assert dim % size == 0, (path, leaf.shape, spec)
        # batch=1 falls back to sequence sharding
        b = {"tokens": jax.ShapeDtypeStruct((1, 16), jnp.int32)}
        bs = batch_specs(b, rules)
        assert bs["tokens"] == P(None, "data")
        print("SHARDING_RULES_OK")
    """)
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src",
                              "PATH": "/usr/bin:/bin:/usr/local/bin",
                              "JAX_PLATFORMS": "cpu"},
                         cwd=__file__.rsplit("/", 2)[0])
    assert "SHARDING_RULES_OK" in res.stdout, res.stderr[-2000:]


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-4, 1e4), seed=st.integers(0, 10_000),
       n=st.integers(1, 2000))
def test_property_quantization_error_bound(scale, seed, n):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32) * scale
    codes, scales = quantize(x)
    back = dequantize(codes, scales, (n,))
    err = np.abs(np.asarray(back - x))
    assert err.max() <= np.asarray(scales).max() / 2 + 1e-6 * scale
