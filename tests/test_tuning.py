"""Tuning-cache and sweep tests (PR 8).

Covers the cache layer (shape classes, JSON round-trip, lookup), the
planner's "tuned" routing rule against both the committed CPU cache and
synthetic caches, the overlay semantics (explicit knobs beat measured
ones), the CI gate (check_cache), and a miniature end-to-end sweep.
"""

import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import QRConfig, qr
from repro.core.plan import plan, select_method
from repro.tuning import cache as tcache
from repro.tuning.cache import (DEFAULT_CACHE_PATH, TunedConfig, TuningCache,
                                TuningEntry, shape_class, set_active_cache)


@pytest.fixture(autouse=True)
def _restore_active_cache():
    """Every test leaves the process-wide active cache as it found it."""
    prev = set_active_cache(None)
    yield
    set_active_cache(prev)


def _entry(m=2048, n=2048, method="tiled", block=64, backend="cpu",
           device_kind="cpu", dtype="float32", best_us=100.0,
           heuristic_us=200.0, **kw):
    return TuningEntry(
        backend=backend, device_kind=device_kind, shape_class=(m, n),
        dtype=dtype,
        best=TunedConfig(method=method, block=block, **kw),
        best_us=best_us, heuristic_method="geqrf_ht",
        heuristic_us=heuristic_us,
        timings=tuple(sorted(((f"{method}[b{block}]", best_us),
                              ("geqrf_ht", heuristic_us)))))


# ------------------------------------------------------------ shape classes

def test_shape_class_matches_serving_buckets():
    from repro.serving.bucketing import pad_dim

    for m, n in ((256, 256), (300, 280), (511, 500), (1023, 1000)):
        assert shape_class(m, n) == (pad_dim(m, tile=32, max_waste=0.25),
                                     pad_dim(n, tile=32, max_waste=0.25))
    # the classes the routing-table edge shapes collapse into
    assert shape_class(255, 255) == (256, 256)
    assert shape_class(511, 500) == (512, 512)
    assert shape_class(300, 280) == (384, 288)


def test_shape_class_rejects_zero_dims():
    with pytest.raises(ValueError, match="nonempty"):
        shape_class(0, 5)
    with pytest.raises(ValueError, match="nonempty"):
        shape_class(5, 0)


# -------------------------------------------------------- cache round-trip

def test_cache_json_roundtrip(tmp_path):
    e = _entry(use_kernel=True, dispatch_mode="wavefront")
    c = TuningCache([e], source="test")
    path = str(tmp_path / "cache.json")
    c.save(path)
    c2 = TuningCache.load(path)
    assert c2.source == path and len(c2) == 1
    got = c2.lookup(backend="cpu", m=2048, n=2048, dtype=jnp.float32)
    assert got == e  # frozen dataclasses: full value equality
    assert got.best.dispatch_mode == "wavefront"
    assert got.timings_dict["tiled[b64]"] == 100.0


def test_cache_schema_mismatch_raises(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"schema": "qr-tuning-v0", "entries": []}))
    with pytest.raises(ValueError, match="schema"):
        TuningCache.load(str(path))


def test_cache_lookup_prefers_exact_device_kind():
    a = _entry(device_kind="cpu", best_us=10.0)
    b = _entry(device_kind="TPU v4", best_us=20.0, method="geqrf")
    c = TuningCache([a, b])
    assert len(c) == 2  # same key, different device_kind: both kept
    hit = c.lookup(backend="cpu", m=2048, n=2048, dtype=jnp.float32,
                   device_kind="TPU v4")
    assert hit.best.method == "geqrf"
    # unknown device_kind falls back to any same-backend entry
    any_hit = c.lookup(backend="cpu", m=2048, n=2048, dtype=jnp.float32,
                       device_kind="mystery")
    assert any_hit in (a, b)
    assert c.lookup(backend="cpu", m=0, n=2048, dtype=jnp.float32) is None


def test_cache_add_replaces_same_device_kind():
    c = TuningCache([_entry(best_us=10.0)])
    c.add(_entry(best_us=5.0, method="geqrf"))
    assert len(c) == 1
    assert c.lookup(backend="cpu", m=2048, n=2048,
                    dtype=jnp.float32).best.method == "geqrf"


# ------------------------------------------- the committed CPU default cache

def test_committed_default_cache_loads():
    c = TuningCache.load(DEFAULT_CACHE_PATH)
    assert len(c) >= 3
    for e in c.entries():
        assert e.backend == "cpu" and np.isfinite(e.best_us)
        assert e.timings_dict  # provenance: every candidate's wall time
        assert e.provenance_dict.get("mode") == "r"


def test_tuned_256_cpu_crossover_regression():
    """The pinned PR-8 regression: at 256^2 on CPU the measured cache
    must route the blocked LAPACK-style family (geqrf/geqrf_ht), never
    the tiled task graph the old 256-floor heuristic would have picked
    on an accelerator-tuned threshold.  (The committed sweep measured
    tiled ~2.4x slower there.)"""
    c = TuningCache.load(DEFAULT_CACHE_PATH)
    e = c.lookup(backend="cpu", m=256, n=256, dtype=jnp.float32)
    assert e is not None
    assert e.best.method in ("geqrf", "geqrf_ht")
    assert e.best.method != "tiled"
    # and the planner actually consults it
    set_active_cache(c)
    solver = plan((256, 256), jnp.float32, QRConfig(), backend="cpu",
                  explain=True)
    assert solver.config.method == e.best.method
    sel = solver.explain.selected
    assert sel.rule == "tuned" and "measured:" in sel.reason
    assert "us" in sel.reason  # cites real microseconds, not a threshold


def test_tuned_512_cpu_overrides_heuristic_tiled():
    """512^2 is where the heuristics say tiled on CPU; the committed
    measurements say the blocked family is >2x faster.  The cache must
    win and the trail must show tiled was never reached."""
    c = TuningCache.load(DEFAULT_CACHE_PATH)
    set_active_cache(c)
    solver = plan((512, 512), jnp.float32, QRConfig(), backend="cpu",
                  explain=True)
    assert solver.config.method in ("geqrf", "geqrf_ht")
    heur = select_method((512, 512), jnp.float32,
                         QRConfig(use_tuning_cache=False), backend="cpu")
    assert heur == "tiled"  # the displaced heuristic pick


def test_tuned_solver_still_matches_oracle():
    """Routing through the cache changes the method, not the answer."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((256, 256), dtype=np.float32))
    set_active_cache(TuningCache.load(DEFAULT_CACHE_PATH))
    q, r = qr(a)
    rn = jnp.linalg.qr(a)[1]
    s = jnp.sign(jnp.diagonal(r)) * jnp.sign(jnp.diagonal(rn))
    np.testing.assert_allclose(np.asarray(r * s[:, None]), np.asarray(rn),
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), atol=1e-4)


# ------------------------------------------------- planner integration

def test_cache_miss_falls_to_heuristics_with_recorded_decision():
    set_active_cache(TuningCache(source="test-empty"))
    solver = plan((300, 280), jnp.float32, QRConfig(), backend="cpu",
                  explain=True)
    assert solver.config.method == "geqrf_ht"  # the heuristic pick
    d = solver.explain.decision("tuned")
    assert d is not None and d.outcome == "rejected"
    solver2 = plan((4096, 32), jnp.float32, QRConfig(), backend="cpu",
                   explain=True)
    assert solver2.config.method == "tsqr"  # heuristics fully intact


def test_use_tuning_cache_false_pins_heuristics():
    set_active_cache(TuningCache.load(DEFAULT_CACHE_PATH))
    solver = plan((512, 512), jnp.float32,
                  QRConfig(use_tuning_cache=False), backend="cpu",
                  explain=True)
    assert solver.config.method == "tiled"
    d = solver.explain.decision("tuned")
    assert d.outcome == "rejected" and "use_tuning_cache=False" in d.reason


def test_tuned_overlay_respects_explicit_knobs():
    """A measured config only fills knobs the caller left at defaults:
    tuned block applies under QRConfig(), but an explicit block wins."""
    set_active_cache(TuningCache([_entry(method="tiled", block=64)]))
    tuned = plan((2048, 2048), jnp.float32, QRConfig(), backend="cpu",
                 explain=True)
    assert tuned.config.method == "tiled" and tuned.config.block == 64
    d = tuned.explain.decision("tuned_config")
    assert d is not None and d.outcome == "resolved"
    pinned = plan((2048, 2048), jnp.float32, QRConfig(block=48),
                  backend="cpu")
    assert pinned.config.method == "tiled" and pinned.config.block == 48


def test_explicit_method_beats_tuned():
    set_active_cache(TuningCache([_entry(method="tiled", block=64)]))
    solver = plan((2048, 2048), jnp.float32, QRConfig(method="geqrf"),
                  backend="cpu", explain=True)
    assert solver.config.method == "geqrf"
    assert solver.explain.selected.rule == "explicit"


def test_tuned_entry_with_unfit_method_rejected():
    """A cache entry naming a method that cannot serve this plan (here:
    unregistered) records a rejection and falls through — a stale cache
    must degrade to heuristics, never crash the planner."""
    set_active_cache(TuningCache([_entry(method="not_a_method")]))
    solver = plan((2048, 2048), jnp.float32, QRConfig(), backend="cpu",
                  explain=True)
    assert solver.config.method == "tiled"  # heuristic pick
    d = solver.explain.decision("tuned")
    assert d.outcome == "rejected"


def test_tuned_lookup_is_backend_keyed():
    """CPU measurements must not leak onto TPU plans."""
    set_active_cache(TuningCache.load(DEFAULT_CACHE_PATH))
    solver = plan((512, 512), jnp.float32, QRConfig(), backend="tpu",
                  explain=True)
    assert solver.config.method == "tiled"  # TPU heuristic, no cpu entry
    assert solver.explain.decision("tuned").outcome == "rejected"


def test_env_var_cache_loads(tmp_path, monkeypatch):
    path = str(tmp_path / "env_cache.json")
    TuningCache([_entry(method="geqrf", block=32)]).save(path)
    monkeypatch.setenv(tcache.ENV_VAR, path)
    set_active_cache(None)  # force a fresh lazy load
    c = tcache.active_cache()
    assert c.source == path and len(c) == 1
    info = tcache.active_cache_info()
    assert info["source"] == path and info["entries"] == 1
    assert info["schema"] == tcache.SCHEMA


# ----------------------------------------------------------- the CI gate

def test_check_cache_passes_on_consistent_entries():
    from repro.tuning.sweep import check_cache

    fresh = TuningCache([_entry(best_us=100.0, heuristic_us=150.0)])
    assert check_cache(fresh) == []
    assert check_cache(fresh, baseline=fresh) == []


def test_check_cache_flags_tuned_slower_than_heuristic():
    from repro.tuning.sweep import check_cache

    fresh = TuningCache([_entry(best_us=200.0, heuristic_us=100.0)])
    problems = check_cache(fresh)
    assert len(problems) == 1 and "slower than heuristic" in problems[0]


def test_check_cache_flags_baseline_drift():
    from repro.tuning.sweep import check_cache

    baseline = TuningCache([_entry(best_us=10.0, heuristic_us=20.0)])
    fresh = TuningCache([_entry(best_us=100.0, heuristic_us=200.0)])
    problems = check_cache(fresh, baseline, drift_tol=5.0)
    assert len(problems) == 1 and "regressed" in problems[0]
    assert check_cache(fresh, baseline, drift_tol=20.0) == []


# ------------------------------------------------- miniature end-to-end sweep

def test_sweep_small_shape_end_to_end(tmp_path):
    """A real (tiny) sweep: measures candidates, records the heuristic
    pick, emits tuning.* metrics, and the planner consults the result."""
    from repro.observability import metrics
    from repro.tuning.sweep import check_cache, sweep_shapes

    sweeps0 = metrics.counter_value("tuning.sweeps", backend="cpu")
    measured0 = metrics.counter_value("tuning.candidates", status="measured")
    cache = sweep_shapes([(64, 64)], reps=1, backend="cpu")
    assert len(cache) == 1
    e = cache.entries()[0]
    assert e.shape_class == (64, 64) and np.isfinite(e.heuristic_us)
    assert e.heuristic_method in e.timings_dict or any(
        lb.startswith("heuristic:") for lb in e.timings_dict)
    assert metrics.counter_value("tuning.sweeps", backend="cpu") == sweeps0 + 1
    assert metrics.counter_value("tuning.candidates",
                                 status="measured") > measured0
    # argmin construction: the gate passes on a fresh sweep by design
    assert check_cache(cache) == []
    # the planner consults what the sweep wrote
    path = str(tmp_path / "swept.json")
    cache.save(path)
    set_active_cache(TuningCache.load(path))
    solver = plan((64, 64), jnp.float32, QRConfig(), backend="cpu",
                  explain=True)
    assert solver.explain.selected.rule == "tuned"
    assert solver.config.method == e.best.method
