"""End-to-end driver: train the ~135M smollm config for a few hundred
steps with the QR-Muon optimizer (paper technique in production position).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full]

Default uses seq 256 / batch 8 on CPU with the FULL 135M architecture
(30 layers, d=576) — a real ~100M-class model, runnable on the host.
"""

import argparse

from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig
from repro.training import RunConfig, TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config instead of the full 135M")
    ap.add_argument("--optimizer", default="muon-qr",
                    choices=["muon-qr", "muon-ns", "adamw"])
    ap.add_argument("--batched-ortho", action="store_true",
                    help="batch the Muon orthogonalizations per shape "
                         "class: one QR dispatch per class instead of "
                         "one per layer (repro.optim.batched_ortho)")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)("smollm-135m")
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    trainer = Trainer(
        cfg,
        TrainConfig(optimizer=args.optimizer, lr=0.02, microbatch=4,
                    batched_ortho=args.batched_ortho),
        RunConfig(total_steps=args.steps, warmup_steps=20, log_every=10,
                  checkpoint_every=100, checkpoint_dir=args.checkpoint_dir),
        data,
    )
    result = trainer.run()
    hist = result["history"]
    print(f"\nfirst logged loss {hist[0]['loss']:.3f} -> "
          f"final {hist[-1]['loss']:.3f} over {result['final_step']} steps")


if __name__ == "__main__":
    main()
