"""End-to-end driver: train the ~135M smollm config for a few hundred
steps with the QR-Muon optimizer (paper technique in production position).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full]

Default uses seq 256 / batch 8 on CPU with the FULL 135M architecture
(30 layers, d=576) — a real ~100M-class model, runnable on the host.

Fault-tolerance drill (``--fault-tolerance``): wires the step watchdog
(straggler detection at ``--watchdog-threshold`` x median step time)
and checkpoint-restore into the loop, with two chaos knobs for proving
the machinery end to end —

    --inject-straggler-at N   sleep one step so the watchdog must flag it
    --crash-at N              stop at step N, rebuild the trainer from
                              scratch, and resume from the last committed
                              checkpoint (prints CRASH_SIMULATED / the
                              restored step / FT_OK sentinels the smoke
                              test in tests/test_robustness.py asserts)
"""

import argparse
import time

from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig
from repro.distributed import StepWatchdog
from repro.training import RunConfig, TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config instead of the full 135M")
    ap.add_argument("--optimizer", default="muon-qr",
                    choices=["muon-qr", "muon-ns", "adamw"])
    ap.add_argument("--batched-ortho", action="store_true",
                    help="batch the Muon orthogonalizations per shape "
                         "class: one QR dispatch per class instead of "
                         "one per layer (repro.optim.batched_ortho)")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--fault-tolerance", action="store_true",
                    help="straggler watchdog + crash/restore drill "
                         "(repro.distributed.fault_tolerance)")
    ap.add_argument("--watchdog-threshold", type=float, default=2.5,
                    help="straggler rule: flag steps slower than "
                         "THRESHOLD x median step time")
    ap.add_argument("--inject-straggler-at", type=int, default=None,
                    help="chaos: sleep through step N so the watchdog "
                         "must flag it (requires --fault-tolerance)")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="chaos: stop at step N and restart from the "
                         "last committed checkpoint (requires "
                         "--fault-tolerance)")
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)("smollm-135m")
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)

    def build_trainer():
        watchdog = None
        if args.fault_tolerance:
            watchdog = StepWatchdog(
                threshold=args.watchdog_threshold,
                on_straggler=lambda s, dt, med: print(
                    f"[watchdog] straggler step {s}: {dt:.2f}s "
                    f"vs median {med:.2f}s"))
        trainer = Trainer(
            cfg,
            TrainConfig(optimizer=args.optimizer, lr=0.02, microbatch=4,
                        batched_ortho=args.batched_ortho),
            RunConfig(total_steps=args.steps, warmup_steps=20,
                      log_every=10, checkpoint_every=args.checkpoint_every,
                      checkpoint_dir=args.checkpoint_dir),
            data,
            watchdog=watchdog,
        )
        if args.inject_straggler_at is not None:
            # Delay scaled off the live median so the straggler rule must
            # fire regardless of how fast this host steps.
            real_step = trainer._step

            def slow_step(state, batch, lr, _real=real_step):
                if trainer.step_idx == args.inject_straggler_at:
                    wd = trainer.watchdog
                    time.sleep(max(0.5, 2.0 * wd.threshold * wd.median))
                return _real(state, batch, lr)

            trainer._step = slow_step
        return trainer

    trainer = build_trainer()
    if args.fault_tolerance and args.crash_at is not None:
        partial = trainer.run(stop_at=args.crash_at)
        print(f"CRASH_SIMULATED step={partial['final_step']}")
        # A real crash loses the process; rebuilding the trainer from
        # scratch and resuming is exactly the restart path.
        trainer = build_trainer()
    result = trainer.run()
    hist = result["history"]
    print(f"\nfirst logged loss {hist[0]['loss']:.3f} -> "
          f"final {hist[-1]['loss']:.3f} over {result['final_step']} steps")
    if args.fault_tolerance:
        print(f"STRAGGLERS={trainer.watchdog.straggler_steps}")
        print("FT_OK")


if __name__ == "__main__":
    main()
