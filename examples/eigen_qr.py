"""Paper §1 Application 2: eigenvalues via the QR algorithm (Algorithm 1).

    A_0 = A;  A_k = R_k Q_k  with  Q_k R_k = A_{k-1}

using the MHT-based factorization.  Validates against numpy.linalg.eigh.
"""

import numpy as np
import jax.numpy as jnp

from repro.core import QRConfig, qr_algorithm_eig


def main():
    rng = np.random.default_rng(1)
    qm, _ = np.linalg.qr(rng.standard_normal((12, 12)))
    lam = np.sort(rng.uniform(0.5, 10.0, 12))[::-1]
    a = jnp.asarray(qm @ np.diag(lam) @ qm.T, jnp.float32)

    ev = qr_algorithm_eig(a, iters=400, config=QRConfig(method="geqrf_ht"))
    ref = np.sort(np.linalg.eigvalsh(np.asarray(a)))[::-1]
    err = np.abs(np.asarray(ev) - ref).max()
    print("QR-algorithm eigenvalues:", np.round(np.asarray(ev), 3))
    print("numpy eigh             :", np.round(ref, 3))
    print(f"max abs error: {err:.2e}")
    assert err < 5e-2
if __name__ == "__main__":
    main()
