"""Paper §1 Application 1: numerically-stable Kalman filtering via QR.

A square-root Kalman filter tracks a 2-D constant-velocity target; the
covariance propagation uses the MHT QR factorization (the paper's
motivating use of QR as the stable alternative to explicit covariance
updates).  Compares against a naive covariance EKF on conditioning.
"""

import numpy as np
import jax.numpy as jnp

from repro.core import QRConfig, qr

# R-only blocked-MHT factorization, planned once for the whole filter run.
R_CFG = QRConfig(method="geqrf_ht", mode="r")


def main():
    dt = 0.1
    f = jnp.asarray([[1, 0, dt, 0], [0, 1, 0, dt],
                     [0, 0, 1, 0], [0, 0, 0, 1]], jnp.float32)
    h = jnp.asarray([[1, 0, 0, 0], [0, 1, 0, 0]], jnp.float32)
    q_sqrt = jnp.eye(4) * 0.05
    r_sqrt = jnp.eye(2) * 0.3

    rng = np.random.default_rng(0)
    x_true = jnp.asarray([0.0, 0.0, 1.0, 0.5])
    x_est = jnp.zeros(4)
    s = jnp.eye(4) * 1.0          # sqrt covariance (upper triangular)

    errs = []
    for step in range(100):
        # truth + measurement
        x_true = f @ x_true + 0.05 * jnp.asarray(rng.standard_normal(4),
                                                 jnp.float32)
        z = h @ x_true + 0.3 * jnp.asarray(rng.standard_normal(2), jnp.float32)

        # --- time update: S' = R factor of [S F^T; Q^T]  (QR propagation)
        pre = jnp.vstack([s @ f.T, q_sqrt])
        s = qr(pre, config=R_CFG)[:4, :4]
        x_est = f @ x_est

        # --- measurement update via the QR of the augmented array
        m, n = 2, 4
        top = jnp.hstack([r_sqrt, h @ s.T @ s @ h.T * 0])  # layout helper
        aug = jnp.block([[r_sqrt, jnp.zeros((m, n))],
                         [s @ h.T, s]])
        r_all = qr(aug, config=R_CFG)
        s_zz = r_all[:m, :m]
        k_gain_t = r_all[:m, m:]
        s = r_all[m:, m:]
        innov = z - h @ x_est
        x_est = x_est + k_gain_t.T @ jnp.linalg.solve(s_zz.T, innov)
        errs.append(float(jnp.linalg.norm((x_est - x_true)[:2])))

    print(f"square-root KF position RMSE: "
          f"first10={np.mean(errs[:10]):.3f} last10={np.mean(errs[-10:]):.3f}")
    assert np.mean(errs[-10:]) < np.mean(errs[:10])
    print("filter converged (QR-based covariance propagation stable)")


if __name__ == "__main__":
    main()
