"""Serving example: batched prefill + decode over the gemma2 smoke config.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving import ServeEngine


def main():
    cfg = get_smoke_config("gemma2-9b")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    engine = ServeEngine(params, cfg, batch=4, max_len=256, temperature=0.8,
                         seed=1)
    prompts = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)

    t0 = time.time()
    out = engine.generate(prompts, steps=64)
    jax.block_until_ready(out)
    dt = time.time() - t0
    print(f"batch=4 x 64 tokens in {dt:.2f}s "
          f"({4 * 64 / dt:.1f} tok/s on CPU)")
    for i in range(4):
        print(f"request {i}:", out[i, :12].tolist(), "...")


if __name__ == "__main__":
    main()
