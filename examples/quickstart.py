"""Quickstart: the MHT QR library in five minutes.

    PYTHONPATH=src python examples/quickstart.py

The one idea to take away: factorizations are *planned*.  A hashable
``QRConfig`` names what you want (or ``method="auto"`` to let the planner
route by shape/hardware), ``plan()`` resolves it against the method
registry, and the returned ``QRSolver`` does the work — batched, jittable,
kernel-dispatched.
"""

import numpy as np
import jax.numpy as jnp

from repro.core import QRConfig, lstsq, orthogonalize, plan, qr
from repro.core.dag import phase_model_theta, theta_curve
from repro.core.plan import available_methods, get_method


def main():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((512, 128)), jnp.float32)

    # 1. every realization the paper discusses, via the method registry
    for method in available_methods():
        if method == "geqrf_fori":
            continue  # optimizer-internal variant (needs padded shapes)
        if method == "degenerate":
            continue  # zero-dim-only route (auto-selected for empty inputs)
        q, r = qr(a, config=QRConfig(method=method))
        rec = float(jnp.linalg.norm(q @ r - a) / jnp.linalg.norm(a))
        orth = float(jnp.linalg.norm(q.T @ q - jnp.eye(q.shape[1])))
        print(f"{method:10s} reconstruction={rec:.2e} orthogonality={orth:.2e}"
              f"   [{get_method(method).description}]")

    # 2. method="auto": the planner routes by shape and hardware.
    #    Tall-skinny goes to TSQR with a planner-chosen tree; large
    #    near-square matrices go to the tiled task graph; on TPU,
    #    panel-fits-VMEM shapes go to the kernel-backed blocked MHT.
    for shape in [(1024, 32), (512, 512), (512, 128), (24, 16)]:
        solver = plan(shape, jnp.float32, QRConfig())
        print(f"auto {shape}: -> {solver.config.method}"
              f" (use_kernel={solver.config.use_kernel},"
              f" nblocks={solver.config.nblocks})")

    # 2b. the tiled task-graph backend: the factorization becomes a DAG
    #     of tile tasks (GEQRT/TSQRT/LARFB/SSRFB), levelized statically;
    #     the wavefront macro-op engine (repro.core.engine) executes each
    #     level — use_kernel=True lowers it to ONE in-place Pallas
    #     dispatch over the tile workspace (interpret mode on CPU),
    #     use_kernel=False runs the bitwise-identical jnp oracle.  block
    #     doubles as the tile size.
    from repro.core import wavefront_count
    from repro.core.dag import analyze_mht, analyze_tiled

    qt, rt = qr(a, config=QRConfig(method="tiled", block=64))
    rec = float(jnp.linalg.norm(qt @ rt - a) / jnp.linalg.norm(a))
    print(f"{'tiled':10s} reconstruction={rec:.2e} "
          f"wavefronts={wavefront_count(512 // 64, 128 // 64)} "
          f"(vs {128} sequential columns unblocked)")
    beta_gain = analyze_tiled(128, 16).beta / analyze_mht(128).beta
    print(f"tiled ops/DAG-level vs MHT at n=128: {beta_gain:.0f}x")

    # the engine knob: the Pallas path is bitwise-equal to the oracle
    # (wavefront mode pinned — auto would pick megakernel here)
    qe, re_ = qr(a, config=QRConfig(method="tiled", block=64,
                                    use_kernel=True,
                                    dispatch_mode="wavefront"))
    print(f"{'engine':10s} bitwise_vs_oracle="
          f"{bool((qe == qt).all()) and bool((re_ == rt).all())} "
          f"(one Pallas dispatch per DAG level, in-place workspace)")

    # the dispatch-mode knob: "megakernel" collapses the whole schedule
    # into ONE persistent Pallas dispatch — the grid walks a
    # scalar-prefetched task table, switching on task kind, with task
    # t+1's tile DMA overlapping task t's compute (double buffering).
    # None (the default) picks it automatically whenever the table and
    # the working set fit the budgets; bitwise-equal either way.
    from repro.core.engine import schedule_stats

    qm, rm = qr(a, config=QRConfig(method="tiled", block=64,
                                   use_kernel=True,
                                   dispatch_mode="megakernel"))
    stats = schedule_stats(512 // 64, 128 // 64, nb=64)
    print(f"{'megakernel':10s} bitwise_vs_oracle="
          f"{bool((qm == qt).all()) and bool((rm == rt).all())} "
          f"(dispatches {stats['wavefront']['dispatches']} -> "
          f"{stats['megakernel']['dispatches']}, table "
          f"{stats['megakernel']['table_bytes']} B, auto={stats['auto']})")

    # 2c. the multi-device sharded tiled backend: the tile grid splits
    #     into per-device row-block domains (shard_map), each runs its
    #     own wavefronts, and the per-domain R factors merge through a
    #     TSQR-style butterfly tree — critical path O(p/d + 2q + log d).
    #     Works on CPU without accelerators: run with
    #         XLA_FLAGS=--xla_force_host_platform_device_count=8
    #     On one device it degenerates to the tiled backend bit-for-bit.
    import jax

    from repro.core.tilegraph import sharded_wavefront_count

    ndev = jax.local_device_count()
    solver = plan((512, 512), jnp.float32,
                  QRConfig(method="sharded_tiled", block=64))
    big = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
    qs, rs = solver.solve(big)
    rec = float(jnp.linalg.norm(qs @ rs - big) / jnp.linalg.norm(big))
    d = solver.config.ndomains
    print(f"{'sharded':10s} reconstruction={rec:.2e} devices={ndev} "
          f"domains={d} wavefronts={sharded_wavefront_count(8, 8, d)} "
          f"(vs {8 + 2 * 8 - 2} single-device)")

    # 3. the Pallas-kernel-backed blocked MHT (interpret mode on CPU)
    q, r = qr(a, config=QRConfig(method="geqrf_ht", use_kernel=True, block=64))
    print(f"{'kernels':10s} reconstruction="
          f"{float(jnp.linalg.norm(q @ r - a) / jnp.linalg.norm(a)):.2e}")

    # 4. batched QR: leading dims vmap through the same solver
    stack = jnp.asarray(rng.standard_normal((4, 64, 32)), jnp.float32)
    qs, rs = qr(stack, config=QRConfig(method="geqrf_ht", block=16))
    print("batched:", qs.shape, rs.shape)

    # 4b. QR-as-a-service: heterogeneous request streams batch through
    #     shape buckets — each bucket is zero-padded, stacked, and
    #     factored in ONE engine dispatch (factor_tiles_batched; on the
    #     megakernel path a whole bucket is a single pallas_call), with
    #     compiled bucket plans cached so steady-state traffic never
    #     recompiles.  Answers are bitwise what the per-request path
    #     would have produced.
    from repro.serving import BucketingPolicy, QRService

    service = QRService(policy=BucketingPolicy(tile=16, max_batch=8),
                        use_kernel=False)
    mix = [rng.standard_normal(s).astype(np.float32)
           for s in [(48, 48), (45, 41), (96, 32), (48, 48), (37, 23)]]
    results = service.submit_many(mix)       # bucket -> pad -> dispatch
    worst = max(float(jnp.linalg.norm(res.q @ res.r - a_i)
                      / jnp.linalg.norm(a_i))
                for a_i, res in zip(mix, results))
    service.submit_many(mix)                 # warm cache: no new compiles
    s = service.stats()
    print(f"{'serving':10s} requests={s['requests']} "
          f"dispatches={s['dispatches']} compiles={s['compiles']} "
          f"cache_hit_rate={s['cache_hit_rate']:.2f} "
          f"fill={s['bucket_fill_ratio']:.2f} worst_rec={worst:.2e}")

    # 4b'. robustness: the service survives a poisoned batch.  Admission
    #     quarantines the NaN request (named reason, bucket-mates
    #     untouched), and with verify=True every dispatch is
    #     health-checked against the conformance tolerance — failures
    #     walk the escalation ladder megakernel -> wavefront -> oracle
    #     -> lapack, each hop counted.
    from repro.robustness import inject

    hardened = QRService(policy=BucketingPolicy(tile=16, max_batch=8),
                         use_kernel=False, verify=True)
    poisoned = list(mix)
    poisoned[1] = inject.poison(poisoned[1], kind="nan")  # seeded corruption
    hres = hardened.submit_many(poisoned)
    hs = hardened.stats()
    clean_ok = all(res.ok for i, res in enumerate(hres) if i != 1)
    print(f"{'robust':10s} poisoned request -> {hres[1].error} "
          f"(clean {sum(r.ok for r in hres)}/{len(hres)} ok={clean_ok}, "
          f"quarantined={hs['quarantined']}, "
          f"escalations={hs['escalations']})")

    # 4c. observability: plan(explain=True) attaches the machine-readable
    #     routing trail (why THIS method, every fallback by name), and
    #     the off-by-default tracer records nested spans — exportable as
    #     Chrome trace JSON — while the always-on metrics registry holds
    #     planner/engine/serving counters.  Disabled, the layer is free:
    #     the megakernel jaxpr is identical either way (pinned in tests).
    from repro import observability as obs

    #     On swept shape classes the first decision is the autotuner's:
    #     the committed measured cache (src/repro/tuning/default_cpu.json)
    #     routes by real microseconds, and the reason cites them —
    #     use_tuning_cache=False pins the pure heuristic table.
    explained = plan((512, 512), jnp.float32, QRConfig(), backend="cpu",
                     explain=True)
    print(f"{'explain':10s} method={explained.config.method} "
          f"<- {explained.explain.selected.rule}: "
          f"{explained.explain.selected.reason}")
    heur = plan((512, 512), jnp.float32, QRConfig(use_tuning_cache=False),
                backend="cpu", explain=True)
    print(f"{'explain':10s} heuristics alone would pick "
          f"{heur.config.method} <- {heur.explain.selected.rule}")
    fb = plan((300, 280), jnp.float32, QRConfig(), backend="cpu",
              explain=True)
    print(f"{'explain':10s} (300,280)@cpu -> {fb.config.method} "
          f"(tuned: {fb.explain.decision('tuned').reason}) "
          f"fallbacks={list(fb.explain.fallback_reasons)}")
    with obs.enabled_scope():                    # tracing + annotations on
        service.submit_many(mix)
    print(f"{'tracing':10s} {len(obs.spans())} spans "
          f"(serving flush: bucketize -> plan -> dispatch -> unpad); "
          f"obs.export_chrome_trace('trace.json') renders in "
          f"chrome://tracing, `python -m repro.observability.report "
          f"--capture DIR` bundles trace + metrics")

    # 5. the optimizer primitive: orthogonalize a momentum matrix
    #    (auto config routes this tall-skinny input through TSQR)
    o = orthogonalize(jnp.asarray(rng.standard_normal((256, 64)), jnp.float32),
                      config=QRConfig())
    print("orthogonalize:", o.shape,
          float(jnp.linalg.norm(o.T @ o - jnp.eye(64))))

    # 5b. batched optimizer-step orthogonalization: a Muon step holds
    #     dozens of momentum matrices in a few repeated shapes — group
    #     them into shape classes and factor each class in ONE dispatch
    #     instead of one per leaf (muon_update(batched_ortho=True) rides
    #     on this).  plan_batched_ortho is a pure shape query: it counts
    #     dispatches and carries the planner's explain trail per class.
    from repro.optim import plan_batched_ortho

    step_shapes = [((3, 48, 48), jnp.float32)] * 4 + \
        [((3, 96, 48), jnp.float32), ((3, 48, 96), jnp.float32),
         ((40, 24), jnp.float32)]
    oplan = plan_batched_ortho(step_shapes)
    print(f"{'batched':10s} {oplan.n_matrices} matrices / "
          f"{oplan.n_leaves} leaves -> {oplan.dispatches} dispatches "
          f"({len(oplan.classes)} shape classes)")
    for cls in oplan.classes:
        trail = (f"{cls.method} <- {cls.explain.selected.rule}"
                 if cls.route == "batched" else cls.reason.split(":")[0])
        print(f"{'':10s} class {cls.key.m}x{cls.key.n} "
              f"b={len(cls.members)}: {cls.route} ({trail})")

    # 6. least squares (Kalman-filter building block, paper §1)
    x = lstsq(a, a @ jnp.ones((128,), jnp.float32), config=QRConfig())
    print("lstsq residual:", float(jnp.linalg.norm(x - 1.0)))

    # 7. the paper's parallelism claim (fig 9)
    print("theta (4-wide RDP model, n=512):",
          round(phase_model_theta(512)["theta"], 4), "~ paper 0.749")


if __name__ == "__main__":
    main()
