"""Quickstart: the MHT QR library in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import qr, orthogonalize, lstsq
from repro.core.dag import phase_model_theta, theta_curve


def main():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((512, 128)), jnp.float32)

    # 1. QR with every realization the paper discusses
    for method in ("geqr2", "geqr2_ht", "geqrf", "geqrf_ht", "tsqr"):
        q, r = qr(a, method=method)
        rec = float(jnp.linalg.norm(q @ r - a) / jnp.linalg.norm(a))
        orth = float(jnp.linalg.norm(q.T @ q - jnp.eye(q.shape[1])))
        print(f"{method:10s} reconstruction={rec:.2e} orthogonality={orth:.2e}")

    # 2. the Pallas-kernel-backed blocked MHT (interpret mode on CPU)
    q, r = qr(a, method="geqrf_ht", use_kernel=True, block=64)
    print(f"{'kernels':10s} reconstruction="
          f"{float(jnp.linalg.norm(q @ r - a) / jnp.linalg.norm(a)):.2e}")

    # 3. the optimizer primitive: orthogonalize a momentum matrix
    o = orthogonalize(jnp.asarray(rng.standard_normal((256, 64)), jnp.float32))
    print("orthogonalize:", o.shape,
          float(jnp.linalg.norm(o.T @ o - jnp.eye(64))))

    # 4. least squares (Kalman-filter building block, paper §1)
    x = lstsq(a, a @ jnp.ones((128,), jnp.float32))
    print("lstsq residual:", float(jnp.linalg.norm(x - 1.0)))

    # 5. the paper's parallelism claim (fig 9)
    print("theta (4-wide RDP model, n=512):",
          round(phase_model_theta(512)["theta"], 4), "~ paper 0.749")


if __name__ == "__main__":
    main()
