"""Optimizer-step orthogonalization bench — dispatch economy of the
shape-class-batched QR-Muon step (beyond-paper §Perf).

One Muon step orthogonalizes every 2-D momentum matrix in the model.
The leafwise baseline issues one QR program per parameter leaf; the
batched path (``muon_update(..., batched_ortho=True)``) groups the
matrices into shape classes and issues ONE dispatch per class
(:mod:`repro.optim.batched_ortho`).  This bench runs both twins on the
same model/grads and reports, per twin,

  * per-step optimizer wall time (the ``muon_update`` call alone — the
    quantity the batching accelerates; fwd/bwd would dilute it),
  * QR dispatches per step: leafwise = one per Muon leaf, batched =
    ``plan_batched_ortho(...).dispatches`` (a pure shape query — the
    routing is static, so the count needs no runtime instrumentation),
  * shape classes / matrices per step and the resulting speedup,
  * max param divergence between the twins (parity guard: same update,
    different dispatch schedule).

Records merge into BENCH_qr.json on the qr-bench-v2 schema via
``benchmarks/run.py`` (twin rows ``optim_muon_qr_step[batched]`` /
``[leafwise]`` carry ``dispatches_per_step`` / ``shape_classes`` /
``matrices_per_step`` / ``speedup_vs_leafwise`` extras); standalone use
writes BENCH_optim.json:

    PYTHONPATH=src python benchmarks/bench_optim.py --smoke
"""

import argparse
import functools
import json
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import init_params
from repro.observability import metrics as _obs_metrics
from repro.optim import (is_muon_param, muon_init, muon_update,
                         plan_batched_ortho)


def _qr_flops(m: int, n: int) -> float:
    if m < n:
        m, n = n, m
    return 2.0 * n * n * (m - n / 3.0)


def _muon_leaves(params):
    """(shape, dtype) of every Muon-routed leaf, tree order."""
    leaves = []
    jax.tree_util.tree_map_with_path(
        lambda path, p: leaves.append((tuple(p.shape), p.dtype))
        if is_muon_param(path, p) else None, params)
    return leaves


def _time_step(step, grads, state, params, reps):
    """Median per-step wall of a compiled optimizer step (state threads
    through so every rep does real momentum work)."""
    new_p, new_s = step(grads, state, params)
    jax.block_until_ready(new_p)  # compile + warm
    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        new_p, new_s = step(grads, new_s, params)
        jax.block_until_ready(new_p)
        walls.append(time.perf_counter() - t0)
    return float(np.median(walls)), (new_p, new_s)


def sweep(smoke: bool = False) -> list:
    """Run the batched/leafwise optimizer-step twins; returns
    qr-bench-v2-compatible records (run.py merges them into
    BENCH_qr.json next to the method and serving sweeps)."""
    cfg = get_smoke_config("smollm-135m") if smoke \
        else get_config("smollm-135m")
    reps = 10 if smoke else 20
    params = init_params(jax.random.PRNGKey(0), cfg)
    keys = iter(jax.random.split(jax.random.PRNGKey(1),
                                 len(jax.tree.leaves(params))))
    grads = jax.tree.map(
        lambda p: 0.1 * jax.random.normal(next(keys), p.shape, jnp.float32),
        params)
    state = muon_init(params)

    shapes = _muon_leaves(params)
    plan = plan_batched_ortho(shapes)
    step_flops = sum(
        _qr_flops(s[-2], s[-1]) * int(np.prod(s[:-2], dtype=np.int64))
        for s, _ in shapes)

    records, results = [], {}
    for label, batched in [("leafwise", False), ("batched", True)]:
        d0 = _obs_metrics.counter_total("optim.ortho_dispatches")
        step = jax.jit(functools.partial(muon_update, lr=0.02,
                                         batched_ortho=batched))
        wall, results[label] = _time_step(step, grads, state, params, reps)
        dispatches = plan.dispatches if batched else len(shapes)
        records.append(dict(
            method=f"optim_muon_qr_step[{label}]",
            m=max(c.key.m for c in plan.classes),
            n=max(c.key.n for c in plan.classes),
            dtype="float32",
            wall_us=wall * 1e6,
            gflops=step_flops / wall / 1e9,
            engine=False, dispatch_mode=None,
            dispatches_per_step=dispatches,
            shape_classes=len(plan.classes),
            matrices_per_step=plan.n_matrices,
            muon_leaves=len(shapes),
            metrics=dict(traced_ortho_dispatches=int(
                _obs_metrics.counter_total("optim.ortho_dispatches") - d0)),
        ))
    # Parity guard + twin-relative extras ride on the batched record.
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))),
        results["leafwise"][0], results["batched"][0])))
    records[1]["speedup_vs_leafwise"] = records[0]["wall_us"] / \
        records[1]["wall_us"]
    records[1]["max_param_diff_vs_leafwise"] = diff
    print(f"# optim step: {plan.n_matrices} matrices -> "
          f"{plan.dispatches} dispatches ({len(plan.classes)} classes); "
          f"speedup {records[1]['speedup_vs_leafwise']:.2f}x, "
          f"twin param diff {diff:.2e}", file=sys.stderr)
    return records


def rows(records: list) -> list:
    """Format optimizer records as the harness's CSV rows."""
    out = []
    for r in records:
        derived = (f"dispatches={r['dispatches_per_step']};"
                   f"classes={r['shape_classes']};"
                   f"matrices={r['matrices_per_step']}")
        if "speedup_vs_leafwise" in r:
            derived += (f";speedup={r['speedup_vs_leafwise']:.2f}"
                        f";param_diff={r['max_param_diff_vs_leafwise']:.1e}")
        out.append((r["method"], r["wall_us"], derived))
    return out


def run(smoke: bool = False) -> list:
    return rows(sweep(smoke=smoke))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced (smoke) model config")
    ap.add_argument("--json", default="BENCH_optim.json", metavar="PATH",
                    help="where to write records (standalone runs)")
    args = ap.parse_args()
    records = sweep(smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, derived in rows(records):
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": "qr-bench-v2", "smoke": args.smoke,
                       "records": records}, f, indent=1)
        print(f"wrote {len(records)} records to {args.json}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
