"""Beyond-paper table: QR-Muon vs NS-Muon vs AdamW on a small LM.

The paper's MHT QR as a production optimizer primitive (DESIGN.md §3):
loss after a fixed budget of steps on the deterministic synthetic stream,
plus per-step orthogonalization cost.
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.training import TrainConfig, init_train_state, make_train_step


def run() -> list:
    cfg = get_smoke_config("smollm-135m")
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8, seed=3))
    rows = []
    for opt, lr in [("muon-qr", 0.02), ("muon-ns", 0.02), ("adamw", 2e-3)]:
        from repro.models import init_params

        params = init_params(jax.random.PRNGKey(0), cfg)
        tc = TrainConfig(optimizer=opt, lr=lr)
        state = init_train_state(params, tc)
        step = jax.jit(make_train_step(cfg, tc))
        lr_arr = jnp.float32(lr)
        # warmup/compile
        state, metrics = step(state, data.peek(0), lr_arr)
        jax.block_until_ready(metrics["loss"])
        t0 = time.perf_counter()
        losses = []
        for i in range(1, 16):
            state, metrics = step(state, data.peek(i), lr_arr)
            losses.append(float(metrics["loss"]))
        dt = (time.perf_counter() - t0) / 15 * 1e6
        rows.append((f"optim_{opt}", dt,
                     f"loss_step15={losses[-1]:.3f};loss_step1={losses[0]:.3f}"))
    return rows
