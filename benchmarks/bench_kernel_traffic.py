"""Paper §5.1 / Fig. 13 (C3): the fused macro-op halves trailing-update
memory traffic — the Gflops/watt argument is a traffic argument.

Analytic HBM traffic per panel factorization on the TPU memory model:
  * classical two-pass per column: read A + write A (DGEMV pass) then
    read A + write A again (DGER pass) -> 2 HBM round trips x b columns;
  * MHT fused column update: 1 round trip x b columns;
  * mht_panel kernel (panel VMEM-resident for ALL columns): 1 round trip
    for the whole panel.

Wavefront traffic (the tiled DAG analogue of the same argument): per DAG
level the old scheduler gathered each kind's tiles out of a functional
(p, q, nb, nb) array, vmapped, and scattered back with ``.at[].set`` —
each scatter group materializing a FULL fresh workspace (read + write of
all p*q tiles).  The macro-op engine (:mod:`repro.core.engine`) instead
DMAs exactly the tiles each task touches against an aliased in-place
workspace.  :func:`wavefront_traffic` prices both paths per wavefront
from the static schedule + the per-op tile_reads/tile_writes cards in
:mod:`repro.kernels.macro_ops` (reflector-state arrays, ~nb/tile smaller,
are ignored on both sides).

Also times the Pallas kernels (interpret mode) against their oracles to
pin the numbers to a real implementation.
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine
from repro.kernels import macro_ops, ops, ref

# .at[].set scatter groups the old scheduler issued per kind per level
# (TSQRT and SSRFB each wrote two tile index groups).
_OLD_SCATTER_GROUPS = {"GEQRT": 1, "LARFB": 1, "TSQRT": 2, "SSRFB": 2}


def _bytes_model(m, b):
    panel = m * b * 4
    return {
        "classical_two_pass": 2 * 2 * b * panel,  # rd+wr, 2 passes, b cols
        "mht_fused_column": 2 * b * panel,        # rd+wr, 1 pass, b cols
        "mht_panel_kernel": 2 * panel,            # rd+wr once for the panel
    }


def wavefront_traffic(p: int, q: int, nb: int, itemsize: int = 4) -> list:
    """Per-wavefront HBM bytes: old gather/scatter path vs the engine.

    Returns one dict per DAG level with ``old_bytes`` (per-task gathered
    tiles + one full-workspace copy per scatter group) and
    ``engine_bytes`` (per-task DMA'd tiles only).
    """
    tile = nb * nb * itemsize
    workspace = p * q * tile
    out = []
    for lvl, by_kind in enumerate(engine.wavefront_task_arrays(p, q)):
        old = eng = 0
        ntasks = 0
        for kind, idx in by_kind.items():
            op = macro_ops.MACRO_OPS[kind]
            n = idx.shape[0]
            ntasks += n
            moved = n * (op.tile_reads + op.tile_writes) * tile
            eng += moved
            # gather reads + computed-tile writes + the functional
            # array copies behind each .at[].set group (read + write)
            old += moved + _OLD_SCATTER_GROUPS[kind] * 2 * workspace
        out.append(dict(level=lvl, ntasks=ntasks, old_bytes=old,
                        engine_bytes=eng))
    return out


def run() -> list:
    rows = []
    for (m, b) in [(512, 64), (1024, 128)]:
        model = _bytes_model(m, b)
        base = model["classical_two_pass"]
        for k, v in model.items():
            rows.append((f"fig13_traffic_{k}_{m}x{b}", 0.0,
                         f"bytes={v};vs_classical={base / v:.1f}x"))
        # pin to implementation: kernel output must match oracle
        a = jnp.asarray(np.random.default_rng(0).standard_normal((m, b)),
                        jnp.float32)
        t0 = time.perf_counter()
        pk, tk = ops.mht_panel(a)
        jax.block_until_ready(pk)
        dt = (time.perf_counter() - t0) * 1e6
        pr, tr = ref.mht_panel_ref(a)
        err = float(jnp.max(jnp.abs(pk - pr)))
        rows.append((f"fig13_kernel_check_{m}x{b}", dt,
                     f"max_err_vs_oracle={err:.2e}"))

    # -- tiled-DAG wavefront traffic: gather/scatter vs workspace engine --
    for (p, q, nb) in [(8, 8, 64), (16, 4, 64)]:
        levels = wavefront_traffic(p, q, nb)
        tot_old = sum(l["old_bytes"] for l in levels)
        tot_eng = sum(l["engine_bytes"] for l in levels)
        rows.append((
            f"wavefront_traffic_total_{p}x{q}t{nb}", 0.0,
            f"old_bytes={tot_old};engine_bytes={tot_eng};"
            f"saved={1.0 - tot_eng / tot_old:.1%}"))
        for l in levels[:: max(1, len(levels) // 4)]:  # a few sample levels
            rows.append((
                f"wavefront_traffic_L{l['level']}_{p}x{q}t{nb}", 0.0,
                f"ntasks={l['ntasks']};old_bytes={l['old_bytes']};"
                f"engine_bytes={l['engine_bytes']}"))

    # pin to implementation: the engine's two lowerings must agree
    # bitwise on a real workspace (interpret-mode Pallas on CPU)
    p = q = 3
    nb = 16
    ws = jnp.asarray(
        np.random.default_rng(1).standard_normal((p, q, nb, nb)), jnp.float32)
    t0 = time.perf_counter()
    f_eng = engine.factor_tiles(ws.copy(), p=p, q=q, nb=nb, use_kernel=True)
    jax.block_until_ready(f_eng.tiles)
    dt = (time.perf_counter() - t0) * 1e6
    f_jnp = engine.factor_tiles(ws, p=p, q=q, nb=nb, use_kernel=False)
    bitwise = all(bool((a == b).all()) for a, b in zip(f_eng, f_jnp))
    rows.append((f"wavefront_engine_check_{p}x{q}t{nb}", dt,
                 f"bitwise_vs_oracle={bitwise}"))
    return rows
