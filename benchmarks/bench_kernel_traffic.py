"""Paper §5.1 / Fig. 13 (C3): the fused macro-op halves trailing-update
memory traffic — the Gflops/watt argument is a traffic argument.

Analytic HBM traffic per panel factorization on the TPU memory model:
  * classical two-pass per column: read A + write A (DGEMV pass) then
    read A + write A again (DGER pass) -> 2 HBM round trips x b columns;
  * MHT fused column update: 1 round trip x b columns;
  * mht_panel kernel (panel VMEM-resident for ALL columns): 1 round trip
    for the whole panel.

Wavefront traffic (the tiled DAG analogue of the same argument): per DAG
level the old scheduler gathered each kind's tiles out of a functional
(p, q, nb, nb) array, vmapped, and scattered back with ``.at[].set`` —
each scatter group materializing a FULL fresh workspace (read + write of
all p*q tiles).  The macro-op engine (:mod:`repro.core.engine`) instead
DMAs exactly the tiles each task touches against an aliased in-place
workspace, and its single-dispatch **megakernel** mode goes one step
further: consecutive tasks re-reading a tile (or block reflector) the
double buffer already holds take a VMEM-local copy instead of touching
HBM again, so per-task DMA drops below the per-level wavefront mode's —
while the dispatch count collapses from O(levels x kinds) pallas_calls
per factorization to exactly ONE.  :func:`wavefront_traffic` prices all
three paths per wavefront from the static schedule + the per-op
tile_reads/tile_writes cards in :mod:`repro.kernels.macro_ops`
(reflector-state arrays, ~nb/tile smaller, are ignored on all sides).

Also times the Pallas kernels (interpret mode) against their oracles to
pin the numbers to a real implementation.
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine
from repro.kernels import macro_ops, ops, ref

# .at[].set scatter groups the old scheduler issued per kind per level
# (TSQRT and SSRFB each wrote two tile index groups).
_OLD_SCATTER_GROUPS = {"GEQRT": 1, "LARFB": 1, "TSQRT": 2, "SSRFB": 2}


def _bytes_model(m, b):
    panel = m * b * 4
    return {
        "classical_two_pass": 2 * 2 * b * panel,  # rd+wr, 2 passes, b cols
        "mht_fused_column": 2 * b * panel,        # rd+wr, 1 pass, b cols
        "mht_panel_kernel": 2 * panel,            # rd+wr once for the panel
    }


def wavefront_traffic(p: int, q: int, nb: int, itemsize: int = 4) -> list:
    """Per-wavefront HBM bytes: old gather/scatter path vs the engine's
    two dispatch modes.

    Returns one dict per DAG level with ``old_bytes`` (per-task gathered
    tiles + one full-workspace copy per scatter group), ``engine_bytes``
    (wavefront mode: per-task DMA'd tiles only — every operand re-fetched
    from HBM each level), and ``megakernel_bytes`` (same per-task DMA
    minus the fetches the persistent kernel's double buffer serves from
    the resident copy, per :func:`repro.core.engine.
    megakernel_reused_reads`).
    """
    tile = nb * nb * itemsize
    workspace = p * q * tile
    reused = engine.megakernel_reused_reads(p, q)
    out = []
    for lvl, by_kind in enumerate(engine.wavefront_task_arrays(p, q)):
        old = eng = 0
        ntasks = 0
        for kind, idx in by_kind.items():
            op = macro_ops.MACRO_OPS[kind]
            n = idx.shape[0]
            ntasks += n
            moved = n * (op.tile_reads + op.tile_writes) * tile
            eng += moved
            # gather reads + computed-tile writes + the functional
            # array copies behind each .at[].set group (read + write)
            old += moved + _OLD_SCATTER_GROUPS[kind] * 2 * workspace
        out.append(dict(level=lvl, ntasks=ntasks, old_bytes=old,
                        engine_bytes=eng,
                        megakernel_bytes=eng - int(reused[lvl]) * tile))
    return out


def run() -> list:
    rows = []
    for (m, b) in [(512, 64), (1024, 128)]:
        model = _bytes_model(m, b)
        base = model["classical_two_pass"]
        for k, v in model.items():
            rows.append((f"fig13_traffic_{k}_{m}x{b}", 0.0,
                         f"bytes={v};vs_classical={base / v:.1f}x"))
        # pin to implementation: kernel output must match oracle
        a = jnp.asarray(np.random.default_rng(0).standard_normal((m, b)),
                        jnp.float32)
        t0 = time.perf_counter()
        pk, tk = ops.mht_panel(a)
        jax.block_until_ready(pk)
        dt = (time.perf_counter() - t0) * 1e6
        pr, tr = ref.mht_panel_ref(a)
        err = float(jnp.max(jnp.abs(pk - pr)))
        rows.append((f"fig13_kernel_check_{m}x{b}", dt,
                     f"max_err_vs_oracle={err:.2e}"))

    # -- tiled-DAG wavefront traffic: gather/scatter vs engine modes ------
    for (p, q, nb) in [(8, 8, 64), (16, 4, 64), (16, 16, 64)]:
        levels = wavefront_traffic(p, q, nb)
        tot_old = sum(l["old_bytes"] for l in levels)
        tot_eng = sum(l["engine_bytes"] for l in levels)
        tot_meg = sum(l["megakernel_bytes"] for l in levels)
        rows.append((
            f"wavefront_traffic_total_{p}x{q}t{nb}", 0.0,
            f"old_bytes={tot_old};engine_bytes={tot_eng};"
            f"megakernel_bytes={tot_meg};"
            f"saved={1.0 - tot_eng / tot_old:.1%};"
            f"mega_vs_wavefront={1.0 - tot_meg / tot_eng:.1%}"))
        stats = engine.schedule_stats(p, q, nb)
        rows.append((
            f"dispatch_count_{p}x{q}t{nb}", 0.0,
            f"wavefront_dispatches={stats['wavefront']['dispatches']};"
            f"megakernel_dispatches={stats['megakernel']['dispatches']};"
            f"reduction={stats['wavefront']['dispatches']}x->1;"
            f"table_bytes={stats['megakernel']['table_bytes']}"))
        for l in levels[:: max(1, len(levels) // 4)]:  # a few sample levels
            rows.append((
                f"wavefront_traffic_L{l['level']}_{p}x{q}t{nb}", 0.0,
                f"ntasks={l['ntasks']};old_bytes={l['old_bytes']};"
                f"engine_bytes={l['engine_bytes']};"
                f"megakernel_bytes={l['megakernel_bytes']}"))

    # pin to implementation: the engine's kernel lowerings (per-level
    # wavefront dispatches AND the single-call megakernel) must agree
    # bitwise with the oracle on a real workspace (interpret-mode Pallas)
    p = q = 3
    nb = 16
    ws = jnp.asarray(
        np.random.default_rng(1).standard_normal((p, q, nb, nb)), jnp.float32)
    f_jnp = engine.factor_tiles(ws.copy(), p=p, q=q, nb=nb, use_kernel=False)
    for mode in engine.DISPATCH_MODES:
        t0 = time.perf_counter()
        f_eng = engine.factor_tiles(ws.copy(), p=p, q=q, nb=nb,
                                    use_kernel=True, dispatch_mode=mode)
        jax.block_until_ready(f_eng.tiles)
        dt = (time.perf_counter() - t0) * 1e6
        bitwise = all(bool((a == b).all()) for a, b in zip(f_eng, f_jnp))
        rows.append((f"{mode}_engine_check_{p}x{q}t{nb}", dt,
                     f"bitwise_vs_oracle={bitwise}"))
    return rows
