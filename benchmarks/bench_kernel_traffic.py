"""Paper §5.1 / Fig. 13 (C3): the fused macro-op halves trailing-update
memory traffic.

Analytic HBM traffic per panel factorization on the TPU memory model:
  * classical two-pass per column: read A + write A (DGEMV pass) then
    read A + write A again (DGER pass) -> 2 HBM round trips x b columns;
  * MHT fused column update: 1 round trip x b columns;
  * mht_panel kernel (panel VMEM-resident for ALL columns): 1 round trip
    for the whole panel.

Also times the Pallas kernel (interpret mode) against its oracle to pin
the numbers to a real implementation.
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _bytes_model(m, b):
    panel = m * b * 4
    return {
        "classical_two_pass": 2 * 2 * b * panel,  # rd+wr, 2 passes, b cols
        "mht_fused_column": 2 * b * panel,        # rd+wr, 1 pass, b cols
        "mht_panel_kernel": 2 * panel,            # rd+wr once for the panel
    }


def run() -> list:
    rows = []
    for (m, b) in [(512, 64), (1024, 128)]:
        model = _bytes_model(m, b)
        base = model["classical_two_pass"]
        for k, v in model.items():
            rows.append((f"fig13_traffic_{k}_{m}x{b}", 0.0,
                         f"bytes={v};vs_classical={base / v:.1f}x"))
        # pin to implementation: kernel output must match oracle
        a = jnp.asarray(np.random.default_rng(0).standard_normal((m, b)),
                        jnp.float32)
        t0 = time.perf_counter()
        pk, tk = ops.mht_panel(a)
        jax.block_until_ready(pk)
        dt = (time.perf_counter() - t0) * 1e6
        pr, tr = ref.mht_panel_ref(a)
        err = float(jnp.max(jnp.abs(pk - pr)))
        rows.append((f"fig13_kernel_check_{m}x{b}", dt,
                     f"max_err_vs_oracle={err:.2e}"))
    return rows
