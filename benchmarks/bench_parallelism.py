"""Paper Fig. 9: theta (parallelism ratio) vs matrix size — HT vs MHT vs
the tiled task graph.

Rebuilds the HT and MHT DAGs symbolically and reports
  - theta_levels: level ratio under unbounded-width tree reductions,
  - theta_width4: the paper's 4-wide RDP phase model (saturates ~0.749),
  - beta gain (equal-ops accounting, eq. 9/10),
and extends the same beta = ops/levels metric to the tiled wavefront
DAG (:func:`repro.core.dag.analyze_tiled`), where a level is one
wavefront of macro tile tasks — the cross-panel parallelism the paper's
§5.2 PE tiling targets — and further to the multi-device sharded
schedule (:func:`repro.core.dag.analyze_sharded_tiled`), where the
domains of the tile grid run concurrently across devices and a level is
one cross-device wavefront.
"""

import time

from repro.core.dag import sharded_curve, theta_curve, tiled_curve


def run() -> list:
    t0 = time.time()
    rows = theta_curve((4, 8, 16, 32, 64, 128))["rows"]
    dt = (time.time() - t0) * 1e6 / len(rows)
    t1 = time.time()
    trows = tiled_curve((64, 128, 256), tile=16)["rows"]
    dt_tiled = (time.time() - t1) * 1e6 / len(trows)
    t2 = time.time()
    srows = sharded_curve((128, 256, 512), tile=16, ndomains=4)["rows"]
    dt_sharded = (time.time() - t2) * 1e6 / len(srows)
    out = []
    for r in rows:
        out.append((f"fig9_theta_n{r['n']}", dt,
                    f"theta_w4={r['theta_width4']:.4f};"
                    f"gain_w4={r['gain_width4']:.3f};"
                    f"theta_tree={r['theta_levels']:.4f};"
                    f"beta_mht={r['beta_mht']:.1f}"))
    for r in trows:
        out.append((f"fig9_tiled_n{r['n']}", dt_tiled,
                    f"beta_tiled={r['beta_tiled']:.1f};"
                    f"beta_mht={r['beta_mht']:.1f};"
                    f"gain_tiled={r['beta_gain_tiled']:.1f};"
                    f"wavefronts={r['tiled_levels']}"))
    for r in srows:
        out.append((f"fig9_sharded_n{r['n']}_d{r['ndomains']}", dt_sharded,
                    f"beta_sharded={r['beta_sharded']:.1f};"
                    f"beta_tiled={r['beta_tiled']:.1f};"
                    f"gain_sharded={r['beta_gain_sharded']:.1f};"
                    f"level_gain={r['level_gain']:.2f};"
                    f"wavefronts={r['sharded_levels']}"))
    return out
