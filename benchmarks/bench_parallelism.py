"""Paper Fig. 9: theta (parallelism ratio) vs matrix size.

Rebuilds the HT and MHT DAGs symbolically and reports
  - theta_levels: level ratio under unbounded-width tree reductions,
  - theta_width4: the paper's 4-wide RDP phase model (saturates ~0.749),
  - beta gain (equal-ops accounting, eq. 9/10).
"""

import time

from repro.core.dag import theta_curve


def run() -> list:
    t0 = time.time()
    rows = theta_curve((4, 8, 16, 32, 64, 128))["rows"]
    dt = (time.time() - t0) * 1e6 / len(rows)
    out = []
    for r in rows:
        out.append((f"fig9_theta_n{r['n']}", dt,
                    f"theta_w4={r['theta_width4']:.4f};"
                    f"gain_w4={r['gain_width4']:.3f};"
                    f"theta_tree={r['theta_levels']:.4f};"
                    f"beta_mht={r['beta_mht']:.1f}"))
    return out
