"""QR serving microbenchmark — the decode-loop-style harness for the
batched serving layer (QRService).

Drives a steady-state request stream through the service the way the
decode microbenchmark drives ServeEngine steps: warm the compiled-plan
cache with one wave of the shape mix (cold compiles excluded from
timing, exactly like discarding the first decode step), then run timed
waves of heterogeneous requests through ``submit_many`` and report

  * per-request latency p50 / p99 (a request's latency is the wall time
    of the flush that served it),
  * throughput in matrices/sec and effective GFLOP/s (thin-QR flop
    count summed over the true, unpadded request shapes),
  * bucket fill ratio and plan-cache hit rate from ``QRService.stats()``,
  * speedup over the one-dispatch-per-request baseline (the same
    stream, flushed after every submit — what serving without bucketing
    would do).

Records merge into BENCH_qr.json on the qr-bench-v2 schema via
``benchmarks/run.py`` (serving rows carry extra ``p50_us`` /
``p99_us`` / ``matrices_per_s`` / ``bucket_fill_ratio`` /
``cache_hit_rate`` / ``speedup_vs_unbatched`` fields); standalone use
writes BENCH_qr_serving.json:

    PYTHONPATH=src python benchmarks/bench_qr_serving.py --smoke
"""

import argparse
import json
import sys
import time

import numpy as np

import jax

from repro.observability import metrics as _obs_metrics
from repro.serving import BucketingPolicy, QRService

# The mixes are weighted toward repeat shapes (steady-state serving
# traffic is bursty around a few hot shape classes) with ragged
# stragglers that bucket-pad into them.  Waves are deep (16 requests)
# and shapes small-to-medium: many concurrent small QRs is the workload
# batched serving exists for — per-dispatch overhead dominates there,
# which is what bucketing amortizes (the >= 2x acceptance regime).
_SMOKE_MIX = [(32, 32), (32, 32), (30, 28), (32, 32), (24, 24), (32, 32),
              (32, 32), (33, 17)] * 2
_FULL_MIX = [(128, 128), (128, 128), (120, 110), (96, 64), (128, 128),
             (64, 64), (130, 120), (128, 128)] * 2


def _qr_flops(m: int, n: int) -> float:
    k = min(m, n)
    return 2.0 * k * k * (m - k / 3.0)


def _mk_wave(shapes, rng, dtype=np.float32):
    return [rng.standard_normal(s).astype(dtype) for s in shapes]


def _serve_stream(svc, waves, *, per_request: bool):
    """Run the stream; returns (per-request latencies in seconds, total
    wall).  ``per_request=True`` is the unbatched baseline: every submit
    is flushed alone (one dispatch per request, no bucketing benefit,
    same plan cache)."""
    lat = []
    t_start = time.perf_counter()
    for wave in waves:
        if per_request:
            for a in wave:
                t0 = time.perf_counter()
                rid = svc.submit(a)
                out = svc.flush()
                np.asarray(out[rid].r)  # materialize
                lat.append(time.perf_counter() - t0)
        else:
            t0 = time.perf_counter()
            results = svc.submit_many(wave)
            for res in results:
                np.asarray(res.r)
            lat.extend([time.perf_counter() - t0] * len(wave))
    return lat, time.perf_counter() - t_start


def _bench_config(label, mix, waves, *, use_kernel, dispatch_mode, tile,
                  max_batch, seed=0):
    """One serving record: warm, stream, baseline, stats."""
    rng = np.random.default_rng(seed)
    mk_svc = lambda: QRService(  # noqa: E731
        policy=BucketingPolicy(tile=tile, max_batch=max_batch),
        use_kernel=use_kernel, dispatch_mode=dispatch_mode)

    dma0 = _obs_metrics.counter_total("engine.modeled_dma_bytes")
    svc = mk_svc()
    svc.submit_many(_mk_wave(mix, rng))  # warm: compiles happen here
    warm_compiles = svc.stats()["compiles"]
    stream = [_mk_wave(mix, rng) for _ in range(waves)]
    lat, wall = _serve_stream(svc, stream, per_request=False)
    stats = svc.stats()
    assert stats["compiles"] == warm_compiles, "recompiled mid-stream"

    base = mk_svc()
    # Warm the baseline's batch-1 plans in its own mode so its timed
    # loop is equally compile-free — the comparison isolates bucketed
    # batching, not cold compiles.
    _serve_stream(base, [_mk_wave(mix, rng)], per_request=True)
    _, base_wall = _serve_stream(base, stream, per_request=True)

    nmat = waves * len(mix)
    flops = waves * sum(_qr_flops(m, n) for m, n in mix)
    mps, base_mps = nmat / wall, nmat / base_wall
    # Registry snapshot attached to the record: serving dispatch economy
    # plus the engine's modeled HBM bytes for the programs traced while
    # this config compiled (engine metrics emit at trace time).
    metrics = dict(
        dispatches=stats["dispatches"], compiles=stats["compiles"],
        padded_slots=stats["padded_slots"],
        cache_hit_rate=stats["cache_hit_rate"],
        traced_modeled_dma_bytes=int(
            _obs_metrics.counter_total("engine.modeled_dma_bytes") - dma0),
    )
    return dict(
        method=label, m=max(s[0] for s in mix), n=max(s[1] for s in mix),
        dtype="float32",
        wall_us=float(np.percentile(lat, 50) * 1e6),
        gflops=flops / wall / 1e9,
        engine=bool(use_kernel), dispatch_mode=dispatch_mode,
        p50_us=float(np.percentile(lat, 50) * 1e6),
        p99_us=float(np.percentile(lat, 99) * 1e6),
        matrices_per_s=mps,
        baseline_matrices_per_s=base_mps,
        speedup_vs_unbatched=mps / base_mps,
        bucket_fill_ratio=stats["bucket_fill_ratio"],
        cache_hit_rate=stats["cache_hit_rate"],
        dispatches=stats["dispatches"],
        matrices_served=stats["matrices_served"],
        shape_mix=[list(s) for s in mix],
        metrics=metrics,
    ), stats


def _bench_chaos(mix, waves, *, tile, max_batch, seed=0,
                 fault_frac=0.05):
    """The ``--chaos`` record: the same stream with verification ON and
    a ~``fault_frac`` injected-fault mix — poisoned (NaN) payloads that
    admission must quarantine, plus armed output-corruption faults the
    per-slice health check must catch and heal.  Reports latency
    percentiles UNDER chaos next to the escalation/quarantine counts, so
    the trajectory prices what hardening costs when things actually go
    wrong (the clean-stream twin prices verify-off overhead: zero)."""
    from repro.robustness import inject as _inject

    rng = np.random.default_rng(seed)
    svc = QRService(
        policy=BucketingPolicy(tile=tile, max_batch=max_batch),
        use_kernel=False, verify=True)
    svc.submit_many(_mk_wave(mix, rng))      # warm compiles
    n_total = waves * len(mix)
    n_faults = max(1, int(fault_frac * n_total))
    # Half the fault budget corrupts inputs (quarantine path), half
    # corrupts dispatch outputs (health-check -> escalation path).
    stream = []
    poisoned = 0
    for w in range(waves):
        wave = _mk_wave(mix, rng)
        if poisoned < n_faults // 2 + n_faults % 2:
            wave[w % len(wave)] = _inject.poison(
                wave[w % len(wave)], kind="nan", seed=seed + w)
            poisoned += 1
        stream.append(wave)
    out_faults = inject_faults = n_faults // 2
    with _inject.active(_inject.Fault(site="output", match="",
                                      times=out_faults, slice_index=0)):
        lat, wall = _serve_stream(svc, stream, per_request=False)
    stats = svc.stats()
    nmat = n_total
    flops = waves * sum(_qr_flops(m, n) for m, n in mix)
    metrics = dict(
        dispatches=stats["dispatches"], compiles=stats["compiles"],
        quarantined=stats["quarantined"],
        escalations=stats["escalations"],
        health_check_failures=stats["health_check_failures"],
        breaker_trips=stats["breaker_trips"],
        injected_input_faults=poisoned,
        injected_output_faults=inject_faults,
    )
    return dict(
        method="qr_service[chaos]",
        m=max(s[0] for s in mix), n=max(s[1] for s in mix),
        dtype="float32",
        wall_us=float(np.percentile(lat, 50) * 1e6),
        gflops=flops / wall / 1e9,
        engine=False, dispatch_mode=None,
        p50_us=float(np.percentile(lat, 50) * 1e6),
        p99_us=float(np.percentile(lat, 99) * 1e6),
        matrices_per_s=nmat / wall,
        bucket_fill_ratio=stats["bucket_fill_ratio"],
        cache_hit_rate=stats["cache_hit_rate"],
        dispatches=stats["dispatches"],
        matrices_served=stats["matrices_served"],
        quarantined=stats["quarantined"],
        escalations=stats["escalations"],
        fault_frac=fault_frac,
        shape_mix=[list(s) for s in mix],
        metrics=metrics,
    ), stats


def sweep(smoke: bool = False, chaos: bool = False) -> list:
    """Run the serving stream(s); returns qr-bench-v2-compatible records
    (run.py merges them into BENCH_qr.json next to the method sweep).
    ``chaos`` appends the injected-fault record (verify on, ~5% faults)."""
    mix = _SMOKE_MIX if smoke else _FULL_MIX
    waves = 4 if smoke else 8
    tile = 16 if smoke else 32
    records = []
    configs = [("qr_service[stream]", False, None)]
    # Kernel serving twin: interpret-mode Pallas is only benchable on the
    # smoke grid; on TPU the megakernel twin always runs.
    if smoke or jax.default_backend() == "tpu":
        configs.append(("qr_service[stream]+megakernel", True, "megakernel"))
    for label, use_kernel, dispatch_mode in configs:
        rec, stats = _bench_config(label, mix, waves, use_kernel=use_kernel,
                                   dispatch_mode=dispatch_mode, tile=tile,
                                   max_batch=16)
        print(f"# {label} service stats: {stats}", file=sys.stderr)
        records.append(rec)
    if chaos:
        rec, stats = _bench_chaos(mix, waves, tile=tile, max_batch=16)
        print(f"# qr_service[chaos] service stats: {stats}",
              file=sys.stderr)
        records.append(rec)
    return records


def rows(records: list) -> list:
    """Format serving records as the harness's CSV rows.  Chaos records
    trade the unbatched-baseline column for escalation/quarantine
    counts."""
    out = []
    for r in records:
        if "escalations" in r:
            derived = (f"p99_us={r['p99_us']:.1f};"
                       f"mat_per_s={r['matrices_per_s']:.1f};"
                       f"quarantined={r['quarantined']};"
                       f"escalations={r['escalations']};"
                       f"fault_frac={r['fault_frac']:.2f}")
        else:
            derived = (f"p99_us={r['p99_us']:.1f};"
                       f"mat_per_s={r['matrices_per_s']:.1f};"
                       f"speedup={r['speedup_vs_unbatched']:.2f};"
                       f"fill={r['bucket_fill_ratio']:.2f};"
                       f"cache_hit={r['cache_hit_rate']:.2f}")
        out.append((f"qr_serving_{r['method']}", r["p50_us"], derived))
    return out


def run(smoke: bool = False) -> list:
    return rows(sweep(smoke=smoke))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shape mix + interpret-mode kernel twin")
    ap.add_argument("--chaos", action="store_true",
                    help="append the injected-fault record: verify on, "
                         "~5%% poisoned/corrupted requests, escalation "
                         "and quarantine counts next to the percentiles")
    ap.add_argument("--json", default="BENCH_qr_serving.json", metavar="PATH",
                    help="where to write serving records (standalone runs)")
    args = ap.parse_args()
    records = sweep(smoke=args.smoke, chaos=args.chaos)
    print("name,us_per_call,derived")
    for name, us, derived in rows(records):
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": "qr-bench-v2", "smoke": args.smoke,
                       "records": records}, f, indent=1)
        print(f"wrote {len(records)} records to {args.json}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
