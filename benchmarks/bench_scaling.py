"""Paper Fig. 14(e,f): parallel QR scaling over the fabric.

The paper tiles PEs K x K on REDEFINE and shows near-linear speedup.  The
mesh analogue is the butterfly-tree TSQR: per-shard work drops linearly
with P while the tree adds log2(P) small (n x n) exchanges.  We measure
structural scaling (per-shard FLOPs, wire bytes, tree depth) exactly and
wall time on P fake CPU devices for reference (host cores bound it).
"""

import json
import subprocess
import sys
import textwrap


def _run_p(p: int, m: int, n: int) -> dict:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={p}"
        import time, json
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.tsqr import tsqr_tree_sharded

        mesh = jax.make_mesh(({p},), ("data",))
        a = jnp.asarray(np.random.default_rng(0).standard_normal(({m}, {n})),
                        jnp.float32)
        f = jax.jit(jax.shard_map(lambda x: tsqr_tree_sharded(x, "data"),
                                  mesh=mesh, in_specs=P("data", None),
                                  out_specs=P()))
        r = f(a); jax.block_until_ready(r)
        t0 = time.perf_counter()
        for _ in range(3):
            r = f(a); jax.block_until_ready(r)
        dt = (time.perf_counter() - t0) / 3
        rounds = ({p}).bit_length() - 1
        local_flops = 2.0 * ({m} / {p}) * {n}**2 + rounds * 2.0 * (2*{n}) * {n}**2
        wire = rounds * {n} * {n} * 4
        print(json.dumps(dict(p={p}, wall_us=dt * 1e6,
                              local_flops=local_flops, wire_bytes=wire,
                              rounds=rounds)))
    """)
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src",
                              "PATH": "/usr/bin:/bin:/usr/local/bin"})
    return json.loads(res.stdout.strip().splitlines()[-1])


def run() -> list:
    rows = []
    m, n = 4096, 64
    base = None
    for p in (1, 2, 4, 8):
        try:
            r = _run_p(p, m, n)
        except Exception as e:  # pragma: no cover
            rows.append((f"fig14e_tsqr_p{p}", 0.0, f"error={e}"))
            continue
        if base is None:
            base = r["local_flops"]
        rows.append((f"fig14e_tsqr_p{p}", r["wall_us"],
                     f"flops_per_shard={r['local_flops']:.0f};"
                     f"work_speedup={base / r['local_flops']:.2f}x;"
                     f"wire_bytes={r['wire_bytes']};rounds={r['rounds']}"))
    return rows
