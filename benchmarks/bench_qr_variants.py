"""Paper Fig. 11 / 14(a,b): QR variant performance.

DGEQR2 (classical HT), DGEQR2HT (MHT), DGEQRF (blocked HT), DGEQRFHT
(blocked MHT), DGEQRFHT+kernels (Pallas panel + WY trailing), and the
textbook explicit-P classical — wall time and achieved GFLOP/s on the
host (algorithmic comparison; the TPU story is the §Roofline analysis).

QR FLOPs: 2 m n^2 - (2/3) n^3.
"""

import functools
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.householder import geqr2_explicit_p
from repro.core.plan import QRConfig, plan


def _qr_flops(m, n):
    return 2.0 * m * n * n - 2.0 / 3.0 * n ** 3


def _time(fn, a, iters=3):
    out = fn(a)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(a)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


@functools.lru_cache(maxsize=None)
def _solver(method: str, shape, dtype: str):
    return plan(shape, dtype, QRConfig(method=method, block=32, use_kernel=False))


def _registry_factor(method: str):
    """Packed factorization through the planner — the solver is memoized
    per (method, shape) so re-planning stays out of the timed region."""
    return lambda a: _solver(method, a.shape, str(a.dtype)).factor(a)


def run() -> list:
    rng = np.random.default_rng(0)
    rows = []
    variants = [
        ("DGEQR2", _registry_factor("geqr2")),
        ("DGEQR2HT", _registry_factor("geqr2_ht")),
        ("DGEQR2_explicitP", lambda a: geqr2_explicit_p(a)),
        ("DGEQRF", _registry_factor("geqrf")),
        ("DGEQRFHT", _registry_factor("geqrf_ht")),
        ("DGEQRFHT_fori", _registry_factor("geqrf_fori")),
    ]
    for (m, n) in [(256, 256), (512, 256)]:
        a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        flops = _qr_flops(m, n)
        for name, fn in variants:
            if name == "DGEQR2_explicitP" and m > 256:
                continue  # O(m^2 n) per column — skip the big case
            dt = _time(fn, a)
            rows.append((f"fig11_{name}_{m}x{n}", dt * 1e6,
                         f"gflops={flops / dt / 1e9:.2f}"))
    return rows
