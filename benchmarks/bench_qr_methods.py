"""QR method sweep — the perf-trajectory benchmark behind BENCH_qr.json.

Times every registered realization (including the tiled task-graph
backend and the multi-device sharded_tiled backend) over a shape/dtype
grid and derives effective GFLOP/s from the standard thin-QR flop count
2 n^2 (m - n/3).  ``benchmarks/run.py`` serializes the records to
``BENCH_qr.json`` so the trajectory is comparable across PRs; ``--smoke``
shrinks the grid for CI (it exists to catch interpret-mode regressions
in the Pallas tile ops on CPU, not to measure).

sharded_tiled records sweep the available domain counts (device count x
shape): on a 1-device host that is the d=1 degenerate row; under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` the sweep records
the scaling trajectory over d in {1, 2, 4, 8}.

Engine rows: methods executing through the wavefront macro-op engine
(``tiled`` / ``sharded_tiled``) are timed three ways — engine-off
(``use_kernel=False``, the vmapped jnp-oracle lowering) under the plain
method label, engine-on wavefront mode (``use_kernel=True,
dispatch_mode="wavefront"``: one in-place Pallas dispatch per DAG level;
interpret mode on CPU) as ``<method>+engine``, and the single-dispatch
persistent-kernel mode (``dispatch_mode="megakernel"``: the whole
schedule as ONE pallas_call over a scalar-prefetched task table with
double-buffered tile DMA) as ``<method>+megakernel`` — so the dispatch
trajectory is recorded in the same BENCH_qr.json.  Records carry an
``engine`` boolean and a ``dispatch_mode`` field (null on jnp paths)
for trajectory queries.
"""

import time

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import QRConfig, plan  # noqa: F401

# (method, block) x shapes; tsqr only runs where its 4:1 aspect holds.
_FULL_SHAPES = [(256, 256), (512, 512), (512, 128), (1024, 128), (1024, 256)]
_SMOKE_SHAPES = [(96, 96), (128, 64), (256, 32)]
_METHODS = ["geqr2", "geqr2_ht", "geqrf", "geqrf_ht", "tsqr", "tiled",
            "sharded_tiled"]
_DTYPES = [jnp.float32]


def _domain_counts():
    """Power-of-two domain counts up to the local device count."""
    d, out = 1, []
    while d <= jax.local_device_count():
        out.append(d)
        d *= 2
    return out

# Smoke mode also exercises the Pallas kernel paths in interpret mode.
_SMOKE_KERNEL_METHODS = ("geqrf_ht",)
# Engine-backed methods get engine-on rows in every mode (win/parity
# rows for the wavefront macro-op engine vs its jnp-oracle lowering).
_ENGINE_METHODS = ("tiled", "sharded_tiled")


def _qr_flops(m: int, n: int) -> float:
    k = min(m, n)
    return 2.0 * k * k * (m - k / 3.0)


def _block(out):
    (out[0] if isinstance(out, tuple) else out).block_until_ready()


def _time_solve(solver, a, reps: int) -> float:
    _block(solver.solve(a))  # warm the jit cache
    t0 = time.perf_counter()
    for _ in range(reps):
        out = solver.solve(a)
    _block(out)
    return (time.perf_counter() - t0) / reps


def sweep(smoke: bool = False) -> list:
    """Run the grid; returns JSON-ready records
    (method x shape x dtype -> wall time / effective GFLOPs)."""
    shapes = _SMOKE_SHAPES if smoke else _FULL_SHAPES
    reps = 2 if smoke else 5
    rng = np.random.default_rng(0)
    records = []
    for m, n in shapes:
        for dtype in _DTYPES:
            a = jnp.asarray(rng.standard_normal((m, n)), dtype)
            for method in _METHODS:
                blk = 64 if method in ("tiled", "sharded_tiled") else 32
                if method == "sharded_tiled":
                    # device count x shape: one record per *effective*
                    # domain count (small grids cap d — don't re-time
                    # the same resolved config under different labels)
                    from repro.core.distgraph import effective_domains

                    eff = sorted({effective_domains(m, n, blk, d)
                                  for d in _domain_counts()})
                    cfgs = [(f"{method}@d{d}",
                             QRConfig(method=method, mode="r", block=blk,
                                      ndomains=d))
                            for d in eff]
                else:
                    cfgs = [(method, QRConfig(method=method, mode="r",
                                              block=blk))]
                if smoke and method in _SMOKE_KERNEL_METHODS:
                    cfgs.append((f"{method}+kernel", QRConfig(
                        method=method, mode="r", use_kernel=True, block=blk)))
                if method in _ENGINE_METHODS:
                    # pin the baseline to the jnp-oracle lowering (the
                    # planner would resolve use_kernel=None -> True on
                    # TPU), then add the engine-on twins of every row:
                    # per-level wavefront dispatches (+engine) and the
                    # single persistent-kernel dispatch (+megakernel).
                    # Off-TPU the engine runs interpret-mode Pallas, far
                    # too slow for the full grid — twins only in smoke
                    # (the CI record) or on real kernel hardware.
                    cfgs = [(lbl, c.replace(use_kernel=False))
                            for lbl, c in cfgs]
                    if smoke or jax.default_backend() == "tpu":
                        base = list(cfgs)
                        cfgs.extend(
                            (f"{lbl}+engine",
                             c.replace(use_kernel=True,
                                       dispatch_mode="wavefront"))
                            for lbl, c in base)
                        cfgs.extend(
                            (f"{lbl}+megakernel",
                             c.replace(use_kernel=True,
                                       dispatch_mode="megakernel"))
                            for lbl, c in base)
                for label, cfg in cfgs:
                    try:
                        solver = plan(a.shape, a.dtype, cfg)
                    except ValueError:  # capability mismatch (tsqr aspect)
                        continue
                    dt = _time_solve(solver, a, reps)
                    rec = dict(
                        method=label, m=m, n=n, dtype=str(np.dtype(dtype)),
                        wall_us=dt * 1e6,
                        gflops=_qr_flops(m, n) / dt / 1e9,
                        engine=bool(solver.config.use_kernel)
                        and solver.config.method in ("tiled", "sharded_tiled"),
                        dispatch_mode=solver.config.dispatch_mode,
                    )
                    if rec["engine"] and method == "tiled":
                        # Engine twin rows carry the schedule's modeled
                        # dispatch/traffic economics next to measured wall
                        # time (trajectory queries join on these).
                        from repro.core import engine

                        nb = min(solver.config.block, m, n)
                        st = engine.schedule_stats(
                            -(-m // nb), -(-n // nb), nb,
                            np.dtype(dtype).itemsize)
                        dm = solver.config.dispatch_mode or st["auto"]
                        rec["metrics"] = dict(
                            dispatches=st[dm]["dispatches"],
                            modeled_dma_bytes=st[dm]["modeled_dma_bytes"],
                            roofline_dma_bytes=st["roofline_dma_bytes"],
                            tasks=st["tasks"], levels=st["levels"],
                        )
                    if method == "sharded_tiled":
                        rec.update(ndevices=jax.local_device_count(),
                                   ndomains=solver.config.ndomains)
                    records.append(rec)
    return records


def rows(records: list) -> list:
    """Format sweep records as the harness's CSV rows."""
    return [
        (f"qr_{r['method']}_{r['m']}x{r['n']}_{r['dtype']}", r["wall_us"],
         f"gflops={r['gflops']:.3f}")
        for r in records
    ]


def run(smoke: bool = False) -> list:
    return rows(sweep(smoke=smoke))
