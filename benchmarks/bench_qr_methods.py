"""QR method sweep — the perf-trajectory benchmark behind BENCH_qr.json.

Times every registered realization (including the tiled task-graph
backend) over a shape/dtype grid and derives effective GFLOP/s from the
standard thin-QR flop count 2 n^2 (m - n/3).  ``benchmarks/run.py``
serializes the records to ``BENCH_qr.json`` so the trajectory is
comparable across PRs; ``--smoke`` shrinks the grid for CI (it exists to
catch interpret-mode regressions in the Pallas tile ops on CPU, not to
measure).
"""

import time

import numpy as np
import jax.numpy as jnp

from repro.core import QRConfig, plan  # noqa: F401

# (method, block) x shapes; tsqr only runs where its 4:1 aspect holds.
_FULL_SHAPES = [(256, 256), (512, 512), (512, 128), (1024, 128), (1024, 256)]
_SMOKE_SHAPES = [(96, 96), (128, 64), (256, 32)]
_METHODS = ["geqr2", "geqr2_ht", "geqrf", "geqrf_ht", "tsqr", "tiled"]
_DTYPES = [jnp.float32]

# Smoke mode also exercises the Pallas kernel paths in interpret mode.
_SMOKE_KERNEL_METHODS = ("geqrf_ht", "tiled")


def _qr_flops(m: int, n: int) -> float:
    k = min(m, n)
    return 2.0 * k * k * (m - k / 3.0)


def _block(out):
    (out[0] if isinstance(out, tuple) else out).block_until_ready()


def _time_solve(solver, a, reps: int) -> float:
    _block(solver.solve(a))  # warm the jit cache
    t0 = time.perf_counter()
    for _ in range(reps):
        out = solver.solve(a)
    _block(out)
    return (time.perf_counter() - t0) / reps


def sweep(smoke: bool = False) -> list:
    """Run the grid; returns JSON-ready records
    (method x shape x dtype -> wall time / effective GFLOPs)."""
    shapes = _SMOKE_SHAPES if smoke else _FULL_SHAPES
    reps = 2 if smoke else 5
    rng = np.random.default_rng(0)
    records = []
    for m, n in shapes:
        for dtype in _DTYPES:
            a = jnp.asarray(rng.standard_normal((m, n)), dtype)
            for method in _METHODS:
                cfgs = [(method, QRConfig(method=method, mode="r",
                                          block=64 if method == "tiled" else 32))]
                if smoke and method in _SMOKE_KERNEL_METHODS:
                    cfgs.append((f"{method}+kernel", QRConfig(
                        method=method, mode="r", use_kernel=True,
                        block=64 if method == "tiled" else 32)))
                for label, cfg in cfgs:
                    try:
                        solver = plan(a.shape, a.dtype, cfg)
                    except ValueError:  # capability mismatch (tsqr aspect)
                        continue
                    dt = _time_solve(solver, a, reps)
                    records.append(dict(
                        method=label, m=m, n=n, dtype=str(np.dtype(dtype)),
                        wall_us=dt * 1e6,
                        gflops=_qr_flops(m, n) / dt / 1e9,
                    ))
    return records


def rows(records: list) -> list:
    """Format sweep records as the harness's CSV rows."""
    return [
        (f"qr_{r['method']}_{r['m']}x{r['n']}_{r['dtype']}", r["wall_us"],
         f"gflops={r['gflops']:.3f}")
        for r in records
    ]


def run(smoke: bool = False) -> list:
    return rows(sweep(smoke=smoke))
