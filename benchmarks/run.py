"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig9,...]

Prints ``name,us_per_call,derived`` CSV rows.  The dry-run/roofline
results (launch/dryrun.py + launch/roofline.py) are the TPU-side
counterpart; these benches cover the paper's algorithmic claims on the
host.
"""

import argparse
import sys
import traceback

_MODULES = [
    ("fig9_parallelism", "benchmarks.bench_parallelism"),
    ("fig11_qr_variants", "benchmarks.bench_qr_variants"),
    ("fig13_kernel_traffic", "benchmarks.bench_kernel_traffic"),
    ("fig14e_scaling", "benchmarks.bench_scaling"),
    ("optim_beyond_paper", "benchmarks.bench_optim"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated prefixes to run")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for label, modname in _MODULES:
        if only and not any(label.startswith(o) for o in only):
            continue
        try:
            import importlib

            mod = importlib.import_module(modname)
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
        except Exception:
            failures += 1
            print(f"{label},ERROR,{traceback.format_exc().splitlines()[-1]}",
                  file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
