"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig9,...] [--smoke]
                                            [--json BENCH_qr.json]

Prints ``name,us_per_call,derived`` CSV rows, and serializes the QR
method sweep (method x shape x dtype -> wall time / effective GFLOPs) to
``BENCH_qr.json`` so the perf trajectory is tracked across PRs.

``--smoke`` runs only the QR sweeps (methods + serving stream) on a
reduced grid (including the Pallas kernel paths in interpret mode) —
the CI hook that catches kernel regressions on CPU.  The serving
records (bench_qr_serving: latency percentiles, matrices/sec, bucket
fill, cache hit rate) merge into the same BENCH_qr.json.  The dry-run/roofline results
(launch/dryrun.py + launch/roofline.py) are the TPU-side counterpart;
these benches cover the paper's algorithmic claims on the host.
"""

import argparse
import json
import sys
import traceback

_MODULES = [
    ("fig9_parallelism", "benchmarks.bench_parallelism"),
    ("fig11_qr_variants", "benchmarks.bench_qr_variants"),
    ("fig13_kernel_traffic", "benchmarks.bench_kernel_traffic"),
    ("fig14e_scaling", "benchmarks.bench_scaling"),
    ("optim_beyond_paper", "benchmarks.bench_optim"),
    ("qr_methods", "benchmarks.bench_qr_methods"),
    ("qr_serving", "benchmarks.bench_qr_serving"),
]

# Modules whose sweep() records merge into the BENCH_qr.json trajectory
# (qr-bench-v2 rows; serving rows carry extra latency/throughput fields,
# optimizer rows carry dispatch-economy twins — batched vs leafwise).
_QR_RECORD_MODULES = ("qr_methods", "qr_serving", "optim_beyond_paper")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated prefixes to run")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced QR sweep only (CI kernel smoke)")
    ap.add_argument("--chaos", action="store_true",
                    help="append the serving chaos record (verify on, "
                         "~5%% injected-fault mix — latency percentiles "
                         "plus escalation/quarantine counts) to the "
                         "BENCH_qr.json trajectory")
    ap.add_argument("--json", default="BENCH_qr.json", metavar="PATH",
                    help="where to write the QR sweep records")
    args = ap.parse_args()
    if args.smoke and args.only:
        ap.error("--smoke and --only are mutually exclusive")
    only = list(_QR_RECORD_MODULES) if args.smoke else (
        args.only.split(",") if args.only else None)

    print("name,us_per_call,derived")
    failures = 0
    qr_records = None
    for label, modname in _MODULES:
        if only and not any(label.startswith(o) for o in only):
            continue
        try:
            import importlib

            mod = importlib.import_module(modname)
            if label in _QR_RECORD_MODULES:
                if label == "qr_serving":
                    records = mod.sweep(smoke=args.smoke, chaos=args.chaos)
                else:
                    records = mod.sweep(smoke=args.smoke)
                qr_records = (qr_records or []) + records
                rows = mod.rows(records)
            else:
                rows = mod.run()
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}")
        except Exception:
            failures += 1
            print(f"{label},ERROR,{traceback.format_exc().splitlines()[-1]}",
                  file=sys.stderr)

    if qr_records is not None and args.json:
        from repro.observability import metrics as obs_metrics
        from repro.tuning import cache as tuning_cache

        with open(args.json, "w") as f:
            # v2: records carry a dispatch_mode field (engine lowering:
            # "wavefront" / "megakernel" / null on jnp-oracle paths) and
            # a per-record "metrics" dict on engine/serving rows; the
            # top-level "metrics" key is the process-global registry
            # snapshot at the end of the run (planner explain/fallback
            # counters, engine dispatch/DMA series, serving histograms).
            # "tuning" records which measured planner cache (if any)
            # governed the auto-routed rows, so a trajectory diff can
            # tell a code change from a cache change.
            json.dump({"schema": "qr-bench-v2", "smoke": args.smoke,
                       "records": qr_records,
                       "tuning": tuning_cache.active_cache_info(),
                       "metrics": obs_metrics.snapshot()}, f, indent=1)
        print(f"wrote {len(qr_records)} records to {args.json}",
              file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
