"""Mixture-of-Experts FFN: top-k routing, capacity dispatch, shared experts.

Dispatch is scatter-based (GShard-style capacity buffers, no (T,E,C)
one-hot): token t's slot in its expert's (E, C, d) buffer comes from a
cumulative-sum position, tokens beyond capacity are dropped (standard
capacity_factor semantics).  Under expert-parallel sharding (experts
split over the ``model`` axis) the dispatch/combine gathers lower to
all-to-all-class collectives, which is what the roofline counts.

Compute cost is 3 * E * C * d * d_expert * 2 FLOPs — proportional to
*active* (not total) expert parameters, matching 6*N_active*D accounting.

qwen2-moe extras: ``num_shared`` always-on experts fused into one dense
FFN of width num_shared*d_expert, sigmoid-gated.

Returns (y, aux_loss) with the switch-style load-balance loss.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_expert_stack, \
    constrain_token_stack
from repro.models.layers import dense, dense_init, ffn, ffn_init

Array = jax.Array

__all__ = ["moe_init", "moe_forward"]


def _expert_stack_init(key, e: int, d_in: int, d_out: int) -> Array:
    return jax.random.normal(key, (e, d_in, d_out), jnp.float32) / math.sqrt(d_in)


def moe_init(key, cfg) -> dict:
    moe = cfg.moe
    keys = jax.random.split(key, 6)
    p = {
        "router": dense_init(keys[0], cfg.d_model, moe.num_experts, scale=0.02),
        "gate_w": _expert_stack_init(keys[1], moe.num_experts, cfg.d_model,
                                     moe.d_expert),
        "up_w": _expert_stack_init(keys[2], moe.num_experts, cfg.d_model,
                                   moe.d_expert),
        "down_w": _expert_stack_init(keys[3], moe.num_experts, moe.d_expert,
                                     cfg.d_model),
    }
    if moe.num_shared > 0:
        p["shared"] = ffn_init(keys[4], cfg.d_model,
                               moe.num_shared * moe.d_expert, cfg.ffn_act)
        p["shared_gate"] = dense_init(keys[5], cfg.d_model, 1, scale=0.02)
    return p


def _capacity(tokens: int, moe) -> int:
    c = math.ceil(tokens * moe.top_k / moe.num_experts * moe.capacity_factor)
    return max(8, min(tokens, (c + 7) // 8 * 8))


_MOE_CHUNK_TOKENS = 131_072


def moe_forward(p: dict, x: Array, cfg) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (y, aux_loss).

    Long-sequence inputs (32k prefill = 1M tokens) run the dispatch in
    token chunks via ``lax.scan``: unchunked, the scatter all-gathers the
    full (T*k, d) token stack onto every chip (observed 17+ GiB/device at
    prefill_32k).  Capacity is enforced per chunk — equivalent drop
    semantics at equal load."""
    moe = cfg.moe
    bb, ss, dd = x.shape
    t_total = bb * ss
    if t_total > _MOE_CHUNK_TOKENS and t_total % _MOE_CHUNK_TOKENS == 0:
        n = t_total // _MOE_CHUNK_TOKENS
        xc = x.reshape(n, _MOE_CHUNK_TOKENS, dd)

        def body(aux, xi):
            yi, a = _moe_tokens(p, xi[None], cfg)
            return aux + a / n, yi[0]

        aux, ys = jax.lax.scan(body, jnp.zeros((), jnp.float32), xc)
        return ys.reshape(bb, ss, dd), aux
    y, aux = _moe_tokens(p, x.reshape(1, t_total, dd), cfg)
    return y.reshape(bb, ss, dd), aux


def _moe_tokens(p: dict, x: Array, cfg) -> Tuple[Array, Array]:
    """Core capacity dispatch on a (1, T, d) token block."""
    moe = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = moe.num_experts, moe.top_k
    cap = _capacity(t, moe)
    xt = x.reshape(t, d)

    logits = xt.astype(jnp.float32) @ p["router"]["w"]            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)               # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # position of each (token, slot) within its expert's capacity buffer
    flat_idx = expert_idx.reshape(-1)                             # (T*k,)
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)         # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot                     # 1-based
    pos = jnp.sum(pos, axis=-1) - 1                               # (T*k,)
    keep = (pos >= 0) & (pos < cap)
    pos_c = jnp.clip(pos, 0, cap - 1)

    # dispatch: (E, C, d)
    x_rep = jnp.repeat(xt, k, axis=0)                             # (T*k, d)
    x_rep = constrain_token_stack(jnp.where(keep[:, None], x_rep, 0))
    buf = jnp.zeros((e, cap, d), x.dtype).at[flat_idx, pos_c].add(x_rep)
    buf = constrain_expert_stack(buf)

    # batched expert FFN (active compute only: E*C tokens)
    bw = x.dtype
    if cfg.ffn_act in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.ffn_act == "swiglu" else (
            lambda u: jax.nn.gelu(u, approximate=True))
        h = act(jnp.einsum("ecd,edf->ecf", buf.astype(bw), p["gate_w"].astype(bw))
                ) * jnp.einsum("ecd,edf->ecf", buf.astype(bw), p["up_w"].astype(bw))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf.astype(bw),
                                   p["up_w"].astype(bw)), approximate=True)
    h = constrain_expert_stack(h)
    out_buf = constrain_expert_stack(
        jnp.einsum("ecf,efd->ecd", h, p["down_w"].astype(bw)))   # (E, C, d)

    # combine
    gathered = constrain_token_stack(out_buf[flat_idx, pos_c])    # (T*k, d)
    w = (gate_vals.reshape(-1) * keep).astype(gathered.dtype)
    y = jnp.sum((gathered * w[:, None]).reshape(t, k, d), axis=1)

    if moe.num_shared > 0:
        sg = jax.nn.sigmoid(dense(p["shared_gate"], x, dtype=jnp.float32))
        y = y.reshape(b, s, d) + (sg * ffn(p["shared"], x, cfg.ffn_act
                                           ).astype(jnp.float32)).astype(y.dtype)
        y = y.reshape(t, d)

    # switch-style load balance: E * sum_e f_e * P_e
    f_e = jnp.mean(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=(0, 1)) * k
    p_e = jnp.mean(probs, axis=0)
    aux = moe.router_aux_weight * e * jnp.sum(f_e * p_e)

    return y.reshape(b, s, d).astype(x.dtype), aux
