"""GQA attention: chunked (flash-style) training/prefill + cached decode.

Memory-safe by construction: training/prefill never materializes the
(S x S) score matrix — an outer ``lax.scan`` over query chunks and an
inner scan over key/value chunks carry online-softmax statistics
(running max / denominator / accumulator), so live memory is
O(q_chunk x kv_chunk) per head.  Local (windowed) attention and gemma2
score soft-capping are folded into the same masks.

Decode attends one query against the full KV cache with a length mask —
O(S) per step, sub-quadratic, which is what the decode_* shapes lower.

GQA is computed grouped: q heads are reshaped to (n_kv, group) so k/v are
never repeated in memory.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import constrain_decode_scores
from repro.models.layers import apply_norm, dense, dense_init, rope

Array = jax.Array

__all__ = ["attn_init", "attn_forward", "attn_decode", "chunked_attention"]

_NEG = -1e30


def attn_init(key, cfg) -> dict:
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    p = {
        "wq": dense_init(kq, cfg.d_model, cfg.d_q, bias=cfg.qkv_bias),
        "wk": dense_init(kk, cfg.d_model, cfg.d_kv, bias=cfg.qkv_bias),
        "wv": dense_init(kv, cfg.d_model, cfg.d_kv, bias=cfg.qkv_bias),
        "wo": dense_init(ko, cfg.d_q, cfg.d_model),
    }
    if cfg.qk_norm:
        p["qnorm"] = {"g": jnp.zeros((cfg.d_head,), jnp.float32)}
        p["knorm"] = {"g": jnp.zeros((cfg.d_head,), jnp.float32)}
    return p


def _project_qkv(p, x, cfg, positions):
    b, s, _ = x.shape
    q = dense(p["wq"], x).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = dense(p["wk"], x).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = dense(p["wv"], x).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = apply_norm(p["qnorm"], q, "rmsnorm")
        k = apply_norm(p["knorm"], k, "rmsnorm")
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _block_scores(q, k, *, scale, softcap):
    """q (b, qc, kvh, g, d), k (b, kc, kvh, d) -> (b, kvh, g, qc, kc)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    return s


def chunked_attention(
    q: Array, k: Array, v: Array, *,
    q_pos: Array, k_pos0: int = 0,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_chunk: int = 512, kv_chunk: int = 1024,
    causal_skip: bool = False,
) -> Array:
    """Causal online-softmax attention.

    q: (B, Sq, H, D); k, v: (B, Sk, n_kv, D); q_pos: (Sq,) absolute
    positions of the queries (k positions are k_pos0 + arange(Sk)).
    """
    b, sq, h, d = q.shape
    sk, n_kv = k.shape[1], k.shape[2]
    g = h // n_kv
    scale = d ** -0.5
    q_chunk = min(q_chunk, sq)
    while sq % q_chunk:
        q_chunk //= 2
    kv_chunk = min(kv_chunk, sk)
    while sk % kv_chunk:
        kv_chunk //= 2
    nq, nk = sq // q_chunk, sk // kv_chunk

    qr = q.reshape(b, nq, q_chunk, n_kv, g, d).transpose(1, 0, 2, 3, 4, 5)
    qp = q_pos.reshape(nq, q_chunk)
    kr = k.reshape(b, nk, kv_chunk, n_kv, d).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(b, nk, kv_chunk, n_kv, d).transpose(1, 0, 2, 3, 4)
    kp = (k_pos0 + jnp.arange(sk)).reshape(nk, kv_chunk)

    @jax.checkpoint
    def q_step(_, qc):
        # checkpointed: backward recomputes the inner kv scan instead of
        # saving (q_chunk x kv_chunk) score blocks for every pair — the
        # flash-attention memory profile without a custom vjp.
        qi, qpos = qc  # (b, q_chunk, n_kv, g, d), (q_chunk,)

        def kv_block(carry, ki, vi, kpos):
            m, l, acc = carry
            s = _block_scores(qi, ki, scale=scale, softcap=softcap)
            mask = qpos[:, None] >= kpos[None, :]          # causal
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(mask[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vi.astype(jnp.float32))
            return m_new, l, acc

        def kv_step(carry, kc):
            ki, vi, kpos = kc
            if not causal_skip:
                return kv_block(carry, ki, vi, kpos), None
            # beyond-paper: predicated block skipping — fully-masked
            # blocks (above the causal diagonal / outside the window)
            # branch to a no-op at runtime; compile stays one compact
            # scan body.  ~2x attention FLOPs saved for causal, more for
            # windowed layers.
            needed = kpos[0] <= qpos[-1]
            if window is not None:
                needed &= kpos[-1] > qpos[0] - window
            return lax.cond(needed, lambda c: kv_block(c, ki, vi, kpos),
                            lambda c: c, carry), None

        m0 = jnp.full((b, n_kv, g, q_chunk), _NEG, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, n_kv, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (kr, vr, kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (b,h',g,qc,d)
        return None, out.transpose(0, 3, 1, 2, 4)           # (b,qc,n_kv,g,d)

    _, outs = lax.scan(q_step, None, (qr, qp))              # (nq,b,qc,n_kv,g,d)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def attn_forward(
    p: dict, x: Array, cfg, *, local: bool, pos0: int = 0,
    return_kv: bool = False,
) -> Array | Tuple[Array, Tuple[Array, Array]]:
    """Training / prefill attention over a full sequence."""
    b, s, _ = x.shape
    positions = pos0 + jnp.arange(s)
    q, k, v = _project_qkv(p, x, cfg, positions)
    window = cfg.window if local else None
    out = chunked_attention(
        q, k, v, q_pos=positions, k_pos0=pos0, window=window,
        softcap=cfg.attn_softcap, q_chunk=cfg.seq_chunk,
        kv_chunk=max(cfg.seq_chunk, 1024 if s >= 1024 else s),
        causal_skip=getattr(cfg, "attn_causal_skip", False),
    )
    y = dense(p["wo"], out.reshape(b, s, cfg.d_q))
    if return_kv:
        return y, (k, v)
    return y


def attn_decode(
    p: dict, x: Array, cfg, *, local: bool,
    cache_k: Array, cache_v: Array, cur_len: Array,
) -> Tuple[Array, Tuple[Array, Array]]:
    """One decode step. x: (B, 1, d); caches (B, S_max, n_kv, D); cur_len
    is the number of valid cache entries (the new token's position)."""
    b = x.shape[0]
    s_max = cache_k.shape[1]
    positions = jnp.full((1,), cur_len, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)

    cache_k = lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype),
                                       (0, cur_len, 0, 0))
    cache_v = lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype),
                                       (0, cur_len, 0, 0))

    n_kv, d = cfg.n_kv_heads, cfg.d_head
    g = cfg.n_heads // n_kv
    qg = q.reshape(b, 1, n_kv, g, d)
    s = _block_scores(qg, cache_k, scale=d ** -0.5, softcap=cfg.attn_softcap)
    s = constrain_decode_scores(s)
    kpos = jnp.arange(s_max)
    mask = kpos <= cur_len
    if local and cfg.window is not None:
        mask &= (cur_len - kpos) < cfg.window
    s = jnp.where(mask[None, None, None, None, :], s, _NEG)
    w = constrain_decode_scores(jax.nn.softmax(s, axis=-1))
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, cache_v.astype(jnp.float32))
    out = out.reshape(b, 1, cfg.d_q).astype(x.dtype)
    y = dense(p["wo"], out)
    return y, (cache_k, cache_v)
