"""Shared layer primitives: norms, FFNs, embeddings, RoPE, soft-capping.

Module convention (no flax dependency): each layer is a pair of pure
functions ``init_*(key, ...) -> params`` (a dict pytree, fp32) and an
apply function taking (params, x).  Compute runs in the model dtype
(bf16); params are kept fp32 and cast at use ("mixed precision, fp32
master" policy).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

__all__ = [
    "dense_init", "dense", "norm_init", "apply_norm", "ffn_init", "ffn",
    "embedding_init", "embed", "rope", "softcap",
]


def _normal(key, shape, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(jnp.float32)


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               scale: float | None = None) -> dict:
    scale = (1.0 / jnp.sqrt(d_in)) if scale is None else scale
    p = {"w": _normal(key, (d_in, d_out), scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(p: dict, x: Array, *, dtype=None) -> Array:
    dtype = x.dtype if dtype is None else dtype
    y = x.astype(dtype) @ p["w"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def norm_init(d: int, kind: str) -> dict:
    if kind == "nonparam_ln":          # olmo: no gain/bias
        return {}
    if kind == "rmsnorm":
        return {"g": jnp.zeros((d,), jnp.float32)}   # (1+g) parametrization
    if kind == "layernorm":
        return {"g": jnp.ones((d,), jnp.float32),
                "b": jnp.zeros((d,), jnp.float32)}
    raise ValueError(f"unknown norm {kind!r}")


def apply_norm(p: dict, x: Array, kind: str, *, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        xf = xf * (1.0 + p["g"])
    else:  # layernorm / nonparam_ln
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            xf = xf * p["g"] + p["b"]
    return xf.astype(x.dtype)


def ffn_init(key, d_model: int, d_ff: int, act: str) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"down": dense_init(k2, d_ff, d_model)}
    if act in ("swiglu", "geglu"):
        p["gate"] = dense_init(k1, d_model, d_ff)
        p["up"] = dense_init(k3, d_model, d_ff)
    else:  # gelu
        p["up"] = dense_init(k1, d_model, d_ff)
    return p


def ffn(p: dict, x: Array, act: str, *, dtype=None) -> Array:
    dtype = x.dtype if dtype is None else dtype
    if act == "swiglu":
        h = jax.nn.silu(dense(p["gate"], x, dtype=dtype)) * dense(p["up"], x, dtype=dtype)
    elif act == "geglu":
        h = jax.nn.gelu(dense(p["gate"], x, dtype=dtype), approximate=True) * dense(
            p["up"], x, dtype=dtype)
    elif act == "gelu":
        h = jax.nn.gelu(dense(p["up"], x, dtype=dtype), approximate=True)
    else:
        raise ValueError(f"unknown ffn act {act!r}")
    return dense(p["down"], h, dtype=dtype)


def embedding_init(key, vocab: int, d: int) -> dict:
    return {"table": _normal(key, (vocab, d), 1.0)}


def embed(p: dict, tokens: Array, *, dtype=jnp.bfloat16) -> Array:
    return jnp.take(p["table"], tokens, axis=0).astype(dtype)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding; x is (..., S, H, D), positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: Array, cap: Optional[float]) -> Array:
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    xf = x.astype(jnp.float32)
    return (cap * jnp.tanh(xf / cap)).astype(x.dtype)
