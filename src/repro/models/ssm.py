"""Mamba (selective SSM) mixer — jamba's sequence backbone.

Training/prefill: the selective scan runs chunked — an outer ``lax.scan``
over sequence chunks carrying the (B, d_inner, d_state) SSM state, with a
``jax.checkpoint``-wrapped associative scan inside each chunk.  Live
memory is O(chunk · d_inner · d_state) and the backward pass recomputes
within chunks, so 500k-token sequences fit.

Decode: O(1) per token — one state update, which is why jamba qualifies
for the ``long_500k`` cell.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense, dense_init

Array = jax.Array

__all__ = ["mamba_init", "mamba_forward", "mamba_decode", "mamba_init_state"]


def _d_inner(cfg) -> int:
    return cfg.d_inner if cfg.d_inner is not None else 2 * cfg.d_model


def _dt_rank(cfg) -> int:
    return cfg.dt_rank if cfg.dt_rank is not None else math.ceil(cfg.d_model / 16)


def mamba_init(key, cfg) -> dict:
    di, ds, dtr, k = _d_inner(cfg), cfg.d_state, _dt_rank(cfg), cfg.conv_kernel
    keys = jax.random.split(key, 6)
    # dt bias: init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba paper)
    u = jax.random.uniform(keys[4], (di,), jnp.float32)
    dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(keys[0], cfg.d_model, 2 * di),
        "conv_w": jax.random.normal(keys[1], (k, di), jnp.float32) / math.sqrt(k),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(keys[2], di, dtr + 2 * ds),
        "dt_proj": dense_init(keys[3], dtr, di),
        "dt_bias": dt_bias,
        "a_log": jnp.log(jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                          (di, ds))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(keys[5], di, cfg.d_model),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv along S. x: (B, S, di); w: (k, di)."""
    k = w.shape[0]
    lhs = x.astype(jnp.float32).transpose(0, 2, 1)      # (B, di, S)
    rhs = w.astype(jnp.float32).T[:, None, :]            # (di, 1, k)
    out = lax.conv_general_dilated(
        lhs, rhs, window_strides=(1,), padding=[(k - 1, 0)],
        feature_group_count=lhs.shape[1])
    return (out.transpose(0, 2, 1) + b).astype(x.dtype)


def _ssm_params(p, xc, cfg):
    """Input-dependent dt/B/C from the conv'd activations (B, S, di)."""
    ds, dtr = cfg.d_state, _dt_rank(cfg)
    xdb = dense(p["x_proj"], xc, dtype=jnp.float32)
    dt_r, b_mat, c_mat = jnp.split(xdb, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"]["w"] + p["dt_bias"])  # (B,S,di)
    a = -jnp.exp(p["a_log"])                                       # (di,ds)
    return dt, a, b_mat, c_mat


def _scan_chunked(dt: Array, a: Array, xf: Array, b_mat: Array, c_mat: Array,
                  h0: Array, chunk: int) -> Tuple[Array, Array]:
    """y_t = C_t . h_t with h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t.

    dt, xf: (B, S, di); a: (di, ds); b_mat, c_mat: (B, S, ds);
    h0: (B, di, ds).  The (B, S, di, ds) discretization is NEVER
    materialized for the full sequence — da/dbx are built per chunk
    inside the checkpointed body (live memory O(chunk*di*ds); computing
    them up-front costs B*S*di*ds*4 bytes ~ 34 GiB/layer at jamba's
    train_4k shape and was the dominant temp before this fix)."""
    b, s, di = dt.shape
    ds = a.shape[1]
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    n = s // chunk

    def to_chunks(x):
        return x.reshape(b, n, chunk, *x.shape[2:]).transpose(
            1, 0, 2, *range(3, x.ndim + 1))

    dt_c, xf_c, bm_c, cm_c = (to_chunks(x) for x in (dt, xf, b_mat, c_mat))

    @jax.checkpoint
    def chunk_body(h, xs):
        dt_i, xf_i, bm_i, cm_i = xs            # (B, chunk, di), ..., (B, chunk, ds)
        da_i = jnp.exp(dt_i[..., None] * a)    # (B, chunk, di, ds)
        dbx_i = (dt_i * xf_i)[..., None] * bm_i[:, :, None, :]
        # fold the carry into the first element
        dbx_i = dbx_i.at[:, 0].add(da_i[:, 0] * h)

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        _, h_all = lax.associative_scan(comb, (da_i, dbx_i), axis=1)
        y = jnp.einsum("bcds,bcs->bcd", h_all, cm_i)  # (B, chunk, di)
        return h_all[:, -1], y

    h_last, ys = lax.scan(chunk_body, h0, (dt_c, xf_c, bm_c, cm_c))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    return y, h_last


def mamba_forward(p: dict, x: Array, cfg, *, return_state: bool = False):
    """x: (B, S, d_model) -> (B, S, d_model) [, final states for prefill]."""
    b, s, _ = x.shape
    di = _d_inner(cfg)
    xz = dense(p["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"]))

    dt, a, b_mat, c_mat = _ssm_params(p, xc, cfg)
    xf = xc.astype(jnp.float32)
    h0 = jnp.zeros((b, di, cfg.d_state), jnp.float32)
    y, h_last = _scan_chunked(dt, a, xf, b_mat, c_mat, h0, cfg.seq_chunk)
    y = y + p["d_skip"] * xf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = dense(p["out_proj"], y)
    if return_state:
        k = cfg.conv_kernel
        conv_state = x_in[:, -(k - 1):].astype(jnp.float32) if k > 1 else \
            jnp.zeros((b, 0, di), jnp.float32)
        return out, {"ssm": h_last, "conv": conv_state}
    return out


def mamba_init_state(cfg, batch: int) -> dict:
    di, k = _d_inner(cfg), cfg.conv_kernel
    return {
        "ssm": jnp.zeros((batch, di, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, k - 1, di), jnp.float32),
    }


def mamba_decode(p: dict, x: Array, cfg, state: dict) -> Tuple[Array, dict]:
    """One token. x: (B, 1, d_model); state: {"ssm","conv"}."""
    di, k = _d_inner(cfg), cfg.conv_kernel
    xz = dense(p["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)          # (B, 1, di)
    x_f = x_in[:, 0].astype(jnp.float32)

    conv_state = state["conv"]                    # (B, k-1, di)
    window = jnp.concatenate([conv_state, x_f[:, None]], axis=1)  # (B, k, di)
    xc = jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)[:, None]                 # (B, 1, di)

    dt, a, b_mat, c_mat = _ssm_params(p, xc.astype(x.dtype), cfg)
    dt, b_mat, c_mat = dt[:, 0], b_mat[:, 0], c_mat[:, 0]
    da = jnp.exp(dt[..., None] * a)               # (B, di, ds)
    dbx = (dt * xc[:, 0].astype(jnp.float32))[..., None] * b_mat[:, None, :]
    h = da * state["ssm"] + dbx
    y = jnp.einsum("bds,bs->bd", h, c_mat) + p["d_skip"] * xc[:, 0].astype(jnp.float32)
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    out = dense(p["out_proj"], y[:, None].astype(x.dtype))
    new_state = {"ssm": h, "conv": window[:, 1:] if k > 1 else conv_state}
    return out, new_state
