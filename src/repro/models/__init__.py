"""Model zoo: one composable decoder covering all assigned architectures.

    layers       norms, FFNs, embeddings, RoPE, soft-capping
    attention    GQA chunked (flash-style) attention + cached decode
    ssm          Mamba selective-scan mixer
    xlstm        mLSTM / sLSTM blocks
    moe          top-k capacity-dispatch Mixture-of-Experts
    transformer  period-scanned stack, train/prefill/decode entry points
"""

from repro.models.transformer import (
    active_param_count,
    forward_decode,
    forward_prefill,
    forward_train,
    init_caches,
    init_params,
    param_count,
)

__all__ = [
    "init_params", "forward_train", "forward_prefill", "forward_decode",
    "init_caches", "param_count", "active_param_count",
]
