"""Composable decoder stack: any period pattern of {attn, attn_local,
mamba, mlstm, slstm} x {dense, moe, none} blocks.

The layer stack is a ``lax.scan`` over *periods* (stacked parameters), so
the HLO contains each distinct block exactly once regardless of depth —
compile time and program size are O(len(period)), which is what makes the
40-cell dry-run tractable.  Remat policy is applied to the period body.

Three entry points (all pure):
    forward_train(params, batch, cfg)                 -> (logits, aux)
    forward_prefill(params, batch, cfg)               -> (logits, caches)
    forward_decode(params, tokens, cfg, caches, pos)  -> (logits, caches)

Caches are a tuple (one per period position) of dicts stacked over
periods — attention holds (k, v) rings, SSM/xLSTM hold recurrent state.
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LayerSpec, ModelConfig
from repro.distributed.sharding import constrain_hidden
from repro.models import attention, moe as moe_mod, ssm, xlstm
from repro.models.layers import (
    apply_norm, dense, dense_init, embed, embedding_init, ffn, ffn_init,
    norm_init, softcap,
)

Array = jax.Array

__all__ = ["init_params", "forward_train", "forward_prefill", "forward_decode",
           "init_caches", "param_count", "active_param_count"]


# ------------------------------------------------------------------ init

def _layer_init(key, cfg: ModelConfig, spec: LayerSpec) -> dict:
    keys = jax.random.split(key, 4)
    p: dict = {"norm1": norm_init(cfg.d_model, cfg.norm)}
    if spec.mixer in ("attn", "attn_local"):
        p["mixer"] = attention.attn_init(keys[0], cfg)
    elif spec.mixer == "mamba":
        p["mixer"] = ssm.mamba_init(keys[0], cfg)
    elif spec.mixer == "mlstm":
        p["mixer"] = xlstm.mlstm_init(keys[0], cfg)
    elif spec.mixer == "slstm":
        p["mixer"] = xlstm.slstm_init(keys[0], cfg)
    else:
        raise ValueError(f"unknown mixer {spec.mixer!r}")
    if cfg.post_norm:
        p["norm1_post"] = norm_init(cfg.d_model, cfg.norm)
    if spec.ffn == "dense":
        p["norm2"] = norm_init(cfg.d_model, cfg.norm)
        p["ffn"] = ffn_init(keys[1], cfg.d_model, cfg.d_ff, cfg.ffn_act)
    elif spec.ffn == "moe":
        p["norm2"] = norm_init(cfg.d_model, cfg.norm)
        p["moe"] = moe_mod.moe_init(keys[1], cfg)
    elif spec.ffn != "none":
        raise ValueError(f"unknown ffn {spec.ffn!r}")
    if spec.ffn != "none" and cfg.post_norm:
        p["norm2_post"] = norm_init(cfg.d_model, cfg.norm)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, len(cfg.period) + 3)
    params: dict = {}
    params["embed"] = embedding_init(keys[0], cfg.vocab_size, cfg.d_model)
    if cfg.embedding_input:
        # modality-frontend stub: identity-init adapter over supplied embeds
        params["adapter"] = dense_init(keys[1], cfg.d_model, cfg.d_model)
    layers = []
    for pi, spec in enumerate(cfg.period):
        pkeys = jax.random.split(keys[2 + pi], cfg.n_periods)
        stacked = jax.vmap(lambda k: _layer_init(k, cfg, spec))(pkeys)
        layers.append(stacked)
    params["layers"] = tuple(layers)
    params["final_norm"] = norm_init(cfg.d_model, cfg.norm)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[-1], cfg.d_model, cfg.vocab_size)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def active_param_count(params, cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE counts top_k + shared experts)."""
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        if cfg.moe is not None and any(k in ("gate_w", "up_w", "down_w") for k in keys):
            total += leaf.size * cfg.moe.top_k // cfg.moe.num_experts
        else:
            total += leaf.size
    return total


# ------------------------------------------------------------------ blocks

def _apply_mixer(p, x, cfg, spec, *, mode, cache, pos):
    """Returns (y, new_cache)."""
    local = spec.mixer == "attn_local"
    if spec.mixer in ("attn", "attn_local"):
        if mode == "decode":
            y, (ck, cv) = attention.attn_decode(
                p["mixer"], x, cfg, local=local,
                cache_k=cache["k"], cache_v=cache["v"], cur_len=pos)
            return y, {"k": ck, "v": cv}
        if mode == "prefill":
            y, (k, v) = attention.attn_forward(p["mixer"], x, cfg, local=local,
                                               return_kv=True)
            return y, {"k": k, "v": v}
        return attention.attn_forward(p["mixer"], x, cfg, local=local), None
    if spec.mixer == "mamba":
        if mode == "decode":
            y, st = ssm.mamba_decode(p["mixer"], x, cfg, cache)
            return y, st
        if mode == "prefill":
            return ssm.mamba_forward(p["mixer"], x, cfg, return_state=True)
        return ssm.mamba_forward(p["mixer"], x, cfg), None
    if spec.mixer == "mlstm":
        if mode == "decode":
            y, st = xlstm.mlstm_decode(p["mixer"], x, cfg, cache)
            return y, st
        if mode == "prefill":
            return xlstm.mlstm_forward(p["mixer"], x, cfg, return_state=True)
        return xlstm.mlstm_forward(p["mixer"], x, cfg), None
    if spec.mixer == "slstm":
        if mode == "decode":
            y, st = xlstm.slstm_decode(p["mixer"], x, cfg, cache)
            return y, st
        if mode == "prefill":
            return xlstm.slstm_forward(p["mixer"], x, cfg, return_state=True)
        return xlstm.slstm_forward(p["mixer"], x, cfg), None
    raise ValueError(spec.mixer)


def _apply_layer(p, x, cfg, spec, *, mode, cache, pos):
    h = apply_norm(p["norm1"], x, cfg.norm)
    y, new_cache = _apply_mixer(p, h, cfg, spec, mode=mode, cache=cache, pos=pos)
    if cfg.post_norm:
        y = apply_norm(p["norm1_post"], y, cfg.norm)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn != "none":
        h = apply_norm(p["norm2"], x, cfg.norm)
        if spec.ffn == "dense":
            y = ffn(p["ffn"], h, cfg.ffn_act)
        else:
            y, aux = moe_mod.moe_forward(p["moe"], h, cfg)
        if cfg.post_norm:
            y = apply_norm(p["norm2_post"], y, cfg.norm)
        x = x + y
    return x, new_cache, aux


def _remat_wrap(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def _stack_scan(params, x0, cfg, *, mode, caches=None, pos=None):
    """Scan the period body over n_periods. Returns (x, new_caches, aux)."""
    specs = cfg.period
    layers = params["layers"]

    def period_body(carry, xs):
        x, aux = carry
        x = constrain_hidden(x)
        layer_ps, layer_caches = xs
        new_caches = []
        for pi, spec in enumerate(specs):
            cache = None if layer_caches is None else layer_caches[pi]
            x, nc, a = _apply_layer(layer_ps[pi], x, cfg, spec,
                                    mode=mode, cache=cache, pos=pos)
            aux = aux + a
            new_caches.append(nc)
        out = tuple(new_caches) if mode in ("prefill", "decode") else None
        return (x, aux), out

    body = _remat_wrap(period_body, cfg) if mode == "train" else period_body
    aux0 = jnp.zeros((), jnp.float32)
    xs = (layers, caches if caches is not None else None)
    if caches is None:
        # lax.scan needs a pytree with leading axis; replace None by a dummy
        xs = (layers, tuple({} for _ in specs))
    (x, aux), ys = lax.scan(body, (x0, aux0), xs)
    return x, ys, aux


# ------------------------------------------------------------------ heads

def _lm_logits(params, x, cfg):
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T.astype(x.dtype)
    else:
        logits = dense(params["lm_head"], x)
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def _embed_input(params, batch, cfg):
    dtype = jnp.dtype(cfg.dtype)
    if cfg.embedding_input and "embeds" in batch:
        return dense(params["adapter"], batch["embeds"].astype(dtype))
    x = embed(params["embed"], batch["tokens"], dtype=dtype)
    if cfg.norm == "rmsnorm" and cfg.post_norm:  # gemma-style embed scaling
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


# ------------------------------------------------------------------ API

def forward_hidden(params, batch, cfg: ModelConfig) -> Tuple[Array, Array]:
    """Backbone only: final normed hidden states (B, S, d) + moe aux.

    The training loss projects to the vocabulary chunk-by-chunk (fused
    softmax-CE) instead of materializing (B, S, V) logits."""
    x = constrain_hidden(_embed_input(params, batch, cfg))
    x, _, aux = _stack_scan(params, x, cfg, mode="train")
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, aux


def lm_head_weight(params, cfg: ModelConfig) -> Array:
    """(d, V) projection — the embedding transpose when tied."""
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["w"]


def forward_train(params, batch, cfg: ModelConfig) -> Tuple[Array, Array]:
    x, aux = forward_hidden(params, batch, cfg)
    return _lm_logits(params, x, cfg), aux


def forward_prefill(params, batch, cfg: ModelConfig):
    """Prefill: populate caches; logits only for the LAST position (B,1,V)
    — serving never needs the (B, S, V) tensor and at 32k x 152k vocab it
    would dominate HBM."""
    x = constrain_hidden(_embed_input(params, batch, cfg))
    x, caches, _ = _stack_scan(params, x, cfg, mode="prefill")
    x = apply_norm(params["final_norm"], x[:, -1:], cfg.norm)
    return _lm_logits(params, x, cfg), caches


def forward_decode(params, tokens: Array, cfg: ModelConfig, caches, pos: Array):
    """tokens: (B, 1) ids; pos: scalar current length."""
    x = embed(params["embed"], tokens, dtype=jnp.dtype(cfg.dtype))
    if cfg.embedding_input:
        # early-fusion archs run the frontend adapter on token embeddings
        # too, so decode is consistent with embedding-fed prefill
        x = dense(params["adapter"], x)
    if cfg.norm == "rmsnorm" and cfg.post_norm:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x, new_caches, _ = _stack_scan(params, x, cfg, mode="decode", caches=caches,
                                   pos=pos)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return _lm_logits(params, x, cfg), new_caches


# ------------------------------------------------------------------ caches

def init_caches(cfg: ModelConfig, batch: int, s_max: int,
                dtype=jnp.bfloat16) -> Tuple[Any, ...]:
    """Decode caches, one entry per period position, stacked over periods."""
    caches = []
    np_ = cfg.n_periods

    def stack(tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (np_, *a.shape)), tree)

    for spec in cfg.period:
        if spec.mixer in ("attn", "attn_local"):
            kv = jnp.zeros((batch, s_max, cfg.n_kv_heads, cfg.d_head), dtype)
            caches.append({"k": jnp.broadcast_to(kv, (np_, *kv.shape)),
                           "v": jnp.broadcast_to(kv, (np_, *kv.shape))})
        elif spec.mixer == "mamba":
            caches.append(stack(ssm.mamba_init_state(cfg, batch)))
        elif spec.mixer == "mlstm":
            caches.append(stack(xlstm.mlstm_init_state(cfg, batch)))
        elif spec.mixer == "slstm":
            caches.append(stack(xlstm.slstm_init_state(cfg, batch)))
        else:
            raise ValueError(spec.mixer)
    return tuple(caches)
