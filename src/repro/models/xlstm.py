"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Faithful to the xLSTM paper's cell equations with stabilized exponential
gating (the m-state trick); structural simplifications are documented in
DESIGN.md:

  * mLSTM: pre-LN block, up-projection (factor 2), causal conv4 + SiLU
    feeding q/k (v from the unconv'd branch), block-diagonal per-head
    q/k/v, matrix memory C_t = f C_{t-1} + i v k^T, head-wise norm, output
    gated by SiLU(z), down-projection.  Training runs the recurrence as a
    chunk-checkpointed sequential scan (the state is a (dh x dh) matrix
    per head, so the parallel quadratic form is traded for O(1)-memory
    recurrence; see EXPERIMENTS.md perf notes).
  * sLSTM: scalar memory with recurrent (h_{t-1}) gate contributions —
    inherently sequential — block-diagonal recurrent matrices per head,
    followed by a gated FFN (factor 4/3).

Both expose decode steps with explicit state for serving, making xlstm
eligible for the long_500k cell (O(1) memory per token).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import apply_norm, dense, dense_init, ffn, ffn_init

Array = jax.Array

__all__ = [
    "mlstm_init", "mlstm_forward", "mlstm_decode", "mlstm_init_state",
    "slstm_init", "slstm_forward", "slstm_decode", "slstm_init_state",
]


# --------------------------------------------------------------------- mLSTM

def _mlstm_dims(cfg) -> Tuple[int, int, int]:
    di = int(cfg.mlstm_proj_factor * cfg.d_model)
    h = cfg.n_heads
    return di, h, di // h


def mlstm_init(key, cfg) -> dict:
    di, h, dh = _mlstm_dims(cfg)
    keys = jax.random.split(key, 8)
    blk = lambda k: jax.random.normal(k, (h, dh, dh), jnp.float32) / math.sqrt(dh)
    return {
        "up": dense_init(keys[0], cfg.d_model, 2 * di),
        "conv_w": jax.random.normal(keys[1], (cfg.conv_kernel, di), jnp.float32)
        / math.sqrt(cfg.conv_kernel),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "wq": blk(keys[2]),
        "wk": blk(keys[3]),
        "wv": blk(keys[4]),
        "w_if": dense_init(keys[5], di, 2 * h),
        "if_bias": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]
                                   ).astype(jnp.float32),
        "head_norm": {"g": jnp.zeros((di,), jnp.float32)},
        "down": dense_init(keys[6], di, cfg.d_model),
    }


def _causal_conv(x, w, b):
    k = w.shape[0]
    lhs = x.astype(jnp.float32).transpose(0, 2, 1)
    rhs = w.astype(jnp.float32).T[:, None, :]
    out = lax.conv_general_dilated(lhs, rhs, (1,), [(k - 1, 0)],
                                   feature_group_count=lhs.shape[1])
    return (out.transpose(0, 2, 1) + b).astype(x.dtype)


def _mlstm_qkvif(p, x, cfg):
    """Projections for a (B, S, d) input -> q,k,v (B,S,H,dh), i,f (B,S,H)."""
    di, h, dh = _mlstm_dims(cfg)
    xz = dense(p["up"], x)
    x_m, z = jnp.split(xz, 2, axis=-1)                       # (B,S,di)
    xc = jax.nn.silu(_causal_conv(x_m, p["conv_w"], p["conv_b"]))
    xch = xc.reshape(*xc.shape[:-1], h, dh)
    xmh = x_m.reshape(*x_m.shape[:-1], h, dh)
    q = jnp.einsum("...hd,hde->...he", xch.astype(jnp.float32), p["wq"])
    k = jnp.einsum("...hd,hde->...he", xch.astype(jnp.float32), p["wk"])
    v = jnp.einsum("...hd,hde->...he", xmh.astype(jnp.float32), p["wv"])
    gates = xc.astype(jnp.float32) @ p["w_if"]["w"] + p["if_bias"]
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)              # (B,S,H)
    return q, k / math.sqrt(dh), v, i_pre, f_pre, z


def _mlstm_step(state, inputs):
    """One recurrence step. state: (C, n, m); inputs: (q,k,v,i,f) at t."""
    c, n, m = state
    q, k, v, i_pre, f_pre = inputs
    log_f = -jax.nn.softplus(-f_pre)                          # log sigmoid
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c = f_g[..., None, None] * c + i_g[..., None, None] * (
        k[..., :, None] * v[..., None, :])                    # (B,H,dh,dh)
    n = f_g[..., None] * n + i_g[..., None] * k
    h_num = jnp.einsum("bhd,bhde->bhe", q, c)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)),
                        jnp.exp(-m_new))
    h = h_num / denom[..., None]
    return (c, n, m_new), h


def mlstm_init_state(cfg, batch: int) -> dict:
    di, h, dh = _mlstm_dims(cfg)
    return {
        "c": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, di), jnp.float32),
    }


def mlstm_forward(p: dict, x: Array, cfg, *, return_state: bool = False):
    b, s, _ = x.shape
    di, h, dh = _mlstm_dims(cfg)
    q, k, v, i_pre, f_pre, z = _mlstm_qkvif(p, x, cfg)

    chunk = min(cfg.seq_chunk, s)
    while s % chunk:
        chunk //= 2
    n_chunks = s // chunk

    def to_chunks(a):
        return a.reshape(b, n_chunks, chunk, *a.shape[2:]).transpose(
            1, 2, 0, *range(3, a.ndim + 1))  # (nc, chunk, B, ...)

    xs = tuple(to_chunks(a) for a in (q, k, v, i_pre, f_pre))

    @jax.checkpoint
    def chunk_body(state, xs_c):
        state, hs = lax.scan(_mlstm_step, state, xs_c)
        return state, hs

    c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    state, hs = lax.scan(chunk_body, (c0, n0, m0), xs)       # hs (nc, chunk, B, H, dh)
    hflat = hs.transpose(2, 0, 1, 3, 4).reshape(b, s, di)
    hflat = apply_norm(p["head_norm"], hflat.astype(x.dtype), "rmsnorm")
    out = hflat.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    y = dense(p["down"], out.astype(x.dtype))
    if return_state:
        kk = cfg.conv_kernel
        x_m = jnp.split(dense(p["up"], x), 2, axis=-1)[0]
        conv_state = x_m[:, -(kk - 1):].astype(jnp.float32)
        return y, {"c": state[0], "n": state[1], "m": state[2], "conv": conv_state}
    return y


def mlstm_decode(p: dict, x: Array, cfg, state: dict) -> Tuple[Array, dict]:
    """One token. x: (B, 1, d)."""
    di, h, dh = _mlstm_dims(cfg)
    xz = dense(p["up"], x)
    x_m, z = jnp.split(xz, 2, axis=-1)                        # (B,1,di)
    window = jnp.concatenate([state["conv"], x_m[:, 0].astype(jnp.float32)[:, None]],
                             axis=1)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"])
    xch = xc.reshape(-1, h, dh)
    xmh = x_m[:, 0].reshape(-1, h, dh).astype(jnp.float32)
    q = jnp.einsum("bhd,hde->bhe", xch, p["wq"])
    k = jnp.einsum("bhd,hde->bhe", xch, p["wk"]) / math.sqrt(dh)
    v = jnp.einsum("bhd,hde->bhe", xmh, p["wv"])
    gates = xc @ p["w_if"]["w"] + p["if_bias"]
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)
    (c, n, m), hvec = _mlstm_step((state["c"], state["n"], state["m"]),
                                  (q, k, v, i_pre, f_pre))
    hflat = hvec.reshape(-1, 1, di).astype(x.dtype)
    hflat = apply_norm(p["head_norm"], hflat, "rmsnorm")
    out = hflat.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    y = dense(p["down"], out.astype(x.dtype))
    return y, {"c": c, "n": n, "m": m, "conv": window[:, 1:]}


# --------------------------------------------------------------------- sLSTM

def _slstm_dims(cfg) -> Tuple[int, int]:
    h = cfg.n_heads
    return h, cfg.d_model // h


def slstm_init(key, cfg) -> dict:
    d = cfg.d_model
    h, dh = _slstm_dims(cfg)
    keys = jax.random.split(key, 6)
    ffn_dim = int(round(cfg.slstm_ffn_factor * d / 64) * 64)
    return {
        "conv_w": jax.random.normal(keys[0], (cfg.conv_kernel, d), jnp.float32)
        / math.sqrt(cfg.conv_kernel),
        "conv_b": jnp.zeros((d,), jnp.float32),
        "w_if": dense_init(keys[1], d, 2 * d),     # i,f from conv'd input
        "w_zo": dense_init(keys[2], d, 2 * d),     # z,o from raw input
        "r": jax.random.normal(keys[3], (h, dh, 4 * dh), jnp.float32)
        / math.sqrt(dh),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((d,)), 3.0 * jnp.ones((d,)), jnp.zeros((2 * d,))]
        ).astype(jnp.float32),
        "group_norm": {"g": jnp.zeros((d,), jnp.float32)},
        "out": dense_init(keys[4], d, d),
        "ffn": ffn_init(keys[5], d, ffn_dim, "geglu"),
    }


def _slstm_step(state, inputs, *, r, h_heads, dh):
    c, n, m, h_prev = state
    wx_if, wx_zo = inputs                                     # (B, 2d) each
    rh = jnp.einsum("bhd,hde->bhe", h_prev.reshape(-1, h_heads, dh), r)
    rh = rh.reshape(h_prev.shape[0], 4 * h_heads * dh)        # (B, 4d)
    r_i, r_f, r_z, r_o = jnp.split(rh, 4, axis=-1)
    i_pre = wx_if[:, : wx_if.shape[1] // 2] + r_i
    f_pre = wx_if[:, wx_if.shape[1] // 2 :] + r_f
    z_pre = wx_zo[:, : wx_zo.shape[1] // 2] + r_z
    o_pre = wx_zo[:, wx_zo.shape[1] // 2 :] + r_o
    m_new = jnp.maximum(f_pre + m, i_pre)                     # exp f gating
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(f_pre + m - m_new)
    c = f_g * c + i_g * jnp.tanh(z_pre)
    n = f_g * n + i_g
    h = jax.nn.sigmoid(o_pre) * (c / jnp.maximum(n, 1e-6))
    return (c, n, m_new, h), h


def slstm_init_state(cfg, batch: int) -> dict:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, d), jnp.float32),
    }


def _slstm_gate_inputs(p, x):
    xc = jax.nn.silu(_causal_conv(x, p["conv_w"], p["conv_b"]))
    bias_if, bias_zo = jnp.split(p["gate_bias"], 2)
    wx_if = xc.astype(jnp.float32) @ p["w_if"]["w"] + bias_if
    wx_zo = x.astype(jnp.float32) @ p["w_zo"]["w"] + bias_zo
    return wx_if, wx_zo


def slstm_forward(p: dict, x: Array, cfg, *, return_state: bool = False):
    b, s, d = x.shape
    h_heads, dh = _slstm_dims(cfg)
    wx_if, wx_zo = _slstm_gate_inputs(p, x)

    chunk = min(cfg.seq_chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    xs = tuple(a.reshape(b, nc, chunk, 2 * d).transpose(1, 2, 0, 3)
               for a in (wx_if, wx_zo))

    import functools
    step = functools.partial(_slstm_step, r=p["r"], h_heads=h_heads, dh=dh)

    @jax.checkpoint
    def chunk_body(state, xs_c):
        return lax.scan(step, state, xs_c)

    state0 = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32),
              jnp.full((b, d), -1e30, jnp.float32), jnp.zeros((b, d), jnp.float32))
    state, hs = lax.scan(chunk_body, state0, xs)              # (nc, chunk, B, d)
    hseq = hs.transpose(2, 0, 1, 3).reshape(b, s, d).astype(x.dtype)
    hseq = apply_norm(p["group_norm"], hseq, "rmsnorm")
    y = dense(p["out"], hseq)
    y = y + ffn(p["ffn"], y, "geglu")
    if return_state:
        kk = cfg.conv_kernel
        conv_state = x[:, -(kk - 1):].astype(jnp.float32)
        return y, {"c": state[0], "n": state[1], "m": state[2], "h": state[3],
                   "conv": conv_state}
    return y


def slstm_decode(p: dict, x: Array, cfg, state: dict) -> Tuple[Array, dict]:
    b = x.shape[0]
    d = cfg.d_model
    h_heads, dh = _slstm_dims(cfg)
    window = jnp.concatenate([state["conv"], x[:, 0].astype(jnp.float32)[:, None]],
                             axis=1)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"])
    bias_if, bias_zo = jnp.split(p["gate_bias"], 2)
    wx_if = xc @ p["w_if"]["w"] + bias_if
    wx_zo = x[:, 0].astype(jnp.float32) @ p["w_zo"]["w"] + bias_zo

    import functools
    step = functools.partial(_slstm_step, r=p["r"], h_heads=h_heads, dh=dh)
    (c, n, m, h), hvec = step((state["c"], state["n"], state["m"], state["h"]),
                              (wx_if, wx_zo))
    hseq = apply_norm(p["group_norm"], hvec[:, None].astype(x.dtype), "rmsnorm")
    y = dense(p["out"], hseq)
    y = y + ffn(p["ffn"], y, "geglu")
    return y, {"c": c, "n": n, "m": m, "h": h, "conv": window[:, 1:]}
