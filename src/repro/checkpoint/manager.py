"""Checkpointing: atomic, async, sharded-restore-capable.

Layout (one directory per step):

    <root>/step_00001234/
        manifest.json      # keypaths, shapes, dtypes, user metadata
        arr_00000.npy ...  # leaves in tree order
        COMMITTED          # written last; restore ignores dirs without it

Guarantees used by the fault-tolerance layer:
  * atomicity — writes go to ``.tmp-<step>`` then os.replace + COMMITTED
    marker, so a crash mid-save never corrupts the latest checkpoint;
  * async — ``save(..., blocking=False)`` snapshots to host memory
    synchronously (device_get) and writes on a background thread, so the
    training loop overlaps checkpoint I/O with compute;
  * reshard-on-restore — leaves are stored unsharded; ``restore`` places
    them with whatever shardings the *target* example tree carries, so a
    checkpoint taken on a 512-chip mesh restores onto any other mesh
    (elastic scaling).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


class CheckpointManager:
    def __init__(self, root: str, *, keep_n: int = 3):
        self.root = root
        self.keep_n = keep_n
        os.makedirs(root, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="ckpt")
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------- write

    def save(self, step: int, tree: Any, *, metadata: Optional[dict] = None,
             blocking: bool = True) -> None:
        """Snapshot ``tree`` (any pytree of arrays) at ``step``."""
        self.wait_until_finished()
        leaves_with_path = jax.tree_util.tree_leaves_with_path(tree)
        # Synchronous device->host snapshot (consistent cut), async I/O.
        host_leaves = [(_keystr(p), np.asarray(jax.device_get(x)))
                       for p, x in leaves_with_path]
        meta = dict(metadata or {})

        def _write():
            tmp = os.path.join(self.root, f".tmp-{step}")
            final = os.path.join(self.root, f"step_{step:08d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "metadata": meta, "leaves": []}
            for i, (kp, arr) in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), arr)
                manifest["leaves"].append(
                    {"keypath": kp, "shape": list(arr.shape),
                     "dtype": str(arr.dtype)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            with open(os.path.join(final, "COMMITTED"), "w") as f:
                f.write("ok\n")
            self._gc()

        if blocking:
            _write()
        else:
            with self._lock:
                self._pending = self._pool.submit(_write)

    def wait_until_finished(self) -> None:
        with self._lock:
            pending = self._pending
            self._pending = None
        if pending is not None:
            pending.result()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_n]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- read

    def all_steps(self) -> list:
        out = []
        for name in sorted(os.listdir(self.root)):
            if not name.startswith("step_"):
                continue
            if not os.path.exists(os.path.join(self.root, name, "COMMITTED")):
                continue  # incomplete (crashed mid-save)
            out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def metadata(self, step: int) -> dict:
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)["metadata"]

    def restore(self, step: int, example: Any) -> Any:
        """Restore into the structure/shardings of ``example`` (arrays or
        ShapeDtypeStructs with .sharding).  Cross-mesh restore works
        because leaves are stored unsharded."""
        d = os.path.join(self.root, f"step_{step:08d}")
        if not os.path.exists(os.path.join(d, "COMMITTED")):
            raise FileNotFoundError(f"no committed checkpoint at step {step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_with_path = jax.tree_util.tree_leaves_with_path(example)
        if len(leaves_with_path) != len(manifest["leaves"]):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"target expects {len(leaves_with_path)}")
        restored = []
        for i, ((kp, ex), entry) in enumerate(
                zip(leaves_with_path, manifest["leaves"])):
            if _keystr(kp) != entry["keypath"]:
                raise ValueError(
                    f"leaf {i} keypath mismatch: {entry['keypath']} vs "
                    f"{_keystr(kp)}")
            arr = np.load(os.path.join(d, f"arr_{i:05d}.npy"))
            if tuple(arr.shape) != tuple(ex.shape):
                raise ValueError(f"leaf {entry['keypath']}: shape "
                                 f"{arr.shape} vs target {ex.shape}")
            sharding = getattr(ex, "sharding", None)
            if sharding is not None:
                restored.append(jax.device_put(arr, sharding))
            else:
                restored.append(jax.numpy.asarray(arr))
        treedef = jax.tree_util.tree_structure(example)
        return jax.tree_util.tree_unflatten(treedef, restored)
