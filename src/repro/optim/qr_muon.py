"""QR-Muon: momentum orthogonalization via MHT QR — the paper's technique
as a first-class training feature (DESIGN.md §3).

Muon (momentum + orthogonalized update) normally orthogonalizes with
Newton-Schulz.  Here the orthogonal factor comes from the *Modified
Householder Transform* blocked QR: ``O = Q(m) · diag(sign(diag R))`` —
an exactly-orthonormal factor with the same column space as the momentum,
computed by the paper's algorithm.  Methods:

    "qr"   MHT blocked QR (geqrf_fori: one fused O(1)-HLO program)
    "ns"   Newton-Schulz quintic (baseline for ablation)

Routing: matrix-shaped weights (not embeddings / heads / norms / biases)
get Muon; everything else gets AdamW.  Stacked leaves — (n_periods, ...)
layer stacks, (E, d, f) expert stacks, (H, dh, dh) xLSTM blocks — are
orthogonalized as batched 2-D problems via vmap over leading axes.

Distributed: pass ``orthogonalize_fn`` (e.g. built on
:func:`repro.core.tsqr.distributed_qr`) to orthogonalize FSDP-sharded
momentum with the butterfly-tree TSQR instead of gathering it.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.householder import form_q, unpack_r
from repro.core.plan import QRConfig, plan as qr_plan
from repro.optim.newton_schulz import newton_schulz_orthogonalize

Array = jax.Array

__all__ = ["MuonState", "muon_init", "muon_update", "is_muon_param",
           "qr_orthogonalize_2d"]

_EXCLUDE_NAMES = ("embed", "lm_head", "table", "router", "shared_gate")


class _Out(NamedTuple):
    p: object
    mu: object
    nu: object


class _Pre(NamedTuple):
    """Pass-1 record of the two-pass batched-ortho update: AdamW leaves
    arrive finished (``p`` set, ``direction`` None); Muon leaves carry the
    momentum direction awaiting the shape-class-batched orthogonalization
    before pass 2 finishes ``p``."""
    p: object           # finished param (adam) or original param (muon)
    mu: object
    nu: object
    direction: object   # muon momentum direction, else None


class MuonState(NamedTuple):
    step: Array
    mu: object          # momentum (all leaves)
    nu: object          # adam second moment (None on muon leaves)


def _path_names(path) -> tuple:
    return tuple(str(getattr(k, "key", getattr(k, "name", k))) for k in path)


def is_muon_param(path, leaf) -> bool:
    names = _path_names(path)
    if any(n in _EXCLUDE_NAMES for n in names):
        return False
    if leaf.ndim < 2:
        return False
    d_out, d_in = leaf.shape[-2], leaf.shape[-1]
    return min(d_out, d_in) >= 8


def _pad_to(x: Array, mult: int) -> Array:
    k = min(x.shape)
    pad = (-k) % mult
    if pad == 0:
        return x
    # pad the short dimension with identity-ish columns (they factor to
    # exact reflectors and are sliced away after)
    if x.shape[0] <= x.shape[1]:
        return jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], 0)
    return jnp.concatenate([x, jnp.zeros((x.shape[0], pad), x.dtype)], 1)


def qr_orthogonalize_2d(m_in: Array, *, block: int = 64,
                        q_method: str = "formq",
                        config: Optional[QRConfig] = None) -> Array:
    """Sign-fixed thin Q of a single (possibly wide) matrix via MHT QR.

    ``config`` (a :class:`repro.core.plan.QRConfig`) overrides
    ``block``/``q_method``; the factorization itself always routes through
    the planner's method registry (``geqrf_fori``: one fused O(1)-HLO
    program regardless of matrix size).

    ``q_method``:
      * "solve" (beyond-paper §Perf iteration Q1): Q = A R^{-1}
        by triangular solve — one dense op instead of the k-step
        reflector-application loop.  R comes from the stable MHT QR so
        this is NOT CholeskyQR (no Gram squaring); orthogonality matches
        form-Q to fp32 eps for optimizer-grade conditioning, and the
        diag-clamp handles rank deficiency.
      * "formq" (default — the paper-faithful baseline): accumulate
        reflectors; exact even for singular input, but a min(m,n)-trip
        sequential loop.

    Accumulation runs in ``promote_types(param_dtype, float32)`` — bf16
    storage params factor in fp32 (and round back to bf16 on return),
    fp64 params keep fp64 precision — the factorization never silently
    downcasts the way the old hardcoded-fp32 plan did.
    """
    # Compute dtype: at least fp32 (bf16/f16 storage accumulates in
    # fp32), but NEVER below the param dtype (f64 stays f64).
    compute_dtype = jnp.promote_types(m_in.dtype, jnp.float32)
    if config is None:
        config = QRConfig(method="geqrf_fori", block=block, q_method=q_method,
                          precision=str(np.dtype(compute_dtype)),
                          sign_fix=True)
    q_method = config.q_method
    transpose = m_in.shape[0] < m_in.shape[1]
    a = m_in.T if transpose else m_in
    mrows, ncols = a.shape
    blk = min(config.block, ncols)
    acc = a.astype(compute_dtype)
    padded = _pad_to(acc, blk)
    # The optimizer needs the packed factored form — resolve "auto" to the
    # fused-program realization rather than letting the planner pick TSQR.
    method = "geqrf_fori" if config.method == "auto" else config.method
    solver = qr_plan(padded.shape, compute_dtype,
                     config.replace(block=blk, method=method))
    packed, taus = solver.factor(padded)
    r = unpack_r(packed)[:ncols, :ncols]
    if q_method == "solve":
        # Q = A R^{-1} with R^{-1} formed explicitly: the (n x n)
        # triangular solve runs against the identity (small, replicated)
        # and the application is a plain GEMM — shardable, unlike a
        # batched triangular solve over the full (m, n) operand (GSPMD
        # cannot shard the solve dimension and replicates ~GiB stacks).
        from jax.scipy.linalg import solve_triangular

        d = jnp.diagonal(r)
        dmax = jnp.maximum(jnp.max(jnp.abs(d)), 1e-30)
        clamp = jnp.where(jnp.abs(d) < 1e-7 * dmax,
                          jnp.where(d >= 0, 1e-7 * dmax, -1e-7 * dmax), d)
        r_safe = r + jnp.diag(clamp - d)
        r_inv = solve_triangular(r_safe, jnp.eye(ncols, dtype=compute_dtype),
                                 lower=False)
        q = acc @ r_inv
    else:
        q = form_q(packed, taus)[:mrows, :ncols]
    signs = jnp.where(jnp.diagonal(r) >= 0, 1.0, -1.0).astype(q.dtype)
    q = q * signs[None, :]
    return (q.T if transpose else q).astype(m_in.dtype)


def _orthogonalize_leaf(mu: Array, method: str,
                        orth_fn: Optional[Callable],
                        q_method: str = "formq",
                        shard_leaves: bool = False,
                        config: Optional[QRConfig] = None) -> Array:
    """Batched orthogonalization over any leading axes of a >=2-D leaf.

    ``shard_leaves`` (beyond-paper §Perf iteration Q2): constrain the
    vmapped (lead, m, n) stack to be layer-sharded over the data axis and
    each matrix replicated — the QR's sequential panel loops then run
    device-local (GSPMD otherwise threads tiny collectives through every
    panel iteration of the factorization loop), trading one gather of the
    momentum for collective-free factorization.  Falls back to no
    constraint when the lead dim does not divide."""
    lead = mu.shape[:-2]
    mats = mu.astype(jnp.float32)
    # NEVER reshape the leading axes together: merging an (n_periods, E)
    # pair whose E is model-sharded into one dim is unrepresentable in
    # GSPMD and forces full replication of the momentum stack (observed:
    # +100 GiB temp on the 16-expert cells).  Nested vmap keeps each axis
    # and its sharding intact.
    if shard_leaves and len(lead) >= 1:
        from repro.distributed.sharding import _policy
        from jax.sharding import PartitionSpec as P

        rules, _ = _policy()
        if rules is not None:
            spec = [None] * mats.ndim
            if mats.shape[0] % rules.data_size == 0:
                spec[0] = rules.data_spec()
            # model axis: prefer a second lead dim (expert stacks — each
            # expert's matrix stays whole and local); otherwise the QR's
            # column dim (min of the trailing dims; the orthogonalizer
            # transposes wide inputs) so the (m, n) planes never sit
            # unsharded.  Dynamic panel slices over a sharded column dim
            # are fine for 64-column slivers but replicate whole planes
            # when the lead dims are unsharded — hence the preference
            # order (measured: jamba 20 -> 50 GiB with col-sharding on
            # unsharded-lead expert stacks; qwen 16.2 -> 13.5 with
            # col-sharding on data-sharded 3-D stacks).
            if rules.tp_enabled:
                model_done = False
                for i in range(1, mats.ndim - 2):
                    if mats.shape[i] % rules.model_size == 0:
                        spec[i] = rules.model_axis
                        model_done = True
                        break
                if not model_done and spec[0] is not None:
                    a_dim, b_dim = mats.shape[-2], mats.shape[-1]
                    col = mats.ndim - 2 + (0 if a_dim <= b_dim else 1)
                    if mats.shape[col] % rules.model_size == 0:
                        spec[col] = rules.model_axis
            if any(s is not None for s in spec):
                mats = jax.lax.with_sharding_constraint(mats, P(*spec))
            else:
                # no clean sharding (e.g. 4-period stacks on a 16-way
                # axis): the batched triangular-solve/GEMM Q would
                # replicate whole (m, n) planes — use the incremental
                # reflector accumulation instead (one reused carry
                # buffer; measured jamba 41.5 -> baseline-class temp)
                q_method = "formq"
    if orth_fn is not None:
        f = orth_fn
    elif method == "qr":
        if config is not None:
            config = config.replace(q_method=q_method)
        f = functools.partial(qr_orthogonalize_2d, q_method=q_method,
                              config=config)
    elif method == "ns":
        f = newton_schulz_orthogonalize
    else:
        raise ValueError(f"unknown orthogonalization {method!r}")
    for _ in lead:
        f = jax.vmap(f)
    return f(mats)


def muon_init(params) -> MuonState:
    """Muon leaves carry a scalar placeholder ``nu`` (no second moment) so
    the state tree structure matches the params while costing no memory."""
    mu = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    nu = jax.tree_util.tree_map_with_path(
        lambda path, p: jnp.zeros((), jnp.float32) if is_muon_param(path, p)
        else jnp.zeros_like(p, jnp.float32), params)
    return MuonState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)


def muon_update(
    grads, state: MuonState, params, *,
    lr: float | Array,
    momentum: float = 0.95,
    nesterov: bool = True,
    weight_decay: float = 0.0,
    method: str = "qr",
    adam_lr_ratio: float = 0.3,
    adam_b1: float = 0.9, adam_b2: float = 0.95, adam_eps: float = 1e-8,
    orthogonalize_fn: Optional[Callable] = None,
    qr_q_method: str = "formq",
    qr_shard_leaves: bool = False,
    qr_config: Optional[QRConfig] = None,
    batched_ortho: bool = False,
    ortho_policy=None,
):
    """One optimizer step.  ``lr`` is the Muon LR; AdamW params use
    ``lr * adam_lr_ratio`` (embeddings etc. want a smaller step).

    ``qr_config`` tunes the QR realization (method/block/kernel policy)
    of the orthogonalization; ``qr_q_method`` still wins for the Q
    materialization strategy (the sharding fallback logic may override it
    per leaf).

    ``batched_ortho=True`` routes the orthogonalizations through
    :func:`repro.optim.batched_ortho.batched_orthogonalize`: every Muon
    matrix of the step groups into shape classes and each class factors
    in ONE dispatch, dropping the per-step QR dispatch count from
    O(muon leaves) to O(shape classes).  Applies only to the plain QR
    path — a custom ``orthogonalize_fn`` or ``qr_shard_leaves`` (whose
    per-leaf sharding constraints a cross-leaf stack cannot express)
    keeps the leafwise route.  ``ortho_policy`` (a
    :class:`repro.serving.bucketing.BucketingPolicy`) overrides the
    shape-class edges."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - adam_b1 ** t
    bc2 = 1.0 - adam_b2 ** t

    use_batched = (batched_ortho and method == "qr"
                   and orthogonalize_fn is None and not qr_shard_leaves)

    def finish_muon(p, o):
        d_out, d_in = p.shape[-2], p.shape[-1]
        scale = jnp.sqrt(jnp.maximum(1.0, d_out / d_in))
        new_p = p - lr * (scale * o + weight_decay * p)
        return new_p.astype(p.dtype)

    if use_batched:
        from repro.optim.batched_ortho import batched_orthogonalize

        def pre(path, p, g, mu, nu):
            g = g.astype(jnp.float32)
            if is_muon_param(path, p):
                mu = momentum * mu + g
                direction = g + momentum * mu if nesterov else mu
                return _Pre(p, mu, nu, direction)
            mu2 = adam_b1 * mu + (1 - adam_b1) * g
            nu2 = adam_b2 * nu + (1 - adam_b2) * (g * g)
            upd_ = (mu2 / bc1) / (jnp.sqrt(nu2 / bc2) + adam_eps)
            new_p = p - (lr * adam_lr_ratio) * (upd_ + weight_decay * p)
            return _Pre(new_p.astype(p.dtype), mu2, nu2, None)

        is_pre = lambda x: isinstance(x, _Pre)  # noqa: E731
        pres = jax.tree_util.tree_map_with_path(
            lambda path, p, g, mu, nu: pre(path, p, g, mu, nu),
            params, grads, state.mu, state.nu)
        flat, treedef = jax.tree_util.tree_flatten(pres, is_leaf=is_pre)
        cfg = qr_config
        if cfg is not None:
            cfg = cfg.replace(q_method=qr_q_method)
        orth = iter(batched_orthogonalize(
            [f.direction for f in flat if f.direction is not None],
            policy=ortho_policy, config=cfg,
            fallback=functools.partial(qr_orthogonalize_2d,
                                       q_method=qr_q_method, config=cfg)))
        flat = [f if f.direction is None else
                f._replace(p=finish_muon(f.p, next(orth)), direction=None)
                for f in flat]
        out = jax.tree_util.tree_unflatten(treedef, flat)
        new_params = jax.tree.map(lambda o: o.p, out, is_leaf=is_pre)
        new_mu = jax.tree.map(lambda o: o.mu, out, is_leaf=is_pre)
        new_nu = jax.tree.map(lambda o: o.nu, out, is_leaf=is_pre)
        return new_params, MuonState(step=step, mu=new_mu, nu=new_nu)

    def upd(path, p, g, mu, nu):
        g = g.astype(jnp.float32)
        if is_muon_param(path, p):
            mu = momentum * mu + g
            direction = g + momentum * mu if nesterov else mu
            o = _orthogonalize_leaf(direction, method, orthogonalize_fn,
                                    q_method=qr_q_method,
                                    shard_leaves=qr_shard_leaves,
                                    config=qr_config)
            return finish_muon(p, o), mu, nu  # nu: scalar placeholder
        mu2 = adam_b1 * mu + (1 - adam_b1) * g
        nu2 = adam_b2 * nu + (1 - adam_b2) * (g * g)
        upd_ = (mu2 / bc1) / (jnp.sqrt(nu2 / bc2) + adam_eps)
        new_p = p - (lr * adam_lr_ratio) * (upd_ + weight_decay * p)
        return new_p.astype(p.dtype), mu2, nu2

    out = jax.tree_util.tree_map_with_path(
        lambda path, p, g, mu, nu: _Out(*upd(path, p, g, mu, nu)),
        params, grads, state.mu, state.nu)
    is_out = lambda x: isinstance(x, _Out)
    new_params = jax.tree.map(lambda o: o.p, out, is_leaf=is_out)
    new_mu = jax.tree.map(lambda o: o.mu, out, is_leaf=is_out)
    new_nu = jax.tree.map(lambda o: o.nu, out, is_leaf=is_out)
    return new_params, MuonState(step=step, mu=new_mu, nu=new_nu)
