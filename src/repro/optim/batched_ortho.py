"""Batched optimizer-step orthogonalization: one dispatch per shape class.

The paper's thesis — rearrange the computation to expose more parallel
work per DAG level — applied one level up: a Muon optimizer step
orthogonalizes dozens of independent momentum matrices, and running them
one leaf at a time is the same missed opportunity the tile DAG fixes
inside a single factorization.  This module collects every 2-D momentum
matrix of an update step, groups them into **shape classes** with the
serving layer's bucketing machinery
(:func:`repro.serving.bucketing.group_shape_classes`, under a
tile-granularity optimizer policy — see ``DEFAULT_ORTHO_POLICY``;
measured tuning-cache routings still govern each class plan, because the
planner's tuned rule maps any shape through the cache's own
``shape_class`` edges at lookup), zero-pads
and stacks each class, plans the stack ONCE through
:func:`repro.core.plan.plan`, and factors the whole class in one
dispatch — on the tiled route that is one
:func:`repro.core.engine.factor_tiles_batched` call (a single
``pallas_call`` in megakernel mode); other methods vmap inside one
compiled program.  Q forms batched, the unpadded slices scatter back,
and the per-step QR dispatch count drops from O(number of 2-D params) to
O(shape classes).

Zero padding is numerically free: Householder QR proceeds left to right,
so trailing zero columns never touch the leading ``n`` columns of Q, and
zero rows factor to zero reflector entries — the ``[:m, :n]`` slice of
the padded sign-fixed thin Q IS the sign-fixed thin Q of the member (the
same invariant the serving layer's buckets rely on).

Routing per class is recorded in an :class:`OrthoPlan`:

  * ``"batched"``  — the class stacked and planned as one ``(B, M, N)``
    problem; the planner's full explain trail rides on the class plan.
  * ``"leafwise"`` — fallback to per-matrix
    :func:`repro.optim.qr_muon.qr_orthogonalize_2d`: singleton classes
    (a batch of one amortizes nothing — and the B=1 stacked program is a
    different jit cache entry per step count for no benefit) and shapes
    whose class plan fails capability checks.

:func:`plan_batched_ortho` is a pure, trace-free query over static
shapes — benchmarks and tests count dispatches from it without running
anything.  :func:`batched_orthogonalize` executes the plan (inside jit:
all grouping is static, only the padded stacks are traced), emitting
``optim.*`` counters and spans through the observability registry.
:func:`repro.optim.qr_muon.muon_update` rides on it behind the
``batched_ortho=True`` knob.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import PlanExplain, QRConfig, plan as qr_plan
from repro.observability import metrics as _metrics
from repro.observability import trace as _trace
from repro.serving.bucketing import (
    BucketKey, BucketingPolicy, group_shape_classes)

Array = jax.Array

__all__ = [
    "DEFAULT_ORTHO_POLICY",
    "OrthoClassPlan",
    "OrthoPlan",
    "batched_orthogonalize",
    "plan_batched_ortho",
]

# Optimizer-side bucketing: tile 16, tile-granularity padding only
# (max_waste=0).  Serving pads to pow2-ish edges because open-ended
# traffic needs a logarithmic bucket count; an optimizer step's shapes
# are a small STATIC set in which classes form from exactly repeated
# layer shapes, so coarser edges buy no extra merging — they only burn
# cubic flops (the serving default would pad 48 -> 64 and 576 -> 768,
# ~2.4x the QR work per matrix).  Tuned routings still apply to the
# class plan: the planner's tuned rule maps ANY (m, n) through the
# tuning cache's own ``shape_class`` edges at lookup.  max_batch is
# per-class; an optimizer step's class population is bounded by the
# parameter count, not arrival rate.
DEFAULT_ORTHO_POLICY = BucketingPolicy(tile=16, max_waste=0.0,
                                       max_batch=512)


@dataclasses.dataclass(frozen=True)
class OrthoClassPlan:
    """Routing for one shape class of the step: which flat members it
    owns, whether they run as one stacked dispatch or leafwise, and why.

    ``key`` is the padded, tall-oriented shape class (wide members are
    transposed before classing, exactly as ``qr_orthogonalize_2d``
    transposes wide inputs).  ``explain`` is the planner's full decision
    trail for the stacked plan (batched classes only)."""

    key: BucketKey
    members: Tuple[int, ...]          # flat member indices, step order
    route: str                        # "batched" | "leafwise"
    reason: str
    method: Optional[str] = None      # resolved method (batched only)
    dispatch_mode: Optional[str] = None
    explain: Optional[PlanExplain] = dataclasses.field(default=None,
                                                       compare=False)

    @property
    def dispatches(self) -> int:
        return 1 if self.route == "batched" else len(self.members)


@dataclasses.dataclass(frozen=True)
class OrthoPlan:
    """The step's full dispatch plan: every 2-D matrix of every leaf
    assigned to exactly one shape class.  Member index space is flat:
    leaf ``i``'s lead dims unroll row-major, leaves concatenate in input
    order; ``member_leaf[j]`` maps member ``j`` back to its leaf."""

    classes: Tuple[OrthoClassPlan, ...]
    n_leaves: int
    n_matrices: int
    member_leaf: Tuple[int, ...]

    @property
    def dispatches(self) -> int:
        """QR dispatches one step issues under this plan."""
        return sum(c.dispatches for c in self.classes)

    @property
    def batched_matrices(self) -> int:
        return sum(len(c.members) for c in self.classes
                   if c.route == "batched")

    @property
    def leafwise_matrices(self) -> int:
        return sum(len(c.members) for c in self.classes
                   if c.route == "leafwise")


def _member_geometry(shape, dtype):
    """Oriented 2-D geometry of one leaf's members: ``(lead, m, n,
    transpose, compute_dtype)`` — lead is the unrolled stack depth."""
    m, n = int(shape[-2]), int(shape[-1])
    lead = int(np.prod(shape[:-2], dtype=np.int64)) if len(shape) > 2 else 1
    transpose = m < n
    if transpose:
        m, n = n, m
    compute = jnp.promote_types(np.dtype(dtype), jnp.float32)
    return lead, m, n, transpose, np.dtype(compute)


def plan_batched_ortho(leaves: Sequence[Tuple], *,
                       policy: Optional[BucketingPolicy] = None,
                       config: Optional[QRConfig] = None,
                       backend: Optional[str] = None) -> OrthoPlan:
    """Pure shape-class routing for one step's orthogonalization.

    ``leaves`` is a sequence of ``(shape, dtype)`` pairs, one per >=2-D
    momentum leaf (lead dims unroll into members).  No arrays are
    touched: benchmarks count ``plan.dispatches`` and tests assert
    routes from this alone.  ``config`` seeds the per-class
    :func:`repro.core.plan.plan` call (mode/sign_fix pinned to the
    orthogonalization contract); ``backend`` overrides the routing
    backend as in ``plan``.
    """
    policy = DEFAULT_ORTHO_POLICY if policy is None else policy
    base = QRConfig() if config is None else config
    base = base.replace(mode="reduced", sign_fix=True)

    member_shapes: List[Tuple[int, int, np.dtype]] = []
    member_leaf: List[int] = []
    for li, (shape, dtype) in enumerate(leaves):
        if len(shape) < 2:
            raise ValueError(
                f"orthogonalization needs matrix leaves, got shape {shape}")
        lead, m, n, _, compute = _member_geometry(shape, dtype)
        member_shapes.extend([(m, n, compute)] * lead)
        member_leaf.extend([li] * lead)

    classes: List[OrthoClassPlan] = []
    for key, members in group_shape_classes(member_shapes, policy).items():
        b = len(members)
        if b == 1:
            classes.append(OrthoClassPlan(
                key=key, members=tuple(members), route="leafwise",
                reason="singleton_class: a batch of one amortizes no "
                       "dispatch — per-leaf qr_orthogonalize_2d"))
            continue
        try:
            solver = qr_plan((b, key.m, key.n), np.dtype(key.dtype), base,
                             backend=backend, explain=True)
        except (ValueError, ImportError) as e:
            classes.append(OrthoClassPlan(
                key=key, members=tuple(members), route="leafwise",
                reason=f"plan_failed: {e}"))
            continue
        sel = solver.explain.selected
        classes.append(OrthoClassPlan(
            key=key, members=tuple(members), route="batched",
            reason=f"{sel.rule}: {sel.reason}" if sel is not None else
                   "planned", method=solver.config.method,
            dispatch_mode=solver.config.dispatch_mode,
            explain=solver.explain))
    return OrthoPlan(classes=tuple(classes), n_leaves=len(leaves),
                     n_matrices=len(member_shapes),
                     member_leaf=tuple(member_leaf))


def _default_fallback(a: Array) -> Array:
    from repro.optim.qr_muon import qr_orthogonalize_2d

    return qr_orthogonalize_2d(a)


def _post_dispatch(q_stack: Array, label: str, *,
                   verify: Optional[bool]):
    """Robustness seam of one batched class dispatch: the chaos
    output-corruption hook, then (verify knob on, eager values only —
    host-side resolution never fires under a trace, keeping the
    verify-off jit path jaxpr-identical) a per-slice orthogonality
    health check.  Returns ``(q_stack, bad_slots)``; flagged slots
    escalate batched -> leafwise in the caller, each hop counted under
    ``robustness.escalations{from=batched, to=leafwise}``."""
    if isinstance(q_stack, jax.core.Tracer):
        return q_stack, frozenset()
    from repro.robustness import inject as _inject

    if _inject.enabled():
        q_stack = _inject.corrupt_output(q_stack, f"ortho:{label}")
    from repro.robustness.verify import check_ortho_batch, verify_enabled

    if not verify_enabled(verify):
        return q_stack, frozenset()
    from repro.robustness import escalate as _escalate

    bad = set()
    reports = check_ortho_batch(q_stack)
    for slot, rep in enumerate(reports):
        if rep.ok:
            continue
        bad.add(slot)
        _escalate.record(
            "batched", "leafwise", "health_check_failed",
            f"class {label} slot {slot}: {rep.reason} "
            f"defect={rep.ortho_defect:.3e} tol={rep.tol:.3e}")
        _metrics.counter("optim.ortho_escalations", bucket=label).inc()
    return q_stack, bad


def batched_orthogonalize(leaves: Sequence[Array], *,
                          policy: Optional[BucketingPolicy] = None,
                          config: Optional[QRConfig] = None,
                          fallback: Optional[Callable] = None,
                          backend: Optional[str] = None,
                          ortho_plan: Optional[OrthoPlan] = None
                          ) -> List[Array]:
    """Sign-fixed thin Q of every matrix in ``leaves``, dispatched per
    shape class.

    Each leaf is a >=2-D array (lead dims are independent stacked
    matrices, as in ``muon_update``); the result list matches input
    shapes and dtypes.  Safe (and intended) to call inside ``jit`` — the
    routing is a static function of shapes; only padding, stacking, and
    the factorizations trace.  ``fallback`` handles leafwise-routed
    members (default: :func:`repro.optim.qr_muon.qr_orthogonalize_2d`
    with its defaults); ``ortho_plan`` reuses a precomputed plan (it
    must have been built from these leaves' shapes/dtypes).
    """
    leaves = list(leaves)
    policy = DEFAULT_ORTHO_POLICY if policy is None else policy
    if ortho_plan is None:
        ortho_plan = plan_batched_ortho(
            [(tuple(l.shape), l.dtype) for l in leaves],
            policy=policy, config=config, backend=backend)
    base = QRConfig() if config is None else config
    base = base.replace(mode="reduced", sign_fix=True)
    fallback = _default_fallback if fallback is None else fallback

    # Flat member views, in the plan's member index space.
    members: List[Array] = []
    geom: List[Tuple[int, int, bool]] = []   # oriented (m, n, transposed)
    for leaf in leaves:
        lead, m, n, transpose, _ = _member_geometry(leaf.shape, leaf.dtype)
        stack = leaf.reshape((lead,) + leaf.shape[-2:])
        for s in range(lead):
            mat = stack[s]
            members.append(mat.T if transpose else mat)
            geom.append((m, n, transpose))

    out: List[Optional[Array]] = [None] * len(members)
    with _trace.span("optim.batched_ortho", classes=len(ortho_plan.classes),
                     matrices=ortho_plan.n_matrices):
        for cls in ortho_plan.classes:
            _metrics.counter("optim.ortho_classes", route=cls.route).inc()
            _metrics.counter("optim.ortho_dispatches",
                             route=cls.route).inc(cls.dispatches)
            _metrics.counter("optim.ortho_matrices",
                             route=cls.route).inc(len(cls.members))
            label = f"{cls.key.m}x{cls.key.n}"
            if cls.route == "leafwise":
                with _trace.span("optim.ortho_class", bucket=label,
                                 route="leafwise", batch=len(cls.members)):
                    for j in cls.members:
                        m, n, transpose = geom[j]
                        q = fallback(members[j].T if transpose
                                     else members[j])
                        out[j] = q.T if transpose else q
                continue
            compute = np.dtype(cls.key.dtype)
            solver = qr_plan((len(cls.members), cls.key.m, cls.key.n),
                             compute, base, backend=backend)
            with _trace.span("optim.ortho_class", bucket=label,
                             route="batched", batch=len(cls.members),
                             method=solver.config.method):
                stacked = jnp.stack([
                    jnp.pad(members[j].astype(compute),
                            ((0, cls.key.m - geom[j][0]),
                             (0, cls.key.n - geom[j][1])))
                    for j in cls.members])
                q_stack = solver.orthogonalize(stacked)
                q_stack, bad = _post_dispatch(q_stack, label,
                                              verify=base.verify)
                for slot, j in enumerate(cls.members):
                    m, n, transpose = geom[j]
                    if slot in bad:
                        # Per-slice escalation: the batched dispatch's
                        # flagged slice alone re-solves leafwise; its
                        # class-mates ship as-is.
                        q = fallback(members[j].astype(compute)).astype(
                            leaves[ortho_plan.member_leaf[j]].dtype)
                        out[j] = q.T if transpose else q
                        continue
                    q = q_stack[slot, :m, :n].astype(leaves[
                        ortho_plan.member_leaf[j]].dtype)
                    out[j] = q.T if transpose else q

    # Scatter members back into leaf-shaped stacks.
    results: List[Array] = []
    pos = 0
    for leaf in leaves:
        lead, _, _, _, _ = _member_geometry(leaf.shape, leaf.dtype)
        mats = out[pos:pos + lead]
        pos += lead
        results.append(jnp.stack(mats).reshape(leaf.shape) if lead > 1
                       or len(leaf.shape) > 2 else mats[0])
    return results
