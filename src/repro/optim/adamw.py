"""AdamW — baseline optimizer and the fallback for non-matrix params.

Pure-functional (optax-style): ``init(params) -> state``,
``update(grads, state, params, lr, ...) -> (new_params, new_state)``.
State is fp32, shaped/sharded like the params.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

__all__ = ["AdamWState", "adamw_init", "adamw_update"]


class _Out(NamedTuple):
    p: object
    m: object
    v: object


class AdamWState(NamedTuple):
    step: Array
    m: object
    v: object


def adamw_init(params) -> AdamWState:
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(params),
                      v=zeros(params))


def adamw_update(
    grads, state: AdamWState, params, *,
    lr: float | Array, b1: float = 0.9, b2: float = 0.95,
    eps: float = 1e-8, weight_decay: float = 0.1,
):
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * (g * g)
        mh = m / bc1
        vh = v / bc2
        new_p = p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(lambda *a: _Out(*upd(*a)), params, grads, state.m,
                       state.v)
    is_out = lambda x: isinstance(x, _Out)
    new_params = jax.tree.map(lambda o: o.p, out, is_leaf=is_out)
    new_m = jax.tree.map(lambda o: o.m, out, is_leaf=is_out)
    new_v = jax.tree.map(lambda o: o.v, out, is_leaf=is_out)
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
