"""Optimizers: QR-Muon (the paper's MHT QR as orthogonalizer) + AdamW.

    adamw          baseline / fallback optimizer
    qr_muon        Muon with MHT-QR or Newton-Schulz orthogonalization
    batched_ortho  shape-class-batched orthogonalization (one dispatch
                   per class instead of per leaf)
    newton_schulz  the NS quintic baseline
    schedule       warmup+cosine LR
"""

from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.batched_ortho import (
    DEFAULT_ORTHO_POLICY, OrthoClassPlan, OrthoPlan, batched_orthogonalize,
    plan_batched_ortho,
)
from repro.optim.newton_schulz import newton_schulz_orthogonalize
from repro.optim.qr_muon import (
    MuonState, is_muon_param, muon_init, muon_update, qr_orthogonalize_2d,
)
from repro.optim.schedule import warmup_cosine

__all__ = [
    "AdamWState", "adamw_init", "adamw_update",
    "MuonState", "muon_init", "muon_update", "is_muon_param",
    "qr_orthogonalize_2d", "newton_schulz_orthogonalize", "warmup_cosine",
    "DEFAULT_ORTHO_POLICY", "OrthoClassPlan", "OrthoPlan",
    "batched_orthogonalize", "plan_batched_ortho",
]
