"""Newton-Schulz orthogonalization — the Muon-default baseline the QR path
is ablated against (DESIGN.md §3).

Quintic NS iteration (Keller Jordan's Muon coefficients): approximates
UV^T of the input's SVD.  Works on the normalized matrix; 5 iterations in
bf16 is the published recipe, fp32 here since our host is CPU and the
optimizer state is fp32 anyway.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

__all__ = ["newton_schulz_orthogonalize"]

_NS_COEFFS = (3.4445, -4.7750, 2.0315)


def newton_schulz_orthogonalize(g: Array, *, steps: int = 5,
                                eps: float = 1e-7) -> Array:
    """Approximate orthogonal factor (UV^T) of a 2-D matrix."""
    if g.ndim != 2:
        raise ValueError(f"expected 2-D, got {g.shape}")
    a, b, c = _NS_COEFFS
    transpose = g.shape[0] > g.shape[1]
    x = g.T if transpose else g                       # rows <= cols
    x = x / (jnp.linalg.norm(x) + eps)

    def body(_, x):
        xxt = x @ x.T
        return a * x + (b * xxt + c * (xxt @ xxt)) @ x

    x = jax.lax.fori_loop(0, steps, body, x)
    return x.T if transpose else x
