"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config``.

One module per assigned architecture (exact published configs) plus the
paper's own QR workload sizes.  ``ARCHS`` maps the CLI ``--arch`` ids.
"""

from repro.configs.base import SHAPES, LayerSpec, ModelConfig, MoEConfig, ShapeConfig

_MODULES = {
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "olmo-1b": "olmo_1b",
    "qwen2.5-32b": "qwen2_5_32b",
    "smollm-135m": "smollm_135m",
    "gemma2-9b": "gemma2_9b",
    "xlstm-1.3b": "xlstm_1_3b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "chameleon-34b": "chameleon_34b",
    "musicgen-large": "musicgen_large",
}

ARCHS = tuple(_MODULES)


def _load(arch: str):
    import importlib

    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _load(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _load(arch).SMOKE


__all__ = ["ARCHS", "get_config", "get_smoke_config", "SHAPES",
           "LayerSpec", "ModelConfig", "MoEConfig", "ShapeConfig"]
