"""qwen2.5-32b  [dense]  — GQA with QKV bias, SwiGLU, RMSNorm.

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064
[hf:Qwen/Qwen2.5-0.5B family; hf]
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=27648, vocab_size=152064, period=(LayerSpec("attn", "dense"),),
    qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=160, vocab_size=256, seq_chunk=32)
