"""musicgen-large  [audio]  — decoder-only over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf]
The EnCodec frontend is a STUB per the assignment: input_specs() supplies
precomputed frame embeddings; the backbone is a plain GELU/LayerNorm
decoder over the 2048-entry codebook (RoPE substitutes the original
sinusoidal positions — noted in DESIGN.md).
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab_size=2048, period=(LayerSpec("attn", "dense"),),
    norm="layernorm", ffn_act="gelu", embedding_input=True,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_head=16, d_ff=128, vocab_size=64, seq_chunk=32)
