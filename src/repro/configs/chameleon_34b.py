"""chameleon-34b  [vlm]  — early-fusion over VQ image tokens, QK-norm.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536
[arXiv:2405.09818; unverified]
The modality frontend is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings (B, S, d); decode runs over the unified
text+image token vocabulary.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22016, vocab_size=65536, period=(LayerSpec("attn", "dense"),),
    qk_norm=True, embedding_input=True,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=128, vocab_size=256, seq_chunk=32)
