"""xlstm-1.3b  [ssm]  — sLSTM + mLSTM blocks (xLSTM[7:1]).

48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304  [arXiv:2405.04517; unverified]
Period of 8: seven mLSTM blocks (matrix memory, internal 2x projection, no
separate FFN) then one sLSTM block (scalar memory + 4/3 gated FFN).
Recurrent -> O(1) decode state -> runs the long_500k cell.
"""

from repro.configs.base import LayerSpec, ModelConfig

_PERIOD = tuple(LayerSpec("mlstm", "none") for _ in range(7)) + (
    LayerSpec("slstm", "none"),)

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_head=512,
    d_ff=0, vocab_size=50304, period=_PERIOD,
    norm="layernorm", mlstm_proj_factor=2.0, conv_kernel=4,
    sub_quadratic=True, tie_embeddings=True,
)

SMOKE = CONFIG.scaled(n_layers=8, d_model=64, n_heads=2, n_kv_heads=2,
                      d_head=32, vocab_size=256, seq_chunk=32)
