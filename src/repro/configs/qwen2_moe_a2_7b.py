"""qwen2-moe-a2.7b  [moe]  — 60 routed experts top-4 + 4 shared experts.

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
d_ff is the per-expert width; the 4 shared experts are fused into one
sigmoid-gated dense FFN of width 4*1408.
"""

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1408, vocab_size=151936, period=(LayerSpec("attn", "moe"),),
    moe=MoEConfig(num_experts=60, top_k=4, d_expert=1408, num_shared=4),
    qkv_bias=True,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_head=16, d_ff=32, vocab_size=256,
                      moe=MoEConfig(num_experts=6, top_k=2, d_expert=32,
                                    num_shared=2), seq_chunk=32)
