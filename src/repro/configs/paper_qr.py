"""The paper's own QR workload sizes (its figs 11/14 sweep square
matrices on the PE / REDEFINE fabric).

Not one of the 10 assigned LM architectures — this config parameterizes
the QR benchmarks and examples so the paper's experiment grid is
reproducible from one place.
"""

import dataclasses
from typing import Tuple

__all__ = ["PaperQRConfig", "CONFIG"]


@dataclasses.dataclass(frozen=True)
class PaperQRConfig:
    # matrix sizes swept in the paper's performance figures
    sizes: Tuple[Tuple[int, int], ...] = (
        (64, 64), (128, 128), (256, 256), (512, 512), (512, 256),
    )
    block: int = 32                # WY panel width (DGEQRF/DGEQRFHT)
    kernel_panel_max_m: int = 1024  # VMEM budget bound for mht_panel
    tile_grid: Tuple[int, ...] = (1, 2, 4, 8)   # paper's KxK fabric sweep
    dag_sizes: Tuple[int, ...] = (4, 8, 16, 32, 64, 128)  # fig 9 sweep
    rdp_width: int = 4             # DOT4 width for the theta phase model


CONFIG = PaperQRConfig()
