"""phi3.5-moe-42b-a6.6b  [moe]  — 16 experts, top-2 routing.

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064
[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=6400, vocab_size=32064, period=(LayerSpec("attn", "moe"),),
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=6400),
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=64, vocab_size=256,
                      moe=MoEConfig(num_experts=4, top_k=2, d_expert=64),
                      seq_chunk=32)
