"""smollm-135m  [dense]  — llama-architecture small model.

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M; hf]
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_head=64,
    d_ff=1536, vocab_size=49152, period=(LayerSpec("attn", "dense"),),
    tie_embeddings=True,
)

SMOKE = CONFIG.scaled(n_layers=3, d_model=48, n_heads=3, n_kv_heads=3,
                      d_head=16, d_ff=96, vocab_size=256, seq_chunk=32)
