"""Config schema for the model zoo and run shapes.

One ``ModelConfig`` fully determines a model: the layer *period* (a short
pattern of (mixer, ffn) specs tiled n_layers/len(period) times) composes
dense/GQA attention, local attention, Mamba, mLSTM/sLSTM and dense/MoE
FFNs into any of the assigned architectures.  The stack is scanned over
periods, so HLO size is O(period), not O(n_layers).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["LayerSpec", "MoEConfig", "ModelConfig", "ShapeConfig", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One position in the layer period."""

    mixer: str          # "attn" | "attn_local" | "mamba" | "mlstm" | "slstm"
    ffn: str = "dense"  # "dense" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0          # always-on shared experts (qwen2-moe style)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    period: Tuple[LayerSpec, ...]
    # families / options
    norm: str = "rmsnorm"              # "rmsnorm" | "layernorm" | "nonparam_ln"
    ffn_act: str = "swiglu"            # "swiglu" | "geglu" | "gelu"
    qkv_bias: bool = False
    qk_norm: bool = False              # chameleon
    rope_theta: float = 10_000.0
    logit_softcap: Optional[float] = None   # gemma2
    attn_softcap: Optional[float] = None    # gemma2
    window: Optional[int] = None            # local-attention window
    post_norm: bool = False                 # gemma2 sandwich norms
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    embedding_input: bool = False           # vlm/audio stub: inputs are embeds
    # ssm (mamba)
    d_inner: Optional[int] = None
    d_state: int = 16
    dt_rank: Optional[int] = None
    conv_kernel: int = 4
    # xlstm
    mlstm_proj_factor: float = 2.0
    slstm_ffn_factor: float = 1.3334
    # numerics / scan
    dtype: str = "bfloat16"
    seq_chunk: int = 512               # flash/scan chunk for long sequences
    attn_causal_skip: bool = False     # predicated causal block skipping
    remat: str = "nothing"             # "nothing" | "dots" | "none"
    sub_quadratic: bool = False        # eligible for long_500k

    def __post_init__(self):
        if self.n_layers % len(self.period) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"period length {len(self.period)}")
        if self.n_heads % self.n_kv_heads != 0:
            raise ValueError(f"{self.name}: n_heads % n_kv_heads != 0")

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def d_q(self) -> int:
        return self.n_heads * self.d_head

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.d_head

    def scaled(self, **kw) -> "ModelConfig":
        """A reduced copy for smoke tests (see tests/test_models_smoke.py)."""
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
