"""olmo-1b  [dense]  — non-parametric LayerNorm, SwiGLU, tied embeddings.

16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304  [arXiv:2402.00838; hf]
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=8192, vocab_size=50304, period=(LayerSpec("attn", "dense"),),
    norm="nonparam_ln", ffn_act="swiglu", tie_embeddings=True,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_head=16, d_ff=128, vocab_size=256, seq_chunk=32)
