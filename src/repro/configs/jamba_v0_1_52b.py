"""jamba-v0.1-52b  [hybrid]  — Mamba+attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536  [arXiv:2403.19887; hf]
Period of 8 layers: attention at position 4, Mamba elsewhere; MoE replaces
the dense MLP on every other layer (e/a = 2).  Sub-quadratic -> runs the
long_500k cell.
"""

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

_PERIOD = tuple(
    LayerSpec(mixer=("attn" if i == 4 else "mamba"),
              ffn=("moe" if i % 2 == 1 else "dense"))
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=65536, period=_PERIOD,
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336),
    d_inner=8192, d_state=16, conv_kernel=4,
    rope_theta=10_000.0, sub_quadratic=True,
)

SMOKE = CONFIG.scaled(
    n_layers=16, d_model=64, n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
    vocab_size=256, d_inner=128, d_state=4,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=64), seq_chunk=32,
)
