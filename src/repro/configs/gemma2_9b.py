"""gemma2-9b  [dense]  — local/global alternating attention, logit softcap.

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000  [arXiv:2408.00118; hf]
Period of 2: 4096-window local layer then global layer; attention-score
softcap 50, final-logit softcap 30, sandwich (pre+post) RMSNorm, GeGLU.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_head=256,
    d_ff=14336, vocab_size=256000,
    period=(LayerSpec("attn_local", "dense"), LayerSpec("attn", "dense")),
    window=4096, attn_softcap=50.0, logit_softcap=30.0, post_norm=True,
    ffn_act="geglu", tie_embeddings=True,
)

SMOKE = CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=128, vocab_size=256, window=16,
                      seq_chunk=32)
