"""Fault tolerance: step watchdog (straggler mitigation), elastic re-mesh.

Production contract (DESIGN.md §4):
  * every state mutation in the trainer goes through the atomic async
    CheckpointManager, so any crash restarts from the last committed step
    with bitwise-identical data order (Philox-keyed pipeline);
  * ``StepWatchdog`` tracks a robust step-time median; steps slower than
    ``threshold x median`` fire the straggler callback (in multi-host
    deployments: trigger pre-emptive re-shard / hot-spare swap — here it
    is surfaced to the trainer log and tested with synthetic delays);
  * ``plan_elastic_mesh`` rebuilds the largest power-of-two (data, model)
    mesh from the surviving device pool; restore then re-shards the
    checkpoint onto it (CheckpointManager stores leaves unsharded, so
    this is just device_put with the new shardings).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import largest_pow2 as _largest_pow2_leq
from repro.observability import metrics as _metrics

__all__ = ["StepWatchdog", "plan_elastic_mesh", "ElasticPlan"]


def _median(xs: Sequence[float]) -> float:
    """True median: even-length windows average the two middle samples
    (the upper-middle pick alone biases the baseline high on bimodal
    step-time histories, under-firing the straggler rule)."""
    s = sorted(xs)
    h = len(s) // 2
    return s[h] if len(s) % 2 else 0.5 * (s[h - 1] + s[h])


class StepWatchdog:
    """Detects straggler steps from wall-clock timings."""

    def __init__(self, *, threshold: float = 2.5, window: int = 32,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.threshold = threshold
        self.window = window
        self.on_straggler = on_straggler
        self._times: List[float] = []
        self._t0: Optional[float] = None
        self.straggler_steps: List[int] = []

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self, step: int) -> float:
        assert self._t0 is not None, "stop() without start()"
        dt = time.monotonic() - self._t0
        self._t0 = None
        if len(self._times) >= 5:
            med = _median(self._times)
            if dt > self.threshold * med:
                self.straggler_steps.append(step)
                _metrics.counter("fault.straggler_steps").inc()
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.pop(0)
        return dt

    @property
    def median(self) -> float:
        if not self._times:
            return 0.0
        return _median(self._times)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh: Mesh
    data_size: int
    model_size: int
    dropped_devices: int


def plan_elastic_mesh(devices: Sequence, *, failed: Sequence[int] = (),
                      prefer_model: int = 16) -> ElasticPlan:
    """Rebuild the largest power-of-two (data, model) mesh from surviving
    devices.  ``failed`` lists device ids to exclude (the simulation of a
    host loss).  Keeps the model axis at ``prefer_model`` when possible
    (TP degree is fixed by the model's memory footprint), shrinking the
    data axis — the standard elastic-DP policy."""
    alive = [d for d in devices if d.id not in set(failed)]
    if not alive:
        raise RuntimeError("no devices left")
    usable = _largest_pow2_leq(len(alive))
    model = min(prefer_model, usable)
    data = usable // model
    mesh_devices = __import__("numpy").array(alive[:usable]).reshape(data, model)
    mesh = Mesh(mesh_devices, ("data", "model"))
    return ElasticPlan(mesh=mesh, data_size=data, model_size=model,
                       dropped_devices=len(devices) - usable)
