"""Best-effort logical-axis sharding rules for params, state, batches, caches.

Policy (MaxText-style "fsdp + tensor"):
  * ``model`` axis (16-way TP): output/head/expert/vocab dimension of each
    weight — the dimension whose partial products stay local until the
    next reduce;
  * ``data`` axes (16-way FSDP; ``("pod","data")`` = 32-way on the
    multi-pod mesh): the contraction (embed/ff) dimension — ZeRO-3-style
    parameter + optimizer-state sharding, gathered just-in-time by XLA;
  * batch over the data axes; for batch-1 long-context cells the sequence
    dimension takes the data axes instead (sequence parallelism).

Every rule degrades to replication (None) when a dimension is not
divisible by the axis size — heads of 9 or 60 experts never fail to
compile, they just shard on a different dimension (see DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["MeshRules", "param_specs", "param_shardings", "state_specs",
           "batch_specs", "cache_specs", "tree_shardings",
           "activation_policy", "constrain_hidden", "constrain_logits",
           "largest_pow2", "row_domain_mesh", "row_domain_specs",
           "QR_DOMAIN_AXIS"]

# weight names whose FIRST dim is the TP (model) dim: projections back to
# d_model — their contraction dim (ff/heads) is tensor-parallel.
_DOWN_TYPE = ("down", "wo", "out_proj", "out", "down_w")
_EXCLUDE_MODEL = ("router", "shared_gate", "qnorm", "knorm")

# ------------------------------------------------------- QR domain meshes
#
# The sharded tiled-QR backend (repro.core.distgraph) runs one row-block
# domain of the tile grid per device over a 1-D mesh.  These helpers are
# the mesh/spec plumbing it shares with tests and benchmarks; they use a
# dedicated axis name so a QR domain mesh never collides with the
# training meshes' "data"/"model" axes.

QR_DOMAIN_AXIS = "qr_domain"


def largest_pow2(n: int) -> int:
    """Largest power of two <= n (n >= 1) — butterfly trees need 2^k
    participants, so domain counts round down."""
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    return 1 << (int(n).bit_length() - 1)


def row_domain_mesh(ndomains: int, *, devices=None,
                    axis: str = QR_DOMAIN_AXIS) -> Mesh:
    """1-D mesh of ``ndomains`` devices for row-block domain execution.

    Uses the first ``ndomains`` of ``devices`` (default
    ``jax.devices()``), so a QR mesh can coexist with a larger training
    mesh; callers cap ``ndomains`` at the available device count.
    """
    devices = jax.devices() if devices is None else list(devices)
    if ndomains < 1 or ndomains > len(devices):
        raise ValueError(
            f"need 1 <= ndomains <= {len(devices)} devices, got {ndomains}")
    return Mesh(np.asarray(devices[:ndomains]), (axis,))


def row_domain_specs(*, axis: str = QR_DOMAIN_AXIS
                     ) -> Tuple[P, P, Tuple[P, P]]:
    """(in_spec, r_out_spec, (q_out_spec, r_out_spec)) for shard_map'ing a
    row-sharded QR: the matrix rows over the domain axis, the merged R
    replicated, the thin Q row-sharded like the input."""
    rows = P(axis, None)
    replicated = P()
    return rows, replicated, (rows, replicated)


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    # tp_enabled=False: small-model policy — no tensor parallelism, the
    # model axis joins the batch axes (pure DP/FSDP; kills the TP
    # all-reduces that dominate sub-4B models on a 16-way model axis).
    tp_enabled: bool = True
    batch_axes: Optional[Tuple[str, ...]] = None

    @property
    def data_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes]))

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape[self.model_axis])

    def data_spec(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    @property
    def batch_axes_eff(self) -> Tuple[str, ...]:
        return self.batch_axes if self.batch_axes is not None else self.data_axes

    @property
    def batch_size_eff(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes_eff]))

    def batch_spec(self):
        ax = self.batch_axes_eff
        return ax if len(ax) > 1 else ax[0]


def _path_names(path) -> Tuple[str, ...]:
    return tuple(str(getattr(k, "key", getattr(k, "name", k))) for k in path)


def _div(n: int, k: int) -> bool:
    return n % k == 0


def _weight_spec(names, shape, rules: MeshRules) -> P:
    """Spec for an unstacked weight leaf."""
    ds, ms = rules.data_size, rules.model_size
    dspec, m = rules.data_spec(), rules.model_axis
    nd = len(shape)

    if nd == 1:
        # gains/biases: shard big vectors over data, replicate small ones
        return P(dspec) if shape[0] >= 4096 and _div(shape[0], ds) else P(None)

    no_model = any(n in _EXCLUDE_MODEL for n in names) or not rules.tp_enabled
    down_type = any(n in _DOWN_TYPE for n in names)

    if nd == 2:
        if "table" in names:  # embedding (V, d): vocab-parallel + fsdp
            return P(m if _div(shape[0], ms) else None,
                     dspec if _div(shape[1], ds) else None)
        if "lm_head" in names:  # (d, V): vocab-parallel output
            return P(dspec if _div(shape[0], ds) else None,
                     m if _div(shape[1], ms) else None)
        if down_type:  # (ff/heads, d): TP on contraction, fsdp on output
            return P(m if _div(shape[0], ms) and not no_model else None,
                     dspec if _div(shape[1], ds) else None)
        # up-type (d, ff/heads): fsdp on contraction, TP on output
        return P(dspec if _div(shape[0], ds) else None,
                 m if _div(shape[1], ms) and not no_model else None)

    if nd == 3:
        # expert stacks (E, d, f) / (E, f, d); xLSTM blocks (H, dh, dh/4dh)
        e = shape[0]
        no_model3 = no_model
        if _div(e, ms) and not no_model3:
            return P(m, dspec if _div(shape[1], ds) else None, None)
        # experts/heads not divisible: shard the inner matmul dims instead
        if down_type:
            return P(None, m if _div(shape[1], ms) and not no_model3 else None,
                     dspec if _div(shape[2], ds) else None)
        return P(None, dspec if _div(shape[1], ds) else None,
                 m if _div(shape[2], ms) and not no_model3 else None)

    return P(*([None] * nd))


def param_specs(params: Any, rules: MeshRules) -> Any:
    """PartitionSpec tree for a param tree (arrays or ShapeDtypeStructs).

    Leaves under ``layers`` carry a leading n_periods stack axis which is
    never sharded (it is the scan axis)."""

    def spec(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        if "layers" in names and len(shape) >= 1:
            inner = _weight_spec(names, shape[1:], rules)
            return P(None, *inner)
        return _weight_spec(names, shape, rules)

    return jax.tree_util.tree_map_with_path(spec, params)


def tree_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def param_shardings(params: Any, rules: MeshRules) -> Any:
    return tree_shardings(param_specs(params, rules), rules.mesh)


def state_specs(params: Any, param_spec_tree: Any, state: Any,
                rules: MeshRules) -> Any:
    """Optimizer-state specs: mirror the param spec when ranks match
    (mu/nu/v buffers), replicate rank-mismatched leaves (scalars, step)."""
    flat_params = {}

    def record(path, leaf):
        flat_params[_path_names(path)] = leaf
        return leaf

    jax.tree_util.tree_map_with_path(record, params)
    spec_by_path = {}

    def record_spec(path, s):
        spec_by_path[_path_names(path)] = s
        return s

    jax.tree_util.tree_map_with_path(record_spec, param_spec_tree,
                                     is_leaf=lambda x: isinstance(x, P))

    def spec(path, leaf):
        names = _path_names(path)
        # state trees are nested one level deeper (state.mu.<param path>);
        # find the longest param-path suffix match
        for start in range(len(names)):
            key = names[start:]
            if key in flat_params:
                if flat_params[key].ndim == leaf.ndim:
                    return spec_by_path[key]
                return P()
        return P()

    return jax.tree_util.tree_map_with_path(spec, state)


def batch_specs(batch: Any, rules: MeshRules) -> Any:
    """Batch over the batch axes; sequence-parallel fallback for batch==1."""
    dspec, ds = rules.batch_spec(), rules.batch_size_eff

    def spec(leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        if _div(shape[0], ds):
            return P(dspec, *([None] * (len(shape) - 1)))
        if len(shape) >= 2 and _div(shape[1], ds):
            return P(None, dspec, *([None] * (len(shape) - 2)))
        return P(*([None] * len(shape)))

    return jax.tree.map(spec, batch)


# ----------------------------------------------------------------- activations
#
# XLA's sharding propagation picks pathological layouts for scan-carried
# hidden states when left alone (observed: full rematerialization +
# replication on the embedding gather).  The model code calls
# ``constrain_hidden`` / ``constrain_logits`` at layer and loss boundaries;
# they are no-ops unless a policy is installed (so tests and single-device
# runs never touch mesh state).

import contextlib
import threading

_ACT_POLICY = threading.local()


@contextlib.contextmanager
def activation_policy(rules: "MeshRules", *, seq_axis: Optional[str] = None):
    """Install the activation-sharding policy for model code run inside.

    ``seq_axis``: optionally shard the sequence dimension of hidden states
    (sequence parallelism — used by long-context cells / perf variants).
    """
    _ACT_POLICY.rules = rules
    _ACT_POLICY.seq_axis = seq_axis
    try:
        yield
    finally:
        _ACT_POLICY.rules = None
        _ACT_POLICY.seq_axis = None


def _policy() -> Tuple[Optional["MeshRules"], Optional[str]]:
    return (getattr(_ACT_POLICY, "rules", None),
            getattr(_ACT_POLICY, "seq_axis", None))


def constrain_hidden(x):
    """(B, S, d) hidden states: batch over data axes (sequence fallback
    for batch-1), optional sequence parallelism over ``seq_axis``."""
    rules, seq_axis = _policy()
    if rules is None or x.ndim != 3:
        return x
    ds = rules.batch_size_eff
    b, s, _ = x.shape
    if b % ds == 0:
        batch_s = rules.batch_spec()
        seq_s = seq_axis if (seq_axis and s % rules.mesh.shape[seq_axis] == 0) \
            else None
    elif s % ds == 0:
        batch_s, seq_s = None, rules.batch_spec()  # sequence-sharded
    else:
        batch_s, seq_s = None, None
    return jax.lax.with_sharding_constraint(x, P(batch_s, seq_s, None))


def constrain_logits(x):
    """(B, T, V) logit chunks: batch over data, vocab over model (the
    softmax reduction then runs as a model-axis psum)."""
    rules, _ = _policy()
    if rules is None or x.ndim != 3:
        return x
    b, _, v = x.shape
    batch_s = rules.batch_spec() if b % rules.batch_size_eff == 0 else None
    vocab_s = (rules.model_axis if rules.tp_enabled
               and v % rules.model_size == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(batch_s, None, vocab_s))


def constrain_expert_stack(x):
    """MoE dispatch/compute buffers (E, C, d|f): experts over model.
    Without this, SPMD replicates the (E, C, d_expert) activations of
    every expert on every chip (observed: 120+ GiB temp on the 16-expert
    train cells)."""
    rules, _ = _policy()
    if rules is None or x.ndim != 3:
        return x
    e = x.shape[0]
    m = (rules.model_axis if rules.tp_enabled and e % rules.model_size == 0
         else None)
    # (a capacity-dim data-sharded fallback for E=60 was tried and
    # REVERTED: the cross-shard scatter it induces replicates worse —
    # qwen2-moe prefill temp 9 -> 82 GiB; §Perf log)
    return jax.lax.with_sharding_constraint(x, P(m, None, None))


def constrain_token_stack(x):
    """Flat token tensors ((T,), (T, d), (T, k, d)): tokens over the batch
    axes when divisible."""
    rules, _ = _policy()
    if rules is None or x.ndim < 1:
        return x
    t_s = rules.batch_spec() if x.shape[0] % rules.batch_size_eff == 0 else None
    return jax.lax.with_sharding_constraint(
        x, P(t_s, *([None] * (x.ndim - 1))))


def constrain_decode_scores(s):
    """Decode attention scores (B, n_kv, g, q, S): batch over data, heads
    over model (sequence over model as the GQA-small fallback) — stops
    SPMD replicating the (B, H, S) f32 score tensor per chip."""
    rules, _ = _policy()
    if rules is None or s.ndim != 5:
        return s
    b, h = s.shape[0], s.shape[1]
    batch_s = rules.batch_spec() if b % rules.batch_size_eff == 0 else None
    head_s = seq_s = None
    if rules.tp_enabled:
        if h % rules.model_size == 0:
            head_s = rules.model_axis
        elif s.shape[-1] % rules.model_size == 0:
            seq_s = rules.model_axis
    return jax.lax.with_sharding_constraint(
        s, P(batch_s, head_s, None, None, seq_s))


def cache_specs(caches: Any, rules: MeshRules) -> Any:
    """Decode-cache specs.  Leading axis is the period stack (never
    sharded); then prefer batch -> data, heads -> model, else
    sequence -> model / data (length-sharded KV for batch-1 decode)."""
    dspec, ds, ms = rules.data_spec(), rules.data_size, rules.model_size
    m = rules.model_axis

    def spec(leaf):
        shape = leaf.shape
        out: list = [None] * len(shape)
        if len(shape) < 2:
            return P(*out)
        dims = list(range(1, len(shape)))  # skip period-stack axis
        # batch axis (index 1): data
        used_data = False
        if _div(shape[1], ds):
            out[1] = dspec
            used_data = True
        # model axis: first remaining divisible dim, preferring heads
        # (axis -2 for attention kv (np,B,S,H,D)), else any
        cand = [i for i in dims[1:] if _div(shape[i], ms)]
        pref = [i for i in cand if shape[i] <= 128] + \
               [i for i in cand if shape[i] > 128]
        if pref:
            out[pref[0]] = m
        if not used_data:
            # batch not shardable (e.g. B=1): put data on the longest
            # remaining divisible dim (sequence)
            rem = [i for i in dims[1:] if out[i] is None and _div(shape[i], ds)]
            if rem:
                j = max(rem, key=lambda i: shape[i])
                out[j] = dspec
        return P(*out)

    return jax.tree.map(spec, caches)
