"""Gradient compression: int8 block-quantization with error feedback.

Distributed-optimization substrate for the data-parallel all-reduce: each
shard quantizes its local gradient contribution to int8 (per-block scale),
the *quantized* tensors are summed over the data axis, and the
quantization residual is carried in an error-feedback buffer so the bias
vanishes over steps (EF-SGD / 1-bit-Adam lineage).

Two layers:
  * pure codecs (``quantize``/``dequantize``) + error feedback, usable on
    any tree — unit-tested against reconstruction bounds;
  * :func:`compressed_psum` — the shard_map collective: psum of int8-coded
    gradients (wire bytes = 1/4 of fp32) with fp32 carry of scales.

Trainer integration is opt-in (``--grad-compression``): the wire format
shrinks the collective roofline term by ~4x at the cost of one extra
pass over the gradients (see EXPERIMENTS.md perf log).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size

Array = jax.Array

__all__ = ["quantize", "dequantize", "ef_compress_tree", "compressed_psum",
           "init_error_state"]

_BLOCK = 256


def _pad_len(n: int) -> int:
    return (-n) % _BLOCK


def quantize(x: Array) -> Tuple[Array, Array]:
    """Block-wise symmetric int8 quantization. Returns (codes, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = _pad_len(flat.size)
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    codes = jnp.clip(jnp.round(blocks / safe), -127, 127).astype(jnp.int8)
    return codes, scale[:, 0]


def dequantize(codes: Array, scales: Array, shape: Tuple[int, ...]) -> Array:
    flat = (codes.astype(jnp.float32) * scales[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def init_error_state(tree: Any) -> Any:
    return jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tree)


def ef_compress_tree(grads: Any, error: Any) -> Tuple[Any, Any]:
    """Error-feedback compression of a gradient tree.

    Returns (decoded_grads, new_error): decoded = Q(g + e);
    new_error = (g + e) - decoded.  The decoded tree is exactly what a
    receiver reconstructs, so using it locally == synchronized state."""

    def one(g, e):
        target = g.astype(jnp.float32) + e
        codes, scales = quantize(target)
        dec = dequantize(codes, scales, target.shape)
        return dec, target - dec

    out = jax.tree.map(one, grads, error)
    is_tup = lambda x: isinstance(x, tuple) and len(x) == 2 and not isinstance(x, dict)
    dec = jax.tree.map(lambda o: o[0], out, is_leaf=is_tup)
    err = jax.tree.map(lambda o: o[1], out, is_leaf=is_tup)
    return dec, err


def compressed_psum(tree: Any, axis_name: str, error: Any) -> Tuple[Any, Any]:
    """shard_map collective: error-feedback int8 all-reduce.

    Each shard quantizes (g + e) to int8, the int8 codes are psum'd (wire
    = 1 byte/element vs 4), scales are psum'd in fp32 (1/256 of the
    elements), and every shard decodes sum(codes_i * scale_i) / N — an
    unbiased-in-the-limit mean with local error feedback."""
    n = axis_size(axis_name)

    def one(g, e):
        target = g.astype(jnp.float32) + e
        codes, scales = quantize(target)
        dec_local = dequantize(codes, scales, target.shape)
        new_e = target - dec_local
        # sum of per-shard dequantized contributions == dequantize of the
        # weighted code sum; psum int32 codes and fp32 code*scale products
        contrib = lax.psum(dec_local, axis_name) / n
        return contrib, new_e

    out = jax.tree.map(one, tree, error)
    is_tup = lambda x: isinstance(x, tuple) and len(x) == 2 and not isinstance(x, dict)
    red = jax.tree.map(lambda o: o[0], out, is_leaf=is_tup)
    err = jax.tree.map(lambda o: o[1], out, is_leaf=is_tup)
    return red, err
