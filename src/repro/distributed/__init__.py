"""Distribution substrate: sharding rules, gradient compression,
fault tolerance."""

from repro.distributed.compression import (
    compressed_psum, dequantize, ef_compress_tree, init_error_state, quantize,
)
from repro.distributed.fault_tolerance import (
    ElasticPlan, StepWatchdog, plan_elastic_mesh,
)
from repro.distributed.sharding import (
    MeshRules, batch_specs, cache_specs, param_shardings, param_specs,
    state_specs, tree_shardings,
)

__all__ = [
    "MeshRules", "param_specs", "param_shardings", "state_specs",
    "batch_specs", "cache_specs", "tree_shardings",
    "quantize", "dequantize", "ef_compress_tree", "compressed_psum",
    "init_error_state", "StepWatchdog", "plan_elastic_mesh", "ElasticPlan",
]
