"""Pallas TPU kernel: fused MHT panel factorization (``DGEQR2HT`` panel).

This is the TPU realization of the paper's algorithm-architecture
co-design (§5.1).  The REDEFINE PE streams the panel from Global Memory
into its Local Memory once, then the reconfigured DOT4 data-path executes
the fused macro-operation

    a_ij <- a_ij - tau * v_i * (v . a_:j)

for every column without further GM traffic.  Here the *whole panel* is a
single VMEM block (BlockSpec = full (m, b) tile); the column loop runs
inside the kernel, so per column the dot-reduce (VPU cross-lane) and the
rank-1 fused-multiply-subtract happen register/VMEM-resident — one HBM
read and one HBM write for the entire panel factorization, versus
2·b HBM passes for a column-by-column classical HT.

The column loop itself is :func:`repro.kernels.macro_ops.panel_body` —
the ONE Householder inner loop this package owns, shared with the
tile-DAG GEQRT/TSQRT macro ops and the wavefront engine.  This module
only binds it to a single-grid-cell ``pallas_call``.

VMEM budget: (m, b) fp32 once ≈ m·b·4 bytes; the ops wrapper enforces
m·b·4 ≤ 8 MiB (half of v5e VMEM, leaving room for double buffering).
Taller panels are handled above this kernel by TSQR leaves.

Layout notes for the MXU/VPU era (vs. the paper's 4-wide RDP):
  * all tensors kept 2-D; reductions are cross-lane VPU ops;
  * row/column masks from ``broadcasted_iota`` (TPU requires 2-D iota);
  * accumulation in ``promote_types(dtype, float32)`` irrespective of
    the I/O dtype.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
from jax.experimental import pallas as pl

from repro.kernels import macro_ops

Array = jax.Array

__all__ = ["mht_panel_kernel", "mht_panel_pallas"]


def mht_panel_kernel(panel_ref, out_ref, taus_ref, *, row0: int):
    """Kernel body: factor the VMEM-resident panel in place.

    panel_ref: (m, b) input block
    out_ref:   (m, b) packed factor (R upper / V below pivots)
    taus_ref:  (1, b) tau row
    """
    packed, taus = macro_ops.panel_body(panel_ref[...], row0)
    out_ref[...] = packed
    taus_ref[...] = taus[None]


def mht_panel_pallas(
    panel: Array, *, row0: int = 0, interpret: bool = False
) -> Tuple[Array, Array]:
    """Invoke the panel kernel on a full (m, b) panel (single grid cell —
    the panel IS the block, as in the paper's LM-resident dataflow)."""
    m, b = panel.shape
    kernel = functools.partial(mht_panel_kernel, row0=row0)
    out, taus = pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((m, b), panel.dtype),
            jax.ShapeDtypeStruct((1, b), panel.dtype),
        ],
        in_specs=[pl.BlockSpec((m, b), lambda: (0, 0))],
        out_specs=[
            pl.BlockSpec((m, b), lambda: (0, 0)),
            pl.BlockSpec((1, b), lambda: (0, 0)),
        ],
        interpret=interpret,
    )(panel)
    return out, taus[0]
