"""Pallas TPU kernel: fused MHT panel factorization (``DGEQR2HT`` panel).

This is the TPU realization of the paper's algorithm-architecture
co-design (§5.1).  The REDEFINE PE streams the panel from Global Memory
into its Local Memory once, then the reconfigured DOT4 data-path executes
the fused macro-operation

    a_ij <- a_ij - tau * v_i * (v . a_:j)

for every column without further GM traffic.  Here the *whole panel* is a
single VMEM block (BlockSpec = full (m, b) tile); the column loop runs
inside the kernel, so per column the dot-reduce (VPU cross-lane) and the
rank-1 fused-multiply-subtract happen register/VMEM-resident — one HBM
read and one HBM write for the entire panel factorization, versus
2·b HBM passes for a column-by-column classical HT.

VMEM budget: (m, b) fp32 once ≈ m·b·4 bytes; the ops wrapper enforces
m·b·4 ≤ 8 MiB (half of v5e VMEM, leaving room for double buffering).
Taller panels are handled above this kernel by TSQR leaves.

Layout notes for the MXU/VPU era (vs. the paper's 4-wide RDP):
  * all tensors kept 2-D; reductions are cross-lane VPU ops;
  * row/column masks from ``broadcasted_iota`` (TPU requires 2-D iota);
  * fp32 accumulation irrespective of the I/O dtype.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

Array = jax.Array

__all__ = ["mht_panel_kernel", "mht_panel_pallas"]


def mht_panel_kernel(panel_ref, out_ref, taus_ref, *, row0: int):
    """Kernel body: factor the VMEM-resident panel in place.

    panel_ref: (m, b) input block
    out_ref:   (m, b) packed factor (R upper / V below pivots)
    taus_ref:  (1, b) tau row
    """
    m, b = panel_ref.shape
    a0 = panel_ref[...].astype(jnp.float32)
    rows = lax.broadcasted_iota(jnp.int32, (m, 1), 0)
    cols = lax.broadcasted_iota(jnp.int32, (1, b), 1)
    taus0 = jnp.zeros((1, b), jnp.float32)

    def body(lj, carry):
        a, taus = carry
        pivot = row0 + lj
        colmask = cols == lj                                   # (1, b)
        at = rows == pivot                                     # (m, 1)
        below = rows > pivot

        x = jnp.sum(jnp.where(colmask, a, 0.0), axis=1, keepdims=True)  # (m,1)
        x0 = jnp.sum(jnp.where(at, x, 0.0), axis=0, keepdims=True)      # (1,1)
        tail2 = jnp.sum(jnp.where(below, x * x, 0.0), axis=0, keepdims=True)
        norm = jnp.sqrt(x0 * x0 + tail2)
        beta = jnp.where(x0 >= 0.0, -norm, norm)               # (1,1)
        degen = tail2 == 0.0
        denom = jnp.where(degen, 1.0, x0 - beta)
        v = jnp.where(below, x / denom, 0.0) + jnp.where(at, 1.0, 0.0)  # (m,1)
        tau = jnp.where(
            degen, 0.0, (beta - x0) / jnp.where(beta == 0.0, 1.0, beta)
        )                                                       # (1,1)
        beta_val = jnp.where(degen, x0, beta)

        # --- the fused macro-op: one pass over the panel ---------------
        w = tau * jnp.sum(v * a, axis=0, keepdims=True)         # (1, b)
        trailing = cols > lj
        a = a - jnp.where(trailing, v * w, 0.0)

        # pack column lj: R diag at pivot, reflector below, R above kept
        a = jnp.where(colmask & at, beta_val, a)
        a = jnp.where(colmask & below, v, a)
        taus = jnp.where(colmask, tau, taus)
        return a, taus

    a_out, taus = lax.fori_loop(0, b, body, (a0, taus0))
    out_ref[...] = a_out.astype(out_ref.dtype)
    taus_ref[...] = taus.astype(taus_ref.dtype)


def mht_panel_pallas(
    panel: Array, *, row0: int = 0, interpret: bool = False
) -> Tuple[Array, Array]:
    """Invoke the panel kernel on a full (m, b) panel (single grid cell —
    the panel IS the block, as in the paper's LM-resident dataflow)."""
    m, b = panel.shape
    kernel = functools.partial(mht_panel_kernel, row0=row0)
    out, taus = pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((m, b), panel.dtype),
            jax.ShapeDtypeStruct((1, b), panel.dtype),
        ],
        in_specs=[pl.BlockSpec((m, b), lambda: (0, 0))],
        out_specs=[
            pl.BlockSpec((m, b), lambda: (0, 0)),
            pl.BlockSpec((1, b), lambda: (0, 0)),
        ],
        interpret=interpret,
    )(panel)
    return out, taus[0]
