"""The unified macro-op library: one Householder/WY core, four DAG kinds.

The paper's co-design realizes every QR DAG node as a *fused macro
operation* on the Reconfigurable Data-path instead of a sequence of BLAS
calls (§4-§5).  Before this module the software mirrored the opposite:
four kernel modules (``ops``, ``mht_panel``, ``wy_trailing``,
``tile_ops``) each re-implemented the Householder reflector / WY apply
inner loops.  ``macro_ops`` is the single RDP-analogue:

  * **value-level bodies** — :func:`panel_body`, :func:`tsqrt_factor`,
    :func:`wy_body`, and the four tile-DAG macro ops
    :func:`geqrt_body` / :func:`larfb_body` / :func:`tsqrt_body` /
    :func:`ssrfb_body`.  Each is a pure jnp function on tile *values*:
    the same callable is the Pallas kernel body (traced inside
    ``pallas_call``) **and** the ``use_kernel=False`` oracle (vmapped by
    the engine's jnp lowering).  Because both paths trace the identical
    op sequence, the engine path is *bitwise* equal to the oracle —
    asserted in tests/test_engine.py and tests/test_conformance.py.
  * **wavefront kernels** — ``*_wavefront_kernel``: the uniform-signature
    Pallas bodies the engine (:mod:`repro.core.engine`) dispatches, one
    ``pallas_call`` per (wavefront, kind) task batch.  Tiles move
    HBM -> VMEM scratch -> HBM by explicit DMA against a ``(p, q, nb,
    nb)`` workspace held in ``pltpu.ANY`` memory space and aliased
    in-place; task coordinates arrive as scalar-prefetch index arrays.
  * **VMEM estimators** — :func:`vmem_bytes` per op and
    :func:`engine_vmem_bytes` for the engine's worst case, registered as
    the ``"macro_ops"`` :class:`repro.core.plan.KernelPolicy` so the
    planner's fits-in-VMEM decisions and the engine's runtime guard read
    the same number.

jnp oracles for the bodies live in :mod:`repro.kernels.ref`
(independent realizations via ``panel_factor`` — the numerical anchors);
the legacy single-tile wrappers in ``ops`` / ``tile_ops`` and the panel /
trailing kernels in ``mht_panel`` / ``wy_trailing`` are now thin shells
over these bodies.

All bodies accumulate in ``promote_types(dtype, float32)`` — fp32 for
fp32/bf16 I/O (the VPU/MXU reality), fp64 when x64 is enabled (the
conformance suite's float64 bar).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.blocked import larft, unpack_v_panel
from repro.core.plan import (DEFAULT_TABLE_BUDGET, DEFAULT_VMEM_BUDGET,
                             KernelPolicy, register_kernel_policy)

Array = jax.Array

__all__ = [
    "MacroOp",
    "MACRO_OPS",
    "default_interpret",
    "acc_dtype",
    "reflector_coeffs",
    "panel_body",
    "wy_body",
    "stacked_larft",
    "geqrt_body",
    "larfb_body",
    "tsqrt_factor",
    "tsqrt_body",
    "ssrfb_body",
    "geqrt_wavefront_kernel",
    "larfb_wavefront_kernel",
    "tsqrt_wavefront_kernel",
    "ssrfb_wavefront_kernel",
    "vmem_bytes",
    "engine_vmem_bytes",
    "megakernel_vmem_bytes",
    "batched_megakernel_vmem_bytes",
    "MEGAKERNEL_VMEM_TILES",
]


def default_interpret() -> bool:
    """Kernel dispatch default: compiled on TPU, interpret elsewhere."""
    return jax.default_backend() != "tpu"


def acc_dtype(dtype) -> jnp.dtype:
    """Accumulation dtype: never below fp32, fp64 when the I/O is fp64."""
    return jnp.promote_types(dtype, jnp.float32)


# ---------------------------------------------------------------------------
# the shared Householder reflector core
# ---------------------------------------------------------------------------

def reflector_coeffs(x0, tail2):
    """LAPACK-convention reflector coefficients from the pivot value and
    the squared tail norm: ``(beta, tau, denom)`` with ``v = x / denom``
    below the pivot and ``tau = 0`` for already-eliminated columns.

    This is THE inner loop the paper fuses onto the RDP; every macro op
    below calls it (shapes broadcast, so it serves the (1, 1)-masked
    panel loop and the scalar TSQRT pivot alike).
    """
    norm = jnp.sqrt(x0 * x0 + tail2)
    beta = jnp.where(x0 >= 0.0, -norm, norm)
    degen = tail2 == 0.0
    denom = jnp.where(degen, 1.0, x0 - beta)
    tau = jnp.where(degen, 0.0, (beta - x0) / jnp.where(beta == 0.0, 1.0, beta))
    beta_val = jnp.where(degen, x0, beta)
    return beta_val, tau, denom


def panel_body(panel: Array, row0: int) -> Tuple[Array, Array]:
    """Fused MHT panel factorization of an (m, b) block, pivot rows
    starting at ``row0`` — the ``DGEQR2HT`` macro op (paper §5.1).

    One pass per column: dot-reduce + rank-1 fused-multiply-subtract,
    panel resident the whole time.  Returns ``(packed, taus)`` in the
    LAPACK layout of :func:`repro.core.blocked.panel_factor` (its oracle,
    :func:`repro.kernels.ref.mht_panel_ref`).
    """
    m, b = panel.shape
    acc = acc_dtype(panel.dtype)
    a0 = panel.astype(acc)
    rows = lax.broadcasted_iota(jnp.int32, (m, 1), 0)
    cols = lax.broadcasted_iota(jnp.int32, (1, b), 1)
    taus0 = jnp.zeros((1, b), acc)

    def body(lj, carry):
        a, taus = carry
        pivot = row0 + lj
        colmask = cols == lj                                   # (1, b)
        at = rows == pivot                                     # (m, 1)
        below = rows > pivot

        x = jnp.sum(jnp.where(colmask, a, 0.0), axis=1, keepdims=True)  # (m,1)
        x0 = jnp.sum(jnp.where(at, x, 0.0), axis=0, keepdims=True)      # (1,1)
        tail2 = jnp.sum(jnp.where(below, x * x, 0.0), axis=0, keepdims=True)
        beta_val, tau, denom = reflector_coeffs(x0, tail2)
        v = jnp.where(below, x / denom, 0.0) + jnp.where(at, 1.0, 0.0)  # (m,1)

        # --- the fused macro-op: one pass over the panel ---------------
        w = tau * jnp.sum(v * a, axis=0, keepdims=True)         # (1, b)
        trailing = cols > lj
        a = a - jnp.where(trailing, v * w, 0.0)

        # pack column lj: R diag at pivot, reflector below, R above kept
        a = jnp.where(colmask & at, beta_val, a)
        a = jnp.where(colmask & below, v, a)
        taus = jnp.where(colmask, tau, taus)
        return a, taus

    a_out, taus = lax.fori_loop(0, b, body, (a0, taus0))
    return a_out.astype(panel.dtype), taus[0].astype(panel.dtype)


def wy_body(v: Array, t: Array, c: Array) -> Array:
    """Fused WY trailing update ``C - V (T^T (V^T C))`` — three chained
    MXU products with the intermediates never leaving fast memory."""
    acc = acc_dtype(c.dtype)
    v_a = v.astype(acc)
    c_a = c.astype(acc)
    w = jnp.dot(v_a.T, c_a, preferred_element_type=acc)
    w = jnp.dot(t.astype(acc).T, w, preferred_element_type=acc)
    return (c_a - jnp.dot(v_a, w, preferred_element_type=acc)).astype(c.dtype)


def stacked_larft(v2: Array, taus: Array) -> Array:
    """Block reflector T for the stacked TSQRT reflectors V = [I; V2]."""
    nb = v2.shape[1]
    return larft(jnp.concatenate([jnp.eye(nb, dtype=v2.dtype), v2], axis=0),
                 taus)


# ---------------------------------------------------------------------------
# the four tile-DAG macro ops (value level — kernel body AND jnp oracle)
# ---------------------------------------------------------------------------

def geqrt_body(tile: Array) -> Tuple[Array, Array, Array]:
    """GEQRT: QR of one diagonal tile, T formed in the same pass.

    Returns ``(packed, T, taus)`` — V1 strictly below / R on and above
    the diagonal, plus the WY block reflector for the step's LARFBs.
    """
    packed, taus = panel_body(tile, 0)
    v1 = unpack_v_panel(packed, 0)
    return packed, larft(v1, taus), taus


def larfb_body(diag_packed: Array, t: Array, c: Array) -> Array:
    """LARFB: apply Q_k^T to one trailing tile from the packed diagonal
    tile (V1 unpacked in place — the tile ref is the input)."""
    return wy_body(unpack_v_panel(diag_packed, 0), t, c)


def tsqrt_factor(diag: Array, sub: Array) -> Tuple[Array, Array, Array]:
    """TSQRT inner loop: QR of the stacked pair [R; A] exploiting the
    ``[e_j; v2_j]`` reflector structure (R upper triangular on top).

    ``diag`` may carry V1 strictly below its diagonal (the packed layout)
    — only the upper triangle is factored and the sub-diagonal part is
    passed through untouched in the merged output.  Returns
    ``(merged, V2, taus)``.
    """
    nb = diag.shape[0]
    acc = acc_dtype(diag.dtype)
    rows = lax.broadcasted_iota(jnp.int32, (nb, 1), 0)
    cols = lax.broadcasted_iota(jnp.int32, (1, nb), 1)
    upper = rows <= cols
    r0 = jnp.where(upper, diag, 0.0).astype(acc)
    a0 = sub.astype(acc)

    def body(j, carry):
        r, a, vacc, taus = carry
        colmask = cols == j                                     # (1, nb)
        pivmask = (rows == j) & colmask                         # (nb, nb)
        x0 = jnp.sum(jnp.where(pivmask, r, 0.0))                # pivot R[j,j]
        x2 = jnp.sum(jnp.where(colmask, a, 0.0), axis=1,
                     keepdims=True)                             # (nb, 1)
        tail2 = jnp.sum(x2 * x2)
        beta_val, tau, denom = reflector_coeffs(x0, tail2)
        v2 = x2 / denom                                         # (nb, 1)

        # Structured macro-op: the reflector is [e_j; v2], so the dot
        # touches only R's row j plus the A block — one fused pass.
        rrow = jnp.sum(jnp.where(rows == j, r, 0.0), axis=0,
                       keepdims=True)                           # (1, nb)
        w = tau * (rrow + jnp.sum(v2 * a, axis=0, keepdims=True))
        trailing = cols > j
        r = r - jnp.where((rows == j) & trailing, w, 0.0)
        a = a - jnp.where(trailing, v2 * w, 0.0)

        r = jnp.where(pivmask, beta_val, r)
        vacc = jnp.where(colmask, v2, vacc)
        taus = jnp.where(colmask, tau, taus)
        return r, a, vacc, taus

    r_fin, _, vacc, taus = lax.fori_loop(
        0, nb, body,
        (r0, a0, jnp.zeros((nb, nb), acc), jnp.zeros((1, nb), acc)))
    merged = jnp.where(upper, r_fin, diag.astype(acc))
    return (merged.astype(diag.dtype), vacc.astype(diag.dtype),
            taus[0].astype(diag.dtype))


def tsqrt_body(diag: Array, sub: Array) -> Tuple[Array, Array, Array, Array]:
    """TSQRT as the engine's fused macro op: factor + stacked-T formation.

    Returns ``(merged, V2, T, taus)``.
    """
    merged, v2, taus = tsqrt_factor(diag, sub)
    return merged, v2, stacked_larft(v2, taus), taus


def ssrfb_body(v2: Array, t: Array, ck: Array, ci: Array
               ) -> Tuple[Array, Array]:
    """SSRFB: apply the TSQRT block reflector to a tile pair.

    With V = [I; V2]:  W = T^T (C_k + V2^T C_i);  C_k -= W;  C_i -= V2 W.
    Four chained MXU products fused into one VMEM pass per tile pair.
    """
    acc = acc_dtype(ck.dtype)
    v_a = v2.astype(acc)
    ck_a = ck.astype(acc)
    ci_a = ci.astype(acc)
    w = ck_a + jnp.dot(v_a.T, ci_a, preferred_element_type=acc)
    w = jnp.dot(t.astype(acc).T, w, preferred_element_type=acc)
    return ((ck_a - w).astype(ck.dtype),
            (ci_a - jnp.dot(v_a, w, preferred_element_type=acc)
             ).astype(ci.dtype))


# ---------------------------------------------------------------------------
# wavefront kernels — the engine's per-(wavefront, kind) Pallas bodies
# ---------------------------------------------------------------------------
#
# Uniform signature: scalar-prefetch index refs first (task coordinates,
# one row per grid cell), then the ANY-space workspace + blocked state
# inputs, the aliased outputs, VMEM tile scratch, and one DMA semaphore.
# Tiles are DMA'd workspace -> scratch, transformed by the value-level
# body above, and DMA'd back — the whole DAG node is one VMEM-resident
# fused pass, and the workspace is updated in place (the gather ->
# compute -> ``.at[].set`` round trip of the old scheduler is gone).

def _copy(src, dst, sem) -> None:
    cp = pltpu.make_async_copy(src, dst, sem)
    cp.start()
    cp.wait()


def geqrt_wavefront_kernel(kk_ref, ws_in, dt_in, dtaus_in,
                           ws_out, dt_out, dtaus_out, tile_scr, sem):
    """One GEQRT task per grid cell: tile (k, k) -> packed, T, taus."""
    del ws_in, dt_in, dtaus_in  # aliased: reads go through the out refs
    g = pl.program_id(0)
    k = kk_ref[g]
    _copy(ws_out.at[k, k], tile_scr, sem)
    packed, t, taus = geqrt_body(tile_scr[...])
    tile_scr[...] = packed
    _copy(tile_scr, ws_out.at[k, k], sem)
    dt_out[0] = t
    dtaus_out[0] = taus


def larfb_wavefront_kernel(kk_ref, jj_ref, ws_in, dt_ref,
                           ws_out, diag_scr, c_scr, sem):
    """One LARFB task per grid cell: tile (k, j) -= V1 (T^T (V1^T .))."""
    del ws_in
    g = pl.program_id(0)
    k = kk_ref[g]
    j = jj_ref[g]
    _copy(ws_out.at[k, k], diag_scr, sem)
    _copy(ws_out.at[k, j], c_scr, sem)
    c_scr[...] = larfb_body(diag_scr[...], dt_ref[0], c_scr[...])
    _copy(c_scr, ws_out.at[k, j], sem)


def tsqrt_wavefront_kernel(kk_ref, ii_ref, ws_in, tt_in, ttaus_in,
                           ws_out, tt_out, ttaus_out, diag_scr, sub_scr, sem):
    """One TSQRT task per grid cell: stacked QR of tiles (k,k) / (i,k)."""
    del ws_in, tt_in, ttaus_in
    g = pl.program_id(0)
    k = kk_ref[g]
    i = ii_ref[g]
    _copy(ws_out.at[k, k], diag_scr, sem)
    _copy(ws_out.at[i, k], sub_scr, sem)
    merged, v2, t, taus = tsqrt_body(diag_scr[...], sub_scr[...])
    diag_scr[...] = merged
    sub_scr[...] = v2
    _copy(diag_scr, ws_out.at[k, k], sem)
    _copy(sub_scr, ws_out.at[i, k], sem)
    tt_out[0, 0] = t
    ttaus_out[0, 0] = taus


def ssrfb_wavefront_kernel(kk_ref, ii_ref, jj_ref, ws_in, tt_ref,
                           ws_out, v_scr, ck_scr, ci_scr, sem):
    """One SSRFB task per grid cell: tile pair (k,j) / (i,j) update."""
    del ws_in
    g = pl.program_id(0)
    k = kk_ref[g]
    i = ii_ref[g]
    j = jj_ref[g]
    _copy(ws_out.at[i, k], v_scr, sem)
    _copy(ws_out.at[k, j], ck_scr, sem)
    _copy(ws_out.at[i, j], ci_scr, sem)
    ck, ci = ssrfb_body(v_scr[...], tt_ref[0, 0], ck_scr[...], ci_scr[...])
    ck_scr[...] = ck
    ci_scr[...] = ci
    _copy(ck_scr, ws_out.at[k, j], sem)
    _copy(ci_scr, ws_out.at[i, j], sem)


# ---------------------------------------------------------------------------
# registry + VMEM accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MacroOp:
    """Capability card for one DAG macro op.

    body:        value-level fused realization (kernel body == jnp oracle)
    kernel:      the engine's wavefront Pallas body (uniform signature)
    tile_reads:  workspace tiles read per task  (HBM traffic model)
    tile_writes: workspace tiles written per task
    vmem_tiles:  nb x nb VMEM-resident tiles per task (working-set bound)
    """

    name: str
    body: Callable
    kernel: Callable
    tile_reads: int
    tile_writes: int
    vmem_tiles: int


MACRO_OPS: Dict[str, MacroOp] = {
    "GEQRT": MacroOp("GEQRT", geqrt_body, geqrt_wavefront_kernel,
                     tile_reads=1, tile_writes=1, vmem_tiles=4),
    "LARFB": MacroOp("LARFB", larfb_body, larfb_wavefront_kernel,
                     tile_reads=2, tile_writes=1, vmem_tiles=5),
    "TSQRT": MacroOp("TSQRT", tsqrt_body, tsqrt_wavefront_kernel,
                     tile_reads=2, tile_writes=2, vmem_tiles=6),
    "SSRFB": MacroOp("SSRFB", ssrfb_body, ssrfb_wavefront_kernel,
                     tile_reads=3, tile_writes=2, vmem_tiles=7),
}


def vmem_bytes(kind: str, nb: int, itemsize: int = 4) -> int:
    """Per-task VMEM working set of one macro op at tile size nb."""
    return MACRO_OPS[kind].vmem_tiles * nb * nb * itemsize


def engine_vmem_bytes(nb: int, itemsize: int = 4) -> int:
    """Worst-case per-task working set across all engine macro ops."""
    return max(vmem_bytes(k, nb, itemsize) for k in MACRO_OPS)


# The megakernel dispatch mode holds, per grid step: two phases of the
# worst-case operand set (3 tiles + 1 block reflector, double-buffered
# so task t+1's fetch overlaps task t's compute), the write-back staging
# tiles, and the worst-case body temporaries (SSRFB's 4-product chain).
MEGAKERNEL_VMEM_TILES = 2 * (3 + 1) + 3 + 4


def megakernel_vmem_bytes(nb: int, itemsize: int = 4) -> int:
    """Resident working set of the engine's single-dispatch megakernel
    lowering at tile size nb (double-buffered operands + staging)."""
    return MEGAKERNEL_VMEM_TILES * nb * nb * itemsize


def batched_megakernel_vmem_bytes(nb: int, itemsize: int = 4,
                                  batch: int = 1) -> int:
    """Resident working set of the *batched* megakernel
    (``engine.factor_tiles_batched``): the batch is an outer sequential
    grid axis replaying one shared task table, so the per-step set —
    double-buffered operands + staging — does not grow with ``batch``.
    The explicit ``batch`` parameter keeps the serving layer's VMEM
    gating honest about that invariance instead of assuming it."""
    del batch  # batch-invariant by construction (outer grid axis)
    return megakernel_vmem_bytes(nb, itemsize)


_POLICY = register_kernel_policy(KernelPolicy(
    name="macro_ops",
    vmem_bytes=lambda nb, _b=0: engine_vmem_bytes(nb),
    vmem_budget=DEFAULT_VMEM_BUDGET,
    default_interpret=default_interpret,
    table_budget=DEFAULT_TABLE_BUDGET,
))
