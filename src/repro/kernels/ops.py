"""jit'd public wrappers for the Pallas kernels.

Dispatch policy: on TPU the kernels run compiled; everywhere else they
run in ``interpret=True`` mode (the kernel body executes in Python/XLA on
CPU) so correctness is validated in CI without hardware.  Callers can
force either with ``interpret=``.

Padding: ``wy_trailing`` pads the C column count to the tile size and
strips it after; ``mht_panel`` takes the panel exactly as given (the
panel IS the block).

VMEM budget: this backend registers a :class:`repro.core.plan.KernelPolicy`
carrying its working-set estimator and the shared
:data:`repro.core.plan.DEFAULT_VMEM_BUDGET`; the wrappers' runtime guards
below and the planner's fits-in-VMEM decisions both read that one policy,
so they cannot disagree.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.plan import (DEFAULT_VMEM_BUDGET, KernelPolicy,
                             register_kernel_policy)
from repro.kernels.macro_ops import default_interpret
from repro.kernels.mht_panel import mht_panel_pallas
from repro.kernels.wy_trailing import wy_trailing_pallas

Array = jax.Array

__all__ = ["mht_panel", "wy_trailing", "vmem_bytes_mht_panel", "default_interpret"]


def vmem_bytes_mht_panel(m: int, b: int) -> int:
    """fp32 working set of the panel kernel (panel + packed copy)."""
    return 2 * m * b * 4


# The kernel backend registers its dispatch policy (VMEM estimator +
# budget + interpret default) with the planner, so ``method="auto"`` /
# the ``use_kernel=None`` auto policy can decide panel-fits-VMEM
# centrally against the very same budget enforced here.
_POLICY = register_kernel_policy(KernelPolicy(
    name="mht_panel",
    vmem_bytes=vmem_bytes_mht_panel,
    vmem_budget=DEFAULT_VMEM_BUDGET,
    default_interpret=default_interpret,
))


@functools.partial(jax.jit, static_argnames=("row0", "interpret"))
def _mht_panel_jit(panel: Array, row0: int, interpret: bool):
    return mht_panel_pallas(panel, row0=row0, interpret=interpret)


def mht_panel(panel: Array, *, row0: int = 0,
              interpret: bool | None = None) -> Tuple[Array, Array]:
    """Fused VMEM-resident MHT panel factorization.

    Returns (packed, taus) exactly like
    :func:`repro.core.blocked.panel_factor`; oracle:
    :func:`repro.kernels.ref.mht_panel_ref`.
    """
    m, b = panel.shape
    if vmem_bytes_mht_panel(m, b) > _POLICY.vmem_budget:
        raise ValueError(
            f"panel ({m},{b}) exceeds VMEM budget "
            f"({vmem_bytes_mht_panel(m, b)} > {_POLICY.vmem_budget}); "
            "factor via TSQR leaves instead")
    interp = default_interpret() if interpret is None else interpret
    return _mht_panel_jit(panel, row0, interp)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def _wy_trailing_jit(v: Array, t: Array, c: Array, bn: int, interpret: bool):
    n = c.shape[1]
    n_pad = (n + bn - 1) // bn * bn
    c_p = jnp.pad(c, ((0, 0), (0, n_pad - n))) if n_pad != n else c
    out = wy_trailing_pallas(v, t, c_p, bn=bn, interpret=interpret)
    return out[:, :n]


def wy_trailing(v: Array, t: Array, c: Array, *, bn: int = 128,
                interpret: bool | None = None) -> Array:
    """Fused WY trailing update ``C - V (T^T (V^T C))``.

    Oracle: :func:`repro.kernels.ref.wy_trailing_ref`."""
    m, k = v.shape
    if (m * bn + m * k + k * k + k * bn) * 4 > _POLICY.vmem_budget:
        raise ValueError(f"wy_trailing working set too large for VMEM: m={m} k={k} bn={bn}")
    interp = default_interpret() if interpret is None else interpret
    bn_eff = min(bn, max(8, c.shape[1]))
    return _wy_trailing_jit(v, t, c, bn_eff, interp)
