"""Pallas TPU kernel: fused WY trailing update  ``C <- C - V (T^T (V^T C))``.

The blocked QR trailing update is three chained GEMMs.  Run naively that
is three HBM round-trips over C-sized data; fused per column-tile it is
one read + one write of C, with W = V^T C_tile and X = T^T W living
entirely in VMEM.  This is the Level-3 counterpart of the paper's fused
macro-op: the same "never let the intermediate leave the fast memory"
co-design argument, re-blocked for the 128x128 MXU instead of the DOT4.

Grid: one program per C column-tile (bn columns).  V (m, k), T (k, k) are
broadcast to every program; C tiles stream.  VMEM per program:
m·bn + m·k + k·k + k·bn floats — the ops wrapper checks the budget and
requires m ≤ 8192 for k, bn = 128.

All matmuls run with fp32 accumulation (``preferred_element_type``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

__all__ = ["wy_trailing_kernel", "wy_trailing_pallas"]


def wy_trailing_kernel(v_ref, t_ref, c_ref, out_ref):
    """One C column-tile: W = V^T C (MXU), X = T^T W (MXU), C -= V X (MXU)."""
    v = v_ref[...]
    c = c_ref[...]
    t = t_ref[...]
    w = jnp.dot(v.T, c, preferred_element_type=jnp.float32)        # (k, bn)
    x = jnp.dot(t.T.astype(jnp.float32), w,
                preferred_element_type=jnp.float32)                # (k, bn)
    upd = jnp.dot(v.astype(jnp.float32), x,
                  preferred_element_type=jnp.float32)              # (m, bn)
    out_ref[...] = (c.astype(jnp.float32) - upd).astype(out_ref.dtype)


def wy_trailing_pallas(
    v: Array, t: Array, c: Array, *, bn: int = 128, interpret: bool = False
) -> Array:
    """Fused trailing update over all of C, tiled bn columns at a time.

    Requires c.shape[1] % bn == 0 (ops wrapper pads)."""
    m, k = v.shape
    n = c.shape[1]
    if n % bn != 0:
        raise ValueError(f"n={n} not a multiple of bn={bn}")
    grid = (n // bn,)
    return pl.pallas_call(
        wy_trailing_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),   # V broadcast
            pl.BlockSpec((k, k), lambda j: (0, 0)),   # T broadcast
            pl.BlockSpec((m, bn), lambda j: (0, j)),  # C tile streams
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        interpret=interpret,
    )(v, t, c)
