"""Pallas TPU kernel: fused WY trailing update  ``C <- C - V (T^T (V^T C))``.

The blocked QR trailing update is three chained GEMMs.  Run naively that
is three HBM round-trips over C-sized data; fused per column-tile it is
one read + one write of C, with W = V^T C_tile and X = T^T W living
entirely in VMEM.  This is the Level-3 counterpart of the paper's fused
macro-op: the same "never let the intermediate leave the fast memory"
co-design argument, re-blocked for the 128x128 MXU instead of the DOT4.

The fused product chain is :func:`repro.kernels.macro_ops.wy_body` — the
ONE WY apply this package owns, shared with the tile-DAG LARFB/SSRFB
macro ops and the wavefront engine.  This module only streams C through
it, one column-tile per grid cell.

Grid: one program per C column-tile (bn columns).  V (m, k), T (k, k) are
broadcast to every program; C tiles stream.  VMEM per program:
m·bn + m·k + k·k + k·bn floats — the ops wrapper checks the budget and
requires m ≤ 8192 for k, bn = 128.

All matmuls accumulate in ``promote_types(dtype, float32)``
(``preferred_element_type``).
"""

from __future__ import annotations

import jax
from jax.experimental import pallas as pl

from repro.kernels import macro_ops

Array = jax.Array

__all__ = ["wy_trailing_kernel", "wy_trailing_pallas"]


def wy_trailing_kernel(v_ref, t_ref, c_ref, out_ref):
    """One C column-tile: W = V^T C (MXU), X = T^T W (MXU), C -= V X (MXU)."""
    out_ref[...] = macro_ops.wy_body(v_ref[...], t_ref[...], c_ref[...])


def wy_trailing_pallas(
    v: Array, t: Array, c: Array, *, bn: int = 128, interpret: bool = False
) -> Array:
    """Fused trailing update over all of C, tiled bn columns at a time.

    Requires c.shape[1] % bn == 0 (ops wrapper pads)."""
    m, k = v.shape
    n = c.shape[1]
    if n % bn != 0:
        raise ValueError(f"n={n} not a multiple of bn={bn}")
    grid = (n // bn,)
    return pl.pallas_call(
        wy_trailing_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),   # V broadcast
            pl.BlockSpec((k, k), lambda j: (0, 0)),   # T broadcast
            pl.BlockSpec((m, bn), lambda j: (0, j)),  # C tile streams
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        interpret=interpret,
    )(v, t, c)
