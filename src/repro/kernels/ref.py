"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package pins its numerics against exactly one of
these functions (tests sweep shapes/dtypes and assert_allclose).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

__all__ = ["mht_panel_ref", "wy_trailing_ref", "ht_update_two_pass_ref",
           "geqrt_ref", "larfb_ref", "tsqrt_ref", "ssrfb_ref"]


def mht_panel_ref(panel: Array, row0: int = 0) -> Tuple[Array, Array]:
    """Oracle for :mod:`repro.kernels.mht_panel`.

    Factor an (m, b) panel whose column ``lj`` pivots at row ``row0 + lj``
    with the fused MHT update.  fp32 internally regardless of input dtype
    (the kernel computes in fp32 on the VPU)."""
    from repro.core.blocked import panel_factor

    dtype = panel.dtype
    packed, taus = panel_factor(panel.astype(jnp.float32), row0, method="mht")
    return packed.astype(dtype), taus.astype(dtype)


def wy_trailing_ref(v: Array, t: Array, c: Array) -> Array:
    """Oracle for :mod:`repro.kernels.wy_trailing`:
    ``C - V (T^T (V^T C))`` with fp32 accumulation."""
    dtype = c.dtype
    v32 = v.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    w = v32.T @ c32
    w = t.astype(jnp.float32).T @ w
    return (c32 - v32 @ w).astype(dtype)


def geqrt_ref(tile: Array) -> Tuple[Array, Array, Array]:
    """Oracle for :func:`repro.kernels.macro_ops.geqrt_body`.

    QR of one square tile plus its WY block reflector, via the
    independent :func:`repro.core.blocked.panel_factor` / ``larft``
    realizations; returns ``(packed, T, taus)``."""
    from repro.core.blocked import larft, panel_factor, unpack_v_panel

    dtype = tile.dtype
    packed, taus = panel_factor(tile.astype(jnp.float32), 0, method="mht")
    t = larft(unpack_v_panel(packed, 0), taus)
    return packed.astype(dtype), t.astype(dtype), taus.astype(dtype)


def larfb_ref(diag_packed: Array, t: Array, c: Array) -> Array:
    """Oracle for :func:`repro.kernels.macro_ops.larfb_body`:
    unpack V1 from the packed diagonal tile, then the WY apply."""
    from repro.core.blocked import unpack_v_panel

    return wy_trailing_ref(unpack_v_panel(diag_packed, 0), t, c)


def tsqrt_ref(r: Array, a: Array) -> Tuple[Array, Array, Array]:
    """Oracle for :func:`repro.kernels.tile_ops.tsqrt`.

    QR of the stacked pair [R; A] (R upper triangular on top) via the
    dense MHT panel factorization; returns (R new, V2, taus).  The
    strict-lower top entries come back exactly zero because the stacked
    column tails are zero there, so the dense path and the structured
    kernel agree bit-for-bit in exact arithmetic."""
    from repro.core.blocked import panel_factor

    dtype = r.dtype
    nb = r.shape[0]
    stacked = jnp.concatenate([r, a], axis=0).astype(jnp.float32)
    packed, taus = panel_factor(stacked, 0, method="mht")
    return (packed[:nb].astype(dtype), packed[nb:].astype(dtype),
            taus.astype(dtype))


def ssrfb_ref(v2: Array, t: Array, ck: Array, ci: Array) -> Tuple[Array, Array]:
    """Oracle for :func:`repro.kernels.tile_ops.ssrfb`:
    W = T^T (C_k + V2^T C_i); C_k - W; C_i - V2 W, fp32 accumulation."""
    dtype = ck.dtype
    v32, ck32, ci32 = (v2.astype(jnp.float32), ck.astype(jnp.float32),
                       ci.astype(jnp.float32))
    w = t.astype(jnp.float32).T @ (ck32 + v32.T @ ci32)
    return (ck32 - w).astype(dtype), (ci32 - v32 @ w).astype(dtype)


def ht_update_two_pass_ref(a: Array, v: Array, tau: Array) -> Array:
    """Oracle for the classical two-pass trailing update (used by the
    kernel-traffic benchmark): w = tau v^T A then A - v w."""
    dtype = a.dtype
    a32, v32 = a.astype(jnp.float32), v.astype(jnp.float32)
    w = tau.astype(jnp.float32) * (v32 @ a32)
    return (a32 - jnp.outer(v32, w)).astype(dtype)
