"""Pallas TPU kernels for the paper's compute hot-spots.

    mht_panel    fused VMEM-resident MHT panel factorization (DOT4 analogue)
    wy_trailing  fused WY trailing update  C - V (T^T (V^T C))

``ops`` holds the jit'd public wrappers (interpret-mode on CPU), ``ref``
the pure-jnp oracles the tests pin against.
"""

from repro.kernels import ops, ref  # noqa: F401
