"""Pallas TPU kernels for the paper's compute hot-spots.

    macro_ops    THE unified macro-op library: one Householder/WY core,
                 the four tile-DAG bodies (GEQRT/LARFB/TSQRT/SSRFB), the
                 wavefront-engine kernels, and the VMEM estimators
    mht_panel    fused VMEM-resident MHT panel factorization (DOT4 analogue)
    wy_trailing  fused WY trailing update  C - V (T^T (V^T C))
    tile_ops     standalone single-tile TSQRT / SSRFB wrappers

``ops``/``tile_ops`` hold the jit'd public wrappers (interpret-mode on
CPU), ``ref`` the pure-jnp oracles the tests pin against; every kernel
body is a shell over a ``macro_ops`` value-level function, so the engine
path (:mod:`repro.core.engine`) and the jnp oracle path trace identical
op sequences.
"""

from repro.kernels import macro_ops, ops, ref, tile_ops  # noqa: F401
