"""Pallas TPU kernels for the paper's compute hot-spots.

    mht_panel    fused VMEM-resident MHT panel factorization (DOT4 analogue)
    wy_trailing  fused WY trailing update  C - V (T^T (V^T C))
    tile_ops     tiled-QR macro ops: TSQRT (stacked-triangle QR) and
                 SSRFB (tile-pair block-reflector apply)

``ops``/``tile_ops`` hold the jit'd public wrappers (interpret-mode on
CPU), ``ref`` the pure-jnp oracles the tests pin against.
"""

from repro.kernels import ops, ref, tile_ops  # noqa: F401
