"""Single-tile Pallas wrappers for the TSQRT / SSRFB macro ops.

The macro-op *bodies* live in the unified library
(:mod:`repro.kernels.macro_ops` — one Householder/WY core shared with
the panel and trailing kernels and with the wavefront engine's fused
dispatch).  This module keeps the standalone one-tile entry points:
handy for tests, benchmarks, and callers outside the tile-DAG engine.

  * **TSQRT** — QR of the stacked pair ``[R; A]`` where R is the nb x nb
    upper-triangular tile on top and A a full nb x nb tile below.  Each
    column's reflector is structured ``[e_j; v2_j]``: the dot-reduce and
    the fused update touch only the pivot row of R plus the A block, so
    the kernel does ~half the work of a dense 2nb-tall panel
    factorization and both tiles stay VMEM-resident across all nb
    columns (the paper's LM-resident macro-op argument, §5.1, applied to
    the tile-DAG node).
  * **SSRFB** — apply the TSQRT block reflector to a tile pair:
    with V = [I; V2],  W = T^T (C_k + V2^T C_i),  C_k -= W,  C_i -= V2 W.
    Four chained MXU products fused into one VMEM pass per tile pair.

Both kernels are single-grid-cell (the tile IS the block, like
``mht_panel``); the wavefront engine (:mod:`repro.core.engine`) instead
dispatches whole same-kind task batches as one ``pallas_call`` against
the tile workspace.  Oracles: :func:`repro.kernels.ref.tsqrt_ref` /
``ssrfb_ref``; interpret mode runs the bodies on CPU (the default
off-TPU, as in :mod:`repro.kernels.ops`).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.plan import (DEFAULT_VMEM_BUDGET, KernelPolicy,
                             register_kernel_policy)
from repro.kernels import macro_ops
from repro.kernels.macro_ops import default_interpret

Array = jax.Array

__all__ = [
    "tsqrt",
    "ssrfb",
    "tsqrt_kernel",
    "ssrfb_kernel",
    "vmem_bytes_tsqrt",
    "vmem_bytes_ssrfb",
]


def vmem_bytes_tsqrt(nb: int) -> int:
    """fp32 working set: R + A in, R + V2 out, plus the loop carries."""
    return macro_ops.vmem_bytes("TSQRT", nb)


def vmem_bytes_ssrfb(nb: int) -> int:
    """fp32 working set: V2/T/C_k/C_i in, two tiles out, W scratch."""
    return macro_ops.vmem_bytes("SSRFB", nb)


def _vmem_bytes_tile(nb: int, _b: int = 0) -> int:
    """Worst-case per-tile working set across both macro ops (the policy
    contract is (m, b); tiles are square so only the first dim is used)."""
    return max(vmem_bytes_tsqrt(nb), vmem_bytes_ssrfb(nb))


_POLICY = register_kernel_policy(KernelPolicy(
    name="tile_ops",
    vmem_bytes=_vmem_bytes_tile,
    vmem_budget=DEFAULT_VMEM_BUDGET,
    default_interpret=default_interpret,
))


# ---------------------------------------------------------------------------
# TSQRT
# ---------------------------------------------------------------------------

def tsqrt_kernel(r_ref, a_ref, r_out, v_out, taus_ref):
    """Kernel body: factor the VMEM-resident [R; A] stack in place.

    r_ref/a_ref: (nb, nb) input tiles (R upper triangular)
    r_out:       (nb, nb) updated R (zeros below the diagonal)
    v_out:       (nb, nb) V2 — reflector tails, column j in column j
    taus_ref:    (1, nb) tau row
    """
    r_new, v2, taus = macro_ops.tsqrt_factor(r_ref[...], a_ref[...])
    r_out[...] = r_new
    v_out[...] = v2
    taus_ref[...] = taus[None]


def tsqrt_pallas(r_t: Array, a_t: Array, *, interpret: bool = False
                 ) -> Tuple[Array, Array, Array]:
    """Invoke the TSQRT kernel on one tile pair (single grid cell)."""
    nb = r_t.shape[0]
    r_new, v2, taus = pl.pallas_call(
        tsqrt_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((nb, nb), r_t.dtype),
            jax.ShapeDtypeStruct((nb, nb), r_t.dtype),
            jax.ShapeDtypeStruct((1, nb), r_t.dtype),
        ],
        in_specs=[
            pl.BlockSpec((nb, nb), lambda: (0, 0)),
            pl.BlockSpec((nb, nb), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((nb, nb), lambda: (0, 0)),
            pl.BlockSpec((nb, nb), lambda: (0, 0)),
            pl.BlockSpec((1, nb), lambda: (0, 0)),
        ],
        interpret=interpret,
    )(r_t, a_t)
    return r_new, v2, taus[0]


# ---------------------------------------------------------------------------
# SSRFB
# ---------------------------------------------------------------------------

def ssrfb_kernel(v_ref, t_ref, ck_ref, ci_ref, ck_out, ci_out):
    """One tile pair: W = T^T (C_k + V2^T C_i); C_k -= W; C_i -= V2 W."""
    ck, ci = macro_ops.ssrfb_body(v_ref[...], t_ref[...],
                                  ck_ref[...], ci_ref[...])
    ck_out[...] = ck
    ci_out[...] = ci


def ssrfb_pallas(v2: Array, t: Array, ck: Array, ci: Array, *,
                 interpret: bool = False) -> Tuple[Array, Array]:
    """Invoke the SSRFB kernel on one tile pair (single grid cell)."""
    nb = v2.shape[0]
    spec = pl.BlockSpec((nb, nb), lambda: (0, 0))
    return pl.pallas_call(
        ssrfb_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((nb, nb), ck.dtype),
            jax.ShapeDtypeStruct((nb, nb), ci.dtype),
        ],
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec],
        interpret=interpret,
    )(v2, t, ck, ci)


# ---------------------------------------------------------------------------
# jit'd public wrappers (dispatch pattern mirrors repro.kernels.ops)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("interpret",))
def _tsqrt_jit(r_t: Array, a_t: Array, interpret: bool):
    return tsqrt_pallas(r_t, a_t, interpret=interpret)


def tsqrt(r_t: Array, a_t: Array, *, interpret: bool | None = None
          ) -> Tuple[Array, Array, Array]:
    """Stacked-triangle QR of [R; A] -> (R new, V2, taus).

    Oracle: :func:`repro.kernels.ref.tsqrt_ref`."""
    nb = r_t.shape[0]
    if r_t.shape != a_t.shape or r_t.shape[1] != nb:
        raise ValueError(
            f"tsqrt expects square same-shape tiles, got {r_t.shape} / {a_t.shape}")
    if vmem_bytes_tsqrt(nb) > _POLICY.vmem_budget:
        raise ValueError(
            f"tile ({nb},{nb}) exceeds VMEM budget "
            f"({vmem_bytes_tsqrt(nb)} > {_POLICY.vmem_budget}); shrink the tile")
    interp = _POLICY.default_interpret() if interpret is None else interpret
    return _tsqrt_jit(r_t, a_t, interp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _ssrfb_jit(v2: Array, t: Array, ck: Array, ci: Array, interpret: bool):
    return ssrfb_pallas(v2, t, ck, ci, interpret=interpret)


def ssrfb(v2: Array, t: Array, ck: Array, ci: Array, *,
          interpret: bool | None = None) -> Tuple[Array, Array]:
    """Apply TSQRT reflectors to the tile pair [C_k; C_i].

    Oracle: :func:`repro.kernels.ref.ssrfb_ref`."""
    nb = v2.shape[0]
    if vmem_bytes_ssrfb(nb) > _POLICY.vmem_budget:
        raise ValueError(
            f"tile ({nb},{nb}) exceeds VMEM budget "
            f"({vmem_bytes_ssrfb(nb)} > {_POLICY.vmem_budget}); shrink the tile")
    interp = _POLICY.default_interpret() if interpret is None else interpret
    return _ssrfb_jit(v2, t, ck, ci, interp)
