"""Pallas TPU kernels for the tiled-QR macro ops: TSQRT and SSRFB.

These are the two tile tasks the existing kernels don't cover
(:mod:`repro.kernels.mht_panel` realizes GEQRT, ``wy_trailing`` LARFB):

  * **TSQRT** — QR of the stacked pair ``[R; A]`` where R is the nb x nb
    upper-triangular tile on top and A a full nb x nb tile below.  Each
    column's reflector is structured ``[e_j; v2_j]``: the dot-reduce and
    the fused update touch only the pivot row of R plus the A block, so
    the kernel does ~half the work of a dense 2nb-tall panel
    factorization and both tiles stay VMEM-resident across all nb
    columns (the paper's LM-resident macro-op argument, §5.1, applied to
    the tile-DAG node).
  * **SSRFB** — apply the TSQRT block reflector to a tile pair:
    with V = [I; V2],  W = T^T (C_k + V2^T C_i),  C_k -= W,  C_i -= V2 W.
    Four chained MXU products fused into one VMEM pass per tile pair.

Both kernels are single-grid-cell (the tile IS the block, like
``mht_panel``); the wavefront scheduler in :mod:`repro.core.tilegraph`
vmaps them over the independent tiles of each DAG level.  Oracles:
:func:`repro.kernels.ref.tsqrt_ref` / ``ssrfb_ref``; interpret mode runs
the bodies on CPU (the default off-TPU, as in :mod:`repro.kernels.ops`).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.plan import (DEFAULT_VMEM_BUDGET, KernelPolicy,
                             register_kernel_policy)
from repro.kernels.ops import default_interpret

Array = jax.Array

__all__ = [
    "tsqrt",
    "ssrfb",
    "tsqrt_kernel",
    "ssrfb_kernel",
    "vmem_bytes_tsqrt",
    "vmem_bytes_ssrfb",
]


def vmem_bytes_tsqrt(nb: int) -> int:
    """fp32 working set: R + A in, R + V2 out, plus the loop carries."""
    return 6 * nb * nb * 4


def vmem_bytes_ssrfb(nb: int) -> int:
    """fp32 working set: V2/T/C_k/C_i in, two tiles out, W scratch."""
    return 7 * nb * nb * 4


def _vmem_bytes_tile(nb: int, _b: int = 0) -> int:
    """Worst-case per-tile working set across both macro ops (the policy
    contract is (m, b); tiles are square so only the first dim is used)."""
    return max(vmem_bytes_tsqrt(nb), vmem_bytes_ssrfb(nb))


_POLICY = register_kernel_policy(KernelPolicy(
    name="tile_ops",
    vmem_bytes=_vmem_bytes_tile,
    vmem_budget=DEFAULT_VMEM_BUDGET,
    default_interpret=default_interpret,
))


# ---------------------------------------------------------------------------
# TSQRT
# ---------------------------------------------------------------------------

def tsqrt_kernel(r_ref, a_ref, r_out, v_out, taus_ref):
    """Kernel body: factor the VMEM-resident [R; A] stack in place.

    r_ref/a_ref: (nb, nb) input tiles (R upper triangular)
    r_out:       (nb, nb) updated R (zeros below the diagonal)
    v_out:       (nb, nb) V2 — reflector tails, column j in column j
    taus_ref:    (1, nb) tau row
    """
    nb = r_ref.shape[0]
    r0 = r_ref[...].astype(jnp.float32)
    a0 = a_ref[...].astype(jnp.float32)
    rows = lax.broadcasted_iota(jnp.int32, (nb, 1), 0)
    cols = lax.broadcasted_iota(jnp.int32, (1, nb), 1)

    def body(j, carry):
        r, a, vacc, taus = carry
        colmask = cols == j                                     # (1, nb)
        pivmask = (rows == j) & colmask                         # (nb, nb)
        x0 = jnp.sum(jnp.where(pivmask, r, 0.0))                # pivot R[j,j]
        x2 = jnp.sum(jnp.where(colmask, a, 0.0), axis=1,
                     keepdims=True)                             # (nb, 1)
        tail2 = jnp.sum(x2 * x2)
        norm = jnp.sqrt(x0 * x0 + tail2)
        beta = jnp.where(x0 >= 0.0, -norm, norm)
        degen = tail2 == 0.0
        denom = jnp.where(degen, 1.0, x0 - beta)
        v2 = x2 / denom                                         # (nb, 1)
        tau = jnp.where(
            degen, 0.0, (beta - x0) / jnp.where(beta == 0.0, 1.0, beta))
        beta_val = jnp.where(degen, x0, beta)

        # Structured macro-op: the reflector is [e_j; v2], so the dot
        # touches only R's row j plus the A block — one fused pass.
        rrow = jnp.sum(jnp.where(rows == j, r, 0.0), axis=0,
                       keepdims=True)                           # (1, nb)
        w = tau * (rrow + jnp.sum(v2 * a, axis=0, keepdims=True))
        trailing = cols > j
        r = r - jnp.where((rows == j) & trailing, w, 0.0)
        a = a - jnp.where(trailing, v2 * w, 0.0)

        r = jnp.where(pivmask, beta_val, r)
        vacc = jnp.where(colmask, v2, vacc)
        taus = jnp.where(colmask, tau, taus)
        return r, a, vacc, taus

    r_fin, _, vacc, taus = lax.fori_loop(
        0, nb, body,
        (r0, a0, jnp.zeros((nb, nb), jnp.float32),
         jnp.zeros((1, nb), jnp.float32)))
    r_out[...] = r_fin.astype(r_out.dtype)
    v_out[...] = vacc.astype(v_out.dtype)
    taus_ref[...] = taus.astype(taus_ref.dtype)


def tsqrt_pallas(r_t: Array, a_t: Array, *, interpret: bool = False
                 ) -> Tuple[Array, Array, Array]:
    """Invoke the TSQRT kernel on one tile pair (single grid cell)."""
    nb = r_t.shape[0]
    r_new, v2, taus = pl.pallas_call(
        tsqrt_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((nb, nb), r_t.dtype),
            jax.ShapeDtypeStruct((nb, nb), r_t.dtype),
            jax.ShapeDtypeStruct((1, nb), r_t.dtype),
        ],
        in_specs=[
            pl.BlockSpec((nb, nb), lambda: (0, 0)),
            pl.BlockSpec((nb, nb), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((nb, nb), lambda: (0, 0)),
            pl.BlockSpec((nb, nb), lambda: (0, 0)),
            pl.BlockSpec((1, nb), lambda: (0, 0)),
        ],
        interpret=interpret,
    )(r_t, a_t)
    return r_new, v2, taus[0]


# ---------------------------------------------------------------------------
# SSRFB
# ---------------------------------------------------------------------------

def ssrfb_kernel(v_ref, t_ref, ck_ref, ci_ref, ck_out, ci_out):
    """One tile pair: W = T^T (C_k + V2^T C_i); C_k -= W; C_i -= V2 W."""
    v2 = v_ref[...]
    ck = ck_ref[...].astype(jnp.float32)
    ci = ci_ref[...]
    w = ck + jnp.dot(v2.T, ci, preferred_element_type=jnp.float32)
    w = jnp.dot(t_ref[...].T.astype(jnp.float32), w,
                preferred_element_type=jnp.float32)
    ck_out[...] = (ck - w).astype(ck_out.dtype)
    ci_out[...] = (ci.astype(jnp.float32)
                   - jnp.dot(v2.astype(jnp.float32), w,
                             preferred_element_type=jnp.float32)
                   ).astype(ci_out.dtype)


def ssrfb_pallas(v2: Array, t: Array, ck: Array, ci: Array, *,
                 interpret: bool = False) -> Tuple[Array, Array]:
    """Invoke the SSRFB kernel on one tile pair (single grid cell)."""
    nb = v2.shape[0]
    spec = pl.BlockSpec((nb, nb), lambda: (0, 0))
    return pl.pallas_call(
        ssrfb_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((nb, nb), ck.dtype),
            jax.ShapeDtypeStruct((nb, nb), ci.dtype),
        ],
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec],
        interpret=interpret,
    )(v2, t, ck, ci)


# ---------------------------------------------------------------------------
# jit'd public wrappers (dispatch pattern mirrors repro.kernels.ops)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("interpret",))
def _tsqrt_jit(r_t: Array, a_t: Array, interpret: bool):
    return tsqrt_pallas(r_t, a_t, interpret=interpret)


def tsqrt(r_t: Array, a_t: Array, *, interpret: bool | None = None
          ) -> Tuple[Array, Array, Array]:
    """Stacked-triangle QR of [R; A] -> (R new, V2, taus).

    Oracle: :func:`repro.kernels.ref.tsqrt_ref`."""
    nb = r_t.shape[0]
    if r_t.shape != a_t.shape or r_t.shape[1] != nb:
        raise ValueError(
            f"tsqrt expects square same-shape tiles, got {r_t.shape} / {a_t.shape}")
    if vmem_bytes_tsqrt(nb) > _POLICY.vmem_budget:
        raise ValueError(
            f"tile ({nb},{nb}) exceeds VMEM budget "
            f"({vmem_bytes_tsqrt(nb)} > {_POLICY.vmem_budget}); shrink the tile")
    interp = _POLICY.default_interpret() if interpret is None else interpret
    return _tsqrt_jit(r_t, a_t, interp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _ssrfb_jit(v2: Array, t: Array, ck: Array, ci: Array, interpret: bool):
    return ssrfb_pallas(v2, t, ck, ci, interpret=interpret)


def ssrfb(v2: Array, t: Array, ck: Array, ci: Array, *,
          interpret: bool | None = None) -> Tuple[Array, Array]:
    """Apply TSQRT reflectors to the tile pair [C_k; C_i].

    Oracle: :func:`repro.kernels.ref.ssrfb_ref`."""
    nb = v2.shape[0]
    if vmem_bytes_ssrfb(nb) > _POLICY.vmem_budget:
        raise ValueError(
            f"tile ({nb},{nb}) exceeds VMEM budget "
            f"({vmem_bytes_ssrfb(nb)} > {_POLICY.vmem_budget}); shrink the tile")
    interp = _POLICY.default_interpret() if interpret is None else interpret
    return _ssrfb_jit(v2, t, ck, ci, interp)
