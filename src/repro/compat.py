"""Small jax-version compatibility helpers shared across the library."""

from __future__ import annotations

import jax
from jax import lax

__all__ = ["axis_size", "shard_map", "shard_map_unchecked"]

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pre-0.6 jax keeps it in experimental
    from jax.experimental.shard_map import shard_map


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with the output-replication check disabled.

    Needed when the body contains ops without a replication rule
    (``pallas_call`` — the tile-kernel path of the sharded tiled QR);
    callers must guarantee replicated outputs themselves (e.g. via
    ``lax.pmax``).  The flag was renamed ``check_rep`` -> ``check_vma``
    across jax versions, hence the compat shim.
    """
    try:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def axis_size(axis_name) -> int:
    """Static mesh-axis size from inside shard_map (jax-version compat:
    ``lax.axis_size`` only exists on newer jax)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    from jax import core as _core

    frame = _core.axis_frame(axis_name)
    return frame if isinstance(frame, int) else frame.size
