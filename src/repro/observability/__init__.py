"""Runtime observability: metrics, span tracing, profiler annotation.

Off by default and zero-cost when off; see ARCHITECTURE.md
("Observability") for the tier-by-tier instrumentation map.

    from repro import observability as obs

    obs.enable()                          # or REPRO_OBSERVABILITY=1
    with obs.span("my.workload") as sp:
        q, r = solver.solve(a)
        sp.sync((q, r))
    obs.export_chrome_trace("trace.json")
    print(obs.metrics.to_prometheus())

Render a capture:  ``python -m repro.observability.report --help``
"""

from . import instrument, metrics, profiler, trace
from .instrument import (annotations_enabled, disable, enable, enabled_scope,
                         tracing_enabled)
from .metrics import REGISTRY, counter, gauge, histogram, snapshot
from .profiler import annotate, capture, kernel_label, megakernel_label
from .trace import (chrome_trace, export_chrome_trace, span, spans, traced,
                    tree)

__all__ = [
    "REGISTRY",
    "annotate",
    "annotations_enabled",
    "capture",
    "chrome_trace",
    "counter",
    "disable",
    "enable",
    "enabled_scope",
    "export_chrome_trace",
    "gauge",
    "histogram",
    "instrument",
    "kernel_label",
    "megakernel_label",
    "metrics",
    "profiler",
    "snapshot",
    "span",
    "spans",
    "trace",
    "traced",
    "tracing_enabled",
    "tree",
]
