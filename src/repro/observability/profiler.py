"""XLA/Perfetto profiler hooks: named scopes for kernels, trace capture.

Two planes:

  * :func:`annotate` — a trace-time ``jax.named_scope`` wrapper used
    inside jitted engine code so each macro-op wavefront and megakernel
    dispatch shows up by name (``geqrt@L3``, ``megakernel[16x16]``) in
    XLA HLO metadata and Perfetto timelines.  When annotations are
    disabled (the default) it returns ``nullcontext`` and the lowered
    jaxpr is **identical** to uninstrumented code (``named_scope`` adds
    no equations either way; the test pins this).
  * :func:`capture` — wraps ``jax.profiler.start_trace`` /
    ``stop_trace`` to record a device profile into a logdir, viewable
    with TensorBoard/Perfetto (``xprof``).  Degrades to a no-op with a
    warning counter if the installed jax lacks profiler support.

Label conventions (shared with the engine):

  * ``kernel_label("GEQRT", 3)``  -> ``"geqrt@L3"``
  * ``megakernel_label(16, 16)``  -> ``"megakernel[16x16]"``
"""

from __future__ import annotations

import contextlib
from typing import Iterator, Optional

from . import instrument, metrics

__all__ = [
    "annotate",
    "capture",
    "kernel_label",
    "megakernel_label",
]

_NULL = contextlib.nullcontext()


def annotate(name: str):
    """``jax.named_scope(name)`` when annotations are on, else a no-op.

    Called at trace time inside jitted functions — programs compiled
    while disabled stay annotation-free until retraced.
    """
    if not instrument.annotations_enabled():
        return _NULL
    import jax

    return jax.named_scope(name)


def kernel_label(kind: str, level: Optional[int] = None) -> str:
    """Profiler name for a macro-op dispatch: ``geqrt@L3``."""
    base = kind.lower()
    return f"{base}@L{level}" if level is not None else base


def megakernel_label(p: int, q: int, batch: Optional[int] = None) -> str:
    """Profiler name for a persistent megakernel: ``megakernel[16x16]``."""
    if batch is not None and batch > 1:
        return f"megakernel[{batch}x{p}x{q}]"
    return f"megakernel[{p}x{q}]"


@contextlib.contextmanager
def capture(logdir: str) -> Iterator[None]:
    """Record a JAX device profile into ``logdir`` for Perfetto.

    Enables annotations for the duration so freshly traced programs
    carry kernel names.  Safe no-op (with a ``profiler.capture_errors``
    counter) when the runtime has no profiler backend.
    """
    import jax

    started = False
    prev = (instrument.tracing_enabled(), instrument.annotations_enabled())
    instrument.enable(tracing=prev[0] or True, annotations=True)
    try:
        try:
            jax.profiler.start_trace(logdir)
            started = True
        except Exception:
            metrics.counter("profiler.capture_errors", stage="start").inc()
        yield
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception:
                metrics.counter("profiler.capture_errors", stage="stop").inc()
        instrument.enable(tracing=prev[0], annotations=prev[1])
