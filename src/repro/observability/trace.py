"""Span tracer: nested timed regions exportable as Chrome trace JSON.

    from repro.observability import trace

    with trace.span("serve.flush", bucket="64x64") as sp:
        out = solve(batch)
        sp.sync(out)            # block_until_ready ONLY while tracing

    trace.export_chrome_trace("trace.json")   # load in chrome://tracing

Design points:

  * **Disabled = no-op.**  When tracing is off, :func:`span` returns a
    shared ``_NullSpan`` singleton — no clock reads, no allocation, no
    device sync.  The disabled path is one flag test, which the
    overhead-budget test in tests/test_observability.py holds to < 1%
    of the tiled 256² solve.
  * **JAX-aware sync.**  ``sp.sync(x)`` calls ``jax.block_until_ready``
    so the span measures device work, not dispatch — but skips it for
    abstract tracers (spans inside a ``jit`` trace must not try to
    block on values that don't exist yet).
  * **Correct nesting.**  A thread-local stack gives every span a
    parent; depths and parent ids survive into the export, and
    :func:`tree` renders the hierarchy as text.

Export is the Chrome trace-event format: ``{"traceEvents": [...]}``
with ``ph: "X"`` complete events, microsecond ``ts``/``dur``, ``pid`` /
``tid``, and span labels in ``args``.
"""

from __future__ import annotations

import functools
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

from . import instrument

__all__ = [
    "Span",
    "chrome_trace",
    "clear",
    "export_chrome_trace",
    "span",
    "spans",
    "traced",
    "tree",
]

_EVENTS: List["Span"] = []
_EVENTS_LOCK = threading.Lock()
_TLS = threading.local()
_IDS = iter(range(1, 1 << 62))


def _stack() -> List["Span"]:
    s = getattr(_TLS, "stack", None)
    if s is None:
        s = _TLS.stack = []
    return s


class Span:
    """One timed region.  Create via :func:`span`, not directly."""

    __slots__ = ("name", "labels", "sid", "parent_sid", "depth", "tid",
                 "t_start", "t_end")

    def __init__(self, name: str, labels: Dict[str, Any]) -> None:
        self.name = name
        self.labels = labels
        self.sid = next(_IDS)
        self.parent_sid: Optional[int] = None
        self.depth = 0
        self.tid = threading.get_ident()
        self.t_start = 0.0
        self.t_end = 0.0

    @property
    def duration_us(self) -> float:
        return (self.t_end - self.t_start) * 1e6

    def set(self, **labels: Any) -> "Span":
        self.labels.update(labels)
        return self

    def sync(self, value: Any) -> Any:
        """Block until ``value``'s arrays are ready (skipping abstract
        tracers), so the span covers device execution.  Returns value."""
        import jax

        if not isinstance(value, jax.core.Tracer):
            try:
                jax.block_until_ready(value)
            except Exception:
                pass  # non-array pytree leaves, tracers nested in pytrees
        return value

    def __enter__(self) -> "Span":
        stack = _stack()
        if stack:
            parent = stack[-1]
            self.parent_sid = parent.sid
            self.depth = parent.depth + 1
        stack.append(self)
        self.t_start = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.t_end = time.perf_counter()
        stack = _stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:  # tolerate out-of-order exits
            stack.remove(self)
        with _EVENTS_LOCK:
            _EVENTS.append(self)


class _NullSpan:
    """Shared do-nothing span: the disabled-mode fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        pass

    def set(self, **labels: Any) -> "_NullSpan":
        return self

    def sync(self, value: Any) -> Any:
        return value


_NULL_SPAN = _NullSpan()


def span(name: str, **labels: Any):
    """Context manager timing a region.  No-op singleton when disabled."""
    if not instrument.tracing_enabled():
        return _NULL_SPAN
    return Span(name, labels)


def traced(name: Optional[str] = None, **labels: Any):
    """Decorator form: ``@traced()`` or ``@traced("custom.name")``."""

    def deco(fn):
        span_name = name or f"{fn.__module__}.{fn.__qualname__}"

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not instrument.tracing_enabled():
                return fn(*args, **kwargs)
            with Span(span_name, dict(labels)):
                return fn(*args, **kwargs)

        return wrapper

    return deco


def spans() -> List[Span]:
    """Completed spans, in completion order."""
    with _EVENTS_LOCK:
        return list(_EVENTS)


def clear() -> None:
    with _EVENTS_LOCK:
        _EVENTS.clear()


def chrome_trace() -> Dict[str, Any]:
    """Chrome trace-event JSON object for all completed spans."""
    pid = os.getpid()
    events = []
    for sp in spans():
        events.append({
            "name": sp.name,
            "ph": "X",
            "ts": sp.t_start * 1e6,
            "dur": sp.duration_us,
            "pid": pid,
            "tid": sp.tid,
            "args": {str(k): _jsonable(v) for k, v in sp.labels.items()},
        })
    events.sort(key=lambda e: e["ts"])
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def export_chrome_trace(path: str) -> str:
    """Write :func:`chrome_trace` JSON to ``path``; returns the path."""
    with open(path, "w") as f:
        json.dump(chrome_trace(), f, indent=1)
    return path


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def tree(max_spans: int = 200) -> str:
    """Text rendering of the span hierarchy (start-time ordered)."""
    all_spans = sorted(spans(), key=lambda s: s.t_start)[:max_spans]
    if not all_spans:
        return "(no spans recorded — is observability enabled?)"
    lines = []
    for sp in all_spans:
        label = " ".join(f"{k}={v}" for k, v in sp.labels.items())
        lines.append(f"{'  ' * sp.depth}{sp.name:<40s} "
                     f"{sp.duration_us:12.1f} us"
                     + (f"  [{label}]" if label else ""))
    return "\n".join(lines)
