"""Observability master switches — one place every instrumented call
site checks before doing any work.

The layer is **off by default**: with tracing disabled, span context
managers are shared no-op singletons (no timestamps, no allocation, no
``jax.block_until_ready``), and profiler annotations are
``contextlib.nullcontext`` (so jitted programs trace the *identical*
jaxpr — pinned in tests/test_engine.py).  Metrics counters are always
live: they are plain dict increments, cheap enough to be the substrate
``QRService.stats()`` sits on, and the serving tests rely on them
unconditionally.

Switch surface (re-exported from :mod:`repro.observability`):

  * :func:`enable` / :func:`disable` — flip tracing (+ profiler
    annotations) on or off; ``enable(annotations=False)`` keeps jitted
    programs annotation-free while host spans record.
  * :func:`tracing_enabled` / :func:`annotations_enabled` — the fast
    flags call sites read (one attribute load + bool test).
  * :func:`enabled_scope` — context manager for tests and short
    captures; restores the prior state on exit.
  * ``REPRO_OBSERVABILITY=1`` in the environment enables tracing at
    import time (the CI capture hook).

Annotations are read at **trace time**: jitted programs compiled while
annotations were off keep their unannotated lowering until retraced, so
enable observability *before* first use (or before AOT-compiling
serving plans) to see kernel names in XLA/Perfetto profiles.
"""

from __future__ import annotations

import contextlib
import os
import threading

__all__ = [
    "annotations_enabled",
    "disable",
    "enable",
    "enabled_scope",
    "tracing_enabled",
]


class _State:
    """Mutable flag holder; attribute reads are the disabled fast path."""

    __slots__ = ("tracing", "annotations")

    def __init__(self) -> None:
        self.tracing = False
        self.annotations = False


_STATE = _State()
_LOCK = threading.Lock()


def tracing_enabled() -> bool:
    """Are host-side spans (and their JAX syncs) recording?"""
    return _STATE.tracing


def annotations_enabled() -> bool:
    """Should jitted code pick up ``jax.named_scope`` kernel names?"""
    return _STATE.annotations


def enable(*, tracing: bool = True, annotations: bool = True) -> None:
    """Turn the observability layer on (both planes by default)."""
    with _LOCK:
        _STATE.tracing = bool(tracing)
        _STATE.annotations = bool(annotations)


def disable() -> None:
    """Back to the zero-overhead default: no spans, no annotations."""
    with _LOCK:
        _STATE.tracing = False
        _STATE.annotations = False


@contextlib.contextmanager
def enabled_scope(*, tracing: bool = True, annotations: bool = True):
    """Enable within a ``with`` block, restoring the prior state after
    (test- and capture-friendly; nests correctly)."""
    prev = (_STATE.tracing, _STATE.annotations)
    enable(tracing=tracing, annotations=annotations)
    try:
        yield
    finally:
        with _LOCK:
            _STATE.tracing, _STATE.annotations = prev


if os.environ.get("REPRO_OBSERVABILITY", "").strip() in ("1", "true", "on"):
    enable()
