"""Process-global metrics registry: counters, gauges, histograms.

Zero dependencies, thread-safe, always-on (increments are two dict
lookups and an add — cheap enough that ``QRService.stats()`` is a thin
view over this registry).  Metrics are *labeled*: each metric name owns
a family of series keyed by a sorted ``(key, value)`` label tuple, so
two ``QRService`` instances (``service="qr-3"`` vs ``service="qr-4"``)
or two phases (``phase="trace"`` vs ``phase="execute"``) never collide.

    from repro.observability import metrics
    metrics.counter("engine.dispatches").inc(3)
    metrics.counter("planner.fallbacks", reason="tiled_min_dim_cpu_floor").inc()
    metrics.histogram("service.flush_latency_us").observe(1234.0)

Export:

  * :func:`snapshot` — plain-dict form (JSON-ready), used by the
    benchmark records and ``observability.report``.
  * :func:`to_prometheus` — Prometheus text exposition format.
  * :func:`reset` — drop all series (test isolation).

Histograms keep fixed log-spaced bucket counts plus exact
count/sum/min/max, and estimate percentiles from the bucket CDF —
bounded memory under million-request serving loads.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "counter",
    "gauge",
    "histogram",
    "reset",
    "snapshot",
    "to_prometheus",
]

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically increasing count for one labeled series."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.RLock) -> None:
        self._lock = lock
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a gauge")
        with self._lock:
            self.value += amount


class Gauge:
    """A value that can go up or down (queue depth, cache size)."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.RLock) -> None:
        self._lock = lock
        self.value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value -= amount


# Default buckets: log-spaced from 1 to 1e9 (covers microsecond
# latencies through multi-kilosecond runs and byte counts into the GB).
_DEFAULT_BUCKETS = tuple(10.0 ** (e / 3.0) for e in range(0, 28))


class Histogram:
    """Fixed-bucket histogram with exact count/sum/min/max.

    Percentiles interpolate within the matched bucket, so they are
    estimates (exact only when observations coincide with bounds) —
    the right trade for an always-on registry.
    """

    __slots__ = ("_lock", "bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, lock: threading.RLock,
                 bounds: Tuple[float, ...] = _DEFAULT_BUCKETS) -> None:
        self._lock = lock
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)  # +inf overflow bucket
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            i = 0
            for i, b in enumerate(self.bounds):
                if v <= b:
                    break
            else:
                i = len(self.bounds)
            self.counts[i] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Estimate the q-th percentile (q in [0, 100]) from bucket CDF."""
        with self._lock:
            if not self.count:
                return 0.0
            target = self.count * min(max(q, 0.0), 100.0) / 100.0
            seen = 0
            for i, c in enumerate(self.counts):
                seen += c
                if seen >= target and c:
                    lo = self.bounds[i - 1] if i > 0 else (
                        self.min if self.min != math.inf else 0.0)
                    hi = self.bounds[i] if i < len(self.bounds) else self.max
                    lo = max(lo, self.min)
                    hi = min(hi, self.max)
                    if hi < lo:
                        lo, hi = hi, hi
                    frac = (target - (seen - c)) / c
                    return lo + (hi - lo) * frac
            return self.max


class MetricsRegistry:
    """Name → {labelkey → instrument} map behind one RLock."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._counters: Dict[str, Dict[LabelKey, Counter]] = {}
        self._gauges: Dict[str, Dict[LabelKey, Gauge]] = {}
        self._histograms: Dict[str, Dict[LabelKey, Histogram]] = {}

    def _series(self, table, name: str, labels: Dict[str, object], factory):
        key = _label_key(labels)
        fam = table.get(name)
        if fam is not None:
            inst = fam.get(key)
            if inst is not None:
                return inst
        with self._lock:
            fam = table.setdefault(name, {})
            inst = fam.get(key)
            if inst is None:
                inst = factory(self._lock)
                fam[key] = inst
            return inst

    def counter(self, name: str, **labels: object) -> Counter:
        return self._series(self._counters, name, labels, Counter)

    def gauge(self, name: str, **labels: object) -> Gauge:
        return self._series(self._gauges, name, labels, Gauge)

    def histogram(self, name: str, buckets: Optional[Iterable[float]] = None,
                  **labels: object) -> Histogram:
        if buckets is not None:
            bounds = tuple(sorted(float(b) for b in buckets))
            return self._series(self._histograms, name, labels,
                                lambda lock: Histogram(lock, bounds))
        return self._series(self._histograms, name, labels, Histogram)

    def counter_value(self, name: str, **labels: object) -> float:
        """Read a counter without creating it (0.0 if absent)."""
        fam = self._counters.get(name)
        if not fam:
            return 0.0
        inst = fam.get(_label_key(labels))
        return inst.value if inst is not None else 0.0

    def counter_total(self, name: str, **labels: object) -> float:
        """Sum a counter family over series matching the given labels."""
        fam = self._counters.get(name)
        if not fam:
            return 0.0
        want = set(_label_key(labels))
        with self._lock:
            return sum(c.value for key, c in fam.items()
                       if want <= set(key))

    def snapshot(self) -> Dict[str, object]:
        """JSON-ready dump of every series (histograms summarized)."""
        with self._lock:
            out: Dict[str, object] = {"counters": {}, "gauges": {},
                                      "histograms": {}}
            for name, fam in sorted(self._counters.items()):
                out["counters"][name] = [
                    {"labels": dict(k), "value": c.value}
                    for k, c in sorted(fam.items())]
            for name, fam in sorted(self._gauges.items()):
                out["gauges"][name] = [
                    {"labels": dict(k), "value": g.value}
                    for k, g in sorted(fam.items())]
            for name, fam in sorted(self._histograms.items()):
                out["histograms"][name] = [
                    {"labels": dict(k), "count": h.count, "sum": h.sum,
                     "mean": h.mean,
                     "min": h.min if h.count else 0.0,
                     "max": h.max if h.count else 0.0,
                     "p50": h.percentile(50), "p90": h.percentile(90),
                     "p99": h.percentile(99)}
                    for k, h in sorted(fam.items())]
            return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (names get _total/_sum/...)."""

        def fmt_labels(key: LabelKey, extra: Tuple[Tuple[str, str], ...] = ()):
            items = key + extra
            if not items:
                return ""
            inner = ",".join(f'{k}="{v}"' for k, v in items)
            return "{" + inner + "}"

        def sanitize(name: str) -> str:
            return "".join(c if c.isalnum() or c == "_" else "_"
                           for c in name)

        lines: List[str] = []
        with self._lock:
            for name, fam in sorted(self._counters.items()):
                pname = sanitize(name) + "_total"
                lines.append(f"# TYPE {pname} counter")
                for key, c in sorted(fam.items()):
                    lines.append(f"{pname}{fmt_labels(key)} {c.value:g}")
            for name, fam in sorted(self._gauges.items()):
                pname = sanitize(name)
                lines.append(f"# TYPE {pname} gauge")
                for key, g in sorted(fam.items()):
                    lines.append(f"{pname}{fmt_labels(key)} {g.value:g}")
            for name, fam in sorted(self._histograms.items()):
                pname = sanitize(name)
                lines.append(f"# TYPE {pname} histogram")
                for key, h in sorted(fam.items()):
                    cum = 0
                    for b, c in zip(h.bounds, h.counts):
                        cum += c
                        lines.append(
                            f"{pname}_bucket"
                            f"{fmt_labels(key, (('le', f'{b:g}'),))} {cum}")
                    lines.append(
                        f"{pname}_bucket"
                        f"{fmt_labels(key, (('le', '+Inf'),))} {h.count}")
                    lines.append(f"{pname}_sum{fmt_labels(key)} {h.sum:g}")
                    lines.append(f"{pname}_count{fmt_labels(key)} {h.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


REGISTRY = MetricsRegistry()


def counter(name: str, **labels: object) -> Counter:
    return REGISTRY.counter(name, **labels)


def gauge(name: str, **labels: object) -> Gauge:
    return REGISTRY.gauge(name, **labels)


def histogram(name: str, buckets: Optional[Iterable[float]] = None,
              **labels: object) -> Histogram:
    return REGISTRY.histogram(name, buckets, **labels)


def counter_value(name: str, **labels: object) -> float:
    return REGISTRY.counter_value(name, **labels)


def counter_total(name: str, **labels: object) -> float:
    return REGISTRY.counter_total(name, **labels)


def snapshot() -> Dict[str, object]:
    return REGISTRY.snapshot()


def to_prometheus() -> str:
    return REGISTRY.to_prometheus()


def reset() -> None:
    REGISTRY.reset()
