"""Render (or capture) an observability report.

Render a previously exported capture:

    python -m repro.observability.report --trace trace.json
    python -m repro.observability.report --metrics metrics.json

Run an instrumented smoke workload (planner explains incl. a fallback,
a serving mix through ``QRService``) and write + render the artifacts —
this is what the CI observability job archives:

    python -m repro.observability.report --capture out_dir/

With no arguments, renders whatever the current process has recorded.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, Optional


def _render_trace(doc: Dict[str, Any]) -> str:
    events = sorted(doc.get("traceEvents", []), key=lambda e: e.get("ts", 0))
    if not events:
        return "(empty trace)"
    t0 = events[0]["ts"]
    # Rebuild nesting from containment: an event is a child of the most
    # recent event (per tid) whose [ts, ts+dur] interval encloses it.
    lines = ["trace tree (ts offsets in us):"]
    open_stack: Dict[Any, list] = {}
    for ev in events:
        tid = ev.get("tid", 0)
        stack = open_stack.setdefault(tid, [])
        end = ev["ts"] + ev.get("dur", 0.0)
        while stack and stack[-1] < ev["ts"] + 1e-9:
            stack.pop()
        depth = len(stack)
        stack.append(end)
        args = ev.get("args") or {}
        label = " ".join(f"{k}={v}" for k, v in args.items())
        lines.append(f"  {ev['ts'] - t0:12.1f}  {'  ' * depth}"
                     f"{ev.get('name', '?'):<40s} {ev.get('dur', 0):10.1f} us"
                     + (f"  [{label}]" if label else ""))
    return "\n".join(lines)


def _render_metrics(snap: Dict[str, Any]) -> str:
    lines = ["metrics snapshot:"]
    for name, series in sorted((snap.get("counters") or {}).items()):
        for s in series:
            label = ",".join(f"{k}={v}" for k, v in
                             sorted((s.get("labels") or {}).items()))
            lines.append(f"  counter   {name}{'{' + label + '}' if label else ''}"
                         f" = {s['value']:g}")
    for name, series in sorted((snap.get("gauges") or {}).items()):
        for s in series:
            label = ",".join(f"{k}={v}" for k, v in
                             sorted((s.get("labels") or {}).items()))
            lines.append(f"  gauge     {name}{'{' + label + '}' if label else ''}"
                         f" = {s['value']:g}")
    for name, series in sorted((snap.get("histograms") or {}).items()):
        for s in series:
            label = ",".join(f"{k}={v}" for k, v in
                             sorted((s.get("labels") or {}).items()))
            lines.append(
                f"  histogram {name}{'{' + label + '}' if label else ''}"
                f" count={s['count']} mean={s['mean']:.1f}"
                f" p50={s['p50']:.1f} p99={s['p99']:.1f}"
                f" max={s['max']:.1f}")
    if len(lines) == 1:
        lines.append("  (empty)")
    return "\n".join(lines)


def _capture_smoke(out_dir: str) -> Dict[str, str]:
    """Run an instrumented smoke workload; write trace + metrics files."""
    import numpy as np

    from repro import observability as obs
    from repro.core import QRConfig, plan
    from repro.serving import BucketingPolicy, QRService

    os.makedirs(out_dir, exist_ok=True)
    obs.enable()
    obs.trace.clear()

    with obs.span("smoke.capture"):
        # Planner explains: a routed shape, plus one that trips the CPU
        # floor fallback and one that degrades sharded -> d=1.
        with obs.span("smoke.plan"):
            for shape, cfg in [
                ((512, 512), QRConfig()),
                ((300, 280), QRConfig()),          # CPU floor fallback
                ((1024, 1024), QRConfig(method="sharded_tiled", block=64)),
            ]:
                sol = plan(shape, config=cfg, explain=True)
                rec = sol.explain
                print(f"plan{shape}: method={sol.config.method} "
                      f"dispatch={rec.dispatch_mode if rec else '?'} "
                      f"fallbacks={list(rec.fallback_reasons) if rec else []}")

        # Serving mix: bucket -> pad -> dispatch -> unpad spans.
        with obs.span("smoke.serve"):
            rng = np.random.default_rng(0)
            service = QRService(policy=BucketingPolicy(tile=16, max_batch=8),
                                use_kernel=False)
            mix = [rng.standard_normal(s).astype(np.float32)
                   for s in [(48, 48), (45, 41), (96, 32), (48, 48),
                             (37, 23), (64, 64)]]
            results = service.submit_many(mix)
            with obs.span("smoke.check") as sp:
                for res in results:
                    sp.sync((res.q, res.r))
            service.submit_many(mix)  # warm-cache pass

    trace_path = os.path.join(out_dir, "trace.json")
    metrics_path = os.path.join(out_dir, "metrics.json")
    prom_path = os.path.join(out_dir, "metrics.prom")
    obs.export_chrome_trace(trace_path)
    with open(metrics_path, "w") as f:
        json.dump(obs.snapshot(), f, indent=1)
    with open(prom_path, "w") as f:
        f.write(obs.metrics.to_prometheus())
    return {"trace": trace_path, "metrics": metrics_path, "prom": prom_path}


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.observability.report",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--trace", help="Chrome trace JSON file to render")
    ap.add_argument("--metrics", help="metrics snapshot JSON file to render")
    ap.add_argument("--capture", metavar="OUT_DIR",
                    help="run an instrumented smoke workload and write "
                         "trace.json + metrics.json + metrics.prom there")
    args = ap.parse_args(argv)

    if args.capture:
        paths = _capture_smoke(args.capture)
        with open(paths["trace"]) as f:
            print(_render_trace(json.load(f)))
        with open(paths["metrics"]) as f:
            print(_render_metrics(json.load(f)))
        print(f"wrote {', '.join(sorted(paths.values()))}")
        return 0

    rendered = False
    if args.trace:
        with open(args.trace) as f:
            print(_render_trace(json.load(f)))
        rendered = True
    if args.metrics:
        with open(args.metrics) as f:
            print(_render_metrics(json.load(f)))
        rendered = True
    if not rendered:
        from repro import observability as obs

        print(obs.tree())
        print(_render_metrics(obs.snapshot()))
    return 0


if __name__ == "__main__":
    sys.exit(main())
