import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import — jax locks the device
count at first init, and the production meshes need 512 host devices.
(Only this entry point does so; tests and benches see 1 device.)

Per cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=...).lower(*input_specs(...))
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for the roofline

plus a collective-bytes pass over the optimized HLO (all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute operand
sums — cost_analysis does not report these).  One JSON artifact per cell
lands in ``--out`` for launch/roofline.py and EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
"""

import argparse
import json
import re
import subprocess
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh, make_rules
from repro.launch.specs import cell_is_skipped, input_specs
from repro.distributed.sharding import activation_policy, tree_shardings

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    """Sum the byte sizes of every 'dtype[dims]' in a result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COLL_RE = re.compile(r"%?[\w.\-]+\s*=\s*(.+?)\s+(" + "|".join(_COLLECTIVES)
                      + r")(?:-start|-done)?\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?(?:condition|cond)=%?([\w.\-]+),\s*"
                       r"body=%?([\w.\-]+)")
_WHILE_RE2 = re.compile(r"while\(.*?body=%?([\w.\-]+),\s*"
                        r"(?:condition|cond)=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|called_computations)=\{?%?([\w.\-]+)")
_CONST_RE = re.compile(r"%([\w.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)")
_ROOT_CMP_RE = re.compile(r"ROOT\s+%?[\w.\-]+\s*=\s*pred\[\]\s*compare\("
                          r"%?([\w.\-]+),\s*%?([\w.\-]+)\)")


def _parse_computations(hlo_text: str) -> tuple:
    """Split optimized HLO into computations; find ENTRY."""
    comps, entry, cur = {}, None, None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and not line.startswith(" "):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if cur is not None and line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line.strip())
    return comps, entry


def _trip_count(comp_lines: list) -> int:
    """Trip count of a while condition: the s32 constant in the ROOT
    compare (scan/fori loops compare the induction var against the bound).
    Falls back to 1 (don't multiply) when unrecognized."""
    consts = {}
    for ln in comp_lines:
        m = _CONST_RE.search(ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in comp_lines:
        m = _ROOT_CMP_RE.search(ln)
        if m:
            for op in (m.group(2), m.group(1)):
                if op in consts:
                    return max(1, consts[op])
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Collective operand bytes from optimized HLO — both static (each op
    once) and execution-weighted (x while trip counts, recovered from the
    loop-condition compare constants; scan bodies appear once in HLO but
    run n_periods x n_microbatch x ... times)."""
    comps, entry = _parse_computations(hlo_text)
    static = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    weighted = {k: 0.0 for k in _COLLECTIVES}

    def comp_collectives(name):
        out = []
        for ln in comps.get(name, ()):
            m = _COLL_RE.match(ln)
            if m:
                out.append((m.group(2), _shape_bytes(m.group(1))))
        return out

    visited_static = set()
    for name in comps:
        for kind, b in comp_collectives(name):
            static[kind] += b
            counts[kind] += 1

    def walk(name, mult, seen):
        if name not in comps or name in seen:
            return
        seen = seen | {name}
        for kind, b in comp_collectives(name):
            weighted[kind] += b * mult
        for ln in comps[name]:
            wm = _WHILE_RE.search(ln) or _WHILE_RE2.search(ln)
            if wm:
                a, b2 = wm.group(1), wm.group(2)
                cond, body = (a, b2) if _WHILE_RE.search(ln) else (b2, a)
                # XLA annotates analyzed loops directly:
                tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ln)
                trips = int(tm.group(1)) if tm else \
                    _trip_count(comps.get(cond, []))
                walk(body, mult * trips, seen)
                continue
            cm = _CALL_RE.search(ln)
            if cm and not _COLL_RE.match(ln):
                walk(cm.group(1), mult, seen)

    if entry:
        walk(entry, 1.0, set())
    total_weighted = sum(weighted.values())
    return {"bytes": static, "counts": counts,
            "total_bytes": sum(static.values()),
            "weighted_bytes": {k: float(v) for k, v in weighted.items()},
            "total_weighted_bytes": float(total_weighted)}


def _memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for name in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, name, None)
        if v is not None:
            out[name] = int(v)
    if not out:
        out["repr"] = repr(ma)
    return out


def _cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float))}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             save_hlo: bool = False, variant: str = "baseline") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if variant != "baseline":
        mesh_name += f"__{variant}"
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "variant": variant, "status": "unknown"}
    skip = cell_is_skipped(arch, shape_name)
    if skip:
        record.update(status="skipped", reason=skip)
        return _write(record, out_dir)

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        rules = make_rules(mesh)
        cell = input_specs(arch, shape_name, rules, variant=variant)
        rules = cell.rules or rules
        shardings = tuple(
            tree_shardings(s, mesh) if not isinstance(s, jax.sharding.PartitionSpec)
            else jax.NamedSharding(mesh, s)
            for s in cell.in_specs)
        with mesh, activation_policy(rules):
            # donate train state / decode caches: the functional update
            # aliases its input buffers (in-place on real hardware)
            out_shardings = None
            if cell.out_specs is not None:
                out_shardings = jax.tree.map(
                    lambda s: (jax.NamedSharding(mesh, s)
                               if isinstance(s, jax.sharding.PartitionSpec)
                               else s),
                    cell.out_specs,
                    is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
                    or x is None)
                out_shardings = tuple(out_shardings)
            jitted = jax.jit(cell.step_fn, in_shardings=shardings,
                             donate_argnums=cell.donate,
                             out_shardings=out_shardings)
            lowered = jitted.lower(*cell.args_sds)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = _memory_analysis_dict(compiled)
            cost = _cost_analysis_dict(compiled)
            print(f"[{arch} {shape_name} {mesh_name}] memory_analysis:",
                  {k: f"{v/2**30:.3f}GiB" for k, v in mem.items()
                   if isinstance(v, int)})
            print(f"[{arch} {shape_name} {mesh_name}] cost_analysis flops:",
                  cost.get("flops"))
            hlo = compiled.as_text()
            coll = collective_bytes(hlo)
        record.update(
            status="ok", kind=cell.kind, notes=cell.notes,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            devices=int(mesh.size), memory_analysis=mem, cost_analysis=cost,
            collectives=coll,
        )
        if save_hlo:
            os.makedirs(out_dir, exist_ok=True)
            hp = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.hlo")
            with open(hp, "w") as f:
                f.write(hlo)
            record["hlo_path"] = hp
    except Exception as e:
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    return _write(record, out_dir)


def _write(record: dict, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(record, f, indent=1)
    status = record["status"]
    extra = record.get("reason", record.get("error", ""))
    print(f"[dryrun] {record['arch']} x {record['shape']} x {record['mesh']}"
          f" -> {status} {extra[:200]}")
    return record


def run_all(out_dir: str, meshes: list, archs=None, shapes=None,
            jobs: int = 1) -> int:
    """Spawn one subprocess per cell (isolates compile memory)."""
    cells = []
    for arch in (archs or ARCHS):
        for shape in (shapes or SHAPES):
            for mp in meshes:
                cells.append((arch, shape, mp))
    failures = 0
    running = []
    for (arch, shape, mp) in cells:
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--out", out_dir]
        if mp:
            cmd.append("--multi-pod")
        running.append(((arch, shape, mp), subprocess.Popen(cmd)))
        while len(running) >= jobs:
            done = [(c, p) for c, p in running if p.poll() is not None]
            if not done:
                time.sleep(2)
                continue
            for c, p in done:
                running.remove((c, p))
                if p.returncode != 0:
                    failures += 1
                    print(f"[dryrun] SUBPROCESS FAILED: {c}")
    for c, p in running:
        if p.wait() != 0:
            failures += 1
            print(f"[dryrun] SUBPROCESS FAILED: {c}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--meshes", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "optimized", "optimized_nocast",
                             "optimized_noshard"])
    args = ap.parse_args()

    if args.all:
        meshes = {"single": [False], "multi": [True],
                  "both": [False, True]}[args.meshes]
        sys.exit(1 if run_all(args.out, meshes, jobs=args.jobs) else 0)

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    rec = run_cell(args.arch, args.shape, args.multi_pod, args.out,
                   save_hlo=args.save_hlo, variant=args.variant)
    sys.exit(0 if rec["status"] in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
