"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod
axis extends data parallelism across the ICI/DCN boundary.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — the dry-run sets
``xla_force_host_platform_device_count`` before first jax init and
nothing here may run earlier.
"""

from __future__ import annotations

import jax

from repro.distributed.sharding import MeshRules

__all__ = ["make_production_mesh", "make_rules", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = dict(shape=(16, 16), axes=("data", "model"))
MULTI_POD = dict(shape=(2, 16, 16), axes=("pod", "data", "model"))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_rules(mesh) -> MeshRules:
    """MeshRules for either production mesh (pod folds into data)."""
    if "pod" in mesh.axis_names:
        return MeshRules(mesh=mesh, data_axes=("pod", "data"))
    return MeshRules(mesh=mesh, data_axes=("data",))
