"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq 512 --optimizer muon-qr \
        --checkpoint-dir /tmp/ckpt [--smoke] [--mesh d,m] [--grad-compression]

``--smoke`` selects the reduced config (CPU-friendly); otherwise the full
assigned architecture is built (needs a real TPU slice).  ``--mesh d,m``
builds a (data, model) mesh over the visible devices and applies the
production sharding rules — on CPU combine with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for local
multi-device runs.
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.data import DataConfig
from repro.distributed.sharding import MeshRules, activation_policy
from repro.training import RunConfig, TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--optimizer", default="muon-qr",
                    choices=["muon-qr", "muon-ns", "adamw"])
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="data,model sizes, e.g. 4,2")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = rules = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        rules = MeshRules(mesh=mesh, data_axes=("data",))

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed,
                          embedding_input=cfg.embedding_input,
                          d_model=cfg.d_model)
    train_cfg = TrainConfig(optimizer=args.optimizer, lr=args.lr,
                            microbatch=args.microbatch,
                            grad_compression=args.grad_compression)
    run_cfg = RunConfig(total_steps=args.steps, warmup_steps=args.warmup,
                        checkpoint_dir=args.checkpoint_dir,
                        checkpoint_every=args.checkpoint_every,
                        seed=args.seed)

    trainer = Trainer(cfg, train_cfg, run_cfg, data_cfg, mesh=mesh,
                      rules=rules)
    if mesh is not None:
        with mesh, activation_policy(rules):
            result = trainer.run()
    else:
        result = trainer.run()
    print(json.dumps({"final_step": result["final_step"],
                      "last": result["history"][-1] if result["history"]
                      else None}))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(result, f, indent=1)


if __name__ == "__main__":
    main()
