"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

``input_specs(arch, shape)`` returns everything needed to lower the cell
WITHOUT allocating: the step callable, argument ShapeDtypeStructs, and
their PartitionSpec trees.  Train cells lower ``train_step``; prefill
cells lower ``forward_prefill``; decode cells lower ``serve_step``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import (
    MeshRules, batch_specs, cache_specs, param_specs, state_specs,
)
from repro.models import init_caches, init_params
from repro.models.transformer import forward_prefill
from repro.serving.engine import serve_step
from repro.training.train_step import TrainConfig, init_train_state, \
    make_train_step

__all__ = ["CellSpec", "input_specs", "cell_is_skipped", "train_microbatch"]

_KEY_SDS = jax.ShapeDtypeStruct((2,), jnp.uint32)


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape: ShapeConfig
    cfg: ModelConfig
    step_fn: Callable           # the callable to lower
    args_sds: Tuple[Any, ...]   # ShapeDtypeStruct pytrees
    in_specs: Tuple[Any, ...]   # PartitionSpec pytrees (same structure)
    kind: str                   # "train" | "prefill" | "decode"
    rules: Any = None           # MeshRules actually used (variant may adjust)
    donate: Tuple[int, ...] = ()  # donated arg indices (state / caches alias)
    out_specs: Any = None       # out_shardings (None = let XLA choose);
                                # required for donation to alias (the donated
                                # input and the output must shard identically)
    notes: str = ""


def cell_is_skipped(arch: str, shape_name: str) -> Optional[str]:
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention architecture: 500k-token decode needs "
                "sub-quadratic sequence mixing (DESIGN.md §7)")
    return None


def train_microbatch(cfg: ModelConfig, shape: ShapeConfig,
                     rules: MeshRules) -> int:
    """Global microbatch so one microbatch is ~1 sample per data shard for
    the big models (activation ceiling), larger for the small ones."""
    per_dev = 1 if cfg.d_model >= 2048 else 4
    return min(shape.global_batch, rules.data_size * per_dev)


def _batch_sds(cfg: ModelConfig, b: int, s: int, *, labels: bool) -> dict:
    out = {}
    if cfg.embedding_input:
        out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if labels:
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return out


def _bf16_tree(sds_tree):
    def cast(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        return s
    return jax.tree.map(cast, sds_tree)


_SMALL_MODEL_PARAMS = 4e9


def input_specs(arch: str, shape_name: str, rules: MeshRules,
                *, overrides: Optional[dict] = None,
                variant: str = "baseline") -> CellSpec:
    """``variant="optimized"`` applies the beyond-paper bundle logged in
    EXPERIMENTS.md §Perf: causal block skipping, solve-based thin-Q in the
    QR optimizer, once-per-step bf16 weight casts, 2x microbatch, and the
    no-TP/pure-DP sharding policy for sub-4B models."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.scaled(**overrides)
    if variant.startswith("optimized"):
        cfg = cfg.scaled(attn_causal_skip=True)

    params_sds = jax.eval_shape(lambda k: init_params(k, cfg), _KEY_SDS)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_sds))
    if variant.startswith("optimized") and n_params < _SMALL_MODEL_PARAMS:
        import dataclasses as _dc

        all_axes = tuple(rules.mesh.axis_names)
        rules = _dc.replace(rules, tp_enabled=False, batch_axes=all_axes)
    pspecs = param_specs(params_sds, rules)

    if shape.kind == "train":
        mb = train_microbatch(cfg, shape, rules)
        # (a 2x microbatch was tried and REVERTED: halves gather count but
        # doubles activation temp past the 16 GB budget — §Perf log)
        opt = variant.startswith("optimized")
        tcfg = TrainConfig(optimizer="muon-qr", microbatch=mb,
                           qr_q_method=("solve" if opt else "formq"),
                           cast_params_once=(variant == "optimized"),
                           qr_shard_leaves=(opt and "noshard" not in variant))
        state_sds = jax.eval_shape(
            lambda p: init_train_state(p, tcfg), params_sds)
        state_specs_tree = type(state_sds)(
            params=pspecs,
            opt=state_specs(params_sds, pspecs, state_sds.opt, rules),
            ef_error=P(),
        )
        batch = _batch_sds(cfg, shape.global_batch, shape.seq_len, labels=True)
        bspecs = batch_specs(batch, rules)
        lr_sds = jax.ShapeDtypeStruct((), jnp.float32)
        step = make_train_step(cfg, tcfg)
        return CellSpec(arch, shape, cfg, step,
                        (state_sds, batch, lr_sds),
                        (state_specs_tree, bspecs, P()),
                        "train", rules=rules, donate=(0,),
                        notes=f"microbatch={tcfg.microbatch};variant={variant}")

    serve_params = _bf16_tree(params_sds)

    if shape.kind == "prefill":
        batch = _batch_sds(cfg, shape.global_batch, shape.seq_len, labels=False)
        bspecs = batch_specs(batch, rules)
        step = lambda p, b: forward_prefill(p, b, cfg)
        return CellSpec(arch, shape, cfg, step, (serve_params, batch),
                        (pspecs, bspecs), "prefill", rules=rules,
                        notes=f"variant={variant}")

    # decode: one token against a full-length cache
    caches_sds = jax.eval_shape(
        lambda: init_caches(cfg, shape.global_batch, shape.seq_len))
    cspecs = cache_specs(caches_sds, rules)
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tok_spec = batch_specs(tok, rules)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    step = lambda p, t, c, i: serve_step(p, t, cfg, c, i)
    return CellSpec(arch, shape, cfg, step,
                    (serve_params, tok, caches_sds, pos),
                    (pspecs, tok_spec, cspecs, P()), "decode", rules=rules,
                    donate=(2,), out_specs=(None, cspecs),
                    notes=f"variant={variant}")
