"""Roofline analysis: compute / memory / collective terms per dry-run cell.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

    compute_s    = FLOPs / (chips * 197e12)
    memory_s     = HBM_bytes / (chips * 819e9)
    collective_s = collective_bytes / (chips * 50e9)

FLOPs and HBM bytes are ANALYTIC, derived from the architecture and cell
shape: ``compiled.cost_analysis()`` counts every ``lax.scan`` body once
(layer stack, microbatch accumulation, attention chunks), so its raw
numbers undercount by the trip counts — we report them alongside for
reference, with the analytic model as the roofline source (the
MODEL_FLOPS ratio makes the bookkeeping auditable).  Collective bytes
come from the compiled HLO (per-shard operand sums x chips).

Conventions (documented per DESIGN.md):
  * attention FLOPs count the chunked implementation as written — full
    S^2 masked blocks (the causal-skip optimization is a §Perf item);
  * training FLOPs = 4x forward under full remat ("nothing" policy:
    1 fwd + 1 recompute-fwd + ~2 bwd) + optimizer QR cost;
  * MODEL_FLOPS = 6 * N_active * D (the napkin number) — the ratio
    MODEL/HLO exposes remat, attention and capacity-factor overheads.
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import math
import os
from typing import Optional

import numpy as np

from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link / chip

__all__ = ["analytic_cell_cost", "roofline_row", "build_table", "main",
           "modeled_seconds", "qr_flops"]


# ----------------------------------------------------- generic roofline

def qr_flops(m: int, n: int) -> float:
    """Householder QR flop count: ``2 k^2 (max(m, n) - k/3)`` with
    ``k = min(m, n)`` — the effective-GFLOPs convention the QR benches
    use, here shared with the tuner's candidate pruning."""
    k = min(m, n)
    return 2.0 * k * k * (max(m, n) - k / 3.0)


def modeled_seconds(flops: float, hbm_bytes: float, *,
                    chips: int = 1) -> float:
    """Roofline lower bound on one kernel: the dominant of the compute
    and HBM terms under the per-chip hardware model above.  Absolute
    numbers are TPU-calibrated; the tuner uses it *relatively* (prune
    candidates whose bound already loses by a wide factor), where the
    asymptotics carry over across backends."""
    return max(flops / (chips * PEAK_FLOPS), hbm_bytes / (chips * HBM_BW))


# ------------------------------------------------------------- flop model

def _attn_flops(cfg: ModelConfig, t: int, s_ctx: int,
                window: Optional[int] = None) -> float:
    """One attention layer on t query tokens against s_ctx keys."""
    d, dq, dkv = cfg.d_model, cfg.d_q, cfg.d_kv
    proj = 2 * t * d * (dq + 2 * dkv) + 2 * t * dq * d
    frac = 1.0
    if getattr(cfg, "attn_causal_skip", False) and t > 1:
        c = max(cfg.seq_chunk, 1024)
        nk = max(1, s_ctx // c)
        if window is not None:
            frac = min(1.0, (window / c + 2) / nk)
        else:
            frac = (nk + 1) / (2.0 * nk)    # lower-triangular blocks only
    scores_av = 4 * t * s_ctx * dq * frac   # QK^T + AV
    return proj + scores_av


def _ffn_flops(cfg: ModelConfig, t: int) -> float:
    mats = 3 if cfg.ffn_act in ("swiglu", "geglu") else 2
    return 2 * mats * t * cfg.d_model * cfg.d_ff


def _moe_flops(cfg: ModelConfig, t: int) -> float:
    moe = cfg.moe
    cap = max(8, min(t, math.ceil(t * moe.top_k / moe.num_experts
                                  * moe.capacity_factor + 7) // 8 * 8))
    mats = 3 if cfg.ffn_act in ("swiglu", "geglu") else 2
    routed = 2 * mats * (moe.num_experts * cap) * cfg.d_model * moe.d_expert
    shared = 2 * mats * t * cfg.d_model * (moe.num_shared * moe.d_expert)
    router = 2 * t * cfg.d_model * moe.num_experts
    return routed + shared + router


def _mamba_flops(cfg: ModelConfig, t: int) -> float:
    d = cfg.d_model
    di = cfg.d_inner or 2 * d
    ds = cfg.d_state
    dtr = cfg.dt_rank or math.ceil(d / 16)
    proj = 2 * t * d * 2 * di + 2 * t * di * d
    conv = 2 * t * di * cfg.conv_kernel
    ssm_in = 2 * t * di * (dtr + 2 * ds) + 2 * t * dtr * di
    scan = 8 * t * di * ds
    return proj + conv + ssm_in + scan


def _mlstm_flops(cfg: ModelConfig, t: int) -> float:
    d = cfg.d_model
    di = int(cfg.mlstm_proj_factor * d)
    h = cfg.n_heads
    dh = di // h
    proj = 2 * t * d * 2 * di + 2 * t * di * d
    qkv = 3 * 2 * t * di * dh                  # block-diagonal per head
    cell = 6 * t * h * dh * dh
    return proj + qkv + cell + 2 * t * di * cfg.conv_kernel


def _slstm_flops(cfg: ModelConfig, t: int) -> float:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    gates = 2 * t * d * 4 * d
    rec = 2 * t * h * dh * 4 * dh
    ffn_dim = int(round(cfg.slstm_ffn_factor * d / 64) * 64)
    return gates + rec + 2 * t * d * d + 6 * t * d * ffn_dim + \
        2 * t * d * cfg.conv_kernel


def _layer_flops(cfg: ModelConfig, spec, t: int, s_ctx: int) -> float:
    mixer = {
        "attn": lambda: _attn_flops(cfg, t, s_ctx),
        "attn_local": lambda: _attn_flops(cfg, t, s_ctx, window=cfg.window),
        "mamba": lambda: _mamba_flops(cfg, t),
        "mlstm": lambda: _mlstm_flops(cfg, t),
        "slstm": lambda: _slstm_flops(cfg, t),
    }[spec.mixer]()
    ffn = {"dense": lambda: _ffn_flops(cfg, t),
           "moe": lambda: _moe_flops(cfg, t),
           "none": lambda: 0.0}[spec.ffn]()
    return mixer + ffn


def _param_counts(cfg: ModelConfig) -> tuple:
    """(total, active) parameter counts — analytic, no allocation."""
    import jax

    from repro.models import active_param_count, init_params, param_count

    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), np.uint32))
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    active = active_param_count(shapes, cfg)
    return total, active


def _qr_optimizer_flops(cfg: ModelConfig) -> float:
    """QR-Muon orthogonalization cost per step (DESIGN.md §3): blocked MHT
    QR (~4 m n^2 with the masked full-width fori) + thin-Q formation."""
    import jax

    from repro.models import init_params
    from repro.optim.qr_muon import is_muon_param

    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), np.uint32))
    total = 0.0
    for path, leaf in jax.tree_util.tree_leaves_with_path(shapes):
        if not is_muon_param(path, leaf):
            continue
        lead = int(np.prod(leaf.shape[:-2], initial=1))
        m, n = sorted(leaf.shape[-2:], reverse=True)
        total += lead * 8.0 * m * n * n
    return total


@dataclasses.dataclass
class CellCost:
    flops: float
    hbm_bytes: float
    model_flops: float
    params_total: int
    params_active: int
    tokens: int


def analytic_cell_cost(cfg: ModelConfig, shape: ShapeConfig,
                       kind: str) -> CellCost:
    n_total, n_active = _param_counts(cfg)
    b, s = shape.global_batch, shape.seq_len

    if kind == "decode":
        t, s_ctx, d_tokens = b, s, b
    else:
        t, s_ctx, d_tokens = b * s, s, b * s

    fwd = 0.0
    per_period = cfg.n_layers // len(cfg.period)
    for spec in cfg.period:
        fwd += per_period * _layer_flops(cfg, spec, t, s_ctx)
    head_tokens = b if kind == "prefill" else t
    fwd += 2 * head_tokens * cfg.d_model * cfg.vocab_size
    if cfg.embedding_input and kind != "decode":
        fwd += 2 * t * cfg.d_model * cfg.d_model  # adapter

    if kind == "train":
        flops = 4.0 * fwd + _qr_optimizer_flops(cfg)
        model_flops = 6.0 * n_active * d_tokens
    else:
        flops = fwd
        model_flops = 2.0 * n_active * d_tokens

    # ----------------------------------------------------- traffic model
    if kind == "train":
        # fp32 params+grads+opt read/write (~28 N) + bf16 weight casts per
        # microbatch + activations ~10 passes of (T, d) per layer
        n_micro = 1
        hbm = 28.0 * n_total + 10.0 * cfg.n_layers * t * cfg.d_model * 2
        hbm += 2.0 * n_total * n_micro
    elif kind == "prefill":
        cache = 2 * sum(1 for sp in cfg.period if "attn" in sp.mixer) \
            * per_period * t * cfg.d_kv * 2
        hbm = 2.0 * n_active_traffic(cfg, n_total) + \
            6.0 * cfg.n_layers * t * cfg.d_model * 2 + cache
    else:  # decode: params + full cache read dominate
        n_attn = sum(1 for sp in cfg.period if "attn" in sp.mixer) * per_period
        cache = 2 * n_attn * b * s * cfg.d_kv * 2
        state = _state_bytes(cfg, b)
        hbm = 2.0 * n_active_traffic(cfg, n_total) + cache + state

    return CellCost(flops=flops, hbm_bytes=hbm, model_flops=model_flops,
                    params_total=n_total, params_active=n_active,
                    tokens=d_tokens)


def n_active_traffic(cfg: ModelConfig, n_total: int) -> float:
    """Weights actually read per step (MoE: top-k of expert weights are
    touched per token, but with E*C dispatch all experts stream once)."""
    return float(n_total)


def _state_bytes(cfg: ModelConfig, b: int) -> float:
    per_period = cfg.n_layers // len(cfg.period)
    total = 0.0
    for sp in cfg.period:
        if sp.mixer == "mamba":
            di = cfg.d_inner or 2 * cfg.d_model
            total += per_period * b * di * cfg.d_state * 4 * 2
        elif sp.mixer == "mlstm":
            di = int(cfg.mlstm_proj_factor * cfg.d_model)
            dh = di // cfg.n_heads
            total += per_period * b * cfg.n_heads * dh * dh * 4 * 2
        elif sp.mixer == "slstm":
            total += per_period * b * cfg.d_model * 4 * 8
    return total


# ------------------------------------------------------------- table

def roofline_row(artifact: dict, *, chips: Optional[int] = None) -> dict:
    arch, shape_name = artifact["arch"], artifact["shape"]
    cfg = get_config(arch)
    if artifact.get("variant") == "optimized":
        cfg = cfg.scaled(attn_causal_skip=True)
    shape = SHAPES[shape_name]
    kind = artifact.get("kind", shape.kind)
    chips = chips or artifact.get("devices", 256)
    cost = analytic_cell_cost(cfg, shape, kind)

    # collective bytes in the HLO are per-shard; execution-weighted counts
    # (x while trip counts) when available, else static
    coll = artifact.get("collectives", {})
    coll_per_shard = coll.get("total_weighted_bytes") or coll.get("total_bytes", 0)
    compute_s = cost.flops / (chips * PEAK_FLOPS)
    memory_s = cost.hbm_bytes / (chips * HBM_BW)
    collective_s = coll_per_shard / ICI_BW      # per-chip link time
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    # THE score: useful-FLOP utilization achievable under the dominant
    # roofline term (perfect-overlap assumption) — "what MFU could this
    # cell reach".  Raising it means either shrinking the dominant
    # non-compute term or shrinking compute waste (remat, masked attention
    # blocks, MoE capacity padding).
    mfu_bound = (cost.model_flops / (chips * PEAK_FLOPS * bound_s)
                 if bound_s > 0 else 0.0)
    row = dict(
        arch=arch, shape=shape_name, mesh=artifact["mesh"], kind=kind,
        status=artifact["status"], chips=chips,
        flops=cost.flops, hbm_bytes=cost.hbm_bytes,
        collective_bytes_per_shard=coll_per_shard,
        **{k: v for k, v in terms.items()},
        dominant=dominant.replace("_s", ""),
        roofline_fraction=mfu_bound,
        compute_share=compute_s / bound_s if bound_s > 0 else 0.0,
        model_flops=cost.model_flops,
        model_to_hlo=cost.model_flops / cost.flops if cost.flops else 0.0,
        params_total=cost.params_total, params_active=cost.params_active,
        hlo_flops_reported=artifact.get("cost_analysis", {}).get("flops"),
        temp_bytes=artifact.get("memory_analysis", {}).get("temp_size_in_bytes"),
    )
    return row


def build_table(artifact_dir: str, mesh: str = "pod16x16") -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, f"*__{mesh}.json"))):
        with open(path) as f:
            art = json.load(f)
        if art["status"] == "ok":
            rows.append(roofline_row(art))
        else:
            rows.append(dict(arch=art["arch"], shape=art["shape"],
                             mesh=art["mesh"], status=art["status"],
                             reason=art.get("reason", art.get("error", ""))))
    return rows


def format_markdown(rows: list) -> str:
    hdr = ("| arch | shape | status | compute_s | memory_s | collective_s | "
           "dominant | roofline_frac | MODEL/HLO |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']}"
                         f" | - | - | - | - | - | - |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['roofline_fraction']:.3f} | {r['model_to_hlo']:.3f} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--out", default="benchmarks/artifacts/roofline.json")
    args = ap.parse_args()
    rows = build_table(args.artifacts, args.mesh)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(format_markdown(rows))


if __name__ == "__main__":
    main()
