"""Serving launcher: batched generation demo over a (smoke or full) arch.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --batch 4 --prompt-len 32 --steps 64 [--temperature 0.8]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import init_params
from repro.serving import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    engine = ServeEngine(params, cfg, batch=args.batch,
                         max_len=args.prompt_len + args.steps + 8,
                         temperature=args.temperature, seed=args.seed)
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    embeds = None
    if cfg.embedding_input:
        from repro.models.layers import embed
        embeds = embed(params["embed"], prompts, dtype=jnp.bfloat16)

    t0 = time.time()
    out = engine.generate(prompts, args.steps, prompt_embeds=embeds)
    jax.block_until_ready(out)
    dt = time.time() - t0
    print(json.dumps({
        "arch": cfg.name, "batch": args.batch, "steps": args.steps,
        "wall_s": round(dt, 3),
        "tokens_per_s": round(args.batch * args.steps / dt, 1),
        "sample": out[0, :16].tolist(),
    }))


if __name__ == "__main__":
    main()
