"""Numerical health + failure hardening for the QR stack.

Four small modules, one contract — a dispatch either returns a result
that would pass the conformance suite, or the failure is named,
counted, and recovered from:

  * :mod:`repro.robustness.guards`   — input admission
    (``QRService.submit`` quarantines non-finite / malformed payloads
    before they can poison a padded bucket);
  * :mod:`repro.robustness.verify`   — post-dispatch health checks
    (relative residual + orthogonality defect against the conformance
    tolerance rule, per-slice on batched dispatches), behind
    ``QRConfig.verify`` / ``$REPRO_VERIFY``;
  * :mod:`repro.robustness.escalate` — the deterministic degradation
    ladder megakernel -> wavefront -> oracle -> lapack, every hop a
    named reason plus a ``robustness.escalations{from,to,reason}``
    counter (the serving layer adds a per-bucket circuit breaker on
    top);
  * :mod:`repro.robustness.inject`   — the deterministic fault harness
    (seeded NaN/Inf corruption, forced compile/VMEM failures, per-
    bucket latency) that proves each of those paths actually fires.

The whole layer is free when off: admission is one O(mn) host scan,
verification resolves host-side (off/traced paths are jaxpr-identical
to an unchecked solve), and injection hooks are a single global read.
"""

from repro.robustness.guards import (AdmissionError, AdmissionPolicy,
                                     admit, estimate_condition)
from repro.robustness.verify import (HealthReport, check_batch,
                                     check_ortho, check_ortho_batch,
                                     check_qr, check_r, tolerance,
                                     verify_enabled)
from repro.robustness.escalate import (LADDER, Escalation,
                                       EscalationExhausted, checked_solve,
                                       ladder_below, lapack_qr, record,
                                       solve_below)
from repro.robustness.inject import Fault, InjectedFault

__all__ = [
    "AdmissionError",
    "AdmissionPolicy",
    "Escalation",
    "EscalationExhausted",
    "Fault",
    "HealthReport",
    "InjectedFault",
    "LADDER",
    "admit",
    "check_batch",
    "check_ortho",
    "check_ortho_batch",
    "check_qr",
    "check_r",
    "checked_solve",
    "estimate_condition",
    "ladder_below",
    "lapack_qr",
    "record",
    "solve_below",
    "tolerance",
    "verify_enabled",
]
