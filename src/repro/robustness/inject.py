"""Deterministic fault injection — the chaos harness behind the
robustness layer's tests and the ``--chaos`` serving benchmark.

Every degradation path the stack claims to have (admission quarantine,
health-check escalation, compile-failure retry, VMEM-budget rejection,
circuit breaker) must be *provably reachable*; this module plants
deterministic faults at the seams so tests/test_robustness.py can fire
each one on demand and watch the recovery:

    from repro.robustness import inject

    with inject.active(inject.Fault(site="compile", match="32x32")):
        svc.submit_many(wave)       # the 32x32 bucket's AOT compile
                                    # raises InjectedFault -> the service
                                    # escalates down the ladder

Sites (each corresponds to one hook placed in production code):

  * ``"input"``   — seeded NaN/Inf corruption of a submitted matrix
                    (``QRService.submit``, pre-admission — exercises the
                    guard, not the math).
  * ``"output"``  — corrupt one chosen batch slice of a dispatch result
                    (``QRService.flush`` / ``batched_orthogonalize`` —
                    exercises the post-dispatch health check).
  * ``"compile"`` — raise from a bucket plan's AOT compile
                    (``QRService._build_plan``).
  * ``"dispatch"``— raise from a rung execution in the escalation
                    ladder (:mod:`repro.robustness.escalate`).
  * ``"vmem"``    — forced VMEM-budget rejection: the engine's
                    ``_check_dispatch`` raises exactly where a real
                    over-budget workspace would.
  * ``"latency"`` — ``time.sleep`` before a bucket dispatch (per-bucket
                    artificial latency; straggler/percentile tests).

Faults are matched by ``site`` plus a substring test of ``match``
against the call-site tag (bucket label like ``"64x64"``, rung name,
...; empty string matches everything) and disarm after ``times``
firings (``None`` = unlimited).  Corruption is **seeded** — the same
``Fault(seed=...)`` poisons the same elements every run.

The hooks are free when nothing is armed: every one starts with the
module-level ``enabled()`` flag test (one global read), so production
paths pay a single branch.  This module deliberately imports nothing
from the planner/engine/serving layers — it sits below all of them so
any layer can hook it without cycles.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import List, Optional

import numpy as np

from repro.observability import metrics as _metrics

__all__ = [
    "Fault",
    "InjectedFault",
    "active",
    "check",
    "corrupt_input",
    "corrupt_output",
    "enabled",
    "poison",
    "reset",
    "sleep",
]


class InjectedFault(RuntimeError):
    """Raised by an armed ``compile``/``dispatch``/``vmem`` fault."""

    def __init__(self, site: str, tag: str):
        self.site = site
        self.tag = tag
        super().__init__(f"injected {site} fault (tag={tag!r})")


@dataclasses.dataclass
class Fault:
    """One armed fault.  ``fired`` mutates as the fault triggers.

    site:   hook family — "input" | "output" | "compile" | "dispatch" |
            "vmem" | "latency"
    match:  substring of the call-site tag ("" matches every tag)
    times:  firings before the fault disarms (None = unlimited)
    kind:   corruption payload for input/output sites — "nan" | "inf"
    slice_index: which batch slice an "output" fault corrupts
    frac:   fraction of elements an "input" fault corrupts (>= 1 elem)
    seed:   RNG seed for corruption positions (determinism contract)
    delay_s: sleep duration for "latency" faults
    """

    site: str
    match: str = ""
    times: Optional[int] = 1
    kind: str = "nan"
    slice_index: int = 0
    frac: float = 0.05
    seed: int = 0
    delay_s: float = 0.0
    fired: int = 0

    def matches(self, site: str, tag: str) -> bool:
        if self.site != site or (self.match and self.match not in tag):
            return False
        return self.times is None or self.fired < self.times

    def fire(self, tag: str) -> None:
        self.fired += 1
        _metrics.counter("robustness.faults_injected", site=self.site).inc()


_FAULTS: List[Fault] = []
_LOCK = threading.Lock()


def enabled() -> bool:
    """Fast hook guard: is ANY fault armed?  (One list-truthiness read —
    the only cost production code pays when chaos is off.)"""
    return bool(_FAULTS)


def reset() -> None:
    """Disarm everything (test teardown)."""
    with _LOCK:
        _FAULTS.clear()


@contextlib.contextmanager
def active(*faults: Fault):
    """Arm ``faults`` for the scope; disarms (and only these) on exit."""
    with _LOCK:
        _FAULTS.extend(faults)
    try:
        yield faults
    finally:
        with _LOCK:
            for f in faults:
                if f in _FAULTS:
                    _FAULTS.remove(f)


def _match(site: str, tag: str) -> Optional[Fault]:
    with _LOCK:
        for f in _FAULTS:
            if f.matches(site, tag):
                f.fire(tag)
                return f
    return None


def check(site: str, tag: str) -> None:
    """Raise :class:`InjectedFault` if a matching fault is armed — the
    hook for the ``compile`` / ``dispatch`` / ``vmem`` sites."""
    if not _FAULTS:
        return
    if _match(site, tag) is not None:
        raise InjectedFault(site, tag)


def sleep(tag: str) -> None:
    """Artificial per-bucket latency (``latency`` site)."""
    if not _FAULTS:
        return
    f = _match("latency", tag)
    if f is not None and f.delay_s > 0:
        time.sleep(f.delay_s)


def _payload(kind: str) -> float:
    return float("inf") if kind == "inf" else float("nan")


def poison(a: np.ndarray, *, kind: str = "nan", frac: float = 0.05,
           seed: int = 0) -> np.ndarray:
    """Seeded copy of ``a`` with ``max(1, frac * size)`` elements set to
    NaN/Inf — the pure helper chaos tests and the ``--chaos`` bench use
    to build poisoned requests (same seed => same poisoned positions)."""
    out = np.array(a, copy=True)
    flat = out.reshape(-1)
    n = max(1, int(frac * flat.size))
    idx = np.random.default_rng(seed).choice(flat.size, size=n,
                                             replace=False)
    flat[idx] = _payload(kind)
    return out


def corrupt_input(a: np.ndarray, tag: str) -> np.ndarray:
    """``input`` site hook: poison a submitted matrix pre-admission."""
    if not _FAULTS:
        return a
    f = _match("input", tag)
    if f is None:
        return a
    return poison(a, kind=f.kind, frac=f.frac, seed=f.seed)


def corrupt_output(out, tag: str):
    """``output`` site hook: corrupt one batch slice of a dispatch
    result.  ``out`` is an array or a tuple/list of arrays with a
    leading batch axis; the fault's ``slice_index`` slice of EVERY
    factor goes to NaN/Inf (a health check must flag that slice and
    only that slice).  Single matrices (ndim == 2) corrupt whole."""
    if not _FAULTS:
        return out
    f = _match("output", tag)
    if f is None:
        return out
    import jax.numpy as jnp

    val = _payload(f.kind)

    def bad(x):
        if x is None:
            return x
        if x.ndim >= 3:
            s = min(f.slice_index, x.shape[0] - 1)
            return x.at[s].set(val)
        return jnp.full_like(x, val)

    if isinstance(out, (tuple, list)):
        return type(out)(bad(x) for x in out)
    return bad(out)
