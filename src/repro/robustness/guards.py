"""Input admission: reject a bad request BEFORE it contaminates a bucket.

The serving layer stacks heterogeneous requests into one padded batch
and factors the stack in one dispatch — which means a single NaN
payload poisons every request sharing its bucket (the megakernel's
macro-ops propagate non-finite values across the whole workspace, and
the per-slice bitwise-parity guarantee faithfully reproduces garbage).
Admission moves the failure to the cheapest possible point: an O(mn)
host-side scan at ``QRService.submit``, quarantining the offender with
a named reason while its bucket-mates proceed untouched.

    from repro.robustness import guards

    guards.admit(a)                      # raises AdmissionError or returns
    guards.admit(a, policy=guards.AdmissionPolicy(max_cond=1e8))

Named rejection reasons (``AdmissionError.reason`` — stable slugs the
service surfaces per request and counts under
``robustness.quarantined{reason=...}``):

  * ``nonfinite_input``  — NaN/Inf anywhere in the payload
  * ``bad_ndim``         — not a 2-D matrix
  * ``non_float_dtype``  — integer/complex/bool payload (the engine's
                           macro-ops are real-float realizations)
  * ``ill_conditioned``  — exact 2-norm condition number above
                           ``policy.max_cond`` (OPT-IN: costs an SVD,
                           O(mn^2) — same order as the factorization
                           itself, so it is a debugging/acceptance
                           guard, not a steady-state one; ``max_cond``
                           defaults to None = skip)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["AdmissionError", "AdmissionPolicy", "admit",
           "estimate_condition"]


class AdmissionError(ValueError):
    """A request failed admission; ``reason`` is the stable slug."""

    def __init__(self, reason: str, detail: str):
        self.reason = reason
        super().__init__(f"{reason}: {detail}")


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """What :func:`admit` enforces.  The default is the cheap, always-on
    contract (finite 2-D float); ``max_cond`` opts into the expensive
    conditioning guard."""

    require_finite: bool = True
    require_float: bool = True
    max_cond: Optional[float] = None


DEFAULT_ADMISSION = AdmissionPolicy()


def estimate_condition(a: np.ndarray) -> float:
    """2-norm condition number sigma_max / sigma_min via SVD (exact, and
    priced accordingly — O(mn^2), the cost of the factorization it
    guards).  Rank-deficient input returns inf."""
    s = np.linalg.svd(np.asarray(a, dtype=np.float64), compute_uv=False)
    if s.size == 0 or s[-1] == 0.0:
        return float("inf")
    return float(s[0] / s[-1])


def admit(a: np.ndarray, *, policy: Optional[AdmissionPolicy] = None) -> None:
    """Admission check; raises :class:`AdmissionError` with a named
    reason, returns None on acceptance.  Order: cheap structural checks
    first, the O(mn) finite scan next, the opt-in SVD guard last."""
    policy = DEFAULT_ADMISSION if policy is None else policy
    arr = np.asarray(a)
    if arr.ndim != 2:
        raise AdmissionError("bad_ndim",
                             f"expected a matrix, got shape {arr.shape}")
    if policy.require_float and arr.dtype.kind != "f":
        raise AdmissionError(
            "non_float_dtype",
            f"expected a real floating dtype, got {arr.dtype}")
    if policy.require_finite and arr.size \
            and not bool(np.isfinite(arr).all()):
        bad = int(arr.size - np.isfinite(arr).sum())
        raise AdmissionError(
            "nonfinite_input",
            f"{bad} non-finite element(s) in a {arr.shape} payload")
    if policy.max_cond is not None and min(arr.shape) > 0:
        cond = estimate_condition(arr)
        if cond > policy.max_cond:
            raise AdmissionError(
                "ill_conditioned",
                f"cond_2(a) ~ {cond:.3e} > max_cond={policy.max_cond:.3e}")
