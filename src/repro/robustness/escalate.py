"""The degradation ladder: retry a failed request DOWN, deterministically.

When a dispatch fails — its AOT compile raised, the execution raised,
or the post-dispatch health check rejected the output — the request is
not lost and not poisoned: it re-runs on the next rung of a fixed
ladder, each hop recorded as a named, ``RouteDecision``-style reason
and counted under ``robustness.escalations{from, to, reason}``:

    megakernel  ->  wavefront  ->  oracle  ->  lapack

  * ``megakernel``: the persistent single-``pallas_call`` lowering
    (fastest, most machinery in the blast radius);
  * ``wavefront``:  one Pallas dispatch per DAG level (same kernels,
    simpler launch path — survives task-table/scalar-prefetch issues);
  * ``oracle``:     the bitwise-identical jnp lowering of the same
    schedule (``use_kernel=False`` — no Pallas at all);
  * ``lapack``:     ``jnp.linalg.qr`` on the raw, unpadded request (the
    reference implementation; if THIS fails verification the input is
    the problem, not the realization).

The ladder is strictly monotone — a request never climbs back up — and
deterministic: the same failure on the same input takes the same hops
(the chaos suite in tests/test_robustness.py asserts exactly which
counters fire for each injected fault class).

:class:`QRService` drives the ladder at bucket granularity (with a
per-bucket circuit breaker — see serving/qr_service.py);
:func:`checked_solve` drives it for the plain ``qr()`` path;
``optim/batched_ortho.py`` uses a two-rung batched -> leafwise version
of the same idea.  All of them emit through :func:`record` so the
counter namespace is uniform.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.observability import metrics as _metrics
from repro.observability import trace as _trace
from repro.robustness import inject as _inject
from repro.robustness import verify as _verify

__all__ = [
    "Escalation",
    "EscalationExhausted",
    "LADDER",
    "checked_solve",
    "classify",
    "ladder_below",
    "lapack_qr",
    "record",
    "solve_below",
]

#: The full ladder, fastest first.  Bucket plans start at whichever rung
#: the planner/tuner picked for them; the jnp-oracle serving path starts
#: at "oracle" (there is no kernel above it to fall back from).
LADDER: Tuple[str, ...] = ("megakernel", "wavefront", "oracle", "lapack")


@dataclasses.dataclass(frozen=True)
class Escalation:
    """One recorded hop — the RouteDecision of the failure path.

    rule:   stable slug of WHY ("compile_failed", "dispatch_failed",
            "health_check_failed", "breaker_open", "injected_compile",
            ...) — the low-cardinality counter label
    reason: the concrete arithmetic/exception text behind the hop
    """

    rung_from: str
    rung_to: str
    rule: str
    reason: str = ""


class EscalationExhausted(RuntimeError):
    """Every rung failed; ``escalations`` holds the recorded hops."""

    def __init__(self, msg: str, escalations: Sequence[Escalation]):
        self.escalations = tuple(escalations)
        super().__init__(msg)


def classify(exc: BaseException, stage: str) -> str:
    """Stable slug for a failure: injected faults keep their site name
    (so chaos assertions can tell injected from organic), everything
    else is named by the stage that raised."""
    if isinstance(exc, _inject.InjectedFault):
        return f"injected_{exc.site}"
    return f"{stage}_failed"


def record(rung_from: str, rung_to: str, rule: str,
           reason: str = "") -> Escalation:
    """Emit the ``robustness.escalations{from, to, reason}`` counter and
    return the hop record."""
    _metrics.counter("robustness.escalations",
                     **{"from": rung_from, "to": rung_to,
                        "reason": rule}).inc()
    return Escalation(rung_from=rung_from, rung_to=rung_to, rule=rule,
                      reason=reason)


def ladder_below(rung: str) -> Tuple[str, ...]:
    """The rungs strictly below ``rung`` (unknown rungs — e.g. the
    api-path's "planned" pseudo-rung — see the whole ladder's safe
    tail: oracle then lapack)."""
    if rung in LADDER:
        return LADDER[LADDER.index(rung) + 1:]
    return LADDER[2:]


def lapack_qr(a, mode: str = "reduced"):
    """The bottom rung: ``jnp.linalg.qr`` on the raw request.  Returns
    ``(q, r)`` with ``q=None`` for mode="r"."""
    a = jnp.asarray(a)
    if mode == "r":
        return None, jnp.linalg.qr(a, mode="r")
    q, r = jnp.linalg.qr(a, mode="reduced")
    return q, r


def _run_rung(rung: str, fn: Callable, tag: str):
    """Execute one rung with the dispatch-site injection hook armed."""
    _inject.check("dispatch", f"{tag}:{rung}")
    return fn()


def _health(a, q, r, mode: str) -> _verify.HealthReport:
    if mode == "r" or q is None:
        return _verify.check_r(a, r)
    return _verify.check_qr(a, q, r)


def solve_below(a, *, mode: str = "reduced", start: str = "oracle",
                verify: bool = True, tag: str = "request"
                ) -> Tuple[Optional[object], object, str,
                           List[Escalation]]:
    """Re-solve ONE raw (unpadded) request on the rungs below ``start``.

    This is the per-request recovery path: when a batched dispatch's
    health check flags a single slice, that slice alone walks down from
    the bucket's rung — ``oracle`` re-solves it through the planner's
    jnp lowering, ``lapack`` through ``jnp.linalg.qr`` — verifying each
    attempt (when ``verify``).  Returns ``(q, r, rung_used,
    escalations)``; raises :class:`EscalationExhausted` if every rung
    below raises (a verification failure at the bottom rung returns the
    lapack factors anyway — at that point the INPUT is suspect, which
    admission should have caught, and the caller marks the result).
    """
    escalations: List[Escalation] = []
    prev = start
    rungs = ladder_below(start)
    for i, rung in enumerate(rungs):
        try:
            with _trace.span("robustness.rung", rung=rung, tag=tag):
                if rung == "lapack":
                    q, r = _run_rung(rung, lambda: lapack_qr(a, mode), tag)
                elif rung == "oracle":
                    q, r = _run_rung(
                        rung, lambda: _oracle_qr(a, mode), tag)
                else:
                    # Kernel rungs need a compiled bucket plan; a raw
                    # single request re-solve skips straight to the
                    # kernel-free realizations.
                    continue
        except Exception as e:  # noqa: BLE001 — every rung failure degrades
            escalations.append(record(prev, _next(rungs, i),
                                      classify(e, "dispatch"), str(e)))
            prev = rung
            continue
        if verify:
            rep = _health(a, q, r, mode)
            if not rep.ok:
                if rung == "lapack":
                    return q, r, rung, escalations  # input is the suspect
                escalations.append(record(
                    rung, _next(rungs, i), "health_check_failed",
                    f"{rep.reason}: residual={rep.residual:.3e} "
                    f"defect={rep.ortho_defect:.3e} tol={rep.tol:.3e}"))
                prev = rung
                continue
        return q, r, rung, escalations
    raise EscalationExhausted(
        f"every rung below {start!r} failed for {tag}", escalations)


def _next(rungs: Sequence[str], i: int) -> str:
    return rungs[i + 1] if i + 1 < len(rungs) else "none"


def _oracle_qr(a, mode: str):
    """The planner's kernel-free lowering of one request (eager jnp —
    the degraded path trades compile caching for certainty)."""
    from repro.core.plan import QRConfig, plan

    a = jnp.asarray(a)
    cfg = QRConfig(use_kernel=False,
                   mode="r" if mode == "r" else "reduced")
    solver = plan(a.shape, a.dtype, cfg)
    out = solver.solve(a)
    if mode == "r":
        return None, out
    return out


def checked_solve(solver, a):
    """The plain-``qr()`` escalation driver: run the planned solver,
    health-check the result, and walk the ladder on failure.

    Only called when the verify knob resolves ON and ``a`` is concrete
    (never under a trace) — the verify-off path in repro.core.api calls
    ``solver.solve`` directly, so disabling verification is
    jaxpr-identical to not having this module at all (pinned in
    tests/test_robustness.py).  Batched inputs (ndim > 2) check per
    slice but re-solve whole (the api path has no per-slice scatter).
    """
    mode = solver.config.mode
    tag = f"qr:{'x'.join(str(d) for d in a.shape)}"
    try:
        out = _run_rung("planned", lambda: solver.solve(a), tag)
    except Exception as e:  # noqa: BLE001
        record("planned", "oracle", classify(e, "dispatch"), str(e))
        q, r, _, _ = solve_below(a, mode=mode, start="planned", tag=tag)
        return r if mode == "r" else (q, r)
    out = _inject.corrupt_output(out, tag)
    if mode == "r":
        q, r = None, out
    else:
        q, r = out
    if a.ndim == 2:
        rep = _health(a, q, r, mode)
        ok = rep.ok
        detail = rep.reason
    elif a.ndim == 3:
        reports = (_verify.check_batch(a, None, r) if q is None
                   else _verify.check_batch(a, q, r))
        bad = [i for i, rp in enumerate(reports) if not rp.ok]
        ok = not bad
        detail = f"slices {bad}: {reports[bad[0]].reason}" if bad else None
    else:
        return out  # deeper batching: verified at the vmap'd 3-D level
    if ok:
        return out
    record("planned", "oracle", "health_check_failed", detail or "")
    if a.ndim == 2:
        q, r, _, _ = solve_below(a, mode=mode, start="planned",
                                 verify=True, tag=tag)
        return r if mode == "r" else (q, r)
    # Batched api input: re-solve the failed slices individually.
    q = None if q is None else jnp.asarray(q)
    r = jnp.asarray(r)
    for i in bad:
        qi, ri, _, _ = solve_below(a[i], mode=mode, start="planned",
                                   verify=True, tag=f"{tag}[{i}]")
        r = r.at[i].set(ri)
        if q is not None and qi is not None:
            q = q.at[i].set(qi)
    return r if mode == "r" else (q, r)
