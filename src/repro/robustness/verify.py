"""Post-dispatch health checks: is the factorization a factorization?

Householder QR has cheap, well-conditioned post-conditions — for an
accepted (Q, R) of an m x n input A,

    relative residual   ||A - Q R||_F / ||A||_F        <= tol
    orthogonality       ||Q^T Q - I||_F                <= tol

both hold to O(eps * max(m, n)) for HT and MHT orderings (paper §IV)
and for the tiled flat-tree DAG, so an O(mn k) check certifies an
O(mn^2) factorization.  The tolerance is **derived from the repo's
conformance rule** (tests/test_conformance.py pins every registered
method to ``100 * eps(dtype) * max(m, n)``): a dispatch whose output a
conformance test would fail is exactly a dispatch the escalation
ladder should retry.

For R-only results (serving mode="r") there is no Q to test; the Gram
identity ``A^T A = R^T R`` stands in — its backward error carries the
same eps * max(m, n) scaling relative to ||A||_F^2.

Batched dispatches are checked **per slice** with one vmapped jitted
program (:func:`check_batch` / :func:`check_ortho_batch`) so a single
bad slice is identified and re-solved alone — the rest of the bucket's
results ship as-is.

The knob: ``QRConfig.verify`` (tri-state) with the ``REPRO_VERIFY``
environment default.  Resolution is host-side only
(:func:`verify_enabled`), and verification never runs under a trace —
the verify-off (and traced) paths are jaxpr-identical to an unchecked
solve, pinned in tests/test_robustness.py.
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "HealthReport",
    "VERIFY_TOL_FACTOR",
    "check_batch",
    "check_ortho",
    "check_ortho_batch",
    "check_qr",
    "check_r",
    "tolerance",
    "verify_enabled",
]

# The conformance suite's single tolerance rule (tests/test_conformance.py
# ``_tol``): every registered method is held to 100 * eps * max(m, n).
# Health checks reuse it verbatim so "fails verification" and "would
# fail conformance" are the same predicate.
VERIFY_TOL_FACTOR = 100.0


def tolerance(dtype, m: int, n: int) -> float:
    """The conformance rule: ``100 * eps(dtype) * max(m, n)``."""
    eps = float(jnp.finfo(jnp.dtype(dtype)).eps)
    return VERIFY_TOL_FACTOR * eps * max(m, n, 1)


def verify_enabled(flag: Optional[bool] = None) -> bool:
    """Resolve the tri-state verify knob: an explicit True/False wins;
    None falls back to the ``REPRO_VERIFY`` environment default (read
    at call time, so tests and deployments can flip it live)."""
    if flag is not None:
        return bool(flag)
    return os.environ.get("REPRO_VERIFY", "").strip().lower() in (
        "1", "true", "on", "yes")


@dataclasses.dataclass(frozen=True)
class HealthReport:
    """One slice's verdict.  ``reason`` is None when healthy, else a
    stable slug ("nonfinite_output" | "residual_exceeds_tol" |
    "ortho_defect_exceeds_tol" | "gram_residual_exceeds_tol")."""

    ok: bool
    residual: float
    ortho_defect: float
    tol: float
    reason: Optional[str] = None


def _report(residual: float, defect: float, tol: float,
            gram: bool = False) -> HealthReport:
    residual, defect = float(residual), float(defect)
    if not (np.isfinite(residual) and np.isfinite(defect)):
        reason = "nonfinite_output"
    elif residual > tol:
        reason = "gram_residual_exceeds_tol" if gram \
            else "residual_exceeds_tol"
    elif defect > tol:
        reason = "ortho_defect_exceeds_tol"
    else:
        reason = None
    return HealthReport(ok=reason is None, residual=residual,
                        ortho_defect=defect, tol=tol, reason=reason)


# --------------------------------------------------------- jitted stats
# One compiled program per (batch, m, n, k, dtype) signature; jit's own
# cache keys on shapes so repeated buckets reuse their executable.

@jax.jit
def _qr_stats(a, q, r):
    """Per-slice (relative residual, orthogonality defect) over a
    leading batch axis.  Empty (all-zero) padding slices report 0/0."""
    b = a.shape[0]
    resid = jnp.linalg.norm((a - q @ r).reshape(b, -1), axis=-1)
    scale = jnp.linalg.norm(a.reshape(b, -1), axis=-1)
    rel = jnp.where(scale > 0, resid / jnp.maximum(scale, 1e-300), resid)
    k = q.shape[-1]
    gram = jnp.swapaxes(q, -1, -2) @ q - jnp.eye(k, dtype=q.dtype)
    defect = jnp.linalg.norm(gram.reshape(b, -1), axis=-1)
    return rel, defect


@jax.jit
def _r_stats(a, r):
    """Per-slice Gram residual ||A^T A - R^T R||_F / ||A||_F^2 plus an
    upper-triangularity defect (relative mass below the diagonal)."""
    b = a.shape[0]
    ata = jnp.swapaxes(a, -1, -2) @ a
    rtr = jnp.swapaxes(r, -1, -2) @ r
    resid = jnp.linalg.norm((ata - rtr).reshape(b, -1), axis=-1)
    scale = jnp.linalg.norm(a.reshape(b, -1), axis=-1) ** 2
    rel = jnp.where(scale > 0, resid / jnp.maximum(scale, 1e-300), resid)
    low = r - jnp.triu(r)
    rscale = jnp.linalg.norm(r.reshape(b, -1), axis=-1)
    tri = jnp.linalg.norm(low.reshape(b, -1), axis=-1) \
        / jnp.maximum(rscale, 1e-300)
    return rel, tri


@jax.jit
def _ortho_stats(q):
    b = q.shape[0]
    k = q.shape[-1]
    gram = jnp.swapaxes(q, -1, -2) @ q - jnp.eye(k, dtype=q.dtype)
    return jnp.linalg.norm(gram.reshape(b, -1), axis=-1)


# ------------------------------------------------------- public checks

def check_qr(a, q, r, *, tol: Optional[float] = None) -> HealthReport:
    """Health of one (Q, R) against its input."""
    a, q, r = jnp.asarray(a), jnp.asarray(q), jnp.asarray(r)
    m, n = int(a.shape[-2]), int(a.shape[-1])
    tol = tolerance(a.dtype, m, n) if tol is None else tol
    rel, defect = _qr_stats(a[None], q[None], r[None])
    return _report(rel[0], defect[0], tol)


def check_r(a, r, *, tol: Optional[float] = None) -> HealthReport:
    """Health of an R-only result via the Gram identity."""
    a, r = jnp.asarray(a), jnp.asarray(r)
    m, n = int(a.shape[-2]), int(a.shape[-1])
    tol = tolerance(a.dtype, m, n) if tol is None else tol
    rel, tri = _r_stats(a[None], r[None])
    return _report(rel[0], tri[0], tol, gram=True)


def check_ortho(q, *, tol: Optional[float] = None) -> HealthReport:
    """Orthogonality-only health (the optimizer path holds Q, not R)."""
    q = jnp.asarray(q)
    m, n = int(q.shape[-2]), int(q.shape[-1])
    tol = tolerance(q.dtype, m, n) if tol is None else tol
    defect = _ortho_stats(q[None])
    return _report(0.0, defect[0], tol)


def check_batch(a_stack, q_stack, r_stack, *,
                tol: Optional[float] = None) -> List[HealthReport]:
    """Per-slice health of one batched (Q, R) dispatch — ONE vmapped
    jitted stats program, then host-side verdicts, so a single bad
    slice is identified without re-running the good ones.  Pass
    ``q_stack=None`` for R-only buckets (Gram-identity check)."""
    a_stack = jnp.asarray(a_stack)
    m, n = int(a_stack.shape[-2]), int(a_stack.shape[-1])
    tol = tolerance(a_stack.dtype, m, n) if tol is None else tol
    if q_stack is None:
        rel, defect = _r_stats(a_stack, jnp.asarray(r_stack))
        gram = True
    else:
        rel, defect = _qr_stats(a_stack, jnp.asarray(q_stack),
                                jnp.asarray(r_stack))
        gram = False
    rel = np.asarray(rel)
    defect = np.asarray(defect)
    return [_report(rel[i], defect[i], tol, gram=gram)
            for i in range(rel.shape[0])]


def check_ortho_batch(q_stack, *, tol: Optional[float] = None
                      ) -> List[HealthReport]:
    """Per-slice orthogonality defects of a batched thin-Q stack."""
    q_stack = jnp.asarray(q_stack)
    m, n = int(q_stack.shape[-2]), int(q_stack.shape[-1])
    tol = tolerance(q_stack.dtype, m, n) if tol is None else tol
    defect = np.asarray(_ortho_stats(q_stack))
    return [_report(0.0, defect[i], tol) for i in range(defect.shape[0])]
