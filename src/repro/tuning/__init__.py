"""repro.tuning — the measured half of the planner's co-design story.

``sweep`` measures candidate ``(method, block, dispatch_mode)`` configs
per shape class on the actual backend; ``cache`` persists the results as
a versioned JSON the planner's ``"tuned"`` routing rule consults before
its static heuristics (``repro.core.plan._route``).  Regenerate the
committed CPU default with::

    PYTHONPATH=src python -m repro.tuning.sweep \\
        --out src/repro/tuning/default_cpu.json
"""

from repro.tuning.cache import (  # noqa: F401
    DEFAULT_CACHE_PATH,
    ENV_VAR,
    SCHEMA,
    TunedConfig,
    TuningCache,
    TuningEntry,
    active_cache,
    active_cache_info,
    set_active_cache,
    shape_class,
)

__all__ = [
    "DEFAULT_CACHE_PATH",
    "ENV_VAR",
    "SCHEMA",
    "TunedConfig",
    "TuningCache",
    "TuningEntry",
    "active_cache",
    "active_cache_info",
    "set_active_cache",
    "shape_class",
]
