"""repro.tuning.sweep — measure candidate planner configs per shape class.

    PYTHONPATH=src python -m repro.tuning.sweep --out tuning_cache.json
    PYTHONPATH=src python -m repro.tuning.sweep --smoke --check

For each swept shape class the sweep builds the candidate set
(method x block x dispatch_mode), prunes it structurally (capability
guards, the engine's task-table/VMEM budgets via
:func:`repro.core.engine.explain_dispatch_mode`) and against the
roofline model (:func:`repro.launch.roofline.modeled_seconds` over
:func:`qr_flops` + :func:`repro.core.engine.modeled_dma_bytes` — a
candidate whose modeled lower bound already loses by ``PRUNE_FACTOR``x
is never timed), measures wall time on the **actual** backend
(warm-then-min-of-reps), and records a
:class:`repro.tuning.cache.TuningEntry` whose best pick the planner's
``"tuned"`` routing rule consults.

The heuristic pick (``select_method`` with the cache disabled) is always
measured, so "tuned is never slower than heuristic on swept shapes" is a
same-run comparison CI can gate on (``--check``); ``--baseline`` adds a
tolerance-banded drift gate against a committed cache's recorded
timings (catches a kernel change regressing the previously-measured
best config).

Sweeps time ``mode="r"`` (the factorization core — Q formation is mode-
specific and excluded, so ``q_method`` stays at its default in the
candidate grid); the measured mode is recorded in the entry provenance.
Kernel-path candidates are swept only where the kernel compiles
(TPU) — interpret-mode Pallas timings on CPU are not a serving
configuration and would dominate the sweep budget for nothing.

The sweep emits ``tuning.*`` metrics (candidates measured/pruned/
skipped, per-candidate wall histograms) and ``tuning.sweep`` /
``tuning.shape`` trace spans when observability is enabled.
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.observability import metrics as _metrics
from repro.observability import trace as _trace
from repro.tuning.cache import (DEFAULT_CACHE_PATH, TunedConfig, TuningCache,
                                TuningEntry, shape_class)

__all__ = [
    "DEFAULT_SHAPES",
    "SMOKE_SHAPES",
    "PRUNE_FACTOR",
    "candidates",
    "modeled_bound_us",
    "prune_candidates",
    "measure_candidate",
    "sweep_shapes",
    "check_cache",
    "main",
]

#: Square shape classes the committed default cache covers — around the
#: CPU tiled-vs-blocked crossover the heuristics hard-code at 512
#: (_TILED_MIN_DIM_CPU), which is exactly the guess the cache replaces.
DEFAULT_SHAPES: Tuple[Tuple[int, int], ...] = (
    (256, 256), (384, 384), (512, 512))

#: Reduced grid for the CI smoke gate.
SMOKE_SHAPES: Tuple[Tuple[int, int], ...] = ((256, 256), (512, 512))

#: Candidates whose roofline lower bound already exceeds the best
#: candidate's bound by this factor are pruned unmeasured.  Deliberately
#: loose: the model ranks asymptotics (it cannot see constant factors),
#: so only order-of-magnitude losers are dropped.
PRUNE_FACTOR = 32.0

_TILED_BLOCKS = (32, 64)


def _heuristic_config(m: int, n: int, dtype, backend: str):
    """The planner's pick with the tuning cache pinned off — the
    baseline every tuned pick is measured against."""
    from repro.core.plan import QRConfig, plan

    solver = plan((m, n), dtype, QRConfig(mode="r", use_tuning_cache=False),
                  backend=backend)
    return solver.config


def candidates(m: int, n: int, dtype, backend: str
               ) -> List[Tuple[str, "object"]]:
    """The ``(label, QRConfig)`` candidate grid for one shape class —
    structurally pruned (capability guards, engine budgets) but not yet
    roofline-pruned.  Always includes the heuristic pick."""
    from repro.core import engine
    from repro.core.plan import QRConfig, available_methods

    reg = available_methods()
    base = dict(mode="r", use_tuning_cache=False)
    out: List[Tuple[str, QRConfig]] = []

    for meth in ("geqrf", "geqrf_ht"):
        if meth in reg:
            out.append((meth, QRConfig(method=meth, **base)))
    # Unblocked MHT is O(m n^2) with no blocking — only plausible when
    # the matrix is at most a few panels tall.
    if "geqr2_ht" in reg and min(m, n) <= 128:
        out.append(("geqr2_ht", QRConfig(method="geqr2_ht", **base)))
    if "tsqr" in reg and n >= 1 and m >= 4 * n:
        out.append(("tsqr", QRConfig(method="tsqr", **base)))
    if "tiled" in reg:
        itemsize = np.dtype(dtype).itemsize
        for b in _TILED_BLOCKS:
            if min(m, n) < 2 * b:
                continue  # fewer than 2 tiles per side: no wavefront
            out.append((f"tiled[b{b}]",
                        QRConfig(method="tiled", block=b, use_kernel=False,
                                 **base)))
            if backend != "tpu":
                continue  # interpret-mode Pallas is not a serving config
            from repro.core.tilegraph import tile_grid

            p, q = tile_grid(m, n, b)
            out.append((f"tiled[b{b},wavefront]",
                        QRConfig(method="tiled", block=b, use_kernel=True,
                                 dispatch_mode="wavefront", **base)))
            mode, _ = engine.explain_dispatch_mode(p, q, b, itemsize)
            if mode == "megakernel":  # budget-pruned otherwise
                out.append((f"tiled[b{b},megakernel]",
                            QRConfig(method="tiled", block=b,
                                     use_kernel=True,
                                     dispatch_mode="megakernel", **base)))

    heur = _heuristic_config(m, n, dtype, backend)
    if not any(_cand_key(cfg) == _cand_key(heur) for _, cfg in out):
        out.append((f"heuristic:{heur.method}", heur))
    return out


def _cand_key(cfg) -> Tuple:
    """Dedup key: the knobs that change what actually runs.  Normalizes
    ``use_kernel=None`` (planner resolves it to False off-TPU) so the
    heuristic pick dedups against the equivalent grid candidate."""
    return (cfg.method, cfg.block, bool(cfg.use_kernel), cfg.dispatch_mode,
            cfg.q_method)


def modeled_bound_us(cfg, m: int, n: int, dtype) -> float:
    """Roofline lower bound (us) on one solve: max(compute, HBM) time
    from the analytic QR flop count and the candidate's modeled traffic
    (the engine's per-dispatch-mode DMA model for tiled; compulsory
    read+write for the dense methods)."""
    from repro.core import engine
    from repro.launch.roofline import modeled_seconds, qr_flops

    itemsize = np.dtype(dtype).itemsize
    flops = qr_flops(m, n)
    if cfg.method == "tiled":
        from repro.core.tilegraph import tile_grid

        nb = min(cfg.block, m, n)
        p, q = tile_grid(m, n, nb)
        dma = engine.modeled_dma_bytes(p, q, nb, itemsize)
        key = cfg.dispatch_mode if (cfg.use_kernel and cfg.dispatch_mode
                                    in dma) else "wavefront"
        hbm = dma[key]
    elif cfg.method in ("geqr2", "geqr2_ht"):
        # Unblocked: every reflector re-streams the trailing matrix.
        hbm = 2.0 * min(m, n) * m * n * itemsize / 2.0
    else:
        hbm = 2.0 * (m * n + m * min(m, n) + min(m, n) * n) * itemsize
    return 1e6 * modeled_seconds(flops, hbm)


def prune_candidates(cands: Sequence[Tuple[str, "object"]], m: int, n: int,
                     dtype) -> List[Tuple[str, "object"]]:
    """Drop candidates whose modeled lower bound already loses by
    :data:`PRUNE_FACTOR`x — logged, counted, never silently."""
    bounds = {label: modeled_bound_us(cfg, m, n, dtype)
              for label, cfg in cands}
    floor = min(bounds.values())
    kept = []
    for label, cfg in cands:
        if bounds[label] > PRUNE_FACTOR * floor:
            _metrics.counter("tuning.candidates", status="pruned").inc()
            print(f"  pruned {label}: modeled {bounds[label]:.0f} us > "
                  f"{PRUNE_FACTOR:g}x floor {floor:.0f} us", file=sys.stderr)
        else:
            kept.append((label, cfg))
    return kept


def measure_candidate(cfg, a, reps: int = 3) -> Optional[float]:
    """Min wall time (us) over ``reps`` warm solves (min, not mean: the
    fastest rep is the least scheduler-noise-contaminated estimate of
    the config's cost, which is what the ranking needs); None when the
    plan is infeasible for this shape (capability ValueError)."""
    from repro.core.plan import plan

    try:
        solver = plan(a.shape, a.dtype, cfg)
        jax.block_until_ready(solver.solve(a))  # compile
        jax.block_until_ready(solver.solve(a))  # warm caches
        walls = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(solver.solve(a))
            walls.append(time.perf_counter() - t0)
        return float(min(walls) * 1e6)
    except ValueError as e:
        _metrics.counter("tuning.candidates", status="skipped").inc()
        print(f"  skipped {cfg.method}: {e}", file=sys.stderr)
        return None


def sweep_shapes(shapes: Sequence[Tuple[int, int]], *,
                 dtype=jnp.float32, reps: int = 3,
                 backend: Optional[str] = None,
                 smoke: bool = False) -> TuningCache:
    """Measure every candidate on every shape class; return the cache."""
    backend = jax.default_backend() if backend is None else backend
    device_kind = (jax.devices()[0].device_kind
                   if backend == jax.default_backend() else backend)
    rng = np.random.default_rng(0)
    out = TuningCache(source="sweep")
    dt = str(np.dtype(dtype))

    with _trace.span("tuning.sweep", backend=backend, shapes=len(shapes)):
        for m, n in shapes:
            cls = shape_class(m, n)
            label_cls = f"{cls[0]}x{cls[1]}"
            print(f"sweep {m}x{n} (class {label_cls}, {backend}/{dt})",
                  file=sys.stderr)
            _metrics.counter("tuning.sweeps", backend=backend).inc()
            heur = _heuristic_config(cls[0], cls[1], dtype, backend)
            with _trace.span("tuning.shape", cls=label_cls):
                cands = prune_candidates(
                    candidates(cls[0], cls[1], dtype, backend),
                    cls[0], cls[1], dtype)
                a = jnp.asarray(rng.standard_normal(cls, dtype=np.float32)
                                ).astype(dtype)
                timings: Dict[str, float] = {}
                for label, cfg in cands:
                    us = measure_candidate(cfg, a, reps)
                    if us is None:
                        continue
                    timings[label] = us
                    _metrics.counter("tuning.candidates",
                                     status="measured").inc()
                    _metrics.histogram("tuning.candidate_wall_us",
                                       cls=label_cls).observe(us)
                    print(f"  {label:<24s} {us:10.0f} us", file=sys.stderr)
            if not timings:
                print(f"  no measurable candidate for {label_cls} — "
                      "class skipped", file=sys.stderr)
                continue
            best_label = min(timings, key=timings.get)
            best_cfg = dict(cands)[best_label]
            heur_label = next((lb for lb, c in cands
                               if _cand_key(c) == _cand_key(heur)), None)
            heur_us = timings.get(heur_label, float("nan"))
            entry = TuningEntry(
                backend=backend, device_kind=device_kind,
                shape_class=cls, dtype=dt,
                best=TunedConfig(
                    method=best_cfg.method, block=best_cfg.block,
                    dispatch_mode=best_cfg.dispatch_mode,
                    q_method=best_cfg.q_method,
                    use_kernel=bool(best_cfg.use_kernel)),
                best_us=timings[best_label],
                heuristic_method=heur.method, heuristic_us=heur_us,
                timings=tuple(sorted(timings.items())),
                provenance=tuple(sorted({
                    "generated_by": "repro.tuning.sweep",
                    "mode": "r", "reps": str(reps),
                    "smoke": str(bool(smoke)).lower(),
                }.items())),
            )
            out.add(entry)
            _metrics.counter("tuning.entries", backend=backend).inc()
            print(f"  best: {best_label} ({entry.best_us:.0f} us) vs "
                  f"heuristic {heur.method} ({heur_us:.0f} us)",
                  file=sys.stderr)
    return out


def check_cache(fresh: TuningCache, baseline: Optional[TuningCache] = None,
                *, heuristic_tol: float = 0.05,
                drift_tol: float = 5.0) -> List[str]:
    """The CI gate.  Returns problem strings (empty = pass).

    Per fresh entry: the tuned pick must not be slower than the measured
    heuristic pick (same-run comparison; ``heuristic_tol`` absorbs timer
    noise — the argmin construction makes big violations impossible, so
    this mostly guards hand-edited caches).  With a ``baseline`` (the
    committed cache), the fresh measurement of the baseline's best config
    must stay within ``drift_tol``x of its recorded time — a kernel
    change that slowed a previously-measured winner fails here.  The
    band is generous because CI runners and dev machines differ.
    """
    problems = []
    for e in fresh.entries():
        if np.isfinite(e.heuristic_us) and \
                e.best_us > e.heuristic_us * (1.0 + heuristic_tol):
            problems.append(
                f"{e.backend}:{e.shape_class}: tuned {e.best.method} "
                f"{e.best_us:.0f} us slower than heuristic "
                f"{e.heuristic_method} {e.heuristic_us:.0f} us")
        if baseline is None:
            continue
        b = baseline.lookup(backend=e.backend, m=e.shape_class[0],
                            n=e.shape_class[1], dtype=e.dtype,
                            device_kind=e.device_kind)
        if b is None:
            continue
        base_best_label = next((lb for lb, _ in b.timings
                                if lb == _best_label(b)), _best_label(b))
        fresh_us = e.timings_dict.get(base_best_label)
        if fresh_us is not None and fresh_us > b.best_us * drift_tol:
            problems.append(
                f"{e.backend}:{e.shape_class}: committed best "
                f"{base_best_label} regressed {b.best_us:.0f} -> "
                f"{fresh_us:.0f} us (> {drift_tol:g}x band)")
    return problems


def _best_label(entry: TuningEntry) -> str:
    td = entry.timings_dict
    return min(td, key=td.get) if td else entry.best.method


def _parse_shapes(text: str) -> Tuple[Tuple[int, int], ...]:
    out = []
    for part in text.split(","):
        m, n = part.lower().split("x")
        out.append((int(m), int(n)))
    return tuple(out)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="measure candidate QR configs per shape class and "
                    "write the planner tuning cache")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="where to write the cache JSON")
    ap.add_argument("--shapes", default=None,
                    help="comma-separated MxN list (default: full grid)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI grid (%s)" % (SMOKE_SHAPES,))
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) when a tuned pick is slower than "
                         "the heuristic pick or the baseline regressed")
    ap.add_argument("--baseline", default=DEFAULT_CACHE_PATH, metavar="PATH",
                    help="committed cache the drift gate compares against")
    ap.add_argument("--heuristic-tol", type=float, default=0.05)
    ap.add_argument("--drift", type=float, default=5.0,
                    help="allowed factor vs the baseline's recorded times")
    args = ap.parse_args(argv)

    shapes = (_parse_shapes(args.shapes) if args.shapes
              else SMOKE_SHAPES if args.smoke else DEFAULT_SHAPES)
    cache = sweep_shapes(shapes, dtype=jnp.dtype(args.dtype),
                         reps=args.reps, smoke=args.smoke)
    if args.out:
        cache.save(args.out)
        print(f"wrote {len(cache)} entries to {args.out}", file=sys.stderr)
    if args.check:
        baseline = None
        try:
            baseline = TuningCache.load(args.baseline)
        except (FileNotFoundError, ValueError):
            print(f"no usable baseline at {args.baseline}; "
                  "heuristic gate only", file=sys.stderr)
        problems = check_cache(cache, baseline,
                               heuristic_tol=args.heuristic_tol,
                               drift_tol=args.drift)
        for p in problems:
            print(f"GATE: {p}", file=sys.stderr)
        if problems:
            return 1
        print("tuning gate passed: tuned picks beat (or tie) heuristics "
              f"on all {len(cache)} swept classes", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
