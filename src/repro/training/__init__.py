"""Training loop substrate."""

from repro.training.train_step import (
    TrainConfig, TrainState, fused_lm_loss, init_train_state, make_train_step,
)
from repro.training.trainer import RunConfig, Trainer

__all__ = ["TrainConfig", "TrainState", "make_train_step", "init_train_state",
           "fused_lm_loss", "Trainer", "RunConfig"]
