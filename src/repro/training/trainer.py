"""Trainer: the fault-tolerant orchestration loop.

Wires pipeline -> device placement (mesh shardings) -> train_step ->
watchdog -> async checkpointing.  Restart-safe: `Trainer.run` resumes
from the latest committed checkpoint (params, optimizer, data cursor) and
reproduces the exact batch sequence.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data import DataConfig, make_pipeline
from repro.distributed import MeshRules, StepWatchdog, batch_specs, param_specs, \
    state_specs, tree_shardings
from repro.models import init_params
from repro.optim import warmup_cosine
from repro.training.train_step import TrainConfig, TrainState, \
    init_train_state, make_train_step

__all__ = ["Trainer", "RunConfig"]


@dataclasses.dataclass
class RunConfig:
    total_steps: int = 100
    warmup_steps: int = 10
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    seed: int = 0


class Trainer:
    def __init__(self, model_cfg: ModelConfig, train_cfg: TrainConfig,
                 run_cfg: RunConfig, data_cfg: DataConfig, *,
                 mesh=None, rules: Optional[MeshRules] = None,
                 watchdog: Optional[StepWatchdog] = None,
                 log_fn: Callable[[str], None] = print):
        self.model_cfg = model_cfg
        self.train_cfg = train_cfg
        self.run_cfg = run_cfg
        self.pipeline = make_pipeline(data_cfg)
        self.mesh = mesh
        self.rules = rules
        self.log = log_fn
        # An injected watchdog (custom threshold/window/callback — e.g.
        # examples/train_lm.py --fault-tolerance) replaces the default.
        self.watchdog = watchdog if watchdog is not None else StepWatchdog(
            on_straggler=lambda s, dt, med: log_fn(
                f"[watchdog] straggler step {s}: {dt:.2f}s vs median {med:.2f}s"))
        self.ckpt = (CheckpointManager(run_cfg.checkpoint_dir)
                     if run_cfg.checkpoint_dir else None)
        self.metrics_history: list = []

        key = jax.random.PRNGKey(run_cfg.seed)
        params = init_params(key, model_cfg)
        state = init_train_state(params, train_cfg)
        if mesh is not None and rules is not None:
            pspecs = param_specs(params, rules)
            sspecs = TrainState(
                params=pspecs,
                opt=state_specs(params, pspecs, state.opt, rules),
                ef_error=state_specs(params, pspecs, state.ef_error, rules),
            )
            shardings = tree_shardings(sspecs, mesh)
            state = jax.device_put(state, shardings)
            self._state_shardings = shardings
        else:
            self._state_shardings = None
        self.state = state

        step_fn = make_train_step(model_cfg, train_cfg)
        if mesh is not None:
            self._step = jax.jit(step_fn)
        else:
            self._step = jax.jit(step_fn)
        self.step_idx = 0

    # -------------------------------------------------------------- ckpt

    def _save(self, blocking=False) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save(self.step_idx, self.state,
                       metadata={"data": self.pipeline.state_dict(),
                                 "step": self.step_idx},
                       blocking=blocking)

    def maybe_restore(self) -> bool:
        if self.ckpt is None:
            return False
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        meta = self.ckpt.metadata(latest)
        self.state = self.ckpt.restore(latest, self.state)
        self.pipeline.load_state_dict(meta["data"])
        self.step_idx = int(meta["step"])
        self.log(f"[trainer] restored step {self.step_idx}")
        return True

    # --------------------------------------------------------------- run

    def _place_batch(self, batch):
        arrs = {k: jnp.asarray(v) for k, v in batch.items()}
        if self.mesh is not None and self.rules is not None:
            sh = tree_shardings(batch_specs(arrs, self.rules), self.mesh)
            arrs = jax.device_put(arrs, sh)
        return arrs

    def run(self, *, resume: bool = True, stop_at: Optional[int] = None) -> dict:
        """``stop_at`` ends the loop early (crash simulation / partial runs)
        without changing the LR schedule horizon."""
        if resume:
            self.maybe_restore()
        rc = self.run_cfg
        it = iter(self.pipeline)
        limit = rc.total_steps if stop_at is None else min(stop_at, rc.total_steps)
        while self.step_idx < limit:
            batch = self._place_batch(next(it))
            lr = warmup_cosine(self.step_idx, peak_lr=self.train_cfg.lr,
                               warmup_steps=rc.warmup_steps,
                               total_steps=rc.total_steps)
            self.watchdog.start()
            self.state, metrics = self._step(self.state, batch, lr)
            jax.block_until_ready(metrics["loss"])
            dt = self.watchdog.stop(self.step_idx)
            self.step_idx += 1
            if self.step_idx % rc.log_every == 0 or self.step_idx == 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = self.step_idx
                m["step_time_s"] = round(dt, 4)
                self.metrics_history.append(m)
                self.log(f"[trainer] step {self.step_idx} "
                         f"loss={m['loss']:.4f} acc={m['accuracy']:.3f} "
                         f"gnorm={m['grad_norm']:.2f} ({dt:.2f}s)")
            if self.ckpt and self.step_idx % rc.checkpoint_every == 0:
                self._save(blocking=False)
        if self.ckpt:
            self._save(blocking=True)
            self.ckpt.wait_until_finished()
        return {"final_step": self.step_idx,
                "history": self.metrics_history,
                "stragglers": self.watchdog.straggler_steps}
