"""Training step: fused chunked LM loss, microbatch gradient accumulation,
global-norm clipping, optional int8 error-feedback gradient compression,
QR-Muon/AdamW update.

Memory design (what lets the 32B+ cells fit 16 GB/chip at compile):
  * the (B, S, V) logits tensor is never materialized — the LM head +
    softmax-CE run fused over sequence chunks inside a scan;
  * per-device batches are split into microbatches scanned with gradient
    accumulation, so live activations are one microbatch deep;
  * remat policy on the period body (model side) recomputes the rest.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.compression import ef_compress_tree, init_error_state
from repro.distributed.sharding import constrain_logits
from repro.models.layers import softcap as apply_softcap
from repro.models.transformer import forward_hidden, lm_head_weight
from repro.optim import adamw_init, adamw_update, muon_init, muon_update

Array = jax.Array

__all__ = ["TrainConfig", "TrainState", "make_train_step", "init_train_state",
           "fused_lm_loss"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "muon-qr"      # "muon-qr" | "muon-ns" | "adamw"
    lr: float = 0.02
    weight_decay: float = 0.0
    momentum: float = 0.95
    grad_clip: float = 1.0
    microbatch: int = 0             # per-call microbatch size; 0 = whole batch
    grad_compression: bool = False
    loss_chunk: int = 512           # fused-CE sequence chunk
    qr_q_method: str = "formq"      # "formq" (paper) | "solve" (optimized)
    qr_shard_leaves: bool = False   # layer-shard the QR stacks (see qr_muon)
    batched_ortho: bool = False     # one QR dispatch per shape class
                                    # (repro.optim.batched_ortho)
    cast_params_once: bool = False  # bf16-cast weights before the microbatch
                                    # scan (halves FSDP gather bytes)


class TrainState(NamedTuple):
    params: Any
    opt: Any
    ef_error: Any                   # error-feedback buffers (or 0-size)


def fused_lm_loss(x: Array, head_w: Array, labels: Array,
                  *, logit_softcap: Optional[float], chunk: int = 512
                  ) -> Tuple[Array, Array]:
    """Mean CE over (B, S) without materializing (B, S, V).

    x: (B, S, d) hidden states; head_w: (d, V); labels: (B, S).
    Returns (mean_nll, mean_accuracy)."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        nll_sum, acc_sum = carry
        xi, li = xs
        logits = (xi @ head_w.astype(xi.dtype)).astype(jnp.float32)
        logits = constrain_logits(logits)
        logits = apply_softcap(logits, logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll_sum = nll_sum + jnp.sum(lse - ll)
        acc_sum = acc_sum + jnp.sum(
            (jnp.argmax(logits, axis=-1) == li).astype(jnp.float32))
        return (nll_sum, acc_sum), None

    (nll, acc), _ = lax.scan(body, (jnp.zeros((), jnp.float32),
                                    jnp.zeros((), jnp.float32)), (xc, lc))
    n = b * s
    return nll / n, acc / n


def _loss_fn(params, batch, model_cfg: ModelConfig, train_cfg: TrainConfig):
    x, aux = forward_hidden(params, batch, model_cfg)
    head = lm_head_weight(params, model_cfg)
    nll, acc = fused_lm_loss(x, head, batch["labels"],
                             logit_softcap=model_cfg.logit_softcap,
                             chunk=train_cfg.loss_chunk)
    loss = nll + aux
    return loss, {"nll": nll, "aux": aux, "accuracy": acc}


def _clip_by_global_norm(grads, max_norm: float):
    if max_norm <= 0:
        return grads, jnp.zeros((), jnp.float32)
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def init_train_state(params, train_cfg: TrainConfig) -> TrainState:
    if train_cfg.optimizer.startswith("muon"):
        opt = muon_init(params)
    elif train_cfg.optimizer == "adamw":
        opt = adamw_init(params)
    else:
        raise ValueError(f"unknown optimizer {train_cfg.optimizer!r}")
    ef = init_error_state(params) if train_cfg.grad_compression else \
        jnp.zeros((), jnp.float32)
    return TrainState(params=params, opt=opt, ef_error=ef)


def _cast_params_tree(params):
    """bf16-cast matrix weights ONCE per step (outside the microbatch
    scan) so FSDP all-gathers move bf16, not fp32 — halves gather bytes.
    1-D leaves (norm gains, biases) and a_log stay fp32 (used in fp32
    math).  Gradients flow through the cast (vjp casts back)."""
    import jax.numpy as _jnp

    def cast(path, p):
        names = [str(getattr(k, "key", k)) for k in path]
        if p.dtype == _jnp.float32 and p.ndim >= 2 and "a_log" not in names:
            return p.astype(_jnp.bfloat16)
        return p

    return jax.tree_util.tree_map_with_path(cast, params)


def make_train_step(model_cfg: ModelConfig, train_cfg: TrainConfig):
    """Returns ``train_step(state, batch, lr) -> (state, metrics)``."""

    def grads_and_metrics(params, batch):
        if train_cfg.cast_params_once:
            cast_fn = _cast_params_tree
            def _loss_cast(p, b, mc, tc):
                return _loss_fn(cast_fn(p), b, mc, tc)
            loss_impl = _loss_cast
        else:
            loss_impl = _loss_fn
        vg = jax.value_and_grad(loss_impl, has_aux=True)
        mb = train_cfg.microbatch
        b = batch["labels"].shape[0]
        if mb <= 0 or mb >= b:
            (loss, metrics), grads = vg(params, batch, model_cfg, train_cfg)
            return loss, metrics, grads
        if b % mb != 0:
            raise ValueError(f"batch {b} not divisible by microbatch {mb}")
        n_micro = b // mb
        micro = jax.tree.map(
            lambda a: a.reshape(n_micro, mb, *a.shape[1:]), batch)
        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
        zero_m = {"nll": 0.0, "aux": 0.0, "accuracy": 0.0}
        zero_m = jax.tree.map(jnp.float32, zero_m)

        def body(carry, mb_batch):
            loss_a, metrics_a, grads_a = carry
            (loss, metrics), grads = vg(params, mb_batch, model_cfg,
                                        train_cfg)
            grads_a = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / n_micro,
                                   grads_a, grads)
            metrics_a = jax.tree.map(lambda a, m: a + m / n_micro,
                                     metrics_a, metrics)
            return (loss_a + loss / n_micro, metrics_a, grads_a), None

        (loss, metrics, grads), _ = lax.scan(
            body, (jnp.zeros((), jnp.float32), zero_m, zero_g), micro)
        return loss, metrics, grads

    def train_step(state: TrainState, batch, lr):
        loss, metrics, grads = grads_and_metrics(state.params, batch)
        grads, gnorm = _clip_by_global_norm(grads, train_cfg.grad_clip)
        ef = state.ef_error
        if train_cfg.grad_compression:
            grads, ef = ef_compress_tree(grads, ef)

        if train_cfg.optimizer == "adamw":
            params, opt = adamw_update(grads, state.opt, state.params, lr=lr,
                                       weight_decay=train_cfg.weight_decay)
        else:
            method = "qr" if train_cfg.optimizer.endswith("qr") else "ns"
            params, opt = muon_update(grads, state.opt, state.params, lr=lr,
                                      momentum=train_cfg.momentum,
                                      weight_decay=train_cfg.weight_decay,
                                      method=method,
                                      qr_q_method=train_cfg.qr_q_method,
                                      qr_shard_leaves=train_cfg.qr_shard_leaves,
                                      batched_ortho=train_cfg.batched_ortho)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return TrainState(params=params, opt=opt, ef_error=ef), metrics

    return train_step
