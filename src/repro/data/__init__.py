"""Data pipeline substrate (deterministic, shardable, resumable)."""

from repro.data.pipeline import DataConfig, MemmapCorpus, SyntheticLM, make_pipeline

__all__ = ["DataConfig", "SyntheticLM", "MemmapCorpus", "make_pipeline"]
