"""Data pipeline: deterministic, shardable, resumable token streams.

Two sources:
  * ``SyntheticLM`` — Philox-keyed synthetic token streams.  Fully
    deterministic in (seed, step, sample-index), so a restart from a
    checkpointed ``step`` reproduces the exact batch sequence regardless
    of world size or interruption point (the fault-tolerance contract).
  * ``MemmapCorpus`` — fixed-window sampling from a flat token file
    (np.memmap), deterministic in the same way.

Batches are host-built numpy and placed onto the mesh with the batch
sharding from ``distributed.sharding`` by the trainer.  For the
embedding-input (vlm/audio stub) architectures, the pipeline synthesizes
frame/patch embeddings from the token stream (the frontend stub).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "MemmapCorpus", "make_pipeline"]


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    embedding_input: bool = False
    d_model: int = 0              # needed when embedding_input
    path: Optional[str] = None    # memmap corpus path


class SyntheticLM:
    """Deterministic synthetic LM stream with a causal-learnable structure
    (next token depends on previous ones mod vocab), so optimizers show a
    real loss decrease in the examples."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.step = 0

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "data seed mismatch on restore"
        self.step = int(state["step"])

    def _batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.Generator(np.random.Philox(key=cfg.seed, counter=step))
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        # structured stream: x_{t} = (x_{t-1} * 31 + x_{t-7} + noise) % V
        x = rng.integers(0, v, size=(b, s + 8), dtype=np.int64)
        for t in range(8, s + 8):
            x[:, t] = (x[:, t - 1] * 31 + x[:, t - 7] +
                       (rng.integers(0, 4, size=b))) % v
        tokens = x[:, 7 : 7 + s].astype(np.int32)
        labels = x[:, 8 : 8 + s].astype(np.int32)
        out = {"tokens": tokens, "labels": labels}
        if cfg.embedding_input:
            emb_rng = np.random.Generator(
                np.random.Philox(key=cfg.seed + 1, counter=step))
            proj = emb_rng.standard_normal((64, cfg.d_model)).astype(np.float32)
            feats = (tokens[..., None] % 64 == np.arange(64)).astype(np.float32)
            out["embeds"] = (feats @ proj * 0.1).astype(np.float32)
            del out["tokens"]
        return out

    def __iter__(self) -> Iterator[dict]:
        # increment BEFORE yield: generator suspension must not leave the
        # checkpointable cursor stale by one (a consumed batch would be
        # replayed after restore).
        while True:
            b = self._batch_at(self.step)
            self.step += 1
            yield b

    def peek(self, step: int) -> dict:
        return self._batch_at(step)


class MemmapCorpus:
    """Deterministic window sampler over a flat int32 token file."""

    def __init__(self, cfg: DataConfig):
        if cfg.path is None:
            raise ValueError("MemmapCorpus needs cfg.path")
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")
        self.step = 0
        if len(self.tokens) < cfg.seq_len + 1:
            raise ValueError("corpus shorter than seq_len")

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, state: dict) -> None:
        self.step = int(state["step"])

    def _batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.Generator(np.random.Philox(key=cfg.seed, counter=step))
        starts = rng.integers(0, len(self.tokens) - cfg.seq_len - 1,
                              size=cfg.global_batch)
        rows = np.stack([self.tokens[s : s + cfg.seq_len + 1] for s in starts])
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        while True:
            b = self._batch_at(self.step)
            self.step += 1
            yield b


def make_pipeline(cfg: DataConfig):
    return MemmapCorpus(cfg) if cfg.path else SyntheticLM(cfg)
