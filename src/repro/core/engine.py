"""Wavefront macro-op execution engine — each DAG level is one in-place
Pallas dispatch over a tile workspace.

:mod:`repro.core.tilegraph` levelizes the tiled-QR task DAG statically;
this module *executes* that schedule.  It is the software analogue of the
paper's Reconfigurable Data-path orchestration (§5): every DAG node runs
as a fused macro operation (:mod:`repro.kernels.macro_ops`), and every
wavefront's same-kind task batch lowers to a **single** ``pallas_call``
whose grid enumerates the level's independent tiles.

Execution model (``use_kernel=True``):

  * the factorization state lives in a ``(p, q, nb, nb)`` tile
    **workspace** plus four small reflector-state arrays (``d_t`` /
    ``d_taus`` for GEQRT, ``t_t`` / ``t_taus`` for TSQRT);
  * task coordinates are **scalar-prefetch** index arrays; block
    index-maps and in-kernel DMA read/write tiles *directly* from the
    workspace (held in ``ANY`` memory space), so the gather ->
    vmap-compute -> ``.at[].set`` scatter round trips of the old
    scheduler never happen;
  * every ``pallas_call`` aliases the workspace (and the state arrays it
    writes) input -> output, so the whole factor loop is in place — no
    fresh tile array materializes per wavefront;
  * :func:`factor_tiles` additionally **donates** the workspace
    (``jax.jit(..., donate_argnums=(0,))``), so callers outside a jit
    don't retain a second copy of the input buffer either.

``use_kernel=False`` is the pure-jnp oracle lowering: the *same*
value-level macro-op bodies, vmapped over each batch with functional
updates.  Both lowerings trace identical op sequences per task, so the
engine path is **bitwise** equal to the oracle (asserted in
tests/test_engine.py and tests/test_conformance.py).  Interpret-mode
Pallas (the CPU default) is preserved via the ``interpret`` knob /
``macro_ops.default_interpret``.

Both the single-device ``tiled`` backend and the per-domain local sweeps
of the multi-device ``sharded_tiled`` backend execute through this
engine; the planner's ``"macro_ops"`` kernel policy carries its VMEM
accounting.
"""

from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import macro_ops

Array = jax.Array

__all__ = [
    "FactorState",
    "factor_tiles",
    "wavefront_task_arrays",
]

_KIND_ORDER = ("GEQRT", "LARFB", "TSQRT", "SSRFB")


class FactorState(NamedTuple):
    """Factored tile state: packed reflectors + per-task block reflectors.

    tiles:  (p, q, nb, nb) — diagonal tiles hold V1 strictly below / R on
            and above the diagonal; tiles (i, k), i > k hold the TSQRT V2;
            tiles (k, j), j > k hold R blocks.
    d_t:    (r, nb, nb) GEQRT block reflectors T;  d_taus: (r, nb)
    t_t:    (p, r, nb, nb) TSQRT block reflectors; t_taus: (p, r, nb)
    """

    tiles: Array
    d_t: Array
    d_taus: Array
    t_t: Array
    t_taus: Array


@functools.lru_cache(maxsize=None)
def wavefront_task_arrays(p: int, q: int
                          ) -> Tuple[Dict[str, np.ndarray], ...]:
    """The static schedule as dispatchable batches: one dict per
    wavefront mapping kind -> int32 ``(ntasks, 3)`` array of (k, i, j)."""
    from repro.core.tilegraph import wavefronts  # lazy: tilegraph imports us

    out: List[Dict[str, np.ndarray]] = []
    for wf in wavefronts(p, q):
        by_kind: Dict[str, List] = {}
        for t in wf:
            by_kind.setdefault(t.kind, []).append(t)
        out.append({kind: np.array([[t.k, t.i, t.j] for t in tasks],
                                   dtype=np.int32)
                    for kind, tasks in by_kind.items()})
    return tuple(out)


# ---------------------------------------------------------------------------
# jnp lowering — the bitwise oracle (vmap of the same macro-op bodies)
# ---------------------------------------------------------------------------

def _batched(body, *args):
    """vmap the macro-op body over a task batch — except singleton
    batches, which run unbatched: XLA lowers a batch-1 ``dot_general``
    through a different (reshaped) contraction than the plain dot the
    Pallas body traces, breaking bitwise parity between the lowerings.
    For every batch size > 1 the per-slice results ARE bitwise equal to
    the unbatched body (stress-checked in tests/test_engine.py)."""
    if args[0].shape[0] == 1:
        out = body(*(x[0] for x in args))
        if isinstance(out, tuple):
            return tuple(o[None] for o in out)
        return out[None]
    return jax.vmap(body)(*args)


def _jnp_wavefront(state: FactorState, by_kind: Dict[str, np.ndarray]
                   ) -> FactorState:
    tiles, d_t, d_taus, t_t, t_taus = state
    # Gathers read the pre-wavefront tiles; same-level tasks touch
    # disjoint tile regions (TSQRT merges into the upper triangle only,
    # preserving the GEQRT V1 below the diagonal), so deferring all
    # scatters to the end of the level is value-identical to the
    # engine's in-place execution.
    updates = []
    if "GEQRT" in by_kind:
        kk = by_kind["GEQRT"][:, 0]
        packed, t, taus = _batched(macro_ops.geqrt_body, tiles[kk, kk])
        d_t = d_t.at[kk].set(t)
        d_taus = d_taus.at[kk].set(taus)
        updates.append((kk, kk, packed))
    if "LARFB" in by_kind:
        kk = by_kind["LARFB"][:, 0]
        jj = by_kind["LARFB"][:, 2]
        out = _batched(macro_ops.larfb_body, tiles[kk, kk], d_t[kk],
                       tiles[kk, jj])
        updates.append((kk, jj, out))
    if "TSQRT" in by_kind:
        kk = by_kind["TSQRT"][:, 0]
        ii = by_kind["TSQRT"][:, 1]
        merged, v2, t, taus = _batched(
            macro_ops.tsqrt_body, tiles[kk, kk], tiles[ii, kk])
        t_t = t_t.at[ii, kk].set(t)
        t_taus = t_taus.at[ii, kk].set(taus)
        updates.append((kk, kk, merged))
        updates.append((ii, kk, v2))
    if "SSRFB" in by_kind:
        kk = by_kind["SSRFB"][:, 0]
        ii = by_kind["SSRFB"][:, 1]
        jj = by_kind["SSRFB"][:, 2]
        ck, ci = _batched(
            macro_ops.ssrfb_body,
            tiles[ii, kk], t_t[ii, kk], tiles[kk, jj], tiles[ii, jj])
        updates.append((kk, jj, ck))
        updates.append((ii, jj, ci))
    for ri, ci_, vals in updates:
        tiles = tiles.at[ri, ci_].set(vals)
    return FactorState(tiles, d_t, d_taus, t_t, t_taus)


# ---------------------------------------------------------------------------
# Pallas lowering — one in-place pallas_call per (wavefront, kind) batch
# ---------------------------------------------------------------------------

def _any_spec():
    return pl.BlockSpec(memory_space=pltpu.ANY)


def _dispatch_geqrt(state: FactorState, idx: np.ndarray, nb: int,
                    interpret: bool) -> FactorState:
    tiles, d_t, d_taus, t_t, t_taus = state
    kk = jnp.asarray(idx[:, 0])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(idx.shape[0],),
        in_specs=[
            _any_spec(),
            pl.BlockSpec((1, nb, nb), lambda g, kk: (kk[g], 0, 0)),
            pl.BlockSpec((1, nb), lambda g, kk: (kk[g], 0)),
        ],
        out_specs=[
            _any_spec(),
            pl.BlockSpec((1, nb, nb), lambda g, kk: (kk[g], 0, 0)),
            pl.BlockSpec((1, nb), lambda g, kk: (kk[g], 0)),
        ],
        scratch_shapes=[pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.SemaphoreType.DMA],
    )
    tiles, d_t, d_taus = pl.pallas_call(
        macro_ops.geqrt_wavefront_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(tiles.shape, tiles.dtype),
                   jax.ShapeDtypeStruct(d_t.shape, d_t.dtype),
                   jax.ShapeDtypeStruct(d_taus.shape, d_taus.dtype)],
        input_output_aliases={1: 0, 2: 1, 3: 2},
        interpret=interpret,
    )(kk, tiles, d_t, d_taus)
    return FactorState(tiles, d_t, d_taus, t_t, t_taus)


def _dispatch_larfb(state: FactorState, idx: np.ndarray, nb: int,
                    interpret: bool) -> FactorState:
    tiles, d_t, d_taus, t_t, t_taus = state
    kk = jnp.asarray(idx[:, 0])
    jj = jnp.asarray(idx[:, 2])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(idx.shape[0],),
        in_specs=[
            _any_spec(),
            pl.BlockSpec((1, nb, nb), lambda g, kk, jj: (kk[g], 0, 0)),
        ],
        out_specs=[_any_spec()],
        scratch_shapes=[pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.SemaphoreType.DMA],
    )
    (tiles,) = pl.pallas_call(
        macro_ops.larfb_wavefront_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(tiles.shape, tiles.dtype)],
        input_output_aliases={2: 0},
        interpret=interpret,
    )(kk, jj, tiles, d_t)
    return FactorState(tiles, d_t, d_taus, t_t, t_taus)


def _dispatch_tsqrt(state: FactorState, idx: np.ndarray, nb: int,
                    interpret: bool) -> FactorState:
    tiles, d_t, d_taus, t_t, t_taus = state
    kk = jnp.asarray(idx[:, 0])
    ii = jnp.asarray(idx[:, 1])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(idx.shape[0],),
        in_specs=[
            _any_spec(),
            pl.BlockSpec((1, 1, nb, nb),
                         lambda g, kk, ii: (ii[g], kk[g], 0, 0)),
            pl.BlockSpec((1, 1, nb), lambda g, kk, ii: (ii[g], kk[g], 0)),
        ],
        out_specs=[
            _any_spec(),
            pl.BlockSpec((1, 1, nb, nb),
                         lambda g, kk, ii: (ii[g], kk[g], 0, 0)),
            pl.BlockSpec((1, 1, nb), lambda g, kk, ii: (ii[g], kk[g], 0)),
        ],
        scratch_shapes=[pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.SemaphoreType.DMA],
    )
    tiles, t_t, t_taus = pl.pallas_call(
        macro_ops.tsqrt_wavefront_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(tiles.shape, tiles.dtype),
                   jax.ShapeDtypeStruct(t_t.shape, t_t.dtype),
                   jax.ShapeDtypeStruct(t_taus.shape, t_taus.dtype)],
        input_output_aliases={2: 0, 3: 1, 4: 2},
        interpret=interpret,
    )(kk, ii, tiles, t_t, t_taus)
    return FactorState(tiles, d_t, d_taus, t_t, t_taus)


def _dispatch_ssrfb(state: FactorState, idx: np.ndarray, nb: int,
                    interpret: bool) -> FactorState:
    tiles, d_t, d_taus, t_t, t_taus = state
    kk = jnp.asarray(idx[:, 0])
    ii = jnp.asarray(idx[:, 1])
    jj = jnp.asarray(idx[:, 2])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(idx.shape[0],),
        in_specs=[
            _any_spec(),
            pl.BlockSpec((1, 1, nb, nb),
                         lambda g, kk, ii, jj: (ii[g], kk[g], 0, 0)),
        ],
        out_specs=[_any_spec()],
        scratch_shapes=[pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.SemaphoreType.DMA],
    )
    (tiles,) = pl.pallas_call(
        macro_ops.ssrfb_wavefront_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(tiles.shape, tiles.dtype)],
        input_output_aliases={3: 0},
        interpret=interpret,
    )(kk, ii, jj, tiles, t_t)
    return FactorState(tiles, d_t, d_taus, t_t, t_taus)


_DISPATCH = {
    "GEQRT": _dispatch_geqrt,
    "LARFB": _dispatch_larfb,
    "TSQRT": _dispatch_tsqrt,
    "SSRFB": _dispatch_ssrfb,
}


def _pallas_wavefront(state: FactorState, by_kind: Dict[str, np.ndarray],
                      nb: int, interpret: bool) -> FactorState:
    # Kind order is part of the in-place contract: within a level the
    # only tile shared between kinds is the diagonal, and its two users
    # touch disjoint regions (TSQRT writes the upper triangle, LARFB
    # reads the strictly-lower V1), so any order is value-identical —
    # the canonical order just keeps dispatch deterministic.
    for kind in _KIND_ORDER:
        if kind in by_kind:
            state = _DISPATCH[kind](state, by_kind[kind], nb, interpret)
    return state


# ---------------------------------------------------------------------------
# the factor loop
# ---------------------------------------------------------------------------

def _factor_impl(tiles: Array, p: int, q: int, nb: int, use_kernel: bool,
                 interpret: bool) -> FactorState:
    r = min(p, q)
    dt = tiles.dtype
    state = FactorState(
        tiles,
        jnp.zeros((r, nb, nb), dt),
        jnp.zeros((r, nb), dt),
        jnp.zeros((p, r, nb, nb), dt),
        jnp.zeros((p, r, nb), dt),
    )
    step = (functools.partial(_pallas_wavefront, nb=nb, interpret=interpret)
            if use_kernel else _jnp_wavefront)
    for by_kind in wavefront_task_arrays(p, q):
        state = step(state, by_kind)
    return state


_factor_jit = jax.jit(_factor_impl, static_argnums=(1, 2, 3, 4, 5),
                      donate_argnums=(0,))


def factor_tiles(tiles: Array, *, p: int, q: int, nb: int,
                 use_kernel: bool = False,
                 interpret: Optional[bool] = None) -> FactorState:
    """Run the full wavefront schedule over a ``(p, q, nb, nb)`` workspace.

    The workspace argument is **donated** — the engine factors in place
    and the caller's buffer is consumed (pass ``tiles.copy()`` to keep
    it).  ``use_kernel=True`` dispatches each (wavefront, kind) batch as
    one Pallas macro-op call (``interpret=None`` resolves via the
    ``macro_ops`` kernel policy: compiled on TPU, interpret elsewhere);
    ``use_kernel=False`` runs the bitwise-identical jnp oracle lowering.
    """
    if tiles.ndim != 4 or tiles.shape[:2] != (p, q) \
            or tiles.shape[2:] != (nb, nb):
        raise ValueError(
            f"expected a ({p}, {q}, {nb}, {nb}) tile workspace, "
            f"got {tiles.shape}")
    if use_kernel:
        from repro.core.plan import kernel_vmem_budget

        itemsize = jnp.dtype(tiles.dtype).itemsize
        need = macro_ops.engine_vmem_bytes(nb, itemsize)
        budget = kernel_vmem_budget("macro_ops")
        if need > budget:
            raise ValueError(
                f"tile ({nb},{nb}) exceeds VMEM budget "
                f"({need} > {budget}); shrink the tile")
    if interpret is None:
        interpret = macro_ops.default_interpret()
    return _factor_jit(tiles, p, q, nb, bool(use_kernel), bool(interpret))
