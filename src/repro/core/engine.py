"""Wavefront macro-op execution engine — the levelized tile DAG as one
in-place Pallas dispatch per level, or ONE per factorization.

:mod:`repro.core.tilegraph` levelizes the tiled-QR task DAG statically;
this module *executes* that schedule.  It is the software analogue of the
paper's Reconfigurable Data-path orchestration (§5): every DAG node runs
as a fused macro operation (:mod:`repro.kernels.macro_ops`).  Two kernel
lowerings of the same schedule exist, selected by ``dispatch_mode``:

  * ``"wavefront"`` — every wavefront's same-kind task batch lowers to a
    **single** ``pallas_call`` whose grid enumerates the level's
    independent tiles (~``levels x kinds`` dispatches per factorization);
  * ``"megakernel"`` — the whole schedule flattens into one
    scalar-prefetched **task table** (one ``(kind, k, i, j)`` record per
    DAG node, wavefront-ordered, NOOP-padded to a rectangular
    ``(levels, slots)`` grid) and executes as **one** persistent
    ``pallas_call``: the grid walks the table, each step switches on
    ``kind`` into the same macro-op bodies, and operand DMA is
    **double-buffered** — while task t computes, task t+1's tiles are
    already streaming into the other buffer half (back-to-back macro-op
    streaming, the paper's RDP §5 in software).  Prefetch never crosses
    a level boundary (the level barrier that preserves inter-wavefront
    dependencies), and one-ahead prefetch within a level is value-exact
    because a task's reads never overlap its predecessor's writes —
    asserted per adjacent pair at table-build time (the canonical kind
    order is load-bearing there: it keeps the one same-level same-tile
    overlap, LARFB's strictly-lower V1 read vs TSQRT's upper-triangle
    merge of the diagonal tile, read-before-write and region-disjoint).
    Consecutive tasks reading the same tile reuse the resident copy
    instead of re-touching HBM.  ``dispatch_mode=None`` resolves automatically: megakernel when
    the task table fits the scalar-prefetch budget and the
    double-buffered working set fits VMEM (both read off the
    ``"macro_ops"`` kernel policy), wavefront otherwise —
    :func:`resolve_dispatch_mode` / :func:`schedule_stats`.

Execution model (``use_kernel=True``):

  * the factorization state lives in a ``(p, q, nb, nb)`` tile
    **workspace** plus four small reflector-state arrays (``d_t`` /
    ``d_taus`` for GEQRT, ``t_t`` / ``t_taus`` for TSQRT);
  * task coordinates are **scalar-prefetch** index arrays; block
    index-maps and in-kernel DMA read/write tiles *directly* from the
    workspace (held in ``ANY`` memory space), so the gather ->
    vmap-compute -> ``.at[].set`` scatter round trips of the old
    scheduler never happen;
  * every ``pallas_call`` aliases the workspace (and the state arrays it
    writes) input -> output, so the whole factor loop is in place — no
    fresh tile array materializes per wavefront;
  * :func:`factor_tiles` additionally **donates** the workspace
    (``jax.jit(..., donate_argnums=(0,))``), so callers outside a jit
    don't retain a second copy of the input buffer either.

``use_kernel=False`` is the pure-jnp oracle lowering: the *same*
value-level macro-op bodies, vmapped over each batch with functional
updates.  Both lowerings trace identical op sequences per task, so the
engine path is **bitwise** equal to the oracle (asserted in
tests/test_engine.py and tests/test_conformance.py).  Interpret-mode
Pallas (the CPU default) is preserved via the ``interpret`` knob /
``macro_ops.default_interpret``.

Both the single-device ``tiled`` backend and the per-domain local sweeps
of the multi-device ``sharded_tiled`` backend execute through this
engine; the planner's ``"macro_ops"`` kernel policy carries its VMEM
accounting.
"""

from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import macro_ops
from repro.observability import metrics as _metrics
from repro.observability import profiler as _profiler
from repro.observability import trace as _trace

Array = jax.Array

__all__ = [
    "DISPATCH_MODES",
    "FactorState",
    "explain_dispatch_mode",
    "factor_tiles",
    "factor_tiles_batched",
    "megakernel_task_table",
    "modeled_dma_bytes",
    "resolve_dispatch_mode",
    "schedule_stats",
    "wavefront_task_arrays",
]

_KIND_ORDER = ("GEQRT", "LARFB", "TSQRT", "SSRFB")

#: The engine's kernel lowerings of the static schedule (see module doc).
DISPATCH_MODES = ("wavefront", "megakernel")


class FactorState(NamedTuple):
    """Factored tile state: packed reflectors + per-task block reflectors.

    tiles:  (p, q, nb, nb) — diagonal tiles hold V1 strictly below / R on
            and above the diagonal; tiles (i, k), i > k hold the TSQRT V2;
            tiles (k, j), j > k hold R blocks.
    d_t:    (r, nb, nb) GEQRT block reflectors T;  d_taus: (r, nb)
    t_t:    (p, r, nb, nb) TSQRT block reflectors; t_taus: (p, r, nb)
    """

    tiles: Array
    d_t: Array
    d_taus: Array
    t_t: Array
    t_taus: Array


# lru-cache purity contract: the @lru_cache'd helpers below
# (wavefront_task_arrays, megakernel_task_table, modeled_dma_bytes) are
# PURE functions of their integer arguments — schedule structure and
# traffic counts only.  None of them may read the "macro_ops" kernel
# policy budgets: budgets are runtime knobs (re-registrable, and swept
# by repro.tuning), so every budget comparison happens un-cached at call
# time (explain_dispatch_mode / schedule_stats / _check_dispatch).
# Asserted in tests/test_engine.py (budget-staleness regression).
@functools.lru_cache(maxsize=None)
def wavefront_task_arrays(p: int, q: int
                          ) -> Tuple[Dict[str, np.ndarray], ...]:
    """The static schedule as dispatchable batches: one dict per
    wavefront mapping kind -> int32 ``(ntasks, 3)`` array of (k, i, j)."""
    from repro.core.tilegraph import wavefronts  # lazy: tilegraph imports us

    out: List[Dict[str, np.ndarray]] = []
    for wf in wavefronts(p, q):
        by_kind: Dict[str, List] = {}
        for t in wf:
            by_kind.setdefault(t.kind, []).append(t)
        out.append({kind: np.array([[t.k, t.i, t.j] for t in tasks],
                                   dtype=np.int32)
                    for kind, tasks in by_kind.items()})
    return tuple(out)


# ---------------------------------------------------------------------------
# megakernel task table — the whole schedule as one scalar-prefetch array
# ---------------------------------------------------------------------------
#
# One int32 row per (level, slot) grid cell.  Valid tasks fill each
# level's leading slots in canonical kind order (then (k, i, j)); the
# rectangular remainder is NOOP padding.  Besides the task identity the
# row carries everything the kernel's double-buffered DMA needs decided
# statically: the ordered operand-tile coordinates, whether the
# predecessor slot already prefetched this task's operands, whether this
# slot should prefetch its successor's (never across a level boundary —
# the inter-wavefront barrier), and per-operand reuse flags (successor
# reads the same tile the current task holds resident -> VMEM-local copy
# instead of an HBM fetch).

_KIND_ID = {kind: n for n, kind in enumerate(_KIND_ORDER)}
_NOOP = len(_KIND_ORDER)

_COL_KIND, _COL_K, _COL_I, _COL_J = 0, 1, 2, 3
_COL_R0 = 4            # 3 (row, col) operand-tile coords: columns 4..9
_COL_FETCHED = 10      # operands already streaming (predecessor prefetch)
_COL_PREFETCH = 11     # this slot prefetches the successor's operands
_COL_REUSE0 = 12       # per-operand buffer-reuse flags: columns 12..14
_COL_REUSET = 15       # block-reflector (T) operand reuse flag
_NCOLS = 16


def _task_reads(kind: str, k: int, i: int, j: int) -> List[Tuple[int, int]]:
    """Ordered workspace tiles a task DMAs in (matches the body args)."""
    if kind == "GEQRT":
        return [(k, k)]
    if kind == "LARFB":
        return [(k, k), (k, j)]
    if kind == "TSQRT":
        return [(k, k), (i, k)]
    return [(i, k), (k, j), (i, j)]  # SSRFB


def _task_writes(kind: str, k: int, i: int, j: int) -> set:
    """Workspace tiles a task DMAs back out."""
    if kind == "GEQRT":
        return {(k, k)}
    if kind == "LARFB":
        return {(k, j)}
    if kind == "TSQRT":
        return {(k, k), (i, k)}
    return {(k, j), (i, j)}  # SSRFB


def _task_t_source(kind: str, k: int, i: int, j: int):
    """Identity of the block-reflector (T) operand, or None."""
    if kind == "LARFB":
        return ("d_t", k)
    if kind == "SSRFB":
        return ("t_t", i, k)
    return None


def task_count(p: int, q: int) -> int:
    """Closed-form DAG size: step k contributes (p - k)(q - k) tasks."""
    return sum((p - k) * (q - k) for k in range(min(p, q)))


@functools.lru_cache(maxsize=None)
def megakernel_task_table(p: int, q: int
                          ) -> Tuple[np.ndarray, int, int]:
    """The flattened schedule: ``(table, nlevels, nslots)`` with ``table``
    an int32 ``(nlevels * nslots, 16)`` array, one row per grid cell.

    Builds the prefetch/reuse chains and *verifies* the invariants the
    one-ahead double buffering relies on: level-wide, no two tasks write
    the same tile; and per adjacent slot pair, the successor's reads
    never overlap the current task's writes (so fetching task t+1's
    operands before task t's write-back is value-exact, not just
    race-tolerant).  NOTE the second invariant is a property of the
    canonical ``_KIND_ORDER`` slot ordering, not of levels at large —
    e.g. LARFB reads the diagonal tile a same-level TSQRT later merges
    into (disjoint regions, but the same tile); ordering LARFB first
    keeps every adjacent window clean.  Deepening the prefetch window
    beyond one task would need a correspondingly wider assert.
    """
    levels: List[List[Tuple[str, int, int, int]]] = []
    for by_kind in wavefront_task_arrays(p, q):
        rows = [(kind, int(k), int(i), int(j))
                for kind in _KIND_ORDER
                for k, i, j in by_kind.get(kind, ())]
        levels.append(rows)
    nlevels = len(levels)
    nslots = max(len(rows) for rows in levels)
    tab = np.zeros((nlevels * nslots, _NCOLS), np.int32)
    tab[:, _COL_KIND] = _NOOP
    for lv, rows in enumerate(levels):
        writes = [w for task in rows for w in _task_writes(*task)]
        assert len(writes) == len(set(writes)), "same-level write overlap"
        for s, task in enumerate(rows):
            kind, k, i, j = task
            t = lv * nslots + s
            tab[t, _COL_KIND] = _KIND_ID[kind]
            tab[t, _COL_K], tab[t, _COL_I], tab[t, _COL_J] = k, i, j
            for b, (r, c) in enumerate(_task_reads(*task)):
                tab[t, _COL_R0 + 2 * b] = r
                tab[t, _COL_R0 + 2 * b + 1] = c
        for s in range(len(rows) - 1):
            cur, nxt = rows[s], rows[s + 1]
            t = lv * nslots + s
            cw = _task_writes(*cur)
            nr = _task_reads(*nxt)
            # The level-local safety invariant behind one-ahead prefetch.
            assert not (set(nr) & cw), (cur, nxt)
            tab[t, _COL_PREFETCH] = 1
            tab[t + 1, _COL_FETCHED] = 1
            cr = _task_reads(*cur)
            for b in range(min(len(cr), len(nr))):
                if nr[b] == cr[b]:
                    tab[t + 1, _COL_REUSE0 + b] = 1
            cts = _task_t_source(*cur)
            if cts is not None and cts == _task_t_source(*nxt):
                tab[t + 1, _COL_REUSET] = 1
    return tab, nlevels, nslots


def table_fits(p: int, q: int, budget: int) -> Tuple[bool, int]:
    """Does the ``(p, q)`` megakernel task table fit ``budget`` bytes?
    Returns ``(fits, bytes)``.  Checks the closed-form lower bound first
    so grids whose table cannot fit anyway (the symbolic DAG is
    O(p q min(p, q)) tasks) are rejected without ever being levelized."""
    bound = task_count(p, q) * _NCOLS * 4
    if bound > budget:
        return False, bound
    nbytes = int(megakernel_task_table(p, q)[0].nbytes)
    return nbytes <= budget, nbytes


def explain_dispatch_mode(p: int, q: int, nb: int, itemsize: int = 4, *,
                          vmem_budget: Optional[int] = None,
                          table_budget: Optional[int] = None
                          ) -> Tuple[str, str]:
    """The ``dispatch_mode=None`` auto rule with its concrete reason:
    ``(mode, reason)``.  ``"megakernel"`` when the task table fits the
    scalar-prefetch budget AND the double-buffered tile working set fits
    VMEM, ``"wavefront"`` otherwise — and the reason string names exactly
    which budget rejected it.

    Budgets default to the CURRENT ``"macro_ops"`` kernel policy, read at
    call time — deliberately un-cached, so re-registering the policy (or
    a tuner sweeping budgets) changes the verdict immediately (the
    staleness-vs-lru contract documented at
    :func:`wavefront_task_arrays`).  Explicit ``vmem_budget`` /
    ``table_budget`` overrides let a sweep ask "what would auto pick
    under budget X" without touching the registry."""
    from repro.core.plan import kernel_table_budget, kernel_vmem_budget

    need = macro_ops.megakernel_vmem_bytes(nb, itemsize)
    vbudget = (kernel_vmem_budget("macro_ops") if vmem_budget is None
               else int(vmem_budget))
    if need > vbudget:
        return "wavefront", (
            f"megakernel working set {need} B > VMEM budget {vbudget} B "
            f"at nb={nb}, itemsize={itemsize}")
    tbudget = (kernel_table_budget("macro_ops") if table_budget is None
               else int(table_budget))
    fits, tbytes = table_fits(p, q, tbudget)
    if not fits:
        return "wavefront", (
            f"({p}, {q}) grid's task table >= {tbytes} B > "
            f"scalar-prefetch budget {tbudget} B")
    return "megakernel", (
        f"task table {tbytes} B <= budget {tbudget} B and working set "
        f"{need} B <= VMEM budget {vbudget} B")


def resolve_dispatch_mode(p: int, q: int, nb: int, itemsize: int = 4, *,
                          vmem_budget: Optional[int] = None,
                          table_budget: Optional[int] = None) -> str:
    """The ``dispatch_mode=None`` auto rule: ``"megakernel"`` when the
    task table fits the scalar-prefetch budget AND the double-buffered
    tile working set fits VMEM (both limits read off the current
    ``"macro_ops"`` kernel policy at call time, or passed explicitly),
    ``"wavefront"`` otherwise.  See :func:`explain_dispatch_mode` for
    the rule with its reasoning."""
    return explain_dispatch_mode(p, q, nb, itemsize,
                                 vmem_budget=vmem_budget,
                                 table_budget=table_budget)[0]


@functools.lru_cache(maxsize=None)
def modeled_dma_bytes(p: int, q: int, nb: int,
                      itemsize: int = 4) -> Dict[str, int]:
    """Analytic HBM tile traffic of one ``(p, q)`` factorization, per
    dispatch mode, from the per-op tile_reads/tile_writes cards
    (:mod:`repro.kernels.macro_ops`) — the traffic model behind
    ``benchmarks/bench_kernel_traffic.wavefront_traffic``, totalled.

    ``wavefront``: every task re-fetches its operand tiles from HBM each
    level.  ``megakernel``: the same minus the fetches the persistent
    kernel's double buffer serves from the resident copy
    (:func:`megakernel_reused_reads`).  ``roofline``: compulsory traffic
    — one read + one write of the whole workspace.  Reflector-state
    arrays (~nb/tile smaller) are ignored, as in the benchmark.
    """
    tile = nb * nb * itemsize
    eng = 0
    for by_kind in wavefront_task_arrays(p, q):
        for kind, idx in by_kind.items():
            op = macro_ops.MACRO_OPS[kind]
            eng += idx.shape[0] * (op.tile_reads + op.tile_writes) * tile
    reused = int(megakernel_reused_reads(p, q).sum())
    return dict(
        wavefront=eng,
        megakernel=eng - reused * tile,
        roofline=2 * p * q * tile,
    )


def schedule_stats(p: int, q: int, nb: int = 32, itemsize: int = 4, *,
                   vmem_budget: Optional[int] = None,
                   table_budget: Optional[int] = None) -> Dict[str, object]:
    """Dispatch counts, table/working-set bytes, and modeled HBM traffic
    for both dispatch modes of the ``(p, q)`` schedule — the numbers
    behind the auto rule, the ``bench_kernel_traffic``
    dispatch-reduction row, and the engine's ``engine.*`` metrics.

    Un-cached on purpose: the ``auto`` verdict (and the budget fields)
    reflect the "macro_ops" policy AT CALL TIME unless explicit budget
    overrides are passed — see the lru-cache purity contract at
    :func:`wavefront_task_arrays`."""
    from repro.core.plan import kernel_table_budget, kernel_vmem_budget

    batches = wavefront_task_arrays(p, q)
    table, nlevels, nslots = megakernel_task_table(p, q)
    ntasks = int((table[:, _COL_KIND] != _NOOP).sum())
    dma = modeled_dma_bytes(p, q, nb, itemsize)
    vbudget = (kernel_vmem_budget("macro_ops") if vmem_budget is None
               else int(vmem_budget))
    tbudget = (kernel_table_budget("macro_ops") if table_budget is None
               else int(table_budget))
    return dict(
        p=p, q=q, nb=nb, levels=nlevels, tasks=ntasks,
        vmem_budget=vbudget, table_budget=tbudget,
        roofline_dma_bytes=dma["roofline"],
        wavefront=dict(
            dispatches=sum(len(b) for b in batches),
            vmem_bytes=macro_ops.engine_vmem_bytes(nb, itemsize),
            modeled_dma_bytes=dma["wavefront"],
        ),
        megakernel=dict(
            dispatches=1,
            grid=(nlevels, nslots),
            table_shape=tuple(table.shape),
            table_bytes=int(table.nbytes),
            padded_slots=nlevels * nslots - ntasks,
            reused_tile_fetches=int(
                table[:, _COL_REUSE0:_COL_REUSE0 + 3].sum()),
            reused_t_fetches=int(table[:, _COL_REUSET].sum()),
            vmem_bytes=macro_ops.megakernel_vmem_bytes(nb, itemsize),
            modeled_dma_bytes=dma["megakernel"],
        ),
        auto=resolve_dispatch_mode(p, q, nb, itemsize,
                                   vmem_budget=vbudget,
                                   table_budget=tbudget),
    )


def megakernel_reused_reads(p: int, q: int) -> np.ndarray:
    """Per-level count of operand-tile fetches the megakernel serves from
    the resident double buffer instead of HBM (traffic-model input)."""
    table, nlevels, nslots = megakernel_task_table(p, q)
    per_slot = table[:, _COL_REUSE0:_COL_REUSE0 + 3].sum(axis=1)
    return per_slot.reshape(nlevels, nslots).sum(axis=1)


# ---------------------------------------------------------------------------
# jnp lowering — the bitwise oracle (vmap of the same macro-op bodies)
# ---------------------------------------------------------------------------

def _batched(body, *args):
    """vmap the macro-op body over a task batch — except singleton
    batches, which run unbatched: XLA lowers a batch-1 ``dot_general``
    through a different (reshaped) contraction than the plain dot the
    Pallas body traces, breaking bitwise parity between the lowerings.
    For every batch size > 1 the per-slice results ARE bitwise equal to
    the unbatched body (stress-checked in tests/test_engine.py)."""
    if args[0].shape[0] == 1:
        out = body(*(x[0] for x in args))
        if isinstance(out, tuple):
            return tuple(o[None] for o in out)
        return out[None]
    return jax.vmap(body)(*args)


def _jnp_wavefront(state: FactorState, by_kind: Dict[str, np.ndarray]
                   ) -> FactorState:
    tiles, d_t, d_taus, t_t, t_taus = state
    # Gathers read the pre-wavefront tiles; same-level tasks touch
    # disjoint tile regions (TSQRT merges into the upper triangle only,
    # preserving the GEQRT V1 below the diagonal), so deferring all
    # scatters to the end of the level is value-identical to the
    # engine's in-place execution.
    updates = []
    if "GEQRT" in by_kind:
        kk = by_kind["GEQRT"][:, 0]
        packed, t, taus = _batched(macro_ops.geqrt_body, tiles[kk, kk])
        d_t = d_t.at[kk].set(t)
        d_taus = d_taus.at[kk].set(taus)
        updates.append((kk, kk, packed))
    if "LARFB" in by_kind:
        kk = by_kind["LARFB"][:, 0]
        jj = by_kind["LARFB"][:, 2]
        out = _batched(macro_ops.larfb_body, tiles[kk, kk], d_t[kk],
                       tiles[kk, jj])
        updates.append((kk, jj, out))
    if "TSQRT" in by_kind:
        kk = by_kind["TSQRT"][:, 0]
        ii = by_kind["TSQRT"][:, 1]
        merged, v2, t, taus = _batched(
            macro_ops.tsqrt_body, tiles[kk, kk], tiles[ii, kk])
        t_t = t_t.at[ii, kk].set(t)
        t_taus = t_taus.at[ii, kk].set(taus)
        updates.append((kk, kk, merged))
        updates.append((ii, kk, v2))
    if "SSRFB" in by_kind:
        kk = by_kind["SSRFB"][:, 0]
        ii = by_kind["SSRFB"][:, 1]
        jj = by_kind["SSRFB"][:, 2]
        ck, ci = _batched(
            macro_ops.ssrfb_body,
            tiles[ii, kk], t_t[ii, kk], tiles[kk, jj], tiles[ii, jj])
        updates.append((kk, jj, ck))
        updates.append((ii, jj, ci))
    for ri, ci_, vals in updates:
        tiles = tiles.at[ri, ci_].set(vals)
    return FactorState(tiles, d_t, d_taus, t_t, t_taus)


# ---------------------------------------------------------------------------
# Pallas lowering — one in-place pallas_call per (wavefront, kind) batch
# ---------------------------------------------------------------------------

def _any_spec():
    return pl.BlockSpec(memory_space=pltpu.ANY)


def _dispatch_geqrt(state: FactorState, idx: np.ndarray, nb: int,
                    interpret: bool) -> FactorState:
    tiles, d_t, d_taus, t_t, t_taus = state
    kk = jnp.asarray(idx[:, 0])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(idx.shape[0],),
        in_specs=[
            _any_spec(),
            pl.BlockSpec((1, nb, nb), lambda g, kk: (kk[g], 0, 0)),
            pl.BlockSpec((1, nb), lambda g, kk: (kk[g], 0)),
        ],
        out_specs=[
            _any_spec(),
            pl.BlockSpec((1, nb, nb), lambda g, kk: (kk[g], 0, 0)),
            pl.BlockSpec((1, nb), lambda g, kk: (kk[g], 0)),
        ],
        scratch_shapes=[pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.SemaphoreType.DMA],
    )
    tiles, d_t, d_taus = pl.pallas_call(
        macro_ops.geqrt_wavefront_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(tiles.shape, tiles.dtype),
                   jax.ShapeDtypeStruct(d_t.shape, d_t.dtype),
                   jax.ShapeDtypeStruct(d_taus.shape, d_taus.dtype)],
        input_output_aliases={1: 0, 2: 1, 3: 2},
        interpret=interpret,
    )(kk, tiles, d_t, d_taus)
    return FactorState(tiles, d_t, d_taus, t_t, t_taus)


def _dispatch_larfb(state: FactorState, idx: np.ndarray, nb: int,
                    interpret: bool) -> FactorState:
    tiles, d_t, d_taus, t_t, t_taus = state
    kk = jnp.asarray(idx[:, 0])
    jj = jnp.asarray(idx[:, 2])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(idx.shape[0],),
        in_specs=[
            _any_spec(),
            pl.BlockSpec((1, nb, nb), lambda g, kk, jj: (kk[g], 0, 0)),
        ],
        out_specs=[_any_spec()],
        scratch_shapes=[pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.SemaphoreType.DMA],
    )
    (tiles,) = pl.pallas_call(
        macro_ops.larfb_wavefront_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(tiles.shape, tiles.dtype)],
        input_output_aliases={2: 0},
        interpret=interpret,
    )(kk, jj, tiles, d_t)
    return FactorState(tiles, d_t, d_taus, t_t, t_taus)


def _dispatch_tsqrt(state: FactorState, idx: np.ndarray, nb: int,
                    interpret: bool) -> FactorState:
    tiles, d_t, d_taus, t_t, t_taus = state
    kk = jnp.asarray(idx[:, 0])
    ii = jnp.asarray(idx[:, 1])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(idx.shape[0],),
        in_specs=[
            _any_spec(),
            pl.BlockSpec((1, 1, nb, nb),
                         lambda g, kk, ii: (ii[g], kk[g], 0, 0)),
            pl.BlockSpec((1, 1, nb), lambda g, kk, ii: (ii[g], kk[g], 0)),
        ],
        out_specs=[
            _any_spec(),
            pl.BlockSpec((1, 1, nb, nb),
                         lambda g, kk, ii: (ii[g], kk[g], 0, 0)),
            pl.BlockSpec((1, 1, nb), lambda g, kk, ii: (ii[g], kk[g], 0)),
        ],
        scratch_shapes=[pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.SemaphoreType.DMA],
    )
    tiles, t_t, t_taus = pl.pallas_call(
        macro_ops.tsqrt_wavefront_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(tiles.shape, tiles.dtype),
                   jax.ShapeDtypeStruct(t_t.shape, t_t.dtype),
                   jax.ShapeDtypeStruct(t_taus.shape, t_taus.dtype)],
        input_output_aliases={2: 0, 3: 1, 4: 2},
        interpret=interpret,
    )(kk, ii, tiles, t_t, t_taus)
    return FactorState(tiles, d_t, d_taus, t_t, t_taus)


def _dispatch_ssrfb(state: FactorState, idx: np.ndarray, nb: int,
                    interpret: bool) -> FactorState:
    tiles, d_t, d_taus, t_t, t_taus = state
    kk = jnp.asarray(idx[:, 0])
    ii = jnp.asarray(idx[:, 1])
    jj = jnp.asarray(idx[:, 2])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(idx.shape[0],),
        in_specs=[
            _any_spec(),
            pl.BlockSpec((1, 1, nb, nb),
                         lambda g, kk, ii, jj: (ii[g], kk[g], 0, 0)),
        ],
        out_specs=[_any_spec()],
        scratch_shapes=[pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.VMEM((nb, nb), tiles.dtype),
                        pltpu.SemaphoreType.DMA],
    )
    (tiles,) = pl.pallas_call(
        macro_ops.ssrfb_wavefront_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(tiles.shape, tiles.dtype)],
        input_output_aliases={3: 0},
        interpret=interpret,
    )(kk, ii, jj, tiles, t_t)
    return FactorState(tiles, d_t, d_taus, t_t, t_taus)


_DISPATCH = {
    "GEQRT": _dispatch_geqrt,
    "LARFB": _dispatch_larfb,
    "TSQRT": _dispatch_tsqrt,
    "SSRFB": _dispatch_ssrfb,
}


def _pallas_wavefront(state: FactorState, by_kind: Dict[str, np.ndarray],
                      nb: int, interpret: bool,
                      level: Optional[int] = None) -> FactorState:
    # Kind order is part of the in-place contract: within a level the
    # only tile shared between kinds is the diagonal, and its two users
    # touch disjoint regions (TSQRT writes the upper triangle, LARFB
    # reads the strictly-lower V1), so any order is value-identical —
    # the canonical order just keeps dispatch deterministic.
    for kind in _KIND_ORDER:
        if kind in by_kind:
            with _profiler.annotate(_profiler.kernel_label(kind, level)):
                state = _DISPATCH[kind](state, by_kind[kind], nb, interpret)
    return state


# ---------------------------------------------------------------------------
# Pallas lowering — megakernel: ONE pallas_call for the whole schedule
# ---------------------------------------------------------------------------
#
# The grid is (levels, slots): the sequential walk over the task table.
# Each step reads its row, switches on kind into the same value-level
# macro-op bodies the wavefront lowering uses, and moves tiles by
# explicit DMA against the ANY-space workspace.  Operand fetch is
# double-buffered on the flat task parity: while task t computes out of
# buffer half t%2, it has already started task t+1's fetches into the
# other half (or a VMEM-local copy when t+1 re-reads a tile t holds
# resident).  Start and wait reconstruct their copy descriptors from the
# same table row, so semaphore pairing is static.  Prefetch stops at
# level boundaries: the first slot of each level fetches synchronously,
# after every prior write-back has completed — the wavefront barrier.

def _op_copies(tab_ref, t, phase, ws_at, dt_at, tt_at, opbuf, tbuf, sems,
               start: bool):
    """Start (or wait for) the operand DMAs of task-table row ``t`` into
    buffer half ``phase``.  ``start`` is trace-time: the wait side
    rebuilds the identical descriptors, so each semaphore is started
    exactly once per wait.  ``ws_at`` / ``dt_at`` / ``tt_at`` are
    accessor closures over the workspace refs — the batched lowering
    binds the batch index there, the single-matrix one binds nothing."""
    kind = tab_ref[t, _COL_KIND]

    def go(cp):
        cp.start() if start else cp.wait()

    def tile_fetch(b):
        r = tab_ref[t, _COL_R0 + 2 * b]
        c = tab_ref[t, _COL_R0 + 2 * b + 1]
        reuse = tab_ref[t, _COL_REUSE0 + b]

        @pl.when(reuse == 1)
        def _():
            go(pltpu.make_async_copy(opbuf.at[1 - phase, b],
                                     opbuf.at[phase, b], sems.at[phase, b]))

        @pl.when(reuse == 0)
        def _():
            go(pltpu.make_async_copy(ws_at(r, c), opbuf.at[phase, b],
                                     sems.at[phase, b]))

    tile_fetch(0)  # every kind reads at least one tile

    @pl.when(kind != _KIND_ID["GEQRT"])
    def _():
        tile_fetch(1)

    @pl.when(kind == _KIND_ID["SSRFB"])
    def _():
        tile_fetch(2)

    def t_fetch(src):
        reuse = tab_ref[t, _COL_REUSET]

        @pl.when(reuse == 1)
        def _():
            go(pltpu.make_async_copy(tbuf.at[1 - phase], tbuf.at[phase],
                                     sems.at[phase, 3]))

        @pl.when(reuse == 0)
        def _():
            go(pltpu.make_async_copy(src, tbuf.at[phase], sems.at[phase, 3]))

    @pl.when(kind == _KIND_ID["LARFB"])
    def _():
        t_fetch(dt_at(tab_ref[t, _COL_K]))

    @pl.when(kind == _KIND_ID["SSRFB"])
    def _():
        t_fetch(tt_at(tab_ref[t, _COL_I], tab_ref[t, _COL_K]))


def _sync_put(src, dst, sem):
    cp = pltpu.make_async_copy(src, dst, sem)
    cp.start()
    cp.wait()


def _megakernel_step(tab_ref, ws, d_t, d_taus, t_t, t_taus,
                     opbuf, tbuf, outbuf, taubuf, sems, wbsem,
                     lvl, slot, nslots_axis: int, b=None):
    """One task-table slot: fetch/prefetch bookkeeping + kind-switched
    compute.  ``b`` is the (optional) batch index of the stacked-workspace
    lowering — every batch element replays the SAME table, so the only
    difference is the leading workspace index the accessors bind."""
    if b is None:
        ws_at = lambda r, c: ws.at[r, c]                    # noqa: E731
        dt_at = lambda k: d_t.at[k]                         # noqa: E731
        dtaus_at = lambda k: d_taus.at[k]                   # noqa: E731
        tt_at = lambda i, k: t_t.at[i, k]                   # noqa: E731
        ttaus_at = lambda i, k: t_taus.at[i, k]             # noqa: E731
    else:
        ws_at = lambda r, c: ws.at[b, r, c]                 # noqa: E731
        dt_at = lambda k: d_t.at[b, k]                      # noqa: E731
        dtaus_at = lambda k: d_taus.at[b, k]                # noqa: E731
        tt_at = lambda i, k: t_t.at[b, i, k]                # noqa: E731
        ttaus_at = lambda i, k: t_taus.at[b, i, k]          # noqa: E731

    t = lvl * pl.num_programs(nslots_axis) + slot
    phase = jax.lax.rem(t, 2)
    kind = tab_ref[t, _COL_KIND]
    k = tab_ref[t, _COL_K]
    i = tab_ref[t, _COL_I]
    j = tab_ref[t, _COL_J]
    valid = kind != _NOOP

    # -- operands: self-fetch at level heads, else already in flight ----
    @pl.when(valid & (tab_ref[t, _COL_FETCHED] == 0))
    def _():
        _op_copies(tab_ref, t, phase, ws_at, dt_at, tt_at, opbuf, tbuf,
                   sems, start=True)

    @pl.when(valid)
    def _():
        _op_copies(tab_ref, t, phase, ws_at, dt_at, tt_at, opbuf, tbuf,
                   sems, start=False)

    # -- double buffering: start the successor's fetches before compute -
    @pl.when(tab_ref[t, _COL_PREFETCH] == 1)
    def _():
        _op_copies(tab_ref, t + 1, 1 - phase, ws_at, dt_at, tt_at, opbuf,
                   tbuf, sems, start=True)

    # -- compute: switch on kind into the shared macro-op bodies --------
    @pl.when(kind == _KIND_ID["GEQRT"])
    def _():
        packed, tmat, taus = macro_ops.geqrt_body(opbuf[phase, 0])
        outbuf[0] = packed
        outbuf[1] = tmat
        taubuf[...] = taus
        _sync_put(outbuf.at[0], ws_at(k, k), wbsem)
        _sync_put(outbuf.at[1], dt_at(k), wbsem)
        _sync_put(taubuf, dtaus_at(k), wbsem)

    @pl.when(kind == _KIND_ID["LARFB"])
    def _():
        outbuf[0] = macro_ops.larfb_body(opbuf[phase, 0], tbuf[phase],
                                         opbuf[phase, 1])
        _sync_put(outbuf.at[0], ws_at(k, j), wbsem)

    @pl.when(kind == _KIND_ID["TSQRT"])
    def _():
        merged, v2, tmat, taus = macro_ops.tsqrt_body(opbuf[phase, 0],
                                                      opbuf[phase, 1])
        outbuf[0] = merged
        outbuf[1] = v2
        outbuf[2] = tmat
        taubuf[...] = taus
        _sync_put(outbuf.at[0], ws_at(k, k), wbsem)
        _sync_put(outbuf.at[1], ws_at(i, k), wbsem)
        _sync_put(outbuf.at[2], tt_at(i, k), wbsem)
        _sync_put(taubuf, ttaus_at(i, k), wbsem)

    @pl.when(kind == _KIND_ID["SSRFB"])
    def _():
        ck, ci = macro_ops.ssrfb_body(opbuf[phase, 0], tbuf[phase],
                                      opbuf[phase, 1], opbuf[phase, 2])
        outbuf[0] = ck
        outbuf[1] = ci
        _sync_put(outbuf.at[0], ws_at(k, j), wbsem)
        _sync_put(outbuf.at[1], ws_at(i, j), wbsem)


def megakernel_kernel(tab_ref, ws_in, dt_in, dtaus_in, tt_in, ttaus_in,
                      ws, d_t, d_taus, t_t, t_taus,
                      opbuf, tbuf, outbuf, taubuf, sems, wbsem):
    """One task-table slot per grid cell; the whole schedule is one call."""
    del ws_in, dt_in, dtaus_in, tt_in, ttaus_in  # aliased in place
    _megakernel_step(tab_ref, ws, d_t, d_taus, t_t, t_taus,
                     opbuf, tbuf, outbuf, taubuf, sems, wbsem,
                     lvl=pl.program_id(0), slot=pl.program_id(1),
                     nslots_axis=1)


def megakernel_batched_kernel(tab_ref, ws_in, dt_in, dtaus_in, tt_in,
                              ttaus_in, ws, d_t, d_taus, t_t, t_taus,
                              opbuf, tbuf, outbuf, taubuf, sems, wbsem):
    """The batched megakernel: grid ``(B, levels, slots)`` — ONE
    pallas_call factors the whole stacked ``(B, p, q, nb, nb)`` workspace
    by replaying the SAME task table per batch element.  The flat task
    index (and with it the double-buffer parity and the prefetch chain)
    restarts at every batch boundary: the last slot of a schedule never
    prefetches (``_COL_PREFETCH`` is 0 there) and the first slot of the
    next element self-fetches (``_COL_FETCHED`` is 0), so batch elements
    are as isolated as levels are."""
    del ws_in, dt_in, dtaus_in, tt_in, ttaus_in  # aliased in place
    _megakernel_step(tab_ref, ws, d_t, d_taus, t_t, t_taus,
                     opbuf, tbuf, outbuf, taubuf, sems, wbsem,
                     lvl=pl.program_id(1), slot=pl.program_id(2),
                     nslots_axis=2, b=pl.program_id(0))


def _dispatch_megakernel(state: FactorState, p: int, q: int, nb: int,
                         interpret: bool) -> FactorState:
    table_np, nlevels, nslots = megakernel_task_table(p, q)
    dt = state.tiles.dtype
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nlevels, nslots),
        in_specs=[_any_spec()] * 5,
        out_specs=[_any_spec()] * 5,
        scratch_shapes=[
            pltpu.VMEM((2, 3, nb, nb), dt),   # double-buffered operand tiles
            pltpu.VMEM((2, nb, nb), dt),      # double-buffered T operand
            pltpu.VMEM((3, nb, nb), dt),      # write-back staging
            pltpu.VMEM((nb,), dt),            # taus staging
            pltpu.SemaphoreType.DMA((2, 4)),  # per (phase, operand) fetch
            pltpu.SemaphoreType.DMA,          # synchronous write-back
        ],
    )
    outs = pl.pallas_call(
        megakernel_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(x.shape, x.dtype) for x in state],
        input_output_aliases={1: 0, 2: 1, 3: 2, 4: 3, 5: 4},
        interpret=interpret,
    )(jnp.asarray(table_np), *state)
    return FactorState(*outs)


def _dispatch_megakernel_batched(state: FactorState, p: int, q: int,
                                 nb: int, interpret: bool) -> FactorState:
    """ONE pallas_call for a whole bucket: the single-matrix megakernel
    grid extended by a leading batch axis.  One task table (scalar
    prefetch) is shared across the batch; the per-step VMEM working set
    is batch-invariant (``macro_ops.batched_megakernel_vmem_bytes``)."""
    table_np, nlevels, nslots = megakernel_task_table(p, q)
    batch = state.tiles.shape[0]
    dt = state.tiles.dtype
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(batch, nlevels, nslots),
        in_specs=[_any_spec()] * 5,
        out_specs=[_any_spec()] * 5,
        scratch_shapes=[
            pltpu.VMEM((2, 3, nb, nb), dt),   # double-buffered operand tiles
            pltpu.VMEM((2, nb, nb), dt),      # double-buffered T operand
            pltpu.VMEM((3, nb, nb), dt),      # write-back staging
            pltpu.VMEM((nb,), dt),            # taus staging
            pltpu.SemaphoreType.DMA((2, 4)),  # per (phase, operand) fetch
            pltpu.SemaphoreType.DMA,          # synchronous write-back
        ],
    )
    outs = pl.pallas_call(
        megakernel_batched_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(x.shape, x.dtype) for x in state],
        input_output_aliases={1: 0, 2: 1, 3: 2, 4: 3, 5: 4},
        interpret=interpret,
    )(jnp.asarray(table_np), *state)
    return FactorState(*outs)


# ---------------------------------------------------------------------------
# the factor loop
# ---------------------------------------------------------------------------

def _factor_impl(tiles: Array, p: int, q: int, nb: int, use_kernel: bool,
                 interpret: bool, dispatch_mode: str = "wavefront"
                 ) -> FactorState:
    r = min(p, q)
    dt = tiles.dtype
    state = FactorState(
        tiles,
        jnp.zeros((r, nb, nb), dt),
        jnp.zeros((r, nb), dt),
        jnp.zeros((p, r, nb, nb), dt),
        jnp.zeros((p, r, nb), dt),
    )
    if use_kernel and dispatch_mode == "megakernel":
        with _profiler.annotate(_profiler.megakernel_label(p, q)):
            return _dispatch_megakernel(state, p, q, nb, interpret)
    for lv, by_kind in enumerate(wavefront_task_arrays(p, q)):
        if use_kernel:
            state = _pallas_wavefront(state, by_kind, nb, interpret, level=lv)
        else:
            with _profiler.annotate(f"wavefront@L{lv}"):
                state = _jnp_wavefront(state, by_kind)
    return state


_factor_jit = jax.jit(_factor_impl, static_argnums=(1, 2, 3, 4, 5, 6),
                      donate_argnums=(0,))


def _factor_batched_impl(tiles: Array, p: int, q: int, nb: int,
                         use_kernel: bool, interpret: bool,
                         dispatch_mode: str = "wavefront") -> FactorState:
    """Factor a stacked ``(B, p, q, nb, nb)`` workspace — per-slice
    BITWISE equal to B independent :func:`_factor_impl` runs.

    Megakernel mode extends the persistent kernel's grid by a leading
    batch axis (still exactly ONE ``pallas_call`` per bucket, one shared
    task table).  The wavefront and jnp lowerings vmap the single-matrix
    path — bitwise-clean because every per-task op keeps its task-batch
    shape under the outer vmap.  ``B == 1`` runs the single-matrix path
    directly: a batch-1 outer vmap lowers ``dot_general`` through a
    different contraction (the same quirk :func:`_batched` documents),
    which would break per-slice parity exactly in the degenerate case
    buckets hit most often.
    """
    batch = tiles.shape[0]
    if batch == 1:
        state = _factor_impl(tiles[0], p, q, nb, use_kernel, interpret,
                             dispatch_mode)
        return FactorState(*(x[None] for x in state))
    if use_kernel and dispatch_mode == "megakernel":
        r = min(p, q)
        dt = tiles.dtype
        state = FactorState(
            tiles,
            jnp.zeros((batch, r, nb, nb), dt),
            jnp.zeros((batch, r, nb), dt),
            jnp.zeros((batch, p, r, nb, nb), dt),
            jnp.zeros((batch, p, r, nb), dt),
        )
        with _profiler.annotate(_profiler.megakernel_label(p, q, batch)):
            return _dispatch_megakernel_batched(state, p, q, nb, interpret)
    return jax.vmap(
        lambda w: _factor_impl(w, p, q, nb, use_kernel, interpret,
                               dispatch_mode))(tiles)


_factor_batched_jit = jax.jit(_factor_batched_impl,
                              static_argnums=(1, 2, 3, 4, 5, 6),
                              donate_argnums=(0,))


def _emit_factor_metrics(tiles: Array, p: int, q: int, nb: int, mode: str,
                         use_kernel: bool, batch: int = 1) -> None:
    """Record one factor call in the ``engine.*`` metric series.

    Runs at Python-call time — which, when the entry point is reached
    from inside an outer ``jax.jit`` trace (``tiled_qr``, the serving
    bucket solvers), is *trace* time: the call happens once per compiled
    program, not once per execution.  The ``phase`` label makes that
    explicit ("trace" = counted at compile, replays are invisible;
    "execute" = counted per eager call)."""
    phase = "trace" if isinstance(tiles, jax.core.Tracer) else "execute"
    itemsize = jnp.dtype(tiles.dtype).itemsize
    kernel = "pallas" if use_kernel else "jnp"
    ndisp = 1 if (use_kernel and mode == "megakernel") else (
        sum(len(b) for b in wavefront_task_arrays(p, q)) * batch
        if use_kernel else 0)
    ntasks = task_count(p, q) * batch
    dma = modeled_dma_bytes(p, q, nb, itemsize)
    dma_mode = dma[mode] if use_kernel and mode in dma else dma["wavefront"]
    _metrics.counter("engine.factor_calls", mode=mode, kernel=kernel,
                     phase=phase).inc()
    _metrics.counter("engine.matrices", mode=mode, phase=phase).inc(batch)
    _metrics.counter("engine.dispatches", mode=mode, phase=phase).inc(ndisp)
    _metrics.counter("engine.tasks", mode=mode, phase=phase).inc(ntasks)
    _metrics.counter("engine.modeled_dma_bytes", mode=mode,
                     phase=phase).inc(dma_mode * batch)
    _metrics.counter("engine.roofline_dma_bytes", mode=mode,
                     phase=phase).inc(dma["roofline"] * batch)
    if use_kernel and mode == "megakernel":
        _metrics.gauge("engine.table_bytes", grid=f"{p}x{q}").set(
            megakernel_task_table(p, q)[0].nbytes)


def factor_tiles(tiles: Array, *, p: int, q: int, nb: int,
                 use_kernel: bool = False,
                 interpret: Optional[bool] = None,
                 dispatch_mode: Optional[str] = None) -> FactorState:
    """Run the full wavefront schedule over a ``(p, q, nb, nb)`` workspace.

    The workspace argument is **donated** — the engine factors in place
    and the caller's buffer is consumed (pass ``tiles.copy()`` to keep
    it).  ``use_kernel=True`` runs the Pallas lowering selected by
    ``dispatch_mode`` — ``"wavefront"`` (one in-place macro-op call per
    (wavefront, kind) batch), ``"megakernel"`` (the whole schedule as ONE
    persistent call over the scalar-prefetched task table with
    double-buffered tile DMA), or ``None`` for the budget-driven auto
    rule (:func:`resolve_dispatch_mode`).  ``interpret=None`` resolves
    via the ``macro_ops`` kernel policy: compiled on TPU, interpret
    elsewhere.  ``use_kernel=False`` runs the bitwise-identical jnp
    oracle lowering of the same schedule (``dispatch_mode`` is then
    irrelevant — there is no kernel to dispatch).
    """
    if tiles.ndim != 4 or tiles.shape[:2] != (p, q) \
            or tiles.shape[2:] != (nb, nb):
        raise ValueError(
            f"expected a ({p}, {q}, {nb}, {nb}) tile workspace, "
            f"got {tiles.shape}")
    mode = _check_dispatch(tiles.dtype, p, q, nb, use_kernel, dispatch_mode)
    if interpret is None:
        interpret = macro_ops.default_interpret()
    _emit_factor_metrics(tiles, p, q, nb, mode, bool(use_kernel))
    with _trace.span("engine.factor_tiles", mode=mode, grid=f"{p}x{q}",
                     nb=nb, kernel=bool(use_kernel)) as sp:
        return sp.sync(_factor_jit(tiles, p, q, nb, bool(use_kernel),
                                   bool(interpret), mode))


def _check_dispatch(dtype, p: int, q: int, nb: int, use_kernel: bool,
                    dispatch_mode: Optional[str], batched: bool = False
                    ) -> str:
    """Shared mode resolution + budget guards of the factor entry points.

    Returns the concrete dispatch mode; raises when a *forced* mode does
    not fit its VMEM / task-table budget (auto never picks past them).
    The batched lowering changes neither limit: the batch axis is an
    outer sequential grid dimension over one shared table, so the
    per-step working set and the scalar-prefetch bytes are
    batch-invariant (``macro_ops.batched_megakernel_vmem_bytes``)."""
    if dispatch_mode not in (None,) + DISPATCH_MODES:
        raise ValueError(
            f"unknown dispatch_mode {dispatch_mode!r}; expected one of "
            f"{DISPATCH_MODES} or None (auto)")
    from repro.robustness import inject as _inject

    if _inject.enabled():
        # Chaos hook: a forced VMEM-budget rejection fires from the
        # exact site a real over-budget workspace raises (trace time,
        # Python level — no jaxpr impact), so the escalation ladder
        # sees an indistinguishable failure.
        _inject.check("vmem", f"p{p}q{q}nb{nb}:{dispatch_mode}")
    mode = "wavefront"
    if use_kernel:
        from repro.core.plan import kernel_table_budget, kernel_vmem_budget

        itemsize = jnp.dtype(dtype).itemsize
        mode = (resolve_dispatch_mode(p, q, nb, itemsize)
                if dispatch_mode is None else dispatch_mode)
        if mode == "megakernel":
            need = (macro_ops.batched_megakernel_vmem_bytes(nb, itemsize)
                    if batched else
                    macro_ops.megakernel_vmem_bytes(nb, itemsize))
        else:
            need = macro_ops.engine_vmem_bytes(nb, itemsize)
        budget = kernel_vmem_budget("macro_ops")
        if need > budget:
            raise ValueError(
                f"tile ({nb},{nb}) exceeds the {mode} VMEM budget "
                f"({need} > {budget}); shrink the tile")
        if mode == "megakernel":
            # The scalar-prefetch side of the same contract: a forced
            # megakernel must also fit its task table (auto never picks
            # it past the budget, and an oversized table would only fail
            # opaquely at Mosaic compile time).
            tbudget = kernel_table_budget("macro_ops")
            fits, tbytes = table_fits(p, q, tbudget)
            if not fits:
                raise ValueError(
                    f"({p}, {q}) grid's megakernel task table "
                    f"(>= {tbytes} bytes) exceeds the scalar-prefetch "
                    f"budget ({tbudget}); grow the tile or use "
                    f"dispatch_mode='wavefront'")
    return mode


def factor_tiles_batched(tiles: Array, *, p: int, q: int, nb: int,
                         use_kernel: bool = False,
                         interpret: Optional[bool] = None,
                         dispatch_mode: Optional[str] = None) -> FactorState:
    """Run the full wavefront schedule over a stacked ``(B, p, q, nb, nb)``
    workspace — B independent factorizations in one dispatch, the
    serving layer's batched entry point (:mod:`repro.serving.qr_service`).

    Per batch slice the result is **bitwise** equal to
    :func:`factor_tiles` on that slice (asserted across the conformance
    matrix in tests/test_qr_service.py and tests/test_conformance.py).
    On the kernel path, ``dispatch_mode="megakernel"`` extends the
    persistent kernel's grid by a leading batch axis — still exactly ONE
    ``pallas_call`` for the whole bucket, sharing one scalar-prefetched
    task table across the batch; ``"wavefront"`` and the jnp-oracle
    lowering (``use_kernel=False``) vmap the single-matrix path.  As in
    :func:`factor_tiles`, the workspace argument is **donated**.
    """
    if tiles.ndim != 5 or tiles.shape[1:3] != (p, q) \
            or tiles.shape[3:] != (nb, nb):
        raise ValueError(
            f"expected a (B, {p}, {q}, {nb}, {nb}) stacked tile "
            f"workspace, got {tiles.shape}")
    if tiles.shape[0] < 1:
        raise ValueError("batched workspace needs at least one slice")
    mode = _check_dispatch(tiles.dtype, p, q, nb, use_kernel, dispatch_mode,
                           batched=True)
    if interpret is None:
        interpret = macro_ops.default_interpret()
    _emit_factor_metrics(tiles, p, q, nb, mode, bool(use_kernel),
                         batch=int(tiles.shape[0]))
    with _trace.span("engine.factor_tiles_batched", mode=mode,
                     grid=f"{p}x{q}", nb=nb, batch=int(tiles.shape[0]),
                     kernel=bool(use_kernel)) as sp:
        return sp.sync(_factor_batched_jit(tiles, p, q, nb, bool(use_kernel),
                                           bool(interpret), mode))
