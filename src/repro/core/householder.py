"""Classical Householder Transform (HT) QR factorization — paper §2.2 / Algorithm 2.

LAPACK ``DGEQR2`` semantics throughout the library:

    H_j = I - tau_j * v_j v_j^T,   v_j[0] = 1,   A = Q R,
    Q = H_0 H_1 ... H_{k-1},       k = min(m, n).

The factored form is packed LAPACK-style: R in the upper triangle, the
Householder vectors (sans their implicit leading 1) below the diagonal.

This module is the *classical* realization: per column, the Householder
matrix / reflection is applied to the trailing matrix in two separate
passes (GEMV then rank-1 update), mirroring the paper's Algorithm 2 where
``P = I - 2 v v^T`` is formed conceptually before the trailing update.
The Modified HT (paper §4) lives in :mod:`repro.core.mht`.

Everything is shape-static and ``jit``-compatible: the column loop is a
``lax.fori_loop`` over masked full-width operations.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

__all__ = [
    "house_vector",
    "geqr2",
    "geqr2_explicit_p",
    "form_q",
    "apply_q",
    "unpack_r",
    "unpack_v",
]


def _safe_sign(x: Array) -> Array:
    """sign(x) with sign(0) := 1 (LAPACK convention for dlarfg)."""
    return jnp.where(x >= 0, jnp.ones_like(x), -jnp.ones_like(x))


def _zeros_carry(shape, like: Array) -> Array:
    """Zeros for a loop carry that inherit the varying-manual-axes type of
    ``like`` — required when the factorizations run inside ``shard_map``
    (a plain ``jnp.zeros`` carry is device-invariant and the scan carry
    types would mismatch)."""
    z = jnp.zeros(shape, like.dtype)
    return z + jnp.zeros((), like.dtype) * like.reshape(-1)[0]


def house_vector(x: Array, offset: Array | int) -> Tuple[Array, Array, Array]:
    """Compute the Householder reflector annihilating ``x[offset+1:]``.

    Rows ``< offset`` are ignored (masked to zero); the pivot is
    ``x[offset]``.  Returns ``(v, tau, beta)`` with ``v[offset] = 1``,
    ``v[i] = 0`` for ``i < offset``, and

        (I - tau v v^T) x = [*, ..., beta, 0, ..., 0]^T.

    Numerically this follows LAPACK ``dlarfg``:
        beta = -sign(x0) * ||x[offset:]||_2
        tau  = (beta - x0) / beta
        v[offset+1:] = x[offset+1:] / (x0 - beta)

    Degenerate case ``||x[offset+1:]|| == 0`` gives ``tau = 0`` (H = I).
    """
    m = x.shape[0]
    idx = jnp.arange(m)
    below = idx > offset
    at = idx == offset

    x0 = jnp.sum(jnp.where(at, x, 0.0))
    tail = jnp.where(below, x, 0.0)
    # Scale for overflow safety: ||tail||^2 computed on normalized data.
    scale = jnp.maximum(jnp.max(jnp.abs(tail)), jnp.abs(x0))
    scale = jnp.where(scale == 0.0, 1.0, scale)
    t = tail / scale
    x0s = x0 / scale
    tail_norm2 = jnp.sum(t * t)
    norm = scale * jnp.sqrt(x0s * x0s + tail_norm2)

    beta = -_safe_sign(x0) * norm
    degenerate = tail_norm2 == 0.0

    denom = jnp.where(degenerate, 1.0, x0 - beta)
    v = jnp.where(below, x / denom, 0.0)
    v = v + at.astype(x.dtype)  # v[offset] = 1
    tau = jnp.where(degenerate, 0.0, (beta - x0) / jnp.where(beta == 0.0, 1.0, beta))
    beta = jnp.where(degenerate, x0, beta)
    return v, tau, beta


def _ht_update_two_pass(a: Array, v: Array, tau: Array, col: Array) -> Array:
    """Classical trailing update, two passes (paper Algorithm 2 / fig 6).

    Pass 1 (DGEMV):  w = tau * (v^T A)
    Pass 2 (DGER):   A <- A - v w
    Columns ``<= col`` are left untouched (they hold R / packed V).
    """
    n = a.shape[1]
    trailing = jnp.arange(n) > col
    w = tau * (v @ a)  # (n,)
    update = jnp.outer(v, w)
    return a - jnp.where(trailing[None, :], update, 0.0)


def _write_packed_column(
    a: Array, v: Array, beta: Array, col: Array, pivot_row: Array | int | None = None
) -> Array:
    """Store beta at the pivot row and v (below the pivot) into column ``col``.

    ``pivot_row`` defaults to ``col`` (the square/aligned case); blocked
    panel factorizations pass ``pivot_row = row0 + local_col``.
    """
    m = a.shape[0]
    pivot = col if pivot_row is None else pivot_row
    idx = jnp.arange(m)
    newcol = jnp.where(idx == pivot, beta, jnp.where(idx > pivot, v, 0.0))
    oldcol = jnp.take(a, col, axis=1)
    newcol = jnp.where(idx < pivot, oldcol, newcol)
    return a.at[:, col].set(jnp.asarray(newcol, a.dtype))


@functools.partial(jax.jit, static_argnames=("num_cols",))
def geqr2(a: Array, *, num_cols: int | None = None) -> Tuple[Array, Array]:
    """Classical HT QR (LAPACK ``DGEQR2``): two-pass trailing updates.

    Returns ``(packed, taus)`` where ``packed`` holds R in its upper
    triangle and the Householder vectors below the diagonal, and
    ``taus`` has length ``min(m, n)``.
    """
    m, n = a.shape
    k = min(m, n) if num_cols is None else num_cols
    if m > 1 and k == min(m, n) and n >= m:
        # For square/wide, the last pivot still needs annihilation of 0 rows
        # below it only when m > k; keep full k columns.
        pass
    taus0 = _zeros_carry((k,), a)

    def body(j, carry):
        a, taus = carry
        x = jnp.take(a, j, axis=1)
        v, tau, beta = house_vector(x, j)
        # Store the Householder vector below the diagonal of column j, with
        # v[j] implicit (=1); store beta (the new R diagonal) at (j, j).
        a = _ht_update_two_pass(a, jnp.asarray(v, a.dtype), jnp.asarray(tau, a.dtype), j)
        a = _write_packed_column(a, jnp.asarray(v, a.dtype), jnp.asarray(beta, a.dtype), j)
        taus = taus.at[j].set(jnp.asarray(tau, a.dtype))
        return a, taus

    a_out, taus = lax.fori_loop(0, k, body, (a, taus0))
    return a_out, taus


@functools.partial(jax.jit, static_argnames=())
def geqr2_explicit_p(a: Array) -> Tuple[Array, Array]:
    """Textbook classical HT: materialize ``P = I - tau v v^T`` and GEMM.

    This is the paper's fig-6 DAG made literal — used for DAG/FLOP analysis
    and as the slowest baseline in the QR-variant benchmark. O(m^2 n) per
    column instead of O(mn).
    """
    m, n = a.shape
    k = min(m, n)
    taus0 = _zeros_carry((k,), a)
    eye = jnp.eye(m, dtype=a.dtype)

    def body(j, carry):
        a, taus = carry
        x = jnp.take(a, j, axis=1)
        v, tau, beta = house_vector(x, j)
        v = jnp.asarray(v, a.dtype)
        p = eye - jnp.asarray(tau, a.dtype) * jnp.outer(v, v)  # P materialized
        a_new = p @ a
        trailing = jnp.arange(n)[None, :] > j
        a = jnp.where(trailing, a_new, a)
        a = _write_packed_column(a, v, jnp.asarray(beta, a.dtype), j)
        taus = taus.at[j].set(jnp.asarray(tau, a.dtype))
        return a, taus

    a_out, taus = lax.fori_loop(0, k, body, (a, taus0))
    return a_out, taus


def unpack_r(packed: Array, n: int | None = None) -> Array:
    """Extract R (upper triangular, k x n) from the packed factorization."""
    m, ncols = packed.shape
    n = ncols if n is None else n
    k = min(m, ncols)
    r = jnp.triu(packed)[:k, :n]
    return r


def unpack_v(packed: Array) -> Array:
    """Extract V (m x k, unit lower trapezoidal) from the packed form."""
    m, n = packed.shape
    k = min(m, n)
    v = jnp.tril(packed[:, :k], -1)
    v = v + jnp.eye(m, k, dtype=packed.dtype)
    return v


def apply_q(packed: Array, taus: Array, c: Array, *, transpose: bool = False) -> Array:
    """Apply Q (or Q^T) from the packed factorization to ``c`` (m x p).

    Q   = H_0 H_1 ... H_{k-1}          (applied back-to-front)
    Q^T = H_{k-1} ... H_1 H_0          (applied front-to-back)
    """
    m = packed.shape[0]
    k = taus.shape[0]
    v_all = unpack_v(packed)  # (m, k)

    def apply_one(j, c):
        v = jnp.take(v_all, j, axis=1)
        tau = jnp.take(taus, j)
        w = tau * (v @ c)
        return c - jnp.outer(v, w)

    if transpose:
        c = lax.fori_loop(0, k, apply_one, c)
    else:
        c = lax.fori_loop(0, k, lambda i, c: apply_one(k - 1 - i, c), c)
    return c


def form_q(packed: Array, taus: Array, *, full: bool = False) -> Array:
    """Materialize Q — thin (m x k) by default, or full (m x m)."""
    m = packed.shape[0]
    k = taus.shape[0]
    cols = m if full else k
    eye = jnp.eye(m, cols, dtype=packed.dtype)
    return apply_q(packed, taus, eye)


# -- registry -----------------------------------------------------------------
from repro.core.plan import MethodSpec, register_method  # noqa: E402

register_method(MethodSpec(
    name="geqr2",
    factor=lambda a, cfg: geqr2(a),
    description="classical HT, two-pass updates (LAPACK DGEQR2)",
))
