"""Modified Householder Transform (MHT) — paper §4, Algorithms 6-8.

The classical HT trailing update is two dependent passes over the trailing
matrix:  (1) w = tau * v^T A  (DGEMV),  (2) A <- A - v w  (DGER) — with the
Householder matrix P = I - tau v v^T conceptually materialized in between
(paper fig 6).  MHT fuses them into a single macro-operation per element

    a_ij <- a_ij - tau * v_i * (v . a_:j)

(paper eq. 12, the "new macro operation" mapped onto the DOT4 RDP).  The
DAG gets shallower — more operations per level (higher beta) — while FLOP
count and numerics are unchanged.

On TPU the macro-op is realized by the Pallas kernel
:mod:`repro.kernels.mht_panel`, which keeps the whole panel resident in
VMEM across *all* of its columns (the analogue of the paper's PE Local
Memory) so the per-column dot + update never round-trips HBM.  This module
provides the pure-jnp realization (also the kernel's oracle) and the
dispatch between the two.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.householder import _write_packed_column, _zeros_carry, house_vector

Array = jax.Array

__all__ = ["geqr2_ht", "mht_update", "mht_panel_jnp"]


def mht_update(a: Array, v: Array, tau: Array, col: Array) -> Array:
    """Fused MHT trailing update: ``A <- A - v (tau (v^T A))`` in one pass.

    Columns ``<= col`` are preserved.  This is the jnp form of the paper's
    macro-op; under XLA the dot and the rank-1 subtract fuse into a single
    HBM pass, and on the Pallas path the fusion is explicit in VMEM.
    """
    n = a.shape[1]
    trailing = jnp.arange(n) > col
    # One logical traversal: w folds into the update expression.
    update = v[:, None] * (tau * (v @ a))[None, :]
    return a - jnp.where(trailing[None, :], update, 0.0)


@functools.partial(jax.jit, static_argnames=("num_cols",))
def geqr2_ht(a: Array, *, num_cols: int | None = None) -> Tuple[Array, Array]:
    """MHT QR factorization (``DGEQR2HT``, paper Algorithm 7).

    Identical packed output/taus as :func:`repro.core.householder.geqr2`
    (same reflectors, same R) — only the trailing-update dataflow differs.
    """
    m, n = a.shape
    k = min(m, n) if num_cols is None else num_cols
    taus0 = _zeros_carry((k,), a)

    def body(j, carry):
        a, taus = carry
        x = jnp.take(a, j, axis=1)
        v, tau, beta = house_vector(x, j)
        v = jnp.asarray(v, a.dtype)
        tau_c = jnp.asarray(tau, a.dtype)
        a = mht_update(a, v, tau_c, j)
        a = _write_packed_column(a, v, jnp.asarray(beta, a.dtype), j)
        taus = taus.at[j].set(tau_c)
        return a, taus

    a_out, taus = lax.fori_loop(0, k, body, (a, taus0))
    return a_out, taus


def mht_panel_jnp(panel: Array) -> Tuple[Array, Array]:
    """Factor a full (tall) panel with MHT — pure-jnp oracle for the
    :mod:`repro.kernels.mht_panel` Pallas kernel.

    Input ``panel`` is (m, b) with m >= b; output is the packed factor and
    the b taus.  Semantically identical to ``geqr2_ht(panel)`` — kept as a
    distinct entry point so kernel tests pin against exactly the function
    the kernel replaces.
    """
    return geqr2_ht(panel)


def geqr2_ht_batched(a: Array) -> Tuple[Array, Array]:
    """vmapped MHT over a batch of matrices (leading axis).

    Used by the MoE path of the QR optimizer: expert tensors (E, d, ff)
    factor as E independent QRs.
    """
    return jax.vmap(lambda x: geqr2_ht(x))(a)


# -- registry -----------------------------------------------------------------
from repro.core.plan import MethodSpec, QRConfig, register_method  # noqa: E402


def _factor_geqr2_ht(a: Array, cfg: QRConfig) -> Tuple[Array, Array]:
    if cfg.use_kernel:
        from repro.kernels import ops  # lazy: kernels.ref imports core

        return ops.mht_panel(a, row0=0)
    return geqr2_ht(a)


def _vmem_geqr2_ht(m: int, n: int, cfg: QRConfig) -> int:
    # The whole matrix is one VMEM-resident panel on the kernel path.
    from repro.kernels import ops

    return ops.vmem_bytes_mht_panel(m, n)


register_method(MethodSpec(
    name="geqr2_ht",
    factor=_factor_geqr2_ht,
    kernel_backed=True,
    vmem_bytes=_vmem_geqr2_ht,
    description="MHT, fused macro-op updates (LAPACK DGEQR2HT)",
))
