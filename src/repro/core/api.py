"""Public QR API — thin wrappers over the :mod:`repro.core.plan` planner.

    qr(a, config=QRConfig(...))  -> (Q, R) or R       (batched: a.ndim >= 2)
    orthogonalize(m)             -> sign-fixed thin Q (optimizer primitive)
    lstsq(a, b)                  -> QR-based least-squares solve
    qr_algorithm_eig(a, iters)   -> eigenvalues via the QR algorithm (§1 App. 2)

Every realization lives in the method registry (see
:func:`repro.core.plan.available_methods`); the built-ins:

    "geqr2"      classical HT, two-pass updates          (LAPACK_DGEQR2)
    "geqr2_ht"   MHT, fused macro-op updates             (LAPACK_DGEQR2HT)
    "geqrf"      blocked WY, classical HT panels         (LAPACK_DGEQRF)
    "geqrf_ht"   blocked WY, MHT panels                  (LAPACK_DGEQRFHT)
    "geqrf_fori" blocked MHT, fori_loop panels           (optimizer path)
    "tsqr"       tall-skinny tree QR (single device)
    "tiled"      tiled task-graph QR via the wavefront macro-op engine
                 (GEQRT/TSQRT/LARFB/SSRFB; block = tile size;
                 use_kernel=True -> Pallas dispatch per
                 QRConfig.dispatch_mode: "wavefront" = one in-place
                 call per DAG level, "megakernel" = the whole schedule
                 as ONE persistent call over a scalar-prefetched task
                 table with double-buffered tile DMA, None = auto by
                 table/VMEM budgets; False -> the bitwise-identical
                 jnp oracle)
    "sharded_tiled"  multi-device tiled QR: per-device row-block
                 wavefront domains via shard_map + TSQR-style R merge
                 tree (ndomains = device domains; testable on CPU with
                 XLA_FLAGS=--xla_force_host_platform_device_count=8)
    "auto"       planner heuristics: tall-skinny => tsqr, large
                 near-square => tiled, past the tiled ceiling with >1
                 device => sharded_tiled, panel-fits-VMEM on TPU =>
                 kernel-backed geqrf_ht, single panel => geqr2_ht

Selection, batching (vmap over leading dims), and the Pallas kernel
policy (``use_kernel=None`` => compiled on TPU when the panel fits VMEM,
interpret-mode available on CPU) are all decided by
``plan(shape, dtype, config) -> QRSolver``; prefer holding a solver when
factorizing many same-shaped matrices.  Configuration is by
``config=QRConfig(...)`` only — the pre-planner string kwargs
(``method=``/``block=``/...) were removed after their deprecation cycle.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.plan import QRConfig, plan

Array = jax.Array

__all__ = ["qr", "orthogonalize", "lstsq", "qr_algorithm_eig",
           "QRConfig", "plan"]

_DEFAULT = QRConfig()


def qr(a: Array, *, config: Optional[QRConfig] = None
       ) -> Tuple[Array, Array] | Array:
    """QR factorization with a registry-selected HT/MHT realization.

    ``config.mode``: "reduced" -> (Q thin m x k, R k x n); "r" -> R only;
    "full" -> (Q m x m, R m x n).  Inputs with leading batch dims
    (``a.ndim > 2``) are factorized batch-wise via the solver's vmap rule.
    ``config=None`` plans with ``QRConfig()`` (method "auto").
    """
    if a.ndim < 2:
        raise ValueError(f"qr expects a matrix, got shape {a.shape}")
    cfg = _DEFAULT if config is None else config
    solver = plan(a.shape, a.dtype, cfg)
    if cfg.verify is not False and not isinstance(a, jax.core.Tracer):
        # Health-checked path (QRConfig.verify / $REPRO_VERIFY): verify
        # the planned result and walk the degradation ladder on failure
        # (repro.robustness.escalate).  Resolution is host-side and
        # never fires under a trace, so verify-off stays jaxpr-identical
        # to solver.solve — the lazy import keeps the robustness layer
        # out of the import graph until the knob is actually on.
        from repro.robustness.verify import verify_enabled

        if verify_enabled(cfg.verify):
            from repro.robustness.escalate import checked_solve

            return checked_solve(solver, a)
    return solver.solve(a)


def orthogonalize(m_in: Array, *, config: Optional[QRConfig] = None) -> Array:
    """Nearest-column-space orthonormal factor via QR with sign fixing.

    Returns Q * diag(sign(diag(R))) so the result is a deterministic,
    continuous function of the input (the optimizer primitive; wide
    matrices are handled by factorizing the transpose).  With
    ``config=QRConfig()`` (method "auto") tall-skinny momentum routes
    through TSQR."""
    if m_in.ndim < 2:
        raise ValueError(f"orthogonalize expects a matrix, got shape {m_in.shape}")
    cfg = (_DEFAULT if config is None else config).replace(
        mode="reduced", sign_fix=True)
    transpose = m_in.shape[-2] < m_in.shape[-1]
    a = jnp.swapaxes(m_in, -1, -2) if transpose else m_in
    q = plan(a.shape, a.dtype, cfg).orthogonalize(a)
    return jnp.swapaxes(q, -1, -2) if transpose else q


def lstsq(a: Array, b: Array, *, config: Optional[QRConfig] = None) -> Array:
    """Least-squares solve ``min ||a x - b||`` via QR (m >= n).

    x = R^{-1} Q^T b — the numerically stable path the paper motivates for
    Kalman filtering (§1, Application 1).  With ``config=QRConfig()``
    tall-skinny systems route through TSQR."""
    cfg = (_DEFAULT if config is None else config).replace(
        mode="reduced", sign_fix=False)
    return plan(a.shape, a.dtype, cfg).lstsq(a, b)


def qr_algorithm_eig(a: Array, *, iters: int = 200,
                     config: Optional[QRConfig] = None) -> Array:
    """Eigenvalues of symmetric ``a`` via the (unshifted) QR algorithm —
    paper §1 Application 2, Algorithm 1:  A_{k} = R_k Q_k."""
    cfg = (_DEFAULT if config is None else config).replace(
        mode="reduced", sign_fix=False)
    solver = plan(a.shape, a.dtype, cfg)

    def body(_, ak):
        q, r = solver.solve(ak)
        return r @ q

    ak = jax.lax.fori_loop(0, iters, body, a)
    return jnp.sort(jnp.diagonal(ak))[::-1]
