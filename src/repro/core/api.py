"""Public QR API — the paper's contribution as a composable JAX module.

    qr(a, method=...)          -> (Q, R)  or R
    orthogonalize(m)           -> sign-fixed thin Q (optimizer primitive)
    lstsq(a, b)                -> QR-based least-squares solve
    qr_algorithm_eig(a, iters) -> eigenvalues via the QR algorithm (paper §1 App. 2)

Methods:
    "geqr2"      classical HT, two-pass updates          (LAPACK_DGEQR2)
    "geqr2_ht"   MHT, fused macro-op updates             (LAPACK_DGEQR2HT)
    "geqrf"      blocked WY, classical HT panels         (LAPACK_DGEQRF)
    "geqrf_ht"   blocked WY, MHT panels [default]        (LAPACK_DGEQRFHT)
    "tsqr"       tall-skinny tree QR (single device)
Kernel-backed variants run the Pallas mht_panel / wy_trailing kernels
(``use_kernel=True``; interpret-mode on CPU).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import blocked, householder, mht, tsqr as tsqr_mod

Array = jax.Array

__all__ = ["qr", "orthogonalize", "lstsq", "qr_algorithm_eig", "METHODS"]

METHODS = ("geqr2", "geqr2_ht", "geqrf", "geqrf_ht", "tsqr")


def _factor(a: Array, method: str, block: int, use_kernel: bool):
    if method == "geqr2":
        return householder.geqr2(a)
    if method == "geqr2_ht":
        if use_kernel:
            from repro.kernels import ops

            return ops.mht_panel(a, row0=0)
        return mht.geqr2_ht(a)
    if method == "geqrf":
        return blocked.geqrf(a, block=block, panel_method="ht", use_kernel=False)
    if method == "geqrf_ht":
        return blocked.geqrf(a, block=block, panel_method="mht", use_kernel=use_kernel)
    raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")


def qr(
    a: Array,
    *,
    method: str = "geqrf_ht",
    mode: str = "reduced",
    block: int = 32,
    use_kernel: bool = False,
) -> Tuple[Array, Array] | Array:
    """QR factorization with selectable HT/MHT realization.

    mode: "reduced" -> (Q thin m x k, R k x n); "r" -> R only;
          "full" -> (Q m x m, R m x n).
    """
    if a.ndim != 2:
        raise ValueError(f"qr expects a matrix, got shape {a.shape}")
    m, n = a.shape
    k = min(m, n)

    if method == "tsqr":
        if m < 4 * n:
            raise ValueError("tsqr expects tall-skinny input (m >= 4n)")
        nb = max(2, min(8, m // max(n, 1)))
        while m % nb != 0:
            nb -= 1
        if mode == "r":
            return tsqr_mod.tsqr_r(a, nblocks=nb)
        q, r = tsqr_mod.tsqr_qr(a, nblocks=nb)
        if mode == "full":
            raise ValueError("tsqr produces thin Q only")
        return q, r

    packed, taus = _factor(a, method, block, use_kernel)
    r = householder.unpack_r(packed, n)
    if mode == "r":
        return r
    if mode == "reduced":
        q = householder.form_q(packed, taus)  # (m, k)
        return q, r
    if mode == "full":
        q = householder.form_q(packed, taus, full=True)
        return q, jnp.vstack([r, jnp.zeros((m - k, n), a.dtype)]) if m > k else (q, r)
    raise ValueError(f"unknown mode {mode!r}")


def orthogonalize(m_in: Array, *, method: str = "geqrf_ht", block: int = 32,
                  use_kernel: bool = False) -> Array:
    """Nearest-column-space orthonormal factor via QR with sign fixing.

    Returns Q * diag(sign(diag(R))) so the result is a deterministic,
    continuous function of the input (the optimizer primitive; wide
    matrices are handled by factorizing the transpose)."""
    transpose = m_in.shape[0] < m_in.shape[1]
    a = m_in.T if transpose else m_in
    q, r = qr(a, method=method, mode="reduced", block=block, use_kernel=use_kernel)
    signs = jnp.where(jnp.diagonal(r) >= 0, 1.0, -1.0).astype(q.dtype)
    q = q * signs[None, :]
    return q.T if transpose else q


def lstsq(a: Array, b: Array, *, method: str = "geqrf_ht", block: int = 32) -> Array:
    """Least-squares solve ``min ||a x - b||`` via QR (m >= n).

    x = R^{-1} Q^T b — the numerically stable path the paper motivates for
    Kalman filtering (§1, Application 1)."""
    m, n = a.shape
    if m < n:
        raise ValueError("lstsq expects m >= n")
    packed, taus = _factor(a, method, block, use_kernel=False)
    qtb = householder.apply_q(packed, taus, b if b.ndim == 2 else b[:, None],
                              transpose=True)
    r = householder.unpack_r(packed, n)[:n, :n]
    x = jax.scipy.linalg.solve_triangular(r, qtb[:n], lower=False)
    return x[:, 0] if b.ndim == 1 else x


def qr_algorithm_eig(a: Array, *, iters: int = 200, method: str = "geqrf_ht") -> Array:
    """Eigenvalues of symmetric ``a`` via the (unshifted) QR algorithm —
    paper §1 Application 2, Algorithm 1:  A_{k} = R_k Q_k."""

    def body(_, ak):
        q, r = qr(ak, method=method, mode="reduced")
        return r @ q

    ak = jax.lax.fori_loop(0, iters, body, a)
    return jnp.sort(jnp.diagonal(ak))[::-1]
