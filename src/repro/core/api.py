"""Public QR API — thin wrappers over the :mod:`repro.core.plan` planner.

    qr(a, config=QRConfig(...))  -> (Q, R) or R       (batched: a.ndim >= 2)
    orthogonalize(m)             -> sign-fixed thin Q (optimizer primitive)
    lstsq(a, b)                  -> QR-based least-squares solve
    qr_algorithm_eig(a, iters)   -> eigenvalues via the QR algorithm (§1 App. 2)

Every realization lives in the method registry (see
:func:`repro.core.plan.available_methods`); the built-ins:

    "geqr2"      classical HT, two-pass updates          (LAPACK_DGEQR2)
    "geqr2_ht"   MHT, fused macro-op updates             (LAPACK_DGEQR2HT)
    "geqrf"      blocked WY, classical HT panels         (LAPACK_DGEQRF)
    "geqrf_ht"   blocked WY, MHT panels                  (LAPACK_DGEQRFHT)
    "geqrf_fori" blocked MHT, fori_loop panels           (optimizer path)
    "tsqr"       tall-skinny tree QR (single device)
    "tiled"      tiled task-graph QR, wavefront-scheduled tile kernels
                 (GEQRT/TSQRT/LARFB/SSRFB; block = tile size)
    "sharded_tiled"  multi-device tiled QR: per-device row-block
                 wavefront domains via shard_map + TSQR-style R merge
                 tree (ndomains = device domains; testable on CPU with
                 XLA_FLAGS=--xla_force_host_platform_device_count=8)
    "auto"       planner heuristics: tall-skinny => tsqr, large
                 near-square => tiled, past the tiled ceiling with >1
                 device => sharded_tiled, panel-fits-VMEM on TPU =>
                 kernel-backed geqrf_ht, single panel => geqr2_ht

Selection, batching (vmap over leading dims), and the Pallas kernel
policy (``use_kernel=None`` => compiled on TPU when the panel fits VMEM,
interpret-mode available on CPU) are all decided by
``plan(shape, dtype, config) -> QRSolver``; prefer holding a solver when
factorizing many same-shaped matrices.

Legacy string kwargs (``method=``/``block=``/``use_kernel=``) are kept as
a deprecation shim and route through the same planner.
"""

from __future__ import annotations

import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.plan import QRConfig, plan

Array = jax.Array

__all__ = ["qr", "orthogonalize", "lstsq", "qr_algorithm_eig", "METHODS",
           "QRConfig", "plan"]

# Legacy constant (pre-registry); the registry is the source of truth now.
METHODS = ("geqr2", "geqr2_ht", "geqrf", "geqrf_ht", "tsqr")

_LEGACY = dict(method="geqrf_ht", mode="reduced", block=32, use_kernel=False)


def _shim_config(config: Optional[QRConfig], method, mode, block, use_kernel,
                 nblocks=None, *, sign_fix: bool = False) -> QRConfig:
    """Build a QRConfig from legacy string kwargs (deprecation shim).

    ``config`` is the new-style path and excludes every legacy kwarg.
    Without it, legacy defaults apply (``geqrf_ht``, block 32, no kernel)
    so pre-registry callers see bit-identical behavior.
    """
    if config is not None:
        if any(v is not None for v in (method, mode, block, use_kernel, nblocks)):
            raise ValueError(
                "pass either config=QRConfig(...) or legacy kwargs, not both")
        return config.replace(sign_fix=sign_fix) if sign_fix else config
    if any(v is not None for v in (method, block, use_kernel, nblocks)):
        warnings.warn(
            "string-dispatch qr kwargs (method=/block=/use_kernel=/nblocks=) "
            "are deprecated; pass config=repro.core.QRConfig(...) instead",
            DeprecationWarning, stacklevel=3)
    return QRConfig(
        method=_LEGACY["method"] if method is None else method,
        mode=_LEGACY["mode"] if mode is None else mode,
        block=_LEGACY["block"] if block is None else block,
        use_kernel=_LEGACY["use_kernel"] if use_kernel is None else use_kernel,
        nblocks=nblocks,
        sign_fix=sign_fix,
    )


def qr(
    a: Array,
    *,
    config: Optional[QRConfig] = None,
    method: Optional[str] = None,
    mode: Optional[str] = None,
    block: Optional[int] = None,
    use_kernel: Optional[bool] = None,
    nblocks: Optional[int] = None,
) -> Tuple[Array, Array] | Array:
    """QR factorization with a registry-selected HT/MHT realization.

    ``config.mode``: "reduced" -> (Q thin m x k, R k x n); "r" -> R only;
    "full" -> (Q m x m, R m x n).  Inputs with leading batch dims
    (``a.ndim > 2``) are factorized batch-wise via the solver's vmap rule.
    """
    if a.ndim < 2:
        raise ValueError(f"qr expects a matrix, got shape {a.shape}")
    cfg = _shim_config(config, method, mode, block, use_kernel, nblocks)
    return plan(a.shape, a.dtype, cfg).solve(a)


def orthogonalize(m_in: Array, *, config: Optional[QRConfig] = None,
                  method: Optional[str] = None, block: Optional[int] = None,
                  use_kernel: Optional[bool] = None) -> Array:
    """Nearest-column-space orthonormal factor via QR with sign fixing.

    Returns Q * diag(sign(diag(R))) so the result is a deterministic,
    continuous function of the input (the optimizer primitive; wide
    matrices are handled by factorizing the transpose).  With
    ``config=QRConfig()`` (method "auto") tall-skinny momentum routes
    through TSQR."""
    if m_in.ndim < 2:
        raise ValueError(f"orthogonalize expects a matrix, got shape {m_in.shape}")
    cfg = _shim_config(config, method, None, block, use_kernel, sign_fix=True)
    cfg = cfg.replace(mode="reduced")
    transpose = m_in.shape[-2] < m_in.shape[-1]
    a = jnp.swapaxes(m_in, -1, -2) if transpose else m_in
    q = plan(a.shape, a.dtype, cfg).orthogonalize(a)
    return jnp.swapaxes(q, -1, -2) if transpose else q


def lstsq(a: Array, b: Array, *, config: Optional[QRConfig] = None,
          method: Optional[str] = None, block: Optional[int] = None) -> Array:
    """Least-squares solve ``min ||a x - b||`` via QR (m >= n).

    x = R^{-1} Q^T b — the numerically stable path the paper motivates for
    Kalman filtering (§1, Application 1).  With ``config=QRConfig()``
    tall-skinny systems route through TSQR."""
    cfg = _shim_config(config, method, None, block, None)
    cfg = cfg.replace(mode="reduced", sign_fix=False)
    return plan(a.shape, a.dtype, cfg).lstsq(a, b)


def qr_algorithm_eig(a: Array, *, iters: int = 200,
                     config: Optional[QRConfig] = None,
                     method: Optional[str] = None) -> Array:
    """Eigenvalues of symmetric ``a`` via the (unshifted) QR algorithm —
    paper §1 Application 2, Algorithm 1:  A_{k} = R_k Q_k."""
    cfg = _shim_config(config, method, None, None, None)
    cfg = cfg.replace(mode="reduced", sign_fix=False)
    solver = plan(a.shape, a.dtype, cfg)

    def body(_, ak):
        q, r = solver.solve(ak)
        return r @ q

    ak = jax.lax.fori_loop(0, iters, body, a)
    return jnp.sort(jnp.diagonal(ak))[::-1]
