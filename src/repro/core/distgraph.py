"""Multi-device sharded tiled QR — wavefront domains over a device mesh.

The paper's thesis is that QR speed comes from exposing more parallel
macro operations per DAG level (§4-§5).  :mod:`repro.core.tilegraph`
realizes that on one device: the tile DAG is levelized statically and
executed by the wavefront macro-op engine (:mod:`repro.core.engine` —
one in-place Pallas dispatch per level on the kernel path, the vmapped
jnp oracle otherwise).  This module is the next rung — the hierarchical / distributed tiled QR of Dongarra et
al. (arXiv:1110.1553) on top of the PLASMA tiled algorithm (Buttari et
al., arXiv:0707.3548) — mapped onto a JAX device mesh:

  1. **Domain partition**: the p x q tile grid splits into ``d``
     contiguous row-block *domains*, one per device
     (:func:`repro.core.tilegraph.domain_rows`; rows are zero-padded so
     every device owns ``ceil(p/d)`` tile rows — padded rows yield
     exact-zero reflectors, so the unpadded slices are untouched).
  2. **Domain-local wavefronts**: inside ``shard_map`` each device runs
     the ordinary GEQRT/TSQRT/LARFB/SSRFB wavefront schedule on its own
     (p/d x q) sub-grid through the same :func:`repro.core.engine.
     factor_tiles` loop as the single-device backend — zero cross-device
     traffic during the sweep, one execution path for both backends
     (``dispatch_mode`` selects the kernel lowering per domain sweep:
     per-level wavefront dispatches or the single-call megakernel).
  3. **Hierarchical R merge**: the per-domain R factors reduce through
     the TSQR butterfly tree (:func:`repro.core.tsqr.butterfly_merge_r`),
     exchanging one n x n triangle per link per round; after
     ``log2(d)`` rounds every device holds the identical global R.
  4. **Thin Q** (mode="reduced"): ``Q = A R^{-1}`` domain-locally
     (:func:`repro.core.tsqr.triangular_inverse_apply`), with a CQR2
     refinement pass (a second local-R + merge round) restoring
     orthogonality to ~machine eps; Q never materializes unsharded.

Cross-device critical path: ``wavefront_count(p/d, q) + ceil(log2 d)``
wavefronts — O(p/d + 2q + log d) instead of the single-device
O(p + 2q) (:func:`repro.core.tilegraph.sharded_wavefront_count`), which
is what lets the repo's largest-matrix path scale with device count.

Degeneracies (tested in tests/test_distgraph.py):
  * ``d == 1`` (one device, or ``ndomains=1``) skips shard_map entirely
    and returns the single-device tiled backend's result bit-for-bit.
  * tile grids with fewer row-tiles than devices cap ``d`` at the
    row-tile count; non-power-of-two requests round down (the butterfly
    needs 2^k participants).
  * ``p`` not divisible by ``d`` zero-pads rows up to ``d * ceil(p/d)``.
  * wide matrices (m < n) fall back to the single-device tiled path —
    row-sharding only helps when there are rows to spare.

CPU testing recipe (no accelerator needed — see the CI multi-device job):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        JAX_PLATFORMS=cpu python -m pytest tests/test_distgraph.py
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.compat import shard_map, shard_map_unchecked
from repro.core.tilegraph import tile_grid, tiled_qr
from repro.core.tsqr import butterfly_merge_r, triangular_inverse_apply
from repro.distributed.sharding import (
    QR_DOMAIN_AXIS, largest_pow2, row_domain_mesh, row_domain_specs)

Array = jax.Array

__all__ = [
    "effective_domains",
    "sharded_tiled_qr",
]


def effective_domains(m: int, n: int, tile: int,
                      requested: Optional[int] = None,
                      device_count: Optional[int] = None) -> int:
    """The domain count the executor will actually use.

    Caps the request (default: every local device) at the available
    device count and the tile-row count, rounds down to a power of two
    (butterfly merge), and degenerates to 1 for wide matrices.
    """
    if m < n:
        return 1
    p, _ = tile_grid(m, n, tile)
    avail = jax.local_device_count() if device_count is None else device_count
    d = avail if requested is None else min(requested, avail)
    return largest_pow2(max(1, min(d, p)))


def _pad_rows(x: Array, rows: int) -> Array:
    return x if x.shape[0] == rows else jnp.pad(
        x, ((0, rows - x.shape[0]), (0, 0)))


def _domain_r(a_dom: Array, tile: int, use_kernel: bool,
              dispatch_mode) -> Array:
    """Domain-local R via the tiled wavefront schedule, padded to n x n
    (domains shorter than n contribute zero rows to the merge stack)."""
    n = a_dom.shape[1]
    return _pad_rows(tiled_qr(a_dom, tile=tile, mode="r",
                              use_kernel=use_kernel,
                              dispatch_mode=dispatch_mode), n)


def _merged_r(a_dom: Array, tile: int, use_kernel: bool,
              dispatch_mode) -> Array:
    """Global R from inside shard_map: local tiled wavefronts, then the
    TSQR butterfly over n x n triangles (combine = stacked blocked QR,
    the same tree :func:`repro.core.tsqr.tsqr_tree_sharded` runs)."""
    from repro.core.tsqr import _local_r  # combine logic, shared with TSQR

    n = a_dom.shape[1]
    r = _domain_r(a_dom, tile, use_kernel, dispatch_mode)
    return butterfly_merge_r(
        r, QR_DOMAIN_AXIS,
        lambda stack: _local_r(stack, qr_block=min(32, n)))


def _sharded_body(a_dom: Array, *, tile: int, mode: str, use_kernel: bool,
                  refine: bool, dispatch_mode):
    """Per-device program: local wavefronts -> R merge (-> thin Q)."""
    r1 = _merged_r(a_dom, tile, use_kernel, dispatch_mode)
    if mode == "r":
        return r1
    q_dom = triangular_inverse_apply(a_dom, r1)
    if refine:
        r2 = _merged_r(q_dom, tile, use_kernel, dispatch_mode)
        q_dom = triangular_inverse_apply(q_dom, r2)
        return q_dom, r2 @ r1
    return q_dom, r1


@functools.lru_cache(maxsize=None)
def _sharded_fn(d: int, tile: int, mode: str, use_kernel: bool, refine: bool,
                dispatch_mode):
    """Compiled shard_map program for one (domain count, tile, mode)."""
    mesh = row_domain_mesh(d)
    in_spec, r_spec, qr_specs = row_domain_specs()
    body = functools.partial(_sharded_body, tile=tile, mode=mode,
                             use_kernel=use_kernel, refine=refine,
                             dispatch_mode=dispatch_mode)
    out_specs = r_spec if mode == "r" else qr_specs
    # pallas_call has no replication rule: the kernel path must skip the
    # check (outputs are still replicated — the merge ends in a pmax).
    smap = shard_map_unchecked if use_kernel else shard_map
    return jax.jit(smap(body, mesh=mesh, in_specs=in_spec,
                        out_specs=out_specs))


def sharded_tiled_qr(a: Array, *, tile: int = 32, mode: str = "reduced",
                     use_kernel: bool = False, ndomains: Optional[int] = None,
                     refine: bool = True,
                     dispatch_mode: Optional[str] = None):
    """QR of ``a`` via per-device tiled wavefront domains + R merge tree.

    mode: "reduced" -> (Q m x k, R k x n) with k = min(m, n); "r" -> R.
    Full Q is not supported, and with more than one domain the thin Q is
    always solve-based (CQR2-refined ``A R^{-1}``, like TSQR) — the
    merge tree never materializes the domain-crossing reflectors, so
    there is no formq realization; use ``method="tiled"`` when exact
    reflector-accumulated Q of singular input matters.

    ``ndomains=None`` uses every local device; the effective count is
    :func:`effective_domains` (capped, power-of-two, 1 for wide input).
    With one effective domain this IS ``tiled_qr`` — same program, same
    bits.  ``refine`` runs the CQR2 second pass on the thin Q (two merge
    trees total) — keep it on; it is what holds Q orthogonality at
    ~machine eps independent of the domain count.  ``dispatch_mode``
    picks the engine lowering of each domain-local sweep on the kernel
    path ("wavefront" / "megakernel" / None = the engine's auto rule on
    the per-domain grid).
    """
    if mode not in ("reduced", "r"):
        raise ValueError(
            f"sharded_tiled supports modes 'reduced'/'r', got {mode!r}")
    m, n = a.shape
    d = effective_domains(m, n, tile, ndomains)
    if d == 1:
        return tiled_qr(a, tile=tile, mode=mode, use_kernel=use_kernel,
                        dispatch_mode=dispatch_mode)

    # Equalize domains: pad tile rows up to d * ceil(p / d).
    p, _ = tile_grid(m, n, tile)
    p_dom = -(-p // d)
    m_pad = d * p_dom * tile

    from repro.core.tilegraph import merge_levels
    from repro.observability import metrics as _obs_metrics
    from repro.observability import trace as _obs_trace

    _obs_metrics.counter("distributed.solves", domains=d, mode=mode).inc()
    _obs_metrics.counter("distributed.merge_rounds",
                         domains=d).inc(merge_levels(d) * (2 if (
                             mode != "r" and refine) else 1))
    _obs_metrics.gauge("distributed.domain_tile_rows",
                       domains=d).set(p_dom)

    a_pad = _pad_rows(a, m_pad)
    fn = _sharded_fn(d, tile, mode, bool(use_kernel), bool(refine),
                     dispatch_mode)
    k = min(m, n)
    with _obs_trace.span("distgraph.sharded_tiled_qr", domains=d,
                         shape=f"{m}x{n}", tile=tile,
                         merge_levels=merge_levels(d)) as sp:
        if mode == "r":
            return sp.sync(fn(a_pad)[:k, :n])
        q, r = fn(a_pad)
        return sp.sync((q[:m, :k], r[:k, :n]))


# -- registry -----------------------------------------------------------------
from repro.core.plan import (  # noqa: E402
    MethodSpec, QRConfig, register_method, sign_fix_qr, sign_fix_r)
from repro.core.tilegraph import _solve_tiled, _vmem_tiled  # noqa: E402

# Keep each domain's symbolic task DAG within the single-device budget:
# grow the tile size until the per-domain grid is at most this many tiles
# on its long side (task count is O(p q min(p,q)) per domain).
_MAX_DOMAIN_GRID = 64


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _resolve_sharded(m: int, n: int, cfg: QRConfig, *, dtype=None,
                     explain=None) -> QRConfig:
    from repro.core.plan import RouteDecision
    from repro.observability import metrics as _metrics

    d = effective_domains(m, n, cfg.block, cfg.ndomains)
    tile = min(cfg.block, m, n)

    # Silent-degradation sites: the executor runs fewer domains than the
    # request (or the device count) implies — surface the concrete cause.
    avail = jax.local_device_count()
    wanted = avail if cfg.ndomains is None else min(cfg.ndomains, avail)
    if d == 1 and wanted > 1:
        _metrics.counter("planner.fallbacks",
                         reason="sharded_degraded_to_tiled").inc()
        if explain is not None:
            explain.append(RouteDecision(
                "sharded_degraded_to_tiled", "fallback",
                f"wide matrix m={m} < n={n} shards to 1 domain"
                if m < n else
                f"{wanted} domains requested but the {m}x{n} grid at "
                f"tile {cfg.block} supports 1 — running the "
                f"single-device tiled path bit-for-bit"))
    elif d < wanted:
        _metrics.counter("planner.fallbacks",
                         reason="sharded_domains_capped").inc()
        if explain is not None:
            explain.append(RouteDecision(
                "sharded_domains_capped", "fallback",
                f"{wanted} domains requested, running {d} (capped at "
                f"the tile-row count and rounded down to a power of "
                f"two for the butterfly merge)"))

    def domain_rows_of(t: int) -> int:
        return _ceil_div(_ceil_div(m, t), d)  # ceil(p / d) tile rows/device

    def domain_grid_side(t: int) -> int:
        return max(domain_rows_of(t), _ceil_div(n, t))

    while domain_grid_side(tile) > _MAX_DOMAIN_GRID and tile < min(m, n):
        tile = min(2 * tile, m, n)
    if explain is not None and tile != min(cfg.block, m, n):
        explain.append(RouteDecision(
            "sharded_tile_grown", "resolved",
            f"tile grown {cfg.block} -> {tile} to keep each domain's "
            f"grid side <= {_MAX_DOMAIN_GRID} (task count is "
            f"O(p q min(p, q)) per domain)"))
    if cfg.dispatch_mode is None and cfg.use_kernel:
        # The engine lowering each domain-local sweep will run: resolve
        # the auto rule on the per-domain tile grid, not the global one,
        # at the planned element width.
        from repro.core.tilegraph import (_planned_itemsize,
                                          _resolve_dispatch_explained)

        cfg = cfg.replace(dispatch_mode=_resolve_dispatch_explained(
            domain_rows_of(tile), _ceil_div(n, tile), tile,
            _planned_itemsize(cfg, dtype), explain))
    if d > 1:
        # Across domains the thin Q is always solve-based (CQR2-refined
        # A R^{-1}, like TSQR) — the merge tree never materializes the
        # domain-crossing reflectors, so there is no formq realization.
        # Recording it keeps the resolved config truthful; with d == 1
        # the tiled path runs and honors q_method as planned.
        return cfg.replace(block=tile, ndomains=d, q_method="solve")
    return cfg.replace(block=tile, ndomains=d)


def _solve_sharded(a: Array, cfg: QRConfig):
    m, n = a.shape
    d = effective_domains(m, n, cfg.block, cfg.ndomains)
    if d == 1:
        # Bit-for-bit the single-device tiled backend (same solve hook).
        return _solve_tiled(a, cfg)
    if cfg.mode == "r":
        r = sharded_tiled_qr(a, tile=cfg.block, mode="r",
                             use_kernel=bool(cfg.use_kernel), ndomains=d,
                             dispatch_mode=cfg.dispatch_mode)
        return sign_fix_r(r) if cfg.sign_fix else r
    q, r = sharded_tiled_qr(a, tile=cfg.block, mode="reduced",
                            use_kernel=bool(cfg.use_kernel), ndomains=d,
                            refine=cfg.refine,
                            dispatch_mode=cfg.dispatch_mode)
    return sign_fix_qr(q, r) if cfg.sign_fix else (q, r)


register_method(MethodSpec(
    name="sharded_tiled",
    solve=_solve_sharded,
    resolve=_resolve_sharded,
    supports_full_q=False,
    batched=False,  # shard_map under vmap is not part of the contract
    kernel_backed=True,
    # Per-device working set is one domain's engine dispatch — sharding
    # divides the grid, not the tiles, so the tiled (macro-op engine)
    # estimator is the sharded estimator.
    vmem_bytes=_vmem_tiled,
    kernel_policy="macro_ops",
    description="multi-device tiled QR: per-device row-block wavefront "
                "domains (shard_map) + TSQR-style hierarchical R merge",
))
