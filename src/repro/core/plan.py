"""repro.core.plan — typed QR planning: QRConfig, method registry, QRSolver.

The paper's contribution is a *family* of QR realizations (HT, MHT,
blocked WY, TSQR, Pallas kernel-backed variants) whose relative merit
depends on shape, aspect ratio, and hardware.  This module centralizes
that selection problem once, instead of string dispatch scattered across
call sites:

  * :class:`QRConfig` — a frozen, hashable description of *how* to
    factorize (method, block size, kernel policy, precision, sign fixing,
    Q mode).  Safe to use as a ``jax.jit`` static argument.
  * a **method registry** — every realization registers capability
    metadata (:class:`MethodSpec`) via :func:`register_method`;
    :mod:`repro.core.householder`, :mod:`repro.core.mht`,
    :mod:`repro.core.blocked`, :mod:`repro.core.tsqr`,
    :mod:`repro.core.tilegraph` and :mod:`repro.kernels.ops` /
    ``tile_ops`` self-register at import.  New backends plug in here
    instead of growing another ``if method == ...`` chain.
  * :func:`plan` — resolve ``(shape, dtype, config)`` to a concrete
    :class:`QRSolver`, applying the ``method="auto"`` heuristics
    (tall-skinny => TSQR with planner-chosen ``nblocks``, large
    near-square => tiled task-graph, near-square past the single-device
    tiled ceiling with more than one device => sharded_tiled,
    panel-fits-VMEM on TPU => kernel-backed ``geqrf_ht``, single-panel
    problems => unblocked MHT) and the kernel dispatch policy.
  * :class:`QRSolver` — ``solve`` / ``factor`` / ``lstsq`` on concrete
    shapes, with batched inputs (``a.ndim > 2``) handled by a vmap rule.

Tiled QR task graph
-------------------
``method="tiled"`` (:mod:`repro.core.tilegraph`) decomposes the
factorization into a DAG of tile tasks (GEQRT / TSQRT / LARFB / SSRFB)
over an nb x nb tile grid, levelizes it statically, and executes the
schedule through the wavefront macro-op engine
(:mod:`repro.core.engine`): with ``use_kernel=True`` each level's
same-kind task batch is a **single in-place Pallas dispatch** over a
``(p, q, nb, nb)`` tile workspace (macro-op bodies from the unified
:mod:`repro.kernels.macro_ops` library; interpret mode off-TPU), and
with ``use_kernel=False`` the bitwise-identical vmapped jnp oracle of
the same bodies — cross-panel parallelism the blocked methods serialize
away either way.  On the kernel path ``QRConfig.dispatch_mode`` selects
the engine lowering: ``"wavefront"`` (per-level dispatches) or
``"megakernel"`` (the whole schedule as ONE persistent Pallas call over
a scalar-prefetched task table with double-buffered tile DMA); ``None``
lets the planner pick megakernel whenever the table and the working set
fit the ``"macro_ops"`` policy budgets.  ``QRConfig.block`` doubles as
the tile size; the ``method="auto"`` heuristic routes large near-square
matrices (dims in [256, 2048], aspect < 4 — the upper bound keeps the
symbolic DAG small at the default tile) there.  The engine's VMEM and
task-table accounting is the ``"macro_ops"`` kernel policy.

Sharded tiled QR (multi-device)
-------------------------------
``method="sharded_tiled"`` (:mod:`repro.core.distgraph`) distributes
the tile grid across a 1-D device mesh: each device runs domain-local
wavefronts on its contiguous row-block of tiles under ``shard_map``,
and the per-domain R factors merge through a TSQR-style butterfly tree
(cross-device critical path O(p/d + 2q + log d) wavefronts).
``QRConfig.ndomains`` requests the domain count (default: all local
devices; execution rounds down to a power of two and caps at the
tile-row count — ``ndomains=1`` IS the tiled backend, bit for bit).
``method="auto"`` routes near-square matrices past the single-device
tiled ceiling there when more than one device is available.  Runs on
CPU with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

VMEM budget
-----------
Kernel backends register a :class:`KernelPolicy` carrying their VMEM
working-set estimator *and* the budget they enforce, so the planner's
fits-in-VMEM decisions and the kernel wrappers' runtime guards agree on
one number (:data:`DEFAULT_VMEM_BUDGET`, via :func:`kernel_vmem_budget`).

:mod:`repro.core.api` provides the thin user-facing wrappers
(``qr`` / ``orthogonalize`` / ``lstsq`` / ``qr_algorithm_eig``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.observability import metrics as _metrics

Array = jax.Array

__all__ = [
    "QRConfig",
    "MethodSpec",
    "KernelPolicy",
    "QRSolver",
    "PlanExplain",
    "RouteDecision",
    "plan",
    "select_method",
    "register_method",
    "unregister_method",
    "register_kernel_policy",
    "get_method",
    "available_methods",
    "kernel_vmem_budget",
    "kernel_table_budget",
    "DEFAULT_VMEM_BUDGET",
    "DEFAULT_TABLE_BUDGET",
    "sign_fix_qr",
    "sign_fix_r",
]

_MODES = ("reduced", "r", "full")
_Q_METHODS = ("formq", "solve")

# The single VMEM working-set budget (half of v5e VMEM, double-buffer
# room).  Kernel backends register policies carrying this value, so the
# planner's fits-in-VMEM checks and the kernel wrappers' runtime guards
# cannot drift apart.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024

# Scalar-prefetch (SMEM) budget for persistent task tables — the limit
# the engine's megakernel dispatch mode must fit its flattened schedule
# into (a 16x16 tile grid's table is ~200 KiB; SMEM is ~1 MiB/core).
DEFAULT_TABLE_BUDGET = 512 * 1024

# Matrices at least this large on their short side (and near-square, see
# select_method) route to the tiled task-graph backend under "auto".  The
# upper bound keeps the symbolic task DAG tractable: task count grows as
# O(p q min(p, q)) in the tile-grid dims, so unboundedly large inputs
# stay on the blocked path unless the caller opts into tiled explicitly
# (with a correspondingly larger tile).
_TILED_MIN_DIM = 256
_TILED_MAX_DIM = 2048
_TILED_MAX_ASPECT = 4.0

# On CPU the tiled backend runs through the jnp task-graph oracle and
# has to beat multithreaded LAPACK geqrf, which it only does once the
# wavefront is wide enough to amortize per-task overhead: at 256^2 the
# measured wall is ~2.2x geqrf (see ROADMAP smoke table), crossing over
# near 512.  Keep the 256 floor where the kernel path exists.
# NOTE this constant is now the *fallback* behind the measured tuning
# cache (repro.tuning): on swept shape classes the first-priority
# "tuned" rule routes by real wall times and this guess never fires —
# it only governs cache misses and use_tuning_cache=False plans.
_TILED_MIN_DIM_CPU = 512

# Near-square matrices past the single-device tiled ceiling route to the
# multi-device sharded_tiled backend when more than one device is
# available: each device owns a contiguous row-block domain of the tile
# grid (its local DAG stays within the single-device budget) and the
# domains merge through a TSQR-style reduction tree over R factors.
_SHARDED_MAX_DOM_FACTOR = 8  # auto ceiling: _TILED_MAX_DIM * min(d, factor)


@dataclasses.dataclass(frozen=True)
class QRConfig:
    """Hashable description of a QR realization (``jax.jit``-static safe).

    Fields left at their "decide for me" default (``method="auto"``,
    ``use_kernel=None``, ``nblocks=None``) are resolved by :func:`plan`
    into concrete values on the returned solver's ``config``.

    method:     registry name, or ``"auto"`` for shape/hardware heuristics
    block:      WY panel width for blocked methods (local QR block in TSQR)
    use_kernel: Pallas kernel policy — True force, False never,
                None => auto (TPU and the panel working set fits VMEM)
    nblocks:    TSQR tree leaf count; None => planner picks a divisor of m
    precision:  optional compute-dtype override, e.g. ``"float32"``
    sign_fix:   multiply Q columns (and R rows) by sign(diag R) so the
                factor is a deterministic, continuous function of the input
    mode:       Q mode — "reduced" (thin Q, R), "r" (R only), "full"
    q_method:   how thin Q materializes — "formq" (reflector accumulation,
                exact even for singular input) or "solve" (Q = A R^{-1},
                one dense op; tall matrices only)
    refine:     CQR2-style second pass for TSQR thin-Q orthogonality
    ndomains:   device-domain count for ``sharded_tiled`` (row-block
                domains of the tile grid, one per device); None => the
                planner uses every local device.  Execution rounds down
                to a power of two and caps at the available device count
                and the tile-row count; ``ndomains=1`` is exactly the
                single-device tiled backend.
    dispatch_mode: kernel lowering of the wavefront engine's schedule
                (tiled / sharded_tiled on their kernel paths) —
                "wavefront" (one in-place Pallas dispatch per DAG
                level), "megakernel" (the whole schedule as ONE
                persistent Pallas call over a scalar-prefetched task
                table with double-buffered tile DMA), or None => the
                planner resolves it (megakernel when the task table and
                the double-buffered working set fit the "macro_ops"
                policy budgets, wavefront otherwise).  Both lowerings
                are bitwise-identical to the jnp oracle.
    use_tuning_cache: consult the measured tuning cache
                (:mod:`repro.tuning`) before the static ``method="auto"``
                heuristics.  On a cache hit the measured best config
                overrides exactly the knobs the caller left at their
                defaults (method, block, dispatch_mode, q_method,
                use_kernel); on a miss — or with False — routing falls
                through to the heuristic rules, recording why.
    verify:     post-dispatch health checks (relative residual +
                orthogonality defect against the conformance tolerance
                rule, :mod:`repro.robustness.verify`) with escalation
                down the degradation ladder on failure.  Tri-state:
                True/False force it; None (default) defers to the
                ``REPRO_VERIFY`` environment default.  Resolution is
                host-side and skipped under traces, so the off (and
                traced) paths are jaxpr-identical to an unchecked
                solve — pinned in tests/test_robustness.py.
    """

    method: str = "auto"
    block: int = 32
    use_kernel: Optional[bool] = None
    nblocks: Optional[int] = None
    precision: Optional[str] = None
    sign_fix: bool = False
    mode: str = "reduced"
    q_method: str = "formq"
    refine: bool = True
    ndomains: Optional[int] = None
    dispatch_mode: Optional[str] = None
    use_tuning_cache: bool = True
    verify: Optional[bool] = None

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"unknown mode {self.mode!r}; expected one of {_MODES}")
        if self.dispatch_mode not in (None, "wavefront", "megakernel"):
            raise ValueError(
                f"unknown dispatch_mode {self.dispatch_mode!r}; expected "
                "'wavefront', 'megakernel', or None (auto)")
        if self.q_method not in _Q_METHODS:
            raise ValueError(
                f"unknown q_method {self.q_method!r}; expected one of {_Q_METHODS}")
        if self.block < 1:
            raise ValueError(f"block must be >= 1, got {self.block}")
        if self.nblocks is not None and self.nblocks < 1:
            raise ValueError(f"nblocks must be >= 1, got {self.nblocks}")
        if self.ndomains is not None and self.ndomains < 1:
            raise ValueError(f"ndomains must be >= 1, got {self.ndomains}")
        if self.verify not in (None, True, False):
            raise ValueError(
                f"verify must be True, False, or None (env default), "
                f"got {self.verify!r}")

    def replace(self, **changes) -> "QRConfig":
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """Capability metadata + entry points for one registered realization.

    factor:  ``(a, cfg) -> (packed, taus)`` in LAPACK packed layout, or
             None when the method has no packed form (e.g. TSQR).
    solve:   ``(a, cfg) -> (q, r) | r`` honoring cfg.mode/sign_fix; when
             None the planner derives it from ``factor``.
    solve_batched: optional native batched realization
             ``(a_bmn, cfg) -> (q, r) | r`` over one leading batch axis.
             When present, :meth:`QRSolver.solve` hands 3-D inputs here
             instead of vmapping ``solve`` — the tiled backend uses it to
             factor a whole stack through ONE
             :func:`repro.core.engine.factor_tiles_batched` dispatch
             (megakernel mode: one ``pallas_call`` for the stack).
             Deeper batch dims still vmap down to this rule.
    resolve: optional ``(m, n, cfg, *, dtype) -> cfg`` hook filling
             method-specific fields (TSQR uses it to pick ``nblocks``;
             the tiled backends use ``dtype`` — the planned element
             width — to resolve the engine dispatch mode).
    vmem_bytes: optional ``(m, n, cfg) -> bytes`` working-set estimator
             used by the kernel dispatch policy.
    kernel_policy: name of the :class:`KernelPolicy` whose budget gates
             this method's kernel dispatch (default "mht_panel").
    min_aspect: required m/n ratio (TSQR needs tall-skinny input).
    """

    name: str
    factor: Optional[Callable] = None
    solve: Optional[Callable] = None
    solve_batched: Optional[Callable] = None
    resolve: Optional[Callable] = None
    supports_full_q: bool = True
    min_aspect: float = 0.0
    batched: bool = True
    kernel_backed: bool = False
    vmem_bytes: Optional[Callable] = None
    kernel_policy: str = "mht_panel"
    description: str = ""


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Dispatch policy registered by a kernel backend (kernels.ops).

    table_budget: scalar-prefetch (SMEM) bytes available for persistent
    task tables; 0 means the backend has no megakernel-style lowering.
    """

    name: str
    vmem_bytes: Callable  # (m, b) -> working-set bytes
    vmem_budget: int
    default_interpret: Optional[Callable] = None  # () -> bool
    table_budget: int = 0


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """One machine-readable routing (or resolve) decision.

    rule:    stable slug — the routing rule or fallback reason
             ("tsqr_tall_skinny", "tiled_min_dim_cpu_floor",
             "megakernel_over_budget", ...)
    outcome: "selected" (this rule chose the method), "rejected" (rule
             evaluated and declined), "fallback" (a silent-degradation
             site fired — also counted in ``planner.fallbacks``), or
             "resolved" (a resolve hook recorded a concrete choice)
    reason:  the concrete threshold/budget arithmetic that fired
    """

    rule: str
    outcome: str
    reason: str


@dataclasses.dataclass(frozen=True)
class PlanExplain:
    """Why :func:`plan` chose what it chose — ``plan(..., explain=True)``.

    ``decisions`` holds every rule evaluated, in evaluation order;
    ``fallback_reasons`` are the ``rule`` slugs of the fallback-outcome
    decisions (the silent degradations the planner now surfaces — each
    also increments the ``planner.fallbacks{reason=...}`` counter).
    All fields are hashable; the record rides on the solver without
    affecting its equality or jit-static identity.
    """

    shape: Tuple[int, int]
    dtype: str
    backend: str
    ndevices: int
    requested_method: str
    method: str
    use_kernel: bool
    dispatch_mode: Optional[str]
    decisions: Tuple[RouteDecision, ...]
    fallback_reasons: Tuple[str, ...]

    def decision(self, rule: str) -> Optional[RouteDecision]:
        """The first decision recorded for ``rule`` (None if absent)."""
        for d in self.decisions:
            if d.rule == rule:
                return d
        return None

    @property
    def selected(self) -> Optional[RouteDecision]:
        """The decision that chose the method."""
        for d in self.decisions:
            if d.outcome == "selected":
                return d
        return None


_REGISTRY: Dict[str, MethodSpec] = {}
_KERNEL_POLICIES: Dict[str, KernelPolicy] = {}
_BUILTINS_LOADED = False


def _ensure_builtins() -> None:
    """Import the built-in realizations so they self-register.

    Registration happens at module import (each module calls
    :func:`register_method` at its bottom); this just guarantees the
    imports happened before a lookup, whatever the caller imported first.
    """
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    import repro.core.householder  # noqa: F401
    import repro.core.mht  # noqa: F401
    import repro.core.blocked  # noqa: F401
    import repro.core.tsqr  # noqa: F401
    import repro.core.tilegraph  # noqa: F401
    import repro.core.distgraph  # noqa: F401
    try:
        import repro.kernels.ops  # noqa: F401  (kernel policy registration)
        import repro.kernels.tile_ops  # noqa: F401
    except ImportError:  # Pallas toolchain unavailable — jnp paths only.
        pass


def register_method(spec: MethodSpec) -> MethodSpec:
    """Register (or overwrite) a realization under ``spec.name``."""
    _REGISTRY[spec.name] = spec
    return spec


def unregister_method(name: str) -> None:
    _REGISTRY.pop(name, None)


def register_kernel_policy(policy: KernelPolicy) -> KernelPolicy:
    _KERNEL_POLICIES[policy.name] = policy
    return policy


def get_method(name: str) -> MethodSpec:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown method {name!r}; expected one of {available_methods()}"
        ) from None


def available_methods() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def kernel_vmem_budget(policy: str = "mht_panel") -> int:
    """The VMEM budget the named kernel backend enforces (its registered
    :class:`KernelPolicy`), falling back to :data:`DEFAULT_VMEM_BUDGET`."""
    pol = _KERNEL_POLICIES.get(policy)
    return pol.vmem_budget if pol is not None else DEFAULT_VMEM_BUDGET


def kernel_table_budget(policy: str) -> int:
    """Scalar-prefetch task-table budget of the named kernel policy —
    what the engine's ``dispatch_mode=None`` auto rule checks the
    flattened megakernel schedule against (0: no megakernel lowering)."""
    pol = _KERNEL_POLICIES.get(policy)
    return pol.table_budget if pol is not None else 0


# ---------------------------------------------------------------------------
# sign fixing (shared by the default solve path and TSQR)
# ---------------------------------------------------------------------------

def _pad_signs(signs: Array, size: int, dtype) -> Array:
    if size == signs.shape[0]:
        return signs.astype(dtype)
    return jnp.concatenate(
        [signs.astype(dtype), jnp.ones((size - signs.shape[0],), dtype)])


def sign_fix_qr(q: Array, r: Array) -> Tuple[Array, Array]:
    """Flip Q columns / R rows so diag(R) >= 0 (Q R product unchanged)."""
    signs = jnp.where(jnp.diagonal(r) >= 0, 1.0, -1.0)
    q = q * _pad_signs(signs, q.shape[1], q.dtype)[None, :]
    r = r * _pad_signs(signs, r.shape[0], r.dtype)[:, None]
    return q, r


def sign_fix_r(r: Array) -> Array:
    signs = jnp.where(jnp.diagonal(r) >= 0, 1.0, -1.0)
    return r * _pad_signs(signs, r.shape[0], r.dtype)[:, None]


# ---------------------------------------------------------------------------
# degenerate (zero-dim) shapes — jnp.linalg.qr semantics
# ---------------------------------------------------------------------------

def _solve_degenerate(a: Array, cfg: QRConfig):
    """QR of an empty matrix, matching ``jnp.linalg.qr`` exactly:
    with k = min(m, n) == 0, reduced Q is the (m, 0) identity slice and
    R is the (0, n) empty triangle; full Q is I_m with R all-zero.
    Every backend's tile/panel machinery divides by these extents, so
    the planner routes here before any of them can."""
    m, n = a.shape
    k = min(m, n)
    if cfg.mode == "r":
        return jnp.zeros((k, n), a.dtype)
    if cfg.mode == "reduced":
        return jnp.eye(m, k, dtype=a.dtype), jnp.zeros((k, n), a.dtype)
    return jnp.eye(m, dtype=a.dtype), jnp.zeros((m, n), a.dtype)


register_method(MethodSpec(
    name="degenerate",
    solve=_solve_degenerate,
    supports_full_q=True,
    batched=True,
    description="trivial zero-dim (m == 0 or n == 0) factorization with "
                "jnp.linalg.qr semantics — the planner's early-return for "
                "empty matrices",
))


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------

# The "decide for me" defaults the tuned overlay respects: a measured
# config only overrides knobs the caller left untouched.
_DEFAULT_CONFIG = QRConfig()


def _apply_tuned_config(resolved: "QRConfig", requested: "QRConfig",
                        entry, decisions: List["RouteDecision"]
                        ) -> "QRConfig":
    """Overlay the measured best config onto the knobs the caller left at
    their defaults — explicit knobs always win over the cache.  Records a
    ``tuned_config`` resolve decision when anything changed."""
    best = entry.best
    applied = []
    if (requested.block == _DEFAULT_CONFIG.block
            and best.block != resolved.block):
        resolved = dataclasses.replace(resolved, block=best.block)
        applied.append(f"block={best.block}")
    if (requested.dispatch_mode is None and resolved.use_kernel
            and best.dispatch_mode is not None
            and best.dispatch_mode != resolved.dispatch_mode):
        resolved = dataclasses.replace(resolved,
                                       dispatch_mode=best.dispatch_mode)
        applied.append(f"dispatch_mode={best.dispatch_mode}")
    if (requested.q_method == _DEFAULT_CONFIG.q_method
            and best.q_method != resolved.q_method):
        resolved = dataclasses.replace(resolved, q_method=best.q_method)
        applied.append(f"q_method={best.q_method}")
    if requested.use_kernel is None and resolved.use_kernel:
        applied.append("use_kernel=True")
    if applied:
        decisions.append(RouteDecision(
            "tuned_config", "resolved",
            "measured config applied: " + ", ".join(applied)))
    return resolved

def _kernel_fits(spec: MethodSpec, m: int, n: int, cfg: QRConfig,
                 dtype=jnp.float32) -> bool:
    if spec.vmem_bytes is None:
        return False
    try:
        est = spec.vmem_bytes(m, n, cfg)
    except ImportError:  # kernel backend unavailable — jnp paths only
        return False
    # Estimators are written for fp32; scale to the planned element width.
    scale = np.dtype(dtype).itemsize / 4.0
    return est * scale <= kernel_vmem_budget(spec.kernel_policy)


# Canonical auto-routing rule order.  Trail-completeness contract
# (tests/test_plan.py): an auto plan's non-fallback decisions are exactly
# the prefix of this sequence ending at the selected rule — every rule
# evaluated before the winner records a "rejected" decision, on every
# path.  ("tiled_min_dim_cpu_floor" fallbacks and resolve-hook decisions
# interleave without participating in the prefix.)
_ROUTE_RULES = ("degenerate_empty", "explicit", "tuned", "tsqr_tall_skinny",
                "tiled_near_square", "sharded_past_ceiling",
                "tpu_kernel_panel_fits", "single_panel", "blocked_default")


def _tuned_lookup(m: int, n: int, dtype, config: QRConfig, backend: str,
                  batched: bool):
    """Consult the measured tuning cache: ``(decision, entry-or-None)``.

    A hit must also pass the capability guards the selected method will
    face in :func:`plan` (mode/batched/aspect) — an incompatible measured
    pick records a rejected decision and routing falls through, rather
    than planning a method that will raise."""
    if not config.use_tuning_cache:
        return RouteDecision(
            "tuned", "rejected",
            "use_tuning_cache=False pins the heuristic rules"), None
    from repro.tuning import cache as _tcache

    cache = _tcache.active_cache()
    if len(cache) == 0:
        return RouteDecision(
            "tuned", "rejected",
            f"no tuning cache loaded (source: {cache.source}) — "
            f"heuristic rules apply"), None
    cls = _tcache.shape_class(m, n)
    entry = cache.lookup(backend=backend, m=m, n=n, dtype=np.dtype(dtype))
    if entry is None:
        return RouteDecision(
            "tuned", "rejected",
            f"cache miss: no measured entry for shape-class "
            f"{cls[0]}x{cls[1]} ({backend}, {np.dtype(dtype)}) — "
            f"heuristic rules apply"), None
    best = entry.best
    spec = _REGISTRY.get(best.method)
    why_unfit = (
        f"tuned pick {best.method!r} is not registered" if spec is None else
        f"tuned pick {best.method!r} is thin-only vs mode='full'"
        if config.mode == "full" and not spec.supports_full_q else
        f"tuned pick {best.method!r} does not support batched inputs"
        if batched and not spec.batched else
        f"tuned pick {best.method!r} needs m >= {spec.min_aspect:g}n"
        if spec.min_aspect > 0 and m < spec.min_aspect * n else None)
    if why_unfit is not None:
        return RouteDecision("tuned", "rejected", why_unfit), None
    knobs = f"block={best.block}"
    if best.use_kernel:
        knobs += f", dispatch={best.dispatch_mode}"
    return RouteDecision(
        "tuned", "selected",
        f"measured: {best.method}[{knobs}] {entry.best_us:.0f} us vs "
        f"heuristic {entry.heuristic_method} {entry.heuristic_us:.0f} us "
        f"on {entry.backend}/{entry.device_kind} shape-class "
        f"{cls[0]}x{cls[1]} ({entry.dtype})"), entry


def _route(shape, dtype, config: QRConfig, backend: Optional[str],
           ndevices: Optional[int]):
    """The routing table with its reasoning:
    ``(method, decisions, tuned_entry)``.

    Rules evaluate in :data:`_ROUTE_RULES` order; EVERY rule evaluated
    before the winner records a :class:`RouteDecision` (selected or
    rejected) on every path, and the silent-degradation sites (the CPU
    tiled floor here; dispatch-mode and domain-count degradations in the
    resolve hooks) additionally record ``outcome="fallback"`` decisions
    (counted once per plan in :func:`plan` — this function is a pure
    query).  ``tuned_entry`` is the measured cache entry when the
    ``"tuned"`` rule won, else None.
    """
    _ensure_builtins()
    dec: List[RouteDecision] = []
    m, n = int(shape[-2]), int(shape[-1])

    if min(m, n) == 0:
        why = (f"zero-dim input {m}x{n} — trivial factorization with "
               f"jnp.linalg.qr semantics")
        if config.method not in ("auto", "degenerate"):
            why += (f" (overrides config.method={config.method!r}: no "
                    f"backend factors an empty matrix)")
        dec.append(RouteDecision("degenerate_empty", "selected", why))
        return "degenerate", dec, None
    if config.method != "auto":
        dec.append(RouteDecision(
            "explicit", "selected",
            f"config.method={config.method!r} bypasses auto routing"))
        return config.method, dec, None
    backend = jax.default_backend() if backend is None else backend
    ndevices = jax.local_device_count() if ndevices is None else int(ndevices)
    aspect = m / n if n else float("inf")

    tuned_dec, tuned = _tuned_lookup(m, n, dtype, config, backend,
                                     batched=len(shape) > 2)
    dec.append(tuned_dec)
    if tuned is not None:
        return tuned.best.method, dec, tuned

    tspec = _REGISTRY.get("tsqr")
    if (tspec is not None and config.mode != "full" and n >= 1 and m >= 8
            and m >= tspec.min_aspect * n):
        dec.append(RouteDecision(
            "tsqr_tall_skinny", "selected",
            f"aspect {aspect:.2f} >= {tspec.min_aspect:g} "
            f"({m}x{n}, mode={config.mode!r})"))
        return "tsqr", dec, None
    if tspec is not None:
        dec.append(RouteDecision(
            "tsqr_tall_skinny", "rejected",
            f"mode='full' needs full Q (tsqr is thin-only)"
            if config.mode == "full" else
            f"aspect {aspect:.2f} < {tspec.min_aspect:g} (or m={m} < 8)"))

    tiled_floor = _TILED_MIN_DIM_CPU if backend == "cpu" else _TILED_MIN_DIM
    near_square = (min(m, n) >= tiled_floor
                   and max(m, n) < _TILED_MAX_ASPECT * min(m, n))
    # Silent-degradation site: shapes that would route tiled on an
    # accelerator but sit under the measured CPU crossover floor.
    if (backend == "cpu" and "tiled" in _REGISTRY
            and _TILED_MIN_DIM <= min(m, n) < _TILED_MIN_DIM_CPU
            and max(m, n) < _TILED_MAX_ASPECT * min(m, n)
            and max(m, n) <= _TILED_MAX_DIM):
        dec.append(RouteDecision(
            "tiled_min_dim_cpu_floor", "fallback",
            f"min dim {min(m, n)} >= {_TILED_MIN_DIM} routes tiled "
            f"off-CPU, but < CPU floor {_TILED_MIN_DIM_CPU} (measured "
            f"LAPACK geqrf crossover) — falling through to blocked"))
    if "tiled" in _REGISTRY and near_square and max(m, n) <= _TILED_MAX_DIM:
        dec.append(RouteDecision(
            "tiled_near_square", "selected",
            f"min dim {min(m, n)} >= floor {tiled_floor} "
            f"({backend}), aspect {max(m, n) / min(m, n):.2f} < "
            f"{_TILED_MAX_ASPECT:g}, max dim {max(m, n)} <= "
            f"{_TILED_MAX_DIM}"))
        return "tiled", dec, None
    if "tiled" in _REGISTRY:
        dec.append(RouteDecision(
            "tiled_near_square", "rejected",
            f"min dim {min(m, n)} < floor {tiled_floor} ({backend})"
            if min(m, n) < tiled_floor else
            f"aspect {max(m, n) / min(m, n):.2f} >= {_TILED_MAX_ASPECT:g}"
            if max(m, n) >= _TILED_MAX_ASPECT * min(m, n) else
            f"max dim {max(m, n)} > single-device ceiling {_TILED_MAX_DIM}"))

    sharded_ceiling = _TILED_MAX_DIM * min(ndevices, _SHARDED_MAX_DOM_FACTOR)
    if ("sharded_tiled" in _REGISTRY and near_square and config.mode != "full"
            and len(shape) == 2  # no batched support (shard_map under vmap)
            and m >= n and ndevices > 1
            and max(m, n) <= sharded_ceiling):
        dec.append(RouteDecision(
            "sharded_past_ceiling", "selected",
            f"near-square {m}x{n} <= sharded ceiling {sharded_ceiling} "
            f"({ndevices} devices x {_TILED_MAX_DIM})"))
        return "sharded_tiled", dec, None
    if "sharded_tiled" in _REGISTRY:
        # Record the evaluation on EVERY path (a near-square shape under
        # the ceiling with one device used to silently omit this rule).
        dec.append(RouteDecision(
            "sharded_past_ceiling", "rejected",
            f"not near-square at floor {tiled_floor} (min dim "
            f"{min(m, n)}, aspect {max(m, n) / min(m, n):.2f})"
            if not near_square else
            "batched input (no shard_map under vmap)"
            if len(shape) != 2 else
            "mode='full' needs full Q (sharded merge is thin-only)"
            if config.mode == "full" else
            f"wide matrix ({m}x{n}): row-domain sharding needs m >= n"
            if m < n else
            f"single device available (ndevices={ndevices})"
            if ndevices <= 1 else
            f"max dim {max(m, n)} > sharded ceiling {sharded_ceiling}"
            if max(m, n) > sharded_ceiling else
            f"max dim {max(m, n)} <= single-device tiled ceiling "
            f"{_TILED_MAX_DIM} — tiled declined for its own reason"))

    gspec = _REGISTRY.get("geqrf_ht")
    if gspec is not None:
        if (backend == "tpu" and config.use_kernel is not False
                and _kernel_fits(gspec, m, n, config, dtype)):
            dec.append(RouteDecision(
                "tpu_kernel_panel_fits", "selected",
                f"backend=tpu and geqrf_ht panel working set fits VMEM "
                f"budget {kernel_vmem_budget(gspec.kernel_policy)}"))
            return "geqrf_ht", dec, None
        dec.append(RouteDecision(
            "tpu_kernel_panel_fits", "rejected",
            f"backend={backend} is not tpu" if backend != "tpu" else
            "use_kernel=False pins the jnp path"
            if config.use_kernel is False else
            f"geqrf_ht panel working set exceeds VMEM budget "
            f"{kernel_vmem_budget(gspec.kernel_policy)} at {m}x{n}"))
    if min(m, n) <= config.block:
        dec.append(RouteDecision(
            "single_panel", "selected",
            f"min dim {min(m, n)} <= block {config.block} — one "
            f"unblocked panel (geqr2_ht)"))
        return "geqr2_ht", dec, None
    dec.append(RouteDecision(
        "single_panel", "rejected",
        f"min dim {min(m, n)} > block {config.block} — needs blocking"))
    dec.append(RouteDecision(
        "blocked_default", "selected",
        f"no specialized rule matched {m}x{n} on {backend} — blocked "
        f"geqrf_ht default"))
    return "geqrf_ht", dec, None


def select_method(shape, dtype, config: QRConfig, *, backend: Optional[str] = None,
                  ndevices: Optional[int] = None) -> str:
    """The ``method="auto"`` routing table (trailing two dims of shape).

    0. zero-dim input (m == 0 or n == 0) -> ``degenerate`` (the trivial
       jnp.linalg.qr-style factorization; overrides explicit methods —
       no backend factors an empty matrix); then a measured tuning-cache
       hit for this shape class (:mod:`repro.tuning`, unless
       ``use_tuning_cache=False``) -> the measured best method, with the
       real wall times as the decision reason;
    1. tall-skinny (aspect >= tsqr's min_aspect, default 4:1) -> TSQR,
       with ``nblocks`` chosen by the planner;
    2. large near-square (256 <= dims <= 2048, aspect < 4) -> ``tiled``
       task-graph (cross-panel wavefront parallelism); on CPU the floor
       is 512 — below that multithreaded LAPACK geqrf wins and the
       request falls through to rule 6 (surfaced as the
       ``tiled_min_dim_cpu_floor`` fallback in the explain record);
    3. near-square but past the single-device tiled ceiling, with more
       than one device available (``ndevices``, default
       ``jax.local_device_count()``) -> ``sharded_tiled``: per-device
       row-block domains + a TSQR-style R merge tree, up to
       ``_TILED_MAX_DIM * min(ndevices, 8)`` on the long side;
    4. TPU and the geqrf_ht panel working set fits VMEM -> kernel-backed
       ``geqrf_ht``;
    5. single-panel problems (min(m, n) <= block) -> unblocked ``geqr2_ht``;
    6. otherwise blocked ``geqrf_ht``.

    ``plan(..., explain=True)`` returns the full decision trail as a
    :class:`PlanExplain` record on the solver.

    This function is a pure query: it mirrors :func:`plan`'s routing
    without emitting metrics (fallback counters fire once per plan, in
    :func:`plan` itself).
    """
    return _route(shape, dtype, config, backend, ndevices)[0]


def plan(shape, dtype=jnp.float32, config: Optional[QRConfig] = None, *,
         backend: Optional[str] = None,
         ndevices: Optional[int] = None,
         explain: bool = False) -> "QRSolver":
    """Resolve ``(shape, dtype, config)`` to a concrete :class:`QRSolver`.

    ``shape`` may carry leading batch dims; planning uses the trailing
    matrix dims and the solver vmaps over the rest.  ``backend`` overrides
    ``jax.default_backend()`` for the kernel policy, ``ndevices``
    overrides ``jax.local_device_count()`` for the sharded routing (both
    useful in tests).  ``explain=True`` attaches a :class:`PlanExplain`
    record to the solver: the full routing-decision trail, the resolved
    dispatch mode, and every fallback reason — machine-readable, and
    mirrored into the ``planner.*`` metrics either way.
    """
    _ensure_builtins()
    cfg = QRConfig() if config is None else config
    if len(shape) < 2:
        raise ValueError(f"qr plan expects a matrix shape, got {tuple(shape)}")
    m, n = int(shape[-2]), int(shape[-1])
    batched = len(shape) > 2
    backend = jax.default_backend() if backend is None else backend

    name, decisions, tuned = _route(shape, dtype, cfg, backend, ndevices)
    # Fallback counters for _route-level decisions fire HERE, once per
    # plan — _route/select_method are pure queries, so explain=True (or
    # a select_method probe) cannot double-count a fallback.  Resolve
    # hooks run after this loop and emit their own counters for the
    # decisions they append.
    for d in decisions:
        if d.outcome == "fallback":
            _metrics.counter("planner.fallbacks", reason=d.rule).inc()
    spec = get_method(name)
    if name == "degenerate" and min(m, n) > 0:
        raise ValueError(
            f"method 'degenerate' handles zero-dim shapes only "
            f"(m == 0 or n == 0), got {m}x{n}")

    if batched and not spec.batched:
        raise ValueError(f"method {name!r} does not support batched inputs")
    if cfg.mode == "full" and not spec.supports_full_q:
        raise ValueError(f"method {name!r} produces thin Q only")
    if spec.min_aspect > 0 and m < spec.min_aspect * n:
        raise ValueError(
            f"method {name!r} expects tall-skinny input "
            f"(m >= {spec.min_aspect:g}n, got {m}x{n})")

    use_kernel = cfg.use_kernel
    if use_kernel is None:
        if tuned is not None:
            use_kernel = bool(tuned.best.use_kernel) and spec.kernel_backed
        else:
            use_kernel = (backend == "tpu" and spec.kernel_backed
                          and _kernel_fits(spec, m, n, cfg, dtype))
    elif use_kernel and not spec.kernel_backed:
        raise ValueError(f"method {name!r} has no kernel-backed realization")

    resolved = dataclasses.replace(cfg, method=name, use_kernel=bool(use_kernel))
    if tuned is not None:
        resolved = _apply_tuned_config(resolved, cfg, tuned, decisions)
    if spec.resolve is not None:
        # Resolve hooks may append RouteDecisions (dispatch-mode choices,
        # domain degradations); hooks predating the kwarg still work.
        try:
            resolved = spec.resolve(m, n, resolved, dtype=np.dtype(dtype),
                                    explain=decisions)
        except TypeError:
            resolved = spec.resolve(m, n, resolved, dtype=np.dtype(dtype))
    _metrics.counter("planner.plans", method=name).inc()
    record = None
    if explain:
        record = PlanExplain(
            shape=(m, n), dtype=str(np.dtype(dtype)), backend=backend,
            ndevices=(jax.local_device_count() if ndevices is None
                      else int(ndevices)),
            requested_method=cfg.method, method=name,
            use_kernel=bool(use_kernel),
            dispatch_mode=resolved.dispatch_mode,
            decisions=tuple(decisions),
            fallback_reasons=tuple(d.rule for d in decisions
                                   if d.outcome == "fallback"))
    return QRSolver(shape=(m, n), dtype=np.dtype(dtype), config=resolved,
                    spec=spec, explain=record)


# ---------------------------------------------------------------------------
# solver
# ---------------------------------------------------------------------------

def _default_solve(spec: MethodSpec, a: Array, cfg: QRConfig):
    """Derive per-mode output from a packed ``factor`` realization."""
    from repro.core import householder

    m, n = a.shape
    k = min(m, n)
    packed, taus = spec.factor(a, cfg)
    r = householder.unpack_r(packed, n)
    if cfg.mode == "r":
        return sign_fix_r(r) if cfg.sign_fix else r
    if cfg.mode == "reduced":
        if cfg.q_method == "solve" and m >= n:
            from repro.core.tsqr import triangular_inverse_apply

            q = triangular_inverse_apply(a, r[:n, :n])
        else:
            q = householder.form_q(packed, taus)
        return sign_fix_qr(q, r) if cfg.sign_fix else (q, r)
    # mode == "full": Q is (m, m); R padded to (m, n) with zero rows.
    q = householder.form_q(packed, taus, full=True)
    if m > k:
        r = jnp.vstack([r, jnp.zeros((m - k, n), r.dtype)])
    return sign_fix_qr(q, r) if cfg.sign_fix else (q, r)


@dataclasses.dataclass(frozen=True)
class QRSolver:
    """A planned QR factorization for one matrix shape.

    ``config`` is fully resolved (concrete method / kernel flag / nblocks);
    the solver is hashable and may be closed over or passed as a
    ``jax.jit`` static argument.  ``explain`` (populated by
    ``plan(..., explain=True)``) carries the :class:`PlanExplain`
    decision trail; it is excluded from equality/hashing so explained
    and unexplained solvers are jit-cache-identical.
    """

    shape: Tuple[int, int]
    dtype: np.dtype
    config: QRConfig
    spec: MethodSpec
    explain: Optional[PlanExplain] = dataclasses.field(default=None,
                                                       compare=False)

    # -- internals ---------------------------------------------------------

    def _check(self, a: Array) -> None:
        if a.ndim < 2 or tuple(a.shape[-2:]) != self.shape:
            raise ValueError(
                f"solver planned for {self.shape}, got input shape {a.shape}")
        if np.dtype(a.dtype) != self.dtype:
            raise ValueError(
                f"solver planned for dtype {self.dtype}, got {a.dtype}; "
                "re-plan or cast (kernel/VMEM decisions are dtype-dependent)")
        if a.ndim > 2 and not self.spec.batched:
            raise ValueError(
                f"method {self.config.method!r} does not support batched inputs")

    def _batched(self, f: Callable, a: Array):
        for _ in range(a.ndim - 2):
            f = jax.vmap(f)
        return f(a)

    def _cast(self, a: Array) -> Array:
        if self.config.precision is not None:
            return a.astype(self.config.precision)
        return a

    def _solve2d(self, a: Array):
        cfg = self.config
        a = self._cast(a)
        if self.spec.solve is not None:
            return self.spec.solve(a, cfg)
        return _default_solve(self.spec, a, cfg)

    def _factor2d(self, a: Array):
        return self.spec.factor(self._cast(a), self.config)

    # -- public ------------------------------------------------------------

    def solve(self, a: Array):
        """Factorize per ``config.mode``: (Q, R), R only, or full (Q, R).

        Inputs with leading batch dims are vmapped over those dims —
        except that a method registering ``solve_batched`` receives the
        innermost ``(B, m, n)`` stack natively (the tiled backend turns
        it into ONE batched engine dispatch instead of B vmapped ones).
        """
        self._check(a)
        if self.spec.solve_batched is not None and a.ndim >= 3:
            f = functools.partial(self.spec.solve_batched, cfg=self.config)
            for _ in range(a.ndim - 3):
                f = jax.vmap(f)
            return f(self._cast(a))
        return self._batched(self._solve2d, a)

    def factor(self, a: Array):
        """LAPACK packed form ``(packed, taus)`` (methods that have one)."""
        if self.spec.factor is None:
            raise ValueError(
                f"method {self.config.method!r} has no packed factored form")
        self._check(a)
        return self._batched(self._factor2d, a)

    def orthogonalize(self, a: Array):
        """Sign-fixed thin Q (the optimizer primitive) of tall input."""
        solver = self if (self.config.sign_fix and self.config.mode == "reduced") \
            else dataclasses.replace(
                self, config=self.config.replace(sign_fix=True, mode="reduced"))
        q, _ = solver.solve(a)
        return q

    def lstsq(self, a: Array, b: Array) -> Array:
        """Least-squares solve ``min ||a x - b||`` via this realization."""
        from jax.scipy.linalg import solve_triangular

        m, n = self.shape
        if m < n:
            raise ValueError("lstsq expects m >= n")
        if a.ndim != 2:
            raise ValueError("lstsq expects a single matrix")
        b2 = b if b.ndim == 2 else b[:, None]
        if self.spec.factor is not None:
            from repro.core import householder

            packed, taus = self.factor(a)
            qtb = householder.apply_q(packed, taus, b2, transpose=True)
            r = householder.unpack_r(packed, n)[:n, :n]
            x = solve_triangular(r, qtb[:n], lower=False)
        else:
            cfg = self.config.replace(mode="reduced", sign_fix=False)
            q, r = dataclasses.replace(self, config=cfg).solve(a)
            x = solve_triangular(r[:n, :n], q.T @ self._cast(b2), lower=False)
        return x[:, 0] if b.ndim == 1 else x
