"""repro.core — Householder/MHT QR factorization (the paper's contribution).

Layers:
    householder  classical HT (DGEQR2 semantics), Q application/formation
    mht          Modified Householder Transform (fused macro-op updates)
    blocked      WY-blocked QR (DGEQRF / DGEQRFHT)
    tsqr         communication-avoiding distributed QR over mesh axes
    dag          beta/theta parallelism quantification (paper fig 9)
    api          qr() / orthogonalize() / lstsq() / qr_algorithm_eig()
"""

from repro.core.api import lstsq, orthogonalize, qr, qr_algorithm_eig
from repro.core.blocked import geqrf, larft
from repro.core.householder import apply_q, form_q, geqr2, house_vector, unpack_r, unpack_v
from repro.core.mht import geqr2_ht, mht_update
from repro.core.tsqr import distributed_qr, tsqr_qr, tsqr_r, tsqr_tree_sharded

__all__ = [
    "qr", "orthogonalize", "lstsq", "qr_algorithm_eig",
    "geqr2", "geqr2_ht", "geqrf", "larft",
    "house_vector", "apply_q", "form_q", "unpack_r", "unpack_v", "mht_update",
    "tsqr_r", "tsqr_qr", "tsqr_tree_sharded", "distributed_qr",
]
