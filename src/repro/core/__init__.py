"""repro.core — Householder/MHT QR factorization (the paper's contribution).

Layers:
    householder  classical HT (DGEQR2 semantics), Q application/formation
    mht          Modified Householder Transform (fused macro-op updates)
    blocked      WY-blocked QR (DGEQRF / DGEQRFHT / fori_loop variant)
    tsqr         communication-avoiding distributed QR over mesh axes
    tilegraph    tiled task-graph QR: GEQRT/TSQRT/LARFB/SSRFB tile DAG,
                 statically wavefront-scheduled (cross-panel parallelism)
    engine       wavefront macro-op engine: executes the levelized DAG
                 as one in-place Pallas dispatch per level
                 (dispatch_mode="wavefront"), as ONE persistent
                 task-table dispatch with double-buffered tile DMA
                 ("megakernel"), or as the bitwise-identical vmapped
                 jnp oracle (use_kernel=False)
    distgraph    multi-device sharded tiled QR: per-device row-block
                 wavefront domains (shard_map) + TSQR-style R merge tree
    dag          beta/theta parallelism quantification (paper fig 9),
                 extended to the tiled/sharded wavefront DAGs
    plan         QRConfig + method registry + plan() -> QRSolver
    api          qr() / orthogonalize() / lstsq() / qr_algorithm_eig()

Realization selection is centralized in :mod:`repro.core.plan`: each
algorithm module registers capability metadata (``register_method``) at
import, and ``plan(shape, dtype, QRConfig(...))`` resolves method / block
size / kernel policy / TSQR tree shape — including ``method="auto"``
shape-and-hardware heuristics — into a hashable :class:`QRSolver`.  The
functions in :mod:`repro.core.api` are thin wrappers over that planner.
"""

from repro.core.api import lstsq, orthogonalize, qr, qr_algorithm_eig
from repro.core.blocked import geqrf, geqrf_fori, larft
from repro.core.householder import apply_q, form_q, geqr2, house_vector, unpack_r, unpack_v
from repro.core.mht import geqr2_ht, mht_update
from repro.core.plan import (
    MethodSpec,
    QRConfig,
    QRSolver,
    available_methods,
    get_method,
    plan,
    register_method,
)
from repro.core.engine import schedule_stats
from repro.core.tilegraph import (
    sharded_wavefront_count,
    tiled_qr,
    wavefront_count,
    wavefronts,
)
from repro.core.distgraph import sharded_tiled_qr
from repro.core.tsqr import distributed_qr, tsqr_qr, tsqr_r, tsqr_tree_sharded

__all__ = [
    "qr", "orthogonalize", "lstsq", "qr_algorithm_eig",
    "QRConfig", "QRSolver", "MethodSpec", "plan",
    "register_method", "get_method", "available_methods",
    "geqr2", "geqr2_ht", "geqrf", "geqrf_fori", "larft",
    "house_vector", "apply_q", "form_q", "unpack_r", "unpack_v", "mht_update",
    "tsqr_r", "tsqr_qr", "tsqr_tree_sharded", "distributed_qr",
    "tiled_qr", "wavefronts", "wavefront_count", "schedule_stats",
    "sharded_tiled_qr", "sharded_wavefront_count",
]
