"""DAG-level parallelism quantification — paper §4, eq. 6-10, Fig. 9.

The paper measures fine-grained parallelism of a routine as

    beta = (total scalar operations) / (number of levels in the DAG)

and compares classical HT (fig 6: Householder matrix P materialized, then
P A) against MHT (fig 8: fused macro-op, P never formed), showing the
ratio theta = beta_HT / beta_MHT = levels_MHT / levels_HT saturating
around 0.75 — i.e. ~1.33x more operations available per level in MHT.

This module rebuilds both DAGs *symbolically*: every scalar op node's
level is 1 + max(level of its operands), inputs are level 0.  Levels are
propagated with vectorized numpy (per-node Python graphs would melt at
n=128), and op counts are tallied exactly.  Balanced binary reduction
trees are simulated pairwise in operand order, matching the paper's
tree-sum DAGs.

Conventions (documented vs. the paper, see DESIGN.md §1):
  * classical HT = explicit P: per column, P = I - 2 v v^T costs 3 level-
    chained elementwise ops, then PA is a full matmul (mul + add-tree).
  * MHT = fused: w = v^T A (mul + add-tree), then a - 2 v_i w_k as a
    2-op chain.
  * The Householder-vector computation (norm, sqrt, divide) is identical
    in both, as in the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = ["DagStats", "analyze_ht", "analyze_mht", "analyze_tiled",
           "analyze_sharded_tiled", "theta_curve", "tiled_curve",
           "sharded_curve"]


@dataclasses.dataclass
class DagStats:
    ops: int       # total scalar operations
    depth: int     # number of levels in the DAG

    @property
    def beta(self) -> float:
        return self.ops / max(self.depth, 1)


class _Counter:
    def __init__(self) -> None:
        self.ops = 0

    def add(self, n: int) -> None:
        self.ops += int(n)


def _tree_reduce_levels(levels: np.ndarray, axis: int, counter: _Counter) -> np.ndarray:
    """Level of a balanced pairwise reduction along ``axis``; counts the
    (size-1) combine ops per reduced vector."""
    levels = np.moveaxis(levels, axis, 0)
    n = levels.shape[0]
    counter.add(max(n - 1, 0) * int(np.prod(levels.shape[1:], dtype=np.int64)))
    while levels.shape[0] > 1:
        k = levels.shape[0]
        even = levels[0 : k - (k % 2) : 2]
        odd = levels[1 : k : 2]
        merged = 1 + np.maximum(even, odd)
        if k % 2 == 1:
            merged = np.concatenate([merged, levels[-1:]], axis=0)
        levels = merged
    return levels[0]


def _house_vector_levels(col_levels: np.ndarray, counter: _Counter) -> Tuple[np.ndarray, int]:
    """Levels of v (per element) and of alpha, for one column's reflector.

    alpha = -sign(a_p) * sqrt(sum_i a_i^2)
    r     = sqrt((alpha^2 - a_p * alpha) / 2)
    v_i   = numerator_i / (2 r)
    """
    L = col_levels.shape[0]
    sq = col_levels + 1                      # a_i^2
    counter.add(L)
    ssum = _tree_reduce_levels(sq, 0, counter)
    sqrt_lvl = int(ssum) + 1                 # sqrt
    alpha = sqrt_lvl + 1                     # sign/negate merge
    counter.add(2)
    # r: alpha^2 (1), a_p*alpha (parallel), sub, half, sqrt
    a2 = alpha + 1
    ap_a = max(alpha, int(col_levels[0])) + 1
    sub = max(a2, ap_a) + 1
    r = sub + 2                              # half, then sqrt
    counter.add(5)
    two_r = r + 1
    counter.add(1)
    num = col_levels.copy()
    num[0] = max(int(col_levels[0]), alpha) + 1   # a_p - alpha
    counter.add(1)
    v = np.maximum(num, two_r) + 1           # divide
    counter.add(L)
    return v, alpha


def _analyze(n: int, mode: str) -> DagStats:
    """Walk the full n x n factorization, propagating entry levels."""
    counter = _Counter()
    a = np.zeros((n, n), dtype=np.int64)  # input levels
    depth = 0
    for j in range(n - 1):
        L = n - j                  # active column height
        w = n - j - 1              # trailing width
        col = a[j:, j]
        v, alpha = _house_vector_levels(col, counter)
        depth = max(depth, alpha)
        trail = a[j:, j + 1 :]     # (L, w)

        if mode == "ht":
            # P = I - 2 v v^T: mul, scale, sub  (3 chained ops per entry)
            p = np.maximum(v[:, None], v[None, :]) + 3
            counter.add(3 * L * L)
            # (PA)_ik = tree-add_t( P_it * A_tk )
            mul = 1 + np.maximum(p[:, :, None], trail[None, :, :])  # (L,L,w)
            counter.add(L * L * w)
            new_trail = _tree_reduce_levels(mul, 1, counter)         # (L,w)
        elif mode == "mht":
            # w_k = tree-add_i( v_i * a_ik )
            mul = 1 + np.maximum(v[:, None], trail)                  # (L,w)
            counter.add(L * w)
            wk = _tree_reduce_levels(mul, 0, counter)                # (w,)
            # a_ik' = a_ik - 2 * v_i * w_k : two chained ops (2v_i folds)
            upd = 1 + np.maximum(v[:, None], wk[None, :])
            counter.add(L * w)
            new_trail = 1 + np.maximum(trail, upd)
            counter.add(L * w)
        else:
            raise ValueError(mode)

        a[j:, j + 1 :] = new_trail
        a[j, j] = alpha
        a[j + 1 :, j] = v[1:]
        depth = max(depth, int(new_trail.max()) if new_trail.size else 0)
    depth = max(depth, int(a.max()))
    return DagStats(ops=counter.ops, depth=depth)


def analyze_ht(n: int) -> DagStats:
    """DAG stats for classical HT (paper fig 6) on an n x n matrix."""
    return _analyze(n, "ht")


def analyze_mht(n: int) -> DagStats:
    """DAG stats for MHT (paper fig 8) on an n x n matrix."""
    return _analyze(n, "mht")


# ---------------------------------------------------------------------------
# tiled task-graph parallelism (extends the beta metric to the tile DAG)
# ---------------------------------------------------------------------------

def _qr_column_ops(length: int, trailing: int) -> int:
    """Scalar ops of one Householder column: reflector generation
    (~3L + const for the norm/sqrt/divide chain) plus the fused MHT
    macro update (~4 ops per trailing entry: mul, tree-add share, scale,
    subtract) — the same accounting _analyze tallies node-by-node."""
    return 3 * length + 10 + 4 * length * trailing


def _geqrt_ops(nb: int) -> int:
    return sum(_qr_column_ops(nb - j, nb - 1 - j) for j in range(nb))


def _tsqrt_ops(nb: int) -> int:
    # Structured stacked QR: each column's reflector touches the pivot
    # row of R plus the full nb-tall A block (length nb + 1).
    return sum(_qr_column_ops(nb + 1, nb - 1 - j) for j in range(nb))


def _larfb_ops(nb: int) -> int:
    return 6 * nb**3          # three chained nb x nb GEMMs

def _ssrfb_ops(nb: int) -> int:
    return 6 * nb**3 + 2 * nb**2   # three GEMMs + two tile subtracts


def _tiled_grid_ops(p: int, q: int, tile: int) -> int:
    """Total scalar ops of the flat-tree tile DAG on a p x q grid."""
    ops = 0
    for k in range(min(p, q)):
        ops += _geqrt_ops(tile)
        ops += (q - 1 - k) * _larfb_ops(tile)
        ops += (p - 1 - k) * _tsqrt_ops(tile)
        ops += (p - 1 - k) * (q - 1 - k) * _ssrfb_ops(tile)
    return ops


def analyze_tiled(n: int, tile: int = 16) -> DagStats:
    """DAG stats for the tiled task-graph QR on an n x n matrix.

    The tiled runtime executes *macro operations* (GEQRT / TSQRT / LARFB
    / SSRFB tile tasks) as its DAG nodes — the paper's co-design premise
    realized one level up: each node is a fused tile kernel
    (:mod:`repro.kernels.tile_ops`), and a DAG level is one wavefront of
    the static schedule (:func:`repro.core.tilegraph.wavefront_count`).
    ``ops`` tallies the scalar work inside every macro node with the
    same per-column accounting as :func:`analyze_mht`, so beta =
    ops/levels measures how much scalar work each wavefront exposes.
    Tiling multiplies beta: levels collapse from O(n log n) scalar steps
    to p + 2q - 2 wavefronts while total ops stay O(n^3).
    """
    from repro.core.tilegraph import tile_grid, wavefront_count

    p, q = tile_grid(n, n, tile)
    return DagStats(ops=_tiled_grid_ops(p, q, tile),
                    depth=wavefront_count(p, q))


def _merge_ops(n: int) -> int:
    """Scalar ops of one butterfly-merge node: QR of two stacked n x n
    triangles.  Column j touches ~2(j+1) structurally-nonzero rows."""
    return sum(_qr_column_ops(2 * (j + 1), n - 1 - j) for j in range(n))


def analyze_sharded_tiled(n: int, tile: int = 16, ndomains: int = 4
                          ) -> DagStats:
    """DAG stats for the multi-device sharded tiled QR on an n x n matrix.

    The schedule (:mod:`repro.core.distgraph`) runs d independent
    row-block domains — each a (p/d x q) flat-tree tile DAG — then a
    binary merge tree of stacked-triangle QR nodes over the per-domain R
    factors.  A level is one cross-device wavefront
    (:func:`repro.core.tilegraph.sharded_wavefront_count`): depth drops
    from p + 2q - 2 to p/d + 2q - 2 + ceil(log2 d) while ops gain only
    the (d - 1) merge nodes, so beta = ops/levels rises with d — the
    paper's more-macro-ops-per-level thesis extended across devices.

    Like the executor, domain counts round down to a power of two and
    cap at the tile-row count; p pads up to d * ceil(p/d).
    """
    from repro.core.tilegraph import (
        sharded_wavefront_count, tile_grid, wavefront_count)

    p, q = tile_grid(n, n, tile)
    d = max(1, min(ndomains, p))
    # round down to a power of two, matching the executor (canonical
    # helper: repro.distributed.sharding.largest_pow2 — inlined here to
    # keep dag.py jax-free)
    d = 1 << (d.bit_length() - 1)
    if d == 1:
        return DagStats(ops=_tiled_grid_ops(p, q, tile),
                        depth=wavefront_count(p, q))
    p_dom = -(-p // d)
    ops = d * _tiled_grid_ops(p_dom, q, tile) + (d - 1) * _merge_ops(n)
    return DagStats(ops=ops, depth=sharded_wavefront_count(p, q, d))


def sharded_curve(sizes: Tuple[int, ...] = (128, 256, 512),
                  tile: int = 16, ndomains: int = 4) -> dict:
    """beta of the sharded schedule vs the single-device tiled DAG per
    matrix size (the multi-device extension of :func:`tiled_curve`)."""
    rows = []
    for n in sizes:
        tl = analyze_tiled(n, tile)
        sh = analyze_sharded_tiled(n, tile, ndomains)
        rows.append(dict(
            n=n, tile=tile, ndomains=ndomains,
            sharded_ops=sh.ops, sharded_levels=sh.depth,
            beta_sharded=sh.beta, beta_tiled=tl.beta,
            beta_gain_sharded=sh.beta / tl.beta,
            level_gain=tl.depth / sh.depth,
        ))
    return {"rows": rows}


def tiled_curve(sizes: Tuple[int, ...] = (64, 128, 256),
                tile: int = 16) -> dict:
    """beta of the tiled task DAG vs MHT per matrix size (bench fig-9
    companion: HT vs MHT vs tiled ops-per-level)."""
    rows = []
    for n in sizes:
        mht = analyze_mht(n)
        tl = analyze_tiled(n, tile)
        rows.append(dict(
            n=n, tile=tile,
            tiled_ops=tl.ops, tiled_levels=tl.depth,
            beta_tiled=tl.beta, beta_mht=mht.beta,
            beta_gain_tiled=tl.beta / mht.beta,
        ))
    return {"rows": rows}


def phase_model_theta(n: int, *, width: int = 4, v_const: int = 9) -> dict:
    """theta under the paper's *width-bound* hardware model (fig 9).

    The paper's RDP executes at most ``width`` (=4, the DOT4) scalar ops
    per level, so every length-L phase of a column costs ~L/width levels
    regardless of tree shape.  Per column of height L, classical HT runs
    FOUR such phases — norm reduction, P materialization (fig 6 shows the
    p_ik nodes explicitly), the P.A dot pass, and the subtract pass —
    while MHT runs THREE (norm, v^T A dot, fused update; the paper's new
    DOT4 configuration merges dot+scale+subtract into one pass).  Hence

        theta(n) = levels_MHT / levels_HT
                 = (3 * sum_L L + c n) / (4 * sum_L L + c n)  ->  3/4,

    matching the paper's reported saturation at 0.749.  ``v_const`` models
    the L-independent sqrt/div chain of the reflector computation.
    """
    tot_ht = 0.0
    tot_mht = 0.0
    for j in range(n - 1):
        L = n - j
        tot_ht += 4.0 * L / width + v_const
        tot_mht += 3.0 * L / width + v_const
    return dict(n=n, levels_ht=tot_ht, levels_mht=tot_mht,
                theta=tot_mht / tot_ht, parallelism_gain=tot_ht / tot_mht)


def theta_curve(sizes: Tuple[int, ...] = (4, 8, 16, 32, 64, 128)) -> dict:
    """theta(n) = levels_MHT / levels_HT, plus beta gain, per matrix size.

    Paper fig 9: theta saturates at ~0.749.  Returns a dict of rows for
    the benchmark harness / EXPERIMENTS.md.
    """
    rows = []
    for n in sizes:
        ht = analyze_ht(n)
        mht = analyze_mht(n)
        pm = phase_model_theta(n)
        rows.append(
            dict(
                n=n,
                ht_ops=ht.ops,
                ht_levels=ht.depth,
                mht_ops=mht.ops,
                mht_levels=mht.depth,
                theta_levels=mht.depth / ht.depth,
                beta_ht=ht.beta,
                beta_mht=mht.beta,
                # Equal-ops accounting (paper eq. 9/10): parallelism gain is
                # the inverse level ratio.
                beta_gain_equal_ops=ht.depth / mht.depth,
                theta_width4=pm["theta"],
                gain_width4=pm["parallelism_gain"],
            )
        )
    return {"rows": rows}
