"""Tiled QR task-graph runtime — tile kernels + static wavefront scheduler.

The paper's thesis is that QR speed comes from (1) exposing more parallel
operations per DAG level and (2) realizing each DAG node as a fused macro
operation on specialized hardware (§4-§5).  The unblocked and blocked
realizations in this package still serialize across panels: panel k+1
cannot start until the full trailing update of panel k finished.  Tiled
QR (Buttari et al., PLASMA) removes that barrier by decomposing the
factorization into a DAG of *tile tasks* over an (p x q) grid of nb x nb
tiles:

    GEQRT(k)      QR of diagonal tile (k,k)          -> V1, R, T
    LARFB(k,j)    apply Q_k^T to tile (k,j), j > k   (WY trailing update)
    TSQRT(i,k)    QR of the stacked pair [R_kk; A_ik] (triangle on top)
    SSRFB(k,i,j)  apply the TSQRT reflectors to the tile pair
                  [A_kj; A_ij], j > k

Tasks from *different* panels run concurrently whenever their tile
dependencies allow — exactly the "more macro operations per DAG level"
structure that :mod:`repro.core.dag` quantifies for HT vs MHT
(:func:`repro.core.dag.analyze_tiled` extends the beta/theta metric to
this DAG).

Execution model: the DAG is levelized *statically* (every task's
wavefront = 1 + max over its dependencies) and handed to the wavefront
macro-op engine (:mod:`repro.core.engine`), which lowers each level's
same-kind task batch to a **single in-place Pallas dispatch** over a
``(p, q, nb, nb)`` tile workspace (``use_kernel=True``) or to the
bitwise-identical vmapped jnp oracle (``use_kernel=False``).  Shapes are
static per wavefront, so the whole factorization traces into one
jittable program — no runtime scheduler, the schedule IS the program.

Tile kernels: all four macro ops (GEQRT / LARFB / TSQRT / SSRFB) live in
the unified :mod:`repro.kernels.macro_ops` library — one Householder /
WY core shared with the panel and trailing kernels — with
``interpret=True`` CPU fallback.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.blocked import unpack_v_panel

Array = jax.Array

__all__ = [
    "TileTask",
    "TiledFactors",
    "build_tasks",
    "task_deps",
    "levelize",
    "wavefronts",
    "wavefront_count",
    "tile_grid",
    "tiled_qr",
    "tiled_qr_batched",
    "domain_rows",
    "domain_wavefronts",
    "merge_levels",
    "sharded_wavefront_count",
]


# ---------------------------------------------------------------------------
# symbolic tile-task DAG (no jax — pure graph arithmetic)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, order=True)
class TileTask:
    """One macro operation on the tile grid.

    kind: "GEQRT" | "LARFB" | "TSQRT" | "SSRFB"
    k:    panel step (0 <= k < min(p, q))
    i:    row-tile index (GEQRT/LARFB: i == k)
    j:    column-tile index (GEQRT/TSQRT: j == k)
    """

    kind: str
    k: int
    i: int
    j: int


def tile_grid(m: int, n: int, tile: int) -> Tuple[int, int]:
    """Tile-grid shape (p, q) covering an m x n matrix (ceil division)."""
    if tile < 1:
        raise ValueError(f"tile must be >= 1, got {tile}")
    return -(-m // tile), -(-n // tile)


def build_tasks(p: int, q: int) -> List[TileTask]:
    """All tile tasks of a p x q grid, in a valid topological order."""
    tasks: List[TileTask] = []
    for k in range(min(p, q)):
        tasks.append(TileTask("GEQRT", k, k, k))
        tasks.extend(TileTask("LARFB", k, k, j) for j in range(k + 1, q))
        for i in range(k + 1, p):
            tasks.append(TileTask("TSQRT", k, i, k))
            tasks.extend(TileTask("SSRFB", k, i, j) for j in range(k + 1, q))
    return tasks


def task_deps(t: TileTask) -> Tuple[TileTask, ...]:
    """Immediate dependencies of one task (the PLASMA flat-tree DAG).

    The chain structure: TSQRT(i,k) serializes in i (each updates R_kk),
    SSRFB(k,i,j) serializes in i (each updates the top tile A_kj), and
    every step-k task waits for the step-(k-1) update of its tiles.
    """
    k, i, j = t.k, t.i, t.j
    deps: List[TileTask] = []
    if t.kind == "GEQRT":
        if k > 0:
            deps.append(TileTask("SSRFB", k - 1, k, k))
    elif t.kind == "LARFB":
        deps.append(TileTask("GEQRT", k, k, k))
        if k > 0:
            deps.append(TileTask("SSRFB", k - 1, k, j))
    elif t.kind == "TSQRT":
        deps.append(TileTask("TSQRT", k, i - 1, k) if i > k + 1
                    else TileTask("GEQRT", k, k, k))
        if k > 0:
            deps.append(TileTask("SSRFB", k - 1, i, k))
    elif t.kind == "SSRFB":
        deps.append(TileTask("TSQRT", k, i, k))
        deps.append(TileTask("SSRFB", k, i - 1, j) if i > k + 1
                    else TileTask("LARFB", k, k, j))
        if k > 0:
            deps.append(TileTask("SSRFB", k - 1, i, j))
    else:
        raise ValueError(f"unknown task kind {t.kind!r}")
    return tuple(deps)


def levelize(p: int, q: int) -> Dict[TileTask, int]:
    """Wavefront index of every task: 1 + max over its dependencies."""
    levels: Dict[TileTask, int] = {}
    for t in build_tasks(p, q):
        deps = task_deps(t)
        levels[t] = 1 + max((levels[d] for d in deps), default=0)
    return levels


def wavefronts(p: int, q: int) -> List[List[TileTask]]:
    """Tasks grouped by wavefront (ascending), deterministic order within."""
    levels = levelize(p, q)
    out: List[List[TileTask]] = [[] for _ in range(max(levels.values(), default=0))]
    for t, lv in levels.items():
        out[lv - 1].append(t)
    for wf in out:
        wf.sort()
    return out


def wavefront_count(p: int, q: int) -> int:
    """Closed-form critical-path length of the p x q flat-tree tile DAG.

    Derivation from the recurrences in :func:`task_deps`:
      * q == 1: the TSQRT chain alone — p levels.
      * p >= q: GEQRT(k) fires at 3k+1, the last TSQRT of step k at
        (3k+1) + (p-1-k), giving p + 2q - 2 overall.
      * p <  q: the trailing LARFB of the last step adds one level on
        top of the square case 3p - 2, giving 3p - 1.
    Verified against :func:`levelize` in tests/test_tilegraph.py.
    """
    if p < 1 or q < 1:
        raise ValueError(f"grid must be at least 1x1, got {p}x{q}")
    return p + 2 * q - 2 if p >= q else 3 * p - 1


# ---------------------------------------------------------------------------
# domain-aware DAG metadata (multi-device sharded schedule, core.distgraph)
# ---------------------------------------------------------------------------
#
# The sharded runtime partitions the p x q tile grid into d contiguous
# row-block *domains*, one per device.  Each domain runs the ordinary
# flat-tree wavefront schedule on its own (p_i x q) sub-grid — fully
# independent of the other domains — and the per-domain R factors merge
# through a TSQR-style binary reduction tree (ceil(log2 d) rounds).  The
# cross-device critical path is therefore
#
#     wavefront_count(ceil(p / d), q) + ceil(log2 d)
#
# i.e. O(p/d + 2q + log d) wavefronts instead of the single-device
# O(p + 2q) — the DAG exposes d-way *domain* parallelism on top of the
# per-wavefront tile parallelism.

def domain_rows(p: int, d: int) -> Tuple[Tuple[int, int], ...]:
    """Contiguous per-domain tile-row ranges ``((start, stop), ...)``.

    Balanced split of p tile rows over d domains; when p is not divisible
    by d the first ``p % d`` domains carry one extra tile row (the
    executor instead zero-pads rows so every device gets ``ceil(p / d)``
    — padding rows factor to exact-zero reflectors, see
    :func:`tiled_qr`).  Requires ``1 <= d <= p``.
    """
    if d < 1 or d > p:
        raise ValueError(f"need 1 <= d <= p, got d={d}, p={p}")
    base, extra = divmod(p, d)
    out, start = [], 0
    for i in range(d):
        stop = start + base + (1 if i < extra else 0)
        out.append((start, stop))
        start = stop
    return tuple(out)


def domain_wavefronts(p: int, q: int, d: int) -> List[List[List[TileTask]]]:
    """Per-domain wavefront schedules: ``out[i]`` is the wavefront list of
    domain i's local (p_i x q) tile DAG (task indices are domain-local).
    Domains are mutually independent — level L of every domain runs
    concurrently across devices."""
    return [wavefronts(stop - start, q) if stop > start else []
            for start, stop in domain_rows(p, d)]


def merge_levels(d: int) -> int:
    """Depth of the binary R-merge reduction tree over d domains."""
    if d < 1:
        raise ValueError(f"need d >= 1, got {d}")
    return (d - 1).bit_length()


def sharded_wavefront_count(p: int, q: int, d: int) -> int:
    """Closed-form cross-device critical path of the d-domain schedule.

    The executor pads p up to ``d * ceil(p / d)`` tile rows so every
    domain has the same local grid; the critical path is the (tallest)
    local schedule plus the merge-tree rounds.  ``d=1`` degenerates to
    :func:`wavefront_count` exactly (no merge levels).
    """
    if d < 1:
        raise ValueError(f"need d >= 1, got {d}")
    if d == 1:
        return wavefront_count(p, q)
    p_dom = -(-p // d)
    return wavefront_count(p_dom, q) + merge_levels(d)


# ---------------------------------------------------------------------------
# wavefront execution (repro.core.engine + repro.kernels.macro_ops)
# ---------------------------------------------------------------------------

# The factored tile state is the engine's — re-exported under the
# historical name (same fields, same layout).
TiledFactors = engine.FactorState


def _split_tiles(a: Array, p: int, q: int, nb: int) -> Array:
    return a.reshape(p, nb, q, nb).transpose(0, 2, 1, 3)


def _join_tiles(tiles: Array) -> Array:
    p, q, nb, _ = tiles.shape
    return tiles.transpose(0, 2, 1, 3).reshape(p * nb, q * nb)


def _form_q_tiled(f: TiledFactors, ncols: int) -> Array:
    """Materialize Q columns by applying the task transforms in reverse.

    A = G_0 T_{0,1}..T_{0,p-1} G_1 T_{1,2}.. ... R, so Q E applies the
    per-step transforms right-to-left: TSQRT pairs top-down in reverse,
    then the GEQRT diagonal block.  All applications are (nb x ncols)
    row-block updates — plain jnp, the cost matches the factorization.
    """
    p, q, nb, _ = f.tiles.shape
    m_pad = p * nb
    e = jnp.eye(m_pad, ncols, dtype=f.tiles.dtype)

    for k in reversed(range(min(p, q))):
        for i in reversed(range(k + 1, p)):
            v2, t = f.tiles[i, k], f.t_t[i, k]
            ek, ei = e[k * nb:(k + 1) * nb], e[i * nb:(i + 1) * nb]
            w = t @ (ek + v2.T @ ei)          # non-transposed Q
            e = e.at[k * nb:(k + 1) * nb].set(ek - w)
            e = e.at[i * nb:(i + 1) * nb].set(ei - v2 @ w)
        v1 = unpack_v_panel(f.tiles[k, k], 0)
        ek = e[k * nb:(k + 1) * nb]
        e = e.at[k * nb:(k + 1) * nb].set(ek - v1 @ (f.d_t[k] @ (v1.T @ ek)))
    return e


@functools.partial(jax.jit, static_argnames=("tile", "mode", "use_kernel",
                                             "dispatch_mode"))
def tiled_qr(a: Array, *, tile: int = 32, mode: str = "reduced",
             use_kernel: bool = False, dispatch_mode: str = None):
    """QR of ``a`` via the tiled task-graph runtime.

    ``use_kernel=True`` executes the schedule through the macro-op
    engine's Pallas lowering (:func:`repro.core.engine.factor_tiles`;
    interpret mode off-TPU) selected by ``dispatch_mode`` — per-level
    ``"wavefront"`` dispatches, the single-call ``"megakernel"``, or
    ``None`` for the engine's budget-driven auto rule; ``use_kernel=
    False`` runs the bitwise-identical pure-jnp oracle lowering of the
    same schedule.

    Non-multiple-of-tile shapes are zero-padded: padded rows/columns
    yield exactly-zero reflector entries (degenerate ``tau = 0`` columns),
    so the unpadded Q/R slices are the factorization of ``a`` itself.

    mode: "reduced" -> (Q m x k, R k x n); "r" -> R; "full" -> (Q m x m,
    R m x n), with k = min(m, n).

    Cost note: the symbolic DAG holds O(p q min(p, q)) tasks for a p x q
    tile grid — scale ``tile`` with the matrix so the grid stays modest
    (the "auto" planner caps dims at 2048 for the default tile).
    """
    m, n = a.shape
    if m == 0 or n == 0:
        raise ValueError(
            f"tiled_qr needs a nonempty matrix, got {a.shape}; zero-dim "
            "inputs route to the planner's 'degenerate' method "
            "(jnp.linalg.qr semantics)")
    p, q = tile_grid(m, n, tile)
    nb = tile
    pad = ((0, p * nb - m), (0, q * nb - n))
    a_pad = jnp.pad(a, pad) if (pad[0][1] or pad[1][1]) else a

    f = engine.factor_tiles(_split_tiles(a_pad, p, q, nb),
                            p=p, q=q, nb=nb, use_kernel=use_kernel,
                            dispatch_mode=dispatch_mode)
    k = min(m, n)
    r_full = jnp.triu(_join_tiles(f.tiles))
    if mode == "r":
        return r_full[:k, :n]
    if mode == "reduced":
        q_mat = _form_q_tiled(f, ncols=min(p * nb, q * nb))[:m, :k]
        return q_mat, r_full[:k, :n]
    if mode == "full":
        q_mat = _form_q_tiled(f, ncols=p * nb)[:m, :m]
        return q_mat, r_full[:m, :n]
    raise ValueError(f"unknown mode {mode!r}")


def _factor_stack_padded(a_pad: Array, *, p: int, q: int, nb: int,
                         mode: str, use_kernel: bool = False,
                         dispatch_mode: str = None, interpret: bool = None):
    """Factor a tile-aligned ``(B, p*nb, q*nb)`` stack through ONE
    batched engine dispatch, returning FULL padded factors —
    ``(r_full,)`` for mode="r", ``(q_full, r_full)`` otherwise (both
    batch-leading, grid-extent shapes).  Keeping outputs full-extent lets
    callers that donate the input stack (the serving bucket executables)
    alias it into an output buffer; the unpadding slice lives in the
    wrappers instead.

    The stack shares one task table: on the megakernel path the whole
    batch is a single ``pallas_call`` with a batch axis on the grid;
    other modes vmap the per-slice program.  Bitwise-equal per slice to
    independent :func:`tiled_qr` runs (the ``B == 1`` Q formation skips
    vmap — batch-1 vmapped ``dot_general`` is not bitwise-stable)."""
    b = a_pad.shape[0]
    tiles = jax.vmap(lambda x: _split_tiles(x, p, q, nb))(a_pad)
    f = engine.factor_tiles_batched(tiles, p=p, q=q, nb=nb,
                                    use_kernel=use_kernel,
                                    interpret=interpret,
                                    dispatch_mode=dispatch_mode)
    r_full = jax.vmap(lambda t: jnp.triu(_join_tiles(t)))(f.tiles)
    if mode == "r":
        return (r_full,)
    if mode not in ("reduced", "full"):
        raise ValueError(f"unknown mode {mode!r}")
    ncols = min(p * nb, q * nb) if mode == "reduced" else p * nb
    form = lambda *fs: _form_q_tiled(  # noqa: E731
        engine.FactorState(*fs), ncols=ncols)
    q_mat = (form(*(x[0] for x in f))[None] if b == 1
             else jax.vmap(form)(*f))
    return (q_mat, r_full)


def _tiled_qr_batched_impl(a: Array, *, tile: int = 32,
                           mode: str = "reduced", use_kernel: bool = False,
                           dispatch_mode: str = None,
                           interpret: bool = None):
    """QR of a ``(B, m, n)`` stack through ONE batched engine dispatch.

    Zero-pads every slice to the shared tile grid, factors the whole
    stack via :func:`_factor_stack_padded` (one
    :func:`repro.core.engine.factor_tiles_batched` call — a single
    ``pallas_call`` on the megakernel path), and returns unpadded
    slices: same modes/shapes as :func:`tiled_qr` with a leading batch
    axis.  This is the shared lowering behind the serving layer's bucket
    programs and the optimizer's shape-class dispatch
    (:mod:`repro.optim.batched_ortho`).
    """
    b, m, n = a.shape
    if m == 0 or n == 0:
        raise ValueError(
            f"tiled_qr_batched needs nonempty matrices, got {a.shape}")
    p, q = tile_grid(m, n, tile)
    nb = tile
    pad = ((0, 0), (0, p * nb - m), (0, q * nb - n))
    a_pad = jnp.pad(a, pad) if (pad[1][1] or pad[2][1]) else a
    out = _factor_stack_padded(a_pad, p=p, q=q, nb=nb, mode=mode,
                               use_kernel=use_kernel,
                               dispatch_mode=dispatch_mode,
                               interpret=interpret)
    k = min(m, n)
    if mode == "r":
        return out[0][:, :k, :n]
    q_mat, r_full = out
    if mode == "reduced":
        return q_mat[:, :m, :k], r_full[:, :k, :n]
    return q_mat[:, :m, :m], r_full[:, :m, :n]


# The public wrapper jits once per (shape, knobs); callers composing the
# lowering into a larger traced program (the serving bucket executables,
# the batched-ortho optimizer path) trace the impl or
# ``_factor_stack_padded`` directly — donation does not cross a nested
# jit boundary.
tiled_qr_batched = jax.jit(
    _tiled_qr_batched_impl,
    static_argnames=("tile", "mode", "use_kernel", "dispatch_mode",
                     "interpret"))


# -- registry -----------------------------------------------------------------
from repro.core.plan import (  # noqa: E402
    MethodSpec, QRConfig, register_method, sign_fix_qr, sign_fix_r)


def _planned_itemsize(cfg, dtype) -> int:
    """Element width of the compute dtype the solve will actually run
    (the ``precision`` override wins over the input dtype)."""
    import numpy as np

    if cfg.precision is not None:
        return np.dtype(cfg.precision).itemsize
    return np.dtype(dtype).itemsize if dtype is not None else 4


def _resolve_dispatch_explained(p: int, q: int, nb: int, itemsize: int,
                                explain) -> str:
    """Resolve the engine dispatch mode, surfacing megakernel-over-budget
    rejections as a planner fallback (counter + explain decision) —
    shared by the tiled and sharded resolve hooks."""
    from repro.core.plan import RouteDecision
    from repro.observability import metrics as _metrics

    mode, why = engine.explain_dispatch_mode(p, q, nb, itemsize)
    if mode == "wavefront":
        _metrics.counter("planner.fallbacks",
                         reason="megakernel_over_budget").inc()
        if explain is not None:
            explain.append(RouteDecision("megakernel_over_budget",
                                         "fallback", why))
    elif explain is not None:
        explain.append(RouteDecision("dispatch_mode_auto", "resolved", why))
    return mode


def _resolve_tiled(m: int, n: int, cfg: QRConfig, *, dtype=None,
                   explain=None) -> QRConfig:
    # cfg.block doubles as the tile size; never exceed the matrix itself.
    cfg = cfg.replace(block=min(cfg.block, m, n))
    if cfg.dispatch_mode is None and cfg.use_kernel:
        # Record the engine lowering the kernel path will actually run
        # (megakernel iff the task table + working set fit the budgets
        # at the planned element width — fp64 doubles the working set);
        # the jnp-oracle path has no kernel dispatch — mode stays None.
        p, q = tile_grid(m, n, cfg.block)
        cfg = cfg.replace(dispatch_mode=_resolve_dispatch_explained(
            p, q, cfg.block, _planned_itemsize(cfg, dtype), explain))
    return cfg


def _solve_tiled(a: Array, cfg: QRConfig):
    m, n = a.shape
    tile = cfg.block  # capped at min(m, n) by the _resolve_tiled hook
    if cfg.mode == "r":
        r = tiled_qr(a, tile=tile, mode="r", use_kernel=bool(cfg.use_kernel),
                     dispatch_mode=cfg.dispatch_mode)
        return sign_fix_r(r) if cfg.sign_fix else r
    if cfg.mode == "reduced" and cfg.q_method == "solve" and m >= n:
        from repro.core.tsqr import triangular_inverse_apply

        r = tiled_qr(a, tile=tile, mode="r", use_kernel=bool(cfg.use_kernel),
                     dispatch_mode=cfg.dispatch_mode)
        q = triangular_inverse_apply(a, r[:n, :n])
    else:
        q, r = tiled_qr(a, tile=tile, mode=cfg.mode,
                        use_kernel=bool(cfg.use_kernel),
                        dispatch_mode=cfg.dispatch_mode)
    return sign_fix_qr(q, r) if cfg.sign_fix else (q, r)


def _solve_tiled_batched(a: Array, cfg: QRConfig):
    """Native (B, m, n) solve: same semantics as :func:`_solve_tiled` per
    slice, but the whole stack factors through one batched engine
    dispatch (sign fixing and Q-by-solve vmap over the batch — they are
    elementwise / per-slice dense ops, not engine work)."""
    _, m, n = a.shape
    tile = cfg.block  # capped at min(m, n) by the _resolve_tiled hook
    if cfg.mode == "r":
        r = tiled_qr_batched(a, tile=tile, mode="r",
                             use_kernel=bool(cfg.use_kernel),
                             dispatch_mode=cfg.dispatch_mode)
        return jax.vmap(sign_fix_r)(r) if cfg.sign_fix else r
    if cfg.mode == "reduced" and cfg.q_method == "solve" and m >= n:
        from repro.core.tsqr import triangular_inverse_apply

        r = tiled_qr_batched(a, tile=tile, mode="r",
                             use_kernel=bool(cfg.use_kernel),
                             dispatch_mode=cfg.dispatch_mode)
        q = jax.vmap(triangular_inverse_apply)(a, r[:, :n, :n])
    else:
        q, r = tiled_qr_batched(a, tile=tile, mode=cfg.mode,
                                use_kernel=bool(cfg.use_kernel),
                                dispatch_mode=cfg.dispatch_mode)
    return jax.vmap(sign_fix_qr)(q, r) if cfg.sign_fix else (q, r)


def _vmem_tiled(m: int, n: int, cfg: QRConfig) -> int:
    """Smallest working set the kernel path can run in (fp32 units — the
    caller scales by element width).  With ``dispatch_mode`` unset or
    "wavefront" that is the per-level wavefront set: the megakernel's
    larger double-buffered set is only ever auto-picked when it *also*
    fits (at the planned width, see ``_resolve_tiled``), so pricing it
    here would wrongly reject shapes the wavefront mode handles.  Only a
    forced megakernel must be gated on its own footprint."""
    from repro.kernels import macro_ops

    nb = min(cfg.block, m, n)
    if cfg.dispatch_mode == "megakernel":
        return macro_ops.megakernel_vmem_bytes(nb)
    return macro_ops.engine_vmem_bytes(nb)


register_method(MethodSpec(
    name="tiled",
    solve=_solve_tiled,
    solve_batched=_solve_tiled_batched,
    resolve=_resolve_tiled,
    kernel_backed=True,
    vmem_bytes=_vmem_tiled,
    kernel_policy="macro_ops",
    description="tiled task-graph QR via the wavefront macro-op engine "
                "(GEQRT/TSQRT/LARFB/SSRFB, one Pallas dispatch per level)",
))
