"""Tiled QR task-graph runtime — tile kernels + static wavefront scheduler.

The paper's thesis is that QR speed comes from (1) exposing more parallel
operations per DAG level and (2) realizing each DAG node as a fused macro
operation on specialized hardware (§4-§5).  The unblocked and blocked
realizations in this package still serialize across panels: panel k+1
cannot start until the full trailing update of panel k finished.  Tiled
QR (Buttari et al., PLASMA) removes that barrier by decomposing the
factorization into a DAG of *tile tasks* over an (p x q) grid of nb x nb
tiles:

    GEQRT(k)      QR of diagonal tile (k,k)          -> V1, R, T
    LARFB(k,j)    apply Q_k^T to tile (k,j), j > k   (WY trailing update)
    TSQRT(i,k)    QR of the stacked pair [R_kk; A_ik] (triangle on top)
    SSRFB(k,i,j)  apply the TSQRT reflectors to the tile pair
                  [A_kj; A_ij], j > k

Tasks from *different* panels run concurrently whenever their tile
dependencies allow — exactly the "more macro operations per DAG level"
structure that :mod:`repro.core.dag` quantifies for HT vs MHT
(:func:`repro.core.dag.analyze_tiled` extends the beta/theta metric to
this DAG).

Execution model: the DAG is levelized *statically* (every task's
wavefront = 1 + max over its dependencies), and each wavefront lowers to
JAX as a ``vmap`` over the independent same-kind tiles of that level.
Shapes are static per wavefront, so the whole factorization traces into
one jittable program — no runtime scheduler, the schedule IS the program.

Tile kernels: GEQRT/LARFB reuse the existing Pallas kernels
(:func:`repro.kernels.ops.mht_panel` / ``wy_trailing``); the two new
macro ops TSQRT/SSRFB live in :mod:`repro.kernels.tile_ops` with
``interpret=True`` CPU fallback.  ``use_kernel=False`` runs the pure-jnp
realizations below (also the kernels' oracles).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.blocked import larft, panel_factor, unpack_v_panel

Array = jax.Array

__all__ = [
    "TileTask",
    "TiledFactors",
    "build_tasks",
    "task_deps",
    "levelize",
    "wavefronts",
    "wavefront_count",
    "tile_grid",
    "tiled_qr",
    "domain_rows",
    "domain_wavefronts",
    "merge_levels",
    "sharded_wavefront_count",
]


# ---------------------------------------------------------------------------
# symbolic tile-task DAG (no jax — pure graph arithmetic)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, order=True)
class TileTask:
    """One macro operation on the tile grid.

    kind: "GEQRT" | "LARFB" | "TSQRT" | "SSRFB"
    k:    panel step (0 <= k < min(p, q))
    i:    row-tile index (GEQRT/LARFB: i == k)
    j:    column-tile index (GEQRT/TSQRT: j == k)
    """

    kind: str
    k: int
    i: int
    j: int


def tile_grid(m: int, n: int, tile: int) -> Tuple[int, int]:
    """Tile-grid shape (p, q) covering an m x n matrix (ceil division)."""
    if tile < 1:
        raise ValueError(f"tile must be >= 1, got {tile}")
    return -(-m // tile), -(-n // tile)


def build_tasks(p: int, q: int) -> List[TileTask]:
    """All tile tasks of a p x q grid, in a valid topological order."""
    tasks: List[TileTask] = []
    for k in range(min(p, q)):
        tasks.append(TileTask("GEQRT", k, k, k))
        tasks.extend(TileTask("LARFB", k, k, j) for j in range(k + 1, q))
        for i in range(k + 1, p):
            tasks.append(TileTask("TSQRT", k, i, k))
            tasks.extend(TileTask("SSRFB", k, i, j) for j in range(k + 1, q))
    return tasks


def task_deps(t: TileTask) -> Tuple[TileTask, ...]:
    """Immediate dependencies of one task (the PLASMA flat-tree DAG).

    The chain structure: TSQRT(i,k) serializes in i (each updates R_kk),
    SSRFB(k,i,j) serializes in i (each updates the top tile A_kj), and
    every step-k task waits for the step-(k-1) update of its tiles.
    """
    k, i, j = t.k, t.i, t.j
    deps: List[TileTask] = []
    if t.kind == "GEQRT":
        if k > 0:
            deps.append(TileTask("SSRFB", k - 1, k, k))
    elif t.kind == "LARFB":
        deps.append(TileTask("GEQRT", k, k, k))
        if k > 0:
            deps.append(TileTask("SSRFB", k - 1, k, j))
    elif t.kind == "TSQRT":
        deps.append(TileTask("TSQRT", k, i - 1, k) if i > k + 1
                    else TileTask("GEQRT", k, k, k))
        if k > 0:
            deps.append(TileTask("SSRFB", k - 1, i, k))
    elif t.kind == "SSRFB":
        deps.append(TileTask("TSQRT", k, i, k))
        deps.append(TileTask("SSRFB", k, i - 1, j) if i > k + 1
                    else TileTask("LARFB", k, k, j))
        if k > 0:
            deps.append(TileTask("SSRFB", k - 1, i, j))
    else:
        raise ValueError(f"unknown task kind {t.kind!r}")
    return tuple(deps)


def levelize(p: int, q: int) -> Dict[TileTask, int]:
    """Wavefront index of every task: 1 + max over its dependencies."""
    levels: Dict[TileTask, int] = {}
    for t in build_tasks(p, q):
        deps = task_deps(t)
        levels[t] = 1 + max((levels[d] for d in deps), default=0)
    return levels


def wavefronts(p: int, q: int) -> List[List[TileTask]]:
    """Tasks grouped by wavefront (ascending), deterministic order within."""
    levels = levelize(p, q)
    out: List[List[TileTask]] = [[] for _ in range(max(levels.values(), default=0))]
    for t, lv in levels.items():
        out[lv - 1].append(t)
    for wf in out:
        wf.sort()
    return out


def wavefront_count(p: int, q: int) -> int:
    """Closed-form critical-path length of the p x q flat-tree tile DAG.

    Derivation from the recurrences in :func:`task_deps`:
      * q == 1: the TSQRT chain alone — p levels.
      * p >= q: GEQRT(k) fires at 3k+1, the last TSQRT of step k at
        (3k+1) + (p-1-k), giving p + 2q - 2 overall.
      * p <  q: the trailing LARFB of the last step adds one level on
        top of the square case 3p - 2, giving 3p - 1.
    Verified against :func:`levelize` in tests/test_tilegraph.py.
    """
    if p < 1 or q < 1:
        raise ValueError(f"grid must be at least 1x1, got {p}x{q}")
    return p + 2 * q - 2 if p >= q else 3 * p - 1


# ---------------------------------------------------------------------------
# domain-aware DAG metadata (multi-device sharded schedule, core.distgraph)
# ---------------------------------------------------------------------------
#
# The sharded runtime partitions the p x q tile grid into d contiguous
# row-block *domains*, one per device.  Each domain runs the ordinary
# flat-tree wavefront schedule on its own (p_i x q) sub-grid — fully
# independent of the other domains — and the per-domain R factors merge
# through a TSQR-style binary reduction tree (ceil(log2 d) rounds).  The
# cross-device critical path is therefore
#
#     wavefront_count(ceil(p / d), q) + ceil(log2 d)
#
# i.e. O(p/d + 2q + log d) wavefronts instead of the single-device
# O(p + 2q) — the DAG exposes d-way *domain* parallelism on top of the
# per-wavefront tile parallelism.

def domain_rows(p: int, d: int) -> Tuple[Tuple[int, int], ...]:
    """Contiguous per-domain tile-row ranges ``((start, stop), ...)``.

    Balanced split of p tile rows over d domains; when p is not divisible
    by d the first ``p % d`` domains carry one extra tile row (the
    executor instead zero-pads rows so every device gets ``ceil(p / d)``
    — padding rows factor to exact-zero reflectors, see
    :func:`tiled_qr`).  Requires ``1 <= d <= p``.
    """
    if d < 1 or d > p:
        raise ValueError(f"need 1 <= d <= p, got d={d}, p={p}")
    base, extra = divmod(p, d)
    out, start = [], 0
    for i in range(d):
        stop = start + base + (1 if i < extra else 0)
        out.append((start, stop))
        start = stop
    return tuple(out)


def domain_wavefronts(p: int, q: int, d: int) -> List[List[List[TileTask]]]:
    """Per-domain wavefront schedules: ``out[i]`` is the wavefront list of
    domain i's local (p_i x q) tile DAG (task indices are domain-local).
    Domains are mutually independent — level L of every domain runs
    concurrently across devices."""
    return [wavefronts(stop - start, q) if stop > start else []
            for start, stop in domain_rows(p, d)]


def merge_levels(d: int) -> int:
    """Depth of the binary R-merge reduction tree over d domains."""
    if d < 1:
        raise ValueError(f"need d >= 1, got {d}")
    return (d - 1).bit_length()


def sharded_wavefront_count(p: int, q: int, d: int) -> int:
    """Closed-form cross-device critical path of the d-domain schedule.

    The executor pads p up to ``d * ceil(p / d)`` tile rows so every
    domain has the same local grid; the critical path is the (tallest)
    local schedule plus the merge-tree rounds.  ``d=1`` degenerates to
    :func:`wavefront_count` exactly (no merge levels).
    """
    if d < 1:
        raise ValueError(f"need d >= 1, got {d}")
    if d == 1:
        return wavefront_count(p, q)
    p_dom = -(-p // d)
    return wavefront_count(p_dom, q) + merge_levels(d)


# ---------------------------------------------------------------------------
# tile macro-op realizations (jnp path; kernels in repro.kernels.tile_ops)
# ---------------------------------------------------------------------------

def _geqrt(tile: Array, use_kernel: bool) -> Tuple[Array, Array]:
    """QR of one diagonal tile -> (packed V1\\R, taus)."""
    if use_kernel:
        from repro.kernels import ops  # lazy: kernels.ref imports core

        return ops.mht_panel(tile, row0=0)
    return panel_factor(tile, 0)


def _larfb(v1: Array, t: Array, c: Array, use_kernel: bool) -> Array:
    """Apply Q_k^T to one tile: C - V1 (T^T (V1^T C))."""
    if use_kernel:
        from repro.kernels import ops

        return ops.wy_trailing(v1, t, c)
    w = t.T @ (v1.T @ c)
    return c - v1 @ w


def _tsqrt(r_t: Array, a_t: Array, use_kernel: bool
           ) -> Tuple[Array, Array, Array]:
    """Stacked-triangle QR of [R_kk; A_ik] -> (R new, V2, taus).

    The top block is upper triangular, so each column's reflector is
    ``[e_j; v2_j]``: the strict-lower top entries are exactly zero and the
    new R comes back with zeros below its diagonal (the jnp path realizes
    this through :func:`panel_factor` on the stacked pair; the Pallas
    kernel in :mod:`repro.kernels.tile_ops` exploits the structure
    directly).
    """
    if use_kernel:
        from repro.kernels import tile_ops

        return tile_ops.tsqrt(r_t, a_t)
    nb = r_t.shape[0]
    packed, taus = panel_factor(jnp.concatenate([r_t, a_t], axis=0), 0)
    return packed[:nb], packed[nb:], taus


def _ssrfb(v2: Array, t: Array, ck: Array, ci: Array, use_kernel: bool
           ) -> Tuple[Array, Array]:
    """Apply TSQRT reflectors to the tile pair [C_k; C_i] (transposed Q).

    With V = [I; V2]:  W = T^T (C_k + V2^T C_i);  C_k -= W;  C_i -= V2 W.
    """
    if use_kernel:
        from repro.kernels import tile_ops

        return tile_ops.ssrfb(v2, t, ck, ci)
    w = t.T @ (ck + v2.T @ ci)
    return ck - w, ci - v2 @ w


def _larft_stacked(v2: Array, taus: Array) -> Array:
    """Block-reflector T for the stacked TSQRT reflectors V = [I; V2]."""
    nb = v2.shape[1]
    return larft(jnp.concatenate([jnp.eye(nb, dtype=v2.dtype), v2], axis=0),
                 taus)


# ---------------------------------------------------------------------------
# wavefront executor
# ---------------------------------------------------------------------------

class TiledFactors(NamedTuple):
    """Factored tile state: packed reflectors + per-task block reflectors.

    tiles:  (p, q, nb, nb) — diagonal tiles hold V1 strictly below / R on
            and above the diagonal; tiles (i, k), i > k hold the TSQRT V2;
            tiles (k, j), j > k hold R blocks.
    d_t:    (r, nb, nb) GEQRT block reflectors T;  d_taus: (r, nb)
    t_t:    (p, r, nb, nb) TSQRT block reflectors; t_taus: (p, r, nb)
    """

    tiles: Array
    d_t: Array
    d_taus: Array
    t_t: Array
    t_taus: Array


def _split_tiles(a: Array, p: int, q: int, nb: int) -> Array:
    return a.reshape(p, nb, q, nb).transpose(0, 2, 1, 3)


def _join_tiles(tiles: Array) -> Array:
    p, q, nb, _ = tiles.shape
    return tiles.transpose(0, 2, 1, 3).reshape(p * nb, q * nb)


def _upper_mask(nb: int) -> Array:
    rows = jnp.arange(nb)[:, None]
    return rows <= jnp.arange(nb)[None, :]


def _factor_wavefronts(tiles: Array, p: int, q: int, nb: int,
                       use_kernel: bool) -> TiledFactors:
    """Run the static schedule: one vmap per (wavefront, task kind)."""
    r = min(p, q)
    dt = tiles.dtype
    d_t = jnp.zeros((r, nb, nb), dt)
    d_taus = jnp.zeros((r, nb), dt)
    t_t = jnp.zeros((p, r, nb, nb), dt)
    t_taus = jnp.zeros((p, r, nb), dt)
    upper = _upper_mask(nb)

    for wf in wavefronts(p, q):
        by_kind: Dict[str, List[TileTask]] = {}
        for t in wf:
            by_kind.setdefault(t.kind, []).append(t)

        # All gathers below read the pre-wavefront `tiles`; true data
        # dependencies always span wavefronts, and same-level tasks write
        # disjoint tile regions (TSQRT merges into the upper triangle
        # only, preserving the GEQRT V1 below the diagonal).
        updates = []
        if "GEQRT" in by_kind:
            kk = jnp.array([t.k for t in by_kind["GEQRT"]])
            packed, taus = jax.vmap(
                lambda x: _geqrt(x, use_kernel))(tiles[kk, kk])
            v1 = jax.vmap(lambda pk: unpack_v_panel(pk, 0))(packed)
            d_t = d_t.at[kk].set(jax.vmap(larft)(v1, taus))
            d_taus = d_taus.at[kk].set(taus)
            updates.append((kk, kk, packed))
        if "LARFB" in by_kind:
            kk = jnp.array([t.k for t in by_kind["LARFB"]])
            jj = jnp.array([t.j for t in by_kind["LARFB"]])
            v1 = jax.vmap(lambda pk: unpack_v_panel(pk, 0))(tiles[kk, kk])
            out = jax.vmap(lambda v, t, c: _larfb(v, t, c, use_kernel))(
                v1, d_t[kk], tiles[kk, jj])
            updates.append((kk, jj, out))
        if "TSQRT" in by_kind:
            kk = jnp.array([t.k for t in by_kind["TSQRT"]])
            ii = jnp.array([t.i for t in by_kind["TSQRT"]])
            diag = tiles[kk, kk]
            # The diagonal tile packs V1 below its diagonal — TSQRT
            # factors the R triangle only.
            r_in = jnp.where(upper[None], diag, 0.0)
            r_new, v2, taus = jax.vmap(
                lambda rt, at: _tsqrt(rt, at, use_kernel))(r_in, tiles[ii, kk])
            t_t = t_t.at[ii, kk].set(jax.vmap(_larft_stacked)(v2, taus))
            t_taus = t_taus.at[ii, kk].set(taus)
            # Merge: new R in the upper triangle, keep V1 below it.
            merged = jnp.where(upper[None], r_new, diag)
            updates.append((kk, kk, merged))
            updates.append((ii, kk, v2))
        if "SSRFB" in by_kind:
            kk = jnp.array([t.k for t in by_kind["SSRFB"]])
            ii = jnp.array([t.i for t in by_kind["SSRFB"]])
            jj = jnp.array([t.j for t in by_kind["SSRFB"]])
            ck, ci = jax.vmap(
                lambda v, t, a, b: _ssrfb(v, t, a, b, use_kernel))(
                    tiles[ii, kk], t_t[ii, kk], tiles[kk, jj], tiles[ii, jj])
            updates.append((kk, jj, ck))
            updates.append((ii, jj, ci))
        for ri, ci_, vals in updates:
            tiles = tiles.at[ri, ci_].set(vals)

    return TiledFactors(tiles, d_t, d_taus, t_t, t_taus)


def _form_q_tiled(f: TiledFactors, ncols: int) -> Array:
    """Materialize Q columns by applying the task transforms in reverse.

    A = G_0 T_{0,1}..T_{0,p-1} G_1 T_{1,2}.. ... R, so Q E applies the
    per-step transforms right-to-left: TSQRT pairs top-down in reverse,
    then the GEQRT diagonal block.  All applications are (nb x ncols)
    row-block updates — plain jnp, the cost matches the factorization.
    """
    p, q, nb, _ = f.tiles.shape
    m_pad = p * nb
    e = jnp.eye(m_pad, ncols, dtype=f.tiles.dtype)

    for k in reversed(range(min(p, q))):
        for i in reversed(range(k + 1, p)):
            v2, t = f.tiles[i, k], f.t_t[i, k]
            ek, ei = e[k * nb:(k + 1) * nb], e[i * nb:(i + 1) * nb]
            w = t @ (ek + v2.T @ ei)          # non-transposed Q
            e = e.at[k * nb:(k + 1) * nb].set(ek - w)
            e = e.at[i * nb:(i + 1) * nb].set(ei - v2 @ w)
        v1 = unpack_v_panel(f.tiles[k, k], 0)
        ek = e[k * nb:(k + 1) * nb]
        e = e.at[k * nb:(k + 1) * nb].set(ek - v1 @ (f.d_t[k] @ (v1.T @ ek)))
    return e


@functools.partial(jax.jit, static_argnames=("tile", "mode", "use_kernel"))
def tiled_qr(a: Array, *, tile: int = 32, mode: str = "reduced",
             use_kernel: bool = False):
    """QR of ``a`` via the tiled task-graph runtime.

    Non-multiple-of-tile shapes are zero-padded: padded rows/columns
    yield exactly-zero reflector entries (degenerate ``tau = 0`` columns),
    so the unpadded Q/R slices are the factorization of ``a`` itself.

    mode: "reduced" -> (Q m x k, R k x n); "r" -> R; "full" -> (Q m x m,
    R m x n), with k = min(m, n).

    Cost note: the symbolic DAG holds O(p q min(p, q)) tasks for a p x q
    tile grid — scale ``tile`` with the matrix so the grid stays modest
    (the "auto" planner caps dims at 2048 for the default tile).
    """
    m, n = a.shape
    p, q = tile_grid(m, n, tile)
    nb = tile
    pad = ((0, p * nb - m), (0, q * nb - n))
    a_pad = jnp.pad(a, pad) if (pad[0][1] or pad[1][1]) else a

    f = _factor_wavefronts(_split_tiles(a_pad, p, q, nb), p, q, nb, use_kernel)
    k = min(m, n)
    r_full = jnp.triu(_join_tiles(f.tiles))
    if mode == "r":
        return r_full[:k, :n]
    if mode == "reduced":
        q_mat = _form_q_tiled(f, ncols=min(p * nb, q * nb))[:m, :k]
        return q_mat, r_full[:k, :n]
    if mode == "full":
        q_mat = _form_q_tiled(f, ncols=p * nb)[:m, :m]
        return q_mat, r_full[:m, :n]
    raise ValueError(f"unknown mode {mode!r}")


# -- registry -----------------------------------------------------------------
from repro.core.plan import (  # noqa: E402
    MethodSpec, QRConfig, register_method, sign_fix_qr, sign_fix_r)


def _resolve_tiled(m: int, n: int, cfg: QRConfig) -> QRConfig:
    # cfg.block doubles as the tile size; never exceed the matrix itself.
    return cfg.replace(block=min(cfg.block, m, n))


def _solve_tiled(a: Array, cfg: QRConfig):
    m, n = a.shape
    tile = cfg.block  # capped at min(m, n) by the _resolve_tiled hook
    if cfg.mode == "r":
        r = tiled_qr(a, tile=tile, mode="r", use_kernel=bool(cfg.use_kernel))
        return sign_fix_r(r) if cfg.sign_fix else r
    if cfg.mode == "reduced" and cfg.q_method == "solve" and m >= n:
        from repro.core.tsqr import triangular_inverse_apply

        r = tiled_qr(a, tile=tile, mode="r", use_kernel=bool(cfg.use_kernel))
        q = triangular_inverse_apply(a, r[:n, :n])
    else:
        q, r = tiled_qr(a, tile=tile, mode=cfg.mode,
                        use_kernel=bool(cfg.use_kernel))
    return sign_fix_qr(q, r) if cfg.sign_fix else (q, r)


def _vmem_tiled(m: int, n: int, cfg: QRConfig) -> int:
    """Largest per-task working set on the kernel path (one tile pair)."""
    from repro.kernels import tile_ops

    nb = min(cfg.block, m, n)
    return max(tile_ops.vmem_bytes_tsqrt(nb), tile_ops.vmem_bytes_ssrfb(nb))


register_method(MethodSpec(
    name="tiled",
    solve=_solve_tiled,
    resolve=_resolve_tiled,
    kernel_backed=True,
    vmem_bytes=_vmem_tiled,
    kernel_policy="tile_ops",
    description="tiled task-graph QR, wavefront-scheduled tile kernels "
                "(GEQRT/TSQRT/LARFB/SSRFB)",
))
