"""Blocked (WY-representation) Householder QR — ``DGEQRF`` / ``DGEQRFHT``.

Paper §2.3/§4: the blocked algorithm factors a b-column *panel* with the
unblocked transform (classical HT or MHT), accumulates the reflectors into
the compact WY form

    H_{j0} H_{j0+1} ... H_{j0+b-1} = I - V T V^T        (T upper triangular)

and applies the aggregate to the trailing matrix with three GEMMs

    C <- C - V (T^T (V^T C))

so the trailing update runs at Level-3 (MXU) intensity.  ``DGEQRFHT`` is
this routine with MHT panels — the combination the paper shows reaching
99.3% of DGEMM throughput on the co-designed PE.

Kernel dispatch: with ``use_kernel=True`` the panel factorization runs in
the Pallas ``mht_panel`` kernel (whole panel VMEM-resident) and the
trailing update in the fused ``wy_trailing`` kernel (one HBM pass over C).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.householder import _write_packed_column, _zeros_carry, house_vector
from repro.core.mht import mht_update

Array = jax.Array

__all__ = ["larft", "geqrf", "panel_factor", "unpack_v_panel", "wy_apply"]


def larft(v: Array, taus: Array) -> Array:
    """Form the upper-triangular block reflector T (LAPACK ``DLARFT``,
    direction=Forward, storage=Columnwise).

    ``v`` is (m, b) unit-lower-trapezoidal, ``taus`` length b.
    """
    b = v.shape[1]
    gram = v.T @ v  # (b, b); only the strictly-lower part is consumed

    def body(i, t):
        cols = jnp.arange(b)
        mask = cols < i
        w = jnp.where(mask, jnp.take(gram, i, axis=1), 0.0)  # V[:, :i]^T v_i
        tcol = -jnp.take(taus, i) * (t @ w)
        tcol = jnp.where(mask, tcol, 0.0)
        tcol = jnp.where(cols == i, jnp.take(taus, i), tcol)
        return t.at[:, i].set(tcol)

    t0 = _zeros_carry((b, b), v)
    return lax.fori_loop(0, b, body, t0)


def unpack_v_panel(panel: Array, row0: int) -> Array:
    """Extract the unit-lower-trapezoidal V from a packed panel whose
    pivot rows start at ``row0`` (column lj pivots at row ``row0 + lj``)."""
    m, b = panel.shape
    rows = jnp.arange(m)[:, None]
    pivs = row0 + jnp.arange(b)[None, :]
    v = jnp.where(rows > pivs, panel, 0.0)
    return v + (rows == pivs).astype(panel.dtype)


def panel_factor(
    panel: Array, row0: int, *, method: str = "mht"
) -> Tuple[Array, Array]:
    """Factor an (m, b) panel whose pivot rows start at ``row0``.

    Rows above each column's pivot are preserved (they hold R entries from
    earlier trailing updates).  ``method``: "mht" (fused update) or "ht"
    (classical two-pass).
    """
    if method not in ("mht", "ht"):
        raise ValueError(f"unknown panel method: {method!r}")
    b = panel.shape[1]
    taus0 = _zeros_carry((b,), panel)

    def body(lj, carry):
        p, taus = carry
        x = jnp.take(p, lj, axis=1)
        pivot = row0 + lj
        v, tau, beta = house_vector(x, pivot)
        v = jnp.asarray(v, p.dtype)
        tau_c = jnp.asarray(tau, p.dtype)
        if method == "mht":
            p = mht_update(p, v, tau_c, lj)
        else:
            n = p.shape[1]
            trailing = jnp.arange(n) > lj
            w = tau_c * (v @ p)  # pass 1: DGEMV
            upd = jnp.outer(v, w)  # pass 2: DGER
            p = p - jnp.where(trailing[None, :], upd, 0.0)
        p = _write_packed_column(p, v, jnp.asarray(beta, p.dtype), lj, pivot)
        taus = taus.at[lj].set(tau_c)
        return p, taus

    return lax.fori_loop(0, b, body, (panel, taus0))


def wy_apply(v: Array, t: Array, c: Array, *, use_kernel: bool = False) -> Array:
    """Trailing update ``C <- C - V (T^T (V^T C))`` (applies Q^T).

    The kernel path fuses all three products into a single pass over C
    (:mod:`repro.kernels.wy_trailing`)."""
    if use_kernel:
        from repro.kernels import ops  # lazy: kernels.ref imports core

        return ops.wy_trailing(v, t, c)
    w = v.T @ c
    w = t.T @ w
    return c - v @ w


@functools.partial(jax.jit, static_argnames=("block",))
def geqrf_fori(a: Array, *, block: int = 128) -> Tuple[Array, Array]:
    """Blocked MHT QR with a ``fori_loop`` over panels — O(1) HLO size.

    The trailing update runs full-width with a column mask (~2x the FLOPs
    of the exact-width unrolled :func:`geqrf`), which is the right trade
    when n is large and the QR is a small fraction of the step (the
    QR-Muon optimizer path: one fused program regardless of matrix size).
    Requires ``min(m, n) % block == 0`` — callers pad.
    """
    m, n = a.shape
    k = min(m, n)
    if k % block != 0:
        raise ValueError(f"min(m,n)={k} not divisible by block={block}")
    npanels = k // block
    taus0 = _zeros_carry((k,), a)

    def body(pidx, carry):
        a, taus = carry
        j0 = pidx * block
        panel = lax.dynamic_slice(a, (0, j0), (m, block))
        panel_f, taus_p = panel_factor(panel, j0)
        a = lax.dynamic_update_slice(a, panel_f, (0, j0))
        taus = lax.dynamic_update_slice(taus, taus_p, (j0,))
        v = unpack_v_panel(panel_f, j0)
        t = larft(v, taus_p)
        w = t.T @ (v.T @ a)
        colmask = jnp.arange(n)[None, :] >= (j0 + block)
        a = a - jnp.where(colmask, v @ w, 0.0)
        return a, taus

    return lax.fori_loop(0, npanels, body, (a, taus0))


@functools.partial(jax.jit, static_argnames=("block", "panel_method", "use_kernel"))
def geqrf(
    a: Array,
    *,
    block: int = 32,
    panel_method: str = "mht",
    use_kernel: bool = False,
) -> Tuple[Array, Array]:
    """Blocked WY QR factorization.

    ``panel_method="ht"`` gives DGEQRF; ``"mht"`` gives DGEQRFHT.  Output
    is bit-compatible in layout with :func:`repro.core.householder.geqr2`:
    (packed, taus).
    """
    m, n = a.shape
    k = min(m, n)
    taus = _zeros_carry((k,), a)

    j0 = 0
    while j0 < k:
        bw = min(block, k - j0)
        panel = lax.dynamic_slice(a, (0, j0), (m, bw))
        if use_kernel:
            from repro.kernels import ops  # lazy

            panel_f, taus_p = ops.mht_panel(panel, row0=j0)
        else:
            panel_f, taus_p = panel_factor(panel, j0, method=panel_method)
        a = lax.dynamic_update_slice(a, panel_f, (0, j0))
        taus = lax.dynamic_update_slice(taus, taus_p, (j0,))

        if j0 + bw < n:
            v = unpack_v_panel(panel_f, j0)
            t = larft(v, taus_p)
            c = lax.dynamic_slice(a, (0, j0 + bw), (m, n - j0 - bw))
            c = wy_apply(v, t, c, use_kernel=use_kernel)
            a = lax.dynamic_update_slice(a, c, (0, j0 + bw))
        j0 += bw

    return a, taus


# -- registry -----------------------------------------------------------------
from repro.core.plan import MethodSpec, QRConfig, register_method  # noqa: E402


def _vmem_geqrf_panel(m: int, n: int, cfg: QRConfig) -> int:
    """Working set of the widest VMEM-resident panel on the kernel path."""
    from repro.kernels import ops

    return ops.vmem_bytes_mht_panel(m, min(cfg.block, n))


register_method(MethodSpec(
    name="geqrf",
    factor=lambda a, cfg: geqrf(a, block=cfg.block, panel_method="ht",
                                use_kernel=False),
    description="blocked WY, classical HT panels (LAPACK DGEQRF)",
))

register_method(MethodSpec(
    name="geqrf_ht",
    factor=lambda a, cfg: geqrf(a, block=cfg.block, panel_method="mht",
                                use_kernel=bool(cfg.use_kernel)),
    kernel_backed=True,
    vmem_bytes=_vmem_geqrf_panel,
    description="blocked WY, MHT panels (LAPACK DGEQRFHT) [default]",
))


def _resolve_geqrf_fori(m: int, n: int, cfg: QRConfig, *, dtype=None
                        ) -> QRConfig:
    del dtype  # divisibility is element-width independent
    k = min(m, n)
    if k % cfg.block != 0:
        raise ValueError(
            f"geqrf_fori needs min(m,n) divisible by block "
            f"(got {m}x{n}, block={cfg.block}); callers pad")
    return cfg


register_method(MethodSpec(
    name="geqrf_fori",
    factor=lambda a, cfg: geqrf_fori(a, block=cfg.block),
    resolve=_resolve_geqrf_fori,
    description="blocked MHT with fori_loop panels — O(1)-HLO optimizer path",
))
