"""TSQR / CAQR — communication-avoiding distributed QR over a mesh axis.

Paper §5.2 realizes parallel QR by tiling PEs on the REDEFINE NoC with
PLASMA-style block partitioning.  The TPU-native analogue is TSQR
(tall-skinny QR): row-block-local MHT factorizations reduced through a
binary tree of small stacked-R factorizations, exchanging only n x n
triangles over ICI instead of matrix panels.

Three layers:
  * :func:`tsqr_r` / :func:`tsqr_qr` — single-device reference (the oracle
    for the sharded paths; also used for local block counts > 1).
  * :func:`tsqr_tree_sharded` — inside ``shard_map``: log2(P) rounds of
    ``lax.ppermute`` butterfly exchange; every shard finishes with the
    same global R.
  * :func:`distributed_qr` — thin-Q/R of a row-sharded matrix: TSQR for R,
    ``Q = A R^{-1}`` locally (optionally CQR2-refined).

All in fp32: these feed the QR-Muon optimizer, which orthogonalizes
fp32 momentum.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.scipy.linalg import solve_triangular

from repro.compat import axis_size
from repro.core.blocked import geqrf
from repro.core.householder import unpack_r

Array = jax.Array

__all__ = [
    "tsqr_r",
    "tsqr_qr",
    "tsqr_tree_sharded",
    "butterfly_merge_r",
    "distributed_qr",
    "triangular_inverse_apply",
    "default_nblocks",
]


def _local_r(block: Array, *, qr_block: int = 32, use_kernel: bool = False) -> Array:
    """R factor (n x n) of one (mb x n) block via blocked MHT QR."""
    n = block.shape[1]
    packed, _ = geqrf(block, block=min(qr_block, n), panel_method="mht",
                      use_kernel=use_kernel)
    return unpack_r(packed)[:n, :n]


def tsqr_r(a: Array, *, nblocks: int = 4, qr_block: int = 32,
           use_kernel: bool = False) -> Array:
    """R factor of tall-skinny ``a`` (m x n, m >= n*nblocks) via a local
    TSQR reduction tree.  Single-device reference implementation."""
    m, n = a.shape
    if m % nblocks != 0:
        raise ValueError(f"m={m} not divisible by nblocks={nblocks}")
    blocks = a.reshape(nblocks, m // nblocks, n)
    rs = jax.vmap(lambda b: _local_r(b, qr_block=qr_block, use_kernel=use_kernel))(blocks)

    p = nblocks
    while p > 1:
        if p % 2 == 1:
            # Carry the odd block up one level untouched.
            carry, rs = rs[-1:], rs[:-1]
            p -= 1
        else:
            carry = None
        stacked = jnp.concatenate([rs[0::2], rs[1::2]], axis=1)  # (p/2, 2n, n)
        rs = jax.vmap(lambda b: _local_r(b, qr_block=qr_block,
                                         use_kernel=use_kernel))(stacked)
        if carry is not None:
            rs = jnp.concatenate([rs, carry], axis=0)
        p = rs.shape[0]
    return rs[0]


def triangular_inverse_apply(a: Array, r: Array, *, rcond: float = 1e-7) -> Array:
    """Compute ``a @ r^{-1}`` by triangular solve, with a sign-preserving
    diagonal clamp for near-singular R (rank-deficient momentum)."""
    d = jnp.diagonal(r)
    dmax = jnp.maximum(jnp.max(jnp.abs(d)), 1e-30)
    clamp = jnp.where(jnp.abs(d) < rcond * dmax,
                      jnp.where(d >= 0, rcond * dmax, -rcond * dmax), d)
    r_safe = r + jnp.diag(clamp - d)
    # a r^{-1}  <=>  solve r^T x^T = a^T with lower-triangular r^T
    return solve_triangular(r_safe.T, a.T, lower=True).T


def tsqr_qr(a: Array, *, nblocks: int = 4, refine: bool = True,
            qr_block: int = 32, use_kernel: bool = False
            ) -> Tuple[Array, Array]:
    """Thin QR of tall-skinny ``a`` via TSQR-R + ``Q = A R^{-1}``.

    ``refine=True`` runs a second pass (CQR2-style) restoring orthogonality
    to ~machine eps even for moderately ill-conditioned inputs."""
    r1 = tsqr_r(a, nblocks=nblocks, qr_block=qr_block, use_kernel=use_kernel)
    q = triangular_inverse_apply(a, r1)
    if refine:
        r2 = tsqr_r(q, nblocks=nblocks, qr_block=qr_block,
                    use_kernel=use_kernel)
        q = triangular_inverse_apply(q, r2)
        return q, r2 @ r1
    return q, r1


# ---------------------------------------------------------------------------
# shard_map collective versions
# ---------------------------------------------------------------------------

def butterfly_merge_r(r: Array, axis_name: str, combine) -> Array:
    """Merge per-shard (n x n) R factors into the global R, from inside
    ``shard_map`` — the TSQR combine tree, factored out so other sharded
    backends (the ``sharded_tiled`` task-graph runtime) reuse it.

    Butterfly tree: at round r every shard exchanges its current (n x n) R
    with the partner ``rank XOR 2^r`` (``lax.ppermute``), stacks the pair
    and re-factors via ``combine((2n x n) stack) -> (n x n) R``.  After
    log2(P) rounds all shards hold the identical global R — no broadcast
    needed.  Per-round traffic is one n x n triangle per link, vs. P
    triangles for an all-gather TSQR.

    Requires the mesh axis size to be a power of two (all production
    meshes here are 16/32-way; the sharded-tiled planner rounds its
    domain count down to a power of two).
    """
    p = axis_size(axis_name)
    if p & (p - 1):
        raise ValueError(f"butterfly_merge_r needs power-of-two axis, got {p}")
    rounds = p.bit_length() - 1
    for level in range(rounds):
        stride = 1 << level
        perm = [(i, i ^ stride) for i in range(p)]
        r_partner = lax.ppermute(r, axis_name, perm)
        # Deterministic stacking order (lower rank's R on top) so every
        # shard computes bitwise-identical results.
        idx = lax.axis_index(axis_name)
        first = jnp.where((idx & stride) == 0, 1, 0)
        top = jnp.where(first, r, r_partner)
        bot = jnp.where(first, r_partner, r)
        r = combine(jnp.concatenate([top, bot], axis=0))
    # Every shard now holds the identical global R, but the type system
    # cannot infer that; a pmax over bitwise-identical values is an exact
    # no-op that makes the replication provable (n^2 bytes, negligible).
    return lax.pmax(r, axis_name)


def tsqr_tree_sharded(a_local: Array, axis_name: str, *, qr_block: int = 32,
                      use_kernel: bool = False) -> Array:
    """Global R of a row-sharded tall matrix, from inside ``shard_map``.

    Local blocked-MHT R per shard, then the :func:`butterfly_merge_r`
    combine tree; every shard finishes with the identical global R.
    """
    r = _local_r(a_local, qr_block=qr_block, use_kernel=use_kernel)
    return butterfly_merge_r(
        r, axis_name,
        lambda stack: _local_r(stack, qr_block=qr_block,
                               use_kernel=use_kernel))


def distributed_qr(a_local: Array, axis_name: str, *, refine: bool = True,
                   qr_block: int = 32, use_kernel: bool = False
                   ) -> Tuple[Array, Array]:
    """Thin QR of a row-sharded matrix from inside ``shard_map``.

    Returns ``(q_local, r)``: the caller's row-shard of the thin Q, and the
    (replicated) global R.  This is the distributed orthogonalization
    primitive behind the QR-Muon optimizer: momentum is FSDP-sharded on
    the ``data`` axis, so Q never materializes unsharded anywhere.
    """
    r1 = tsqr_tree_sharded(a_local, axis_name, qr_block=qr_block,
                           use_kernel=use_kernel)
    q_local = triangular_inverse_apply(a_local, r1)
    if refine:
        r2 = tsqr_tree_sharded(q_local, axis_name, qr_block=qr_block,
                               use_kernel=use_kernel)
        q_local = triangular_inverse_apply(q_local, r2)
        return q_local, r2 @ r1
    return q_local, r1


# -- registry -----------------------------------------------------------------
from repro.core.plan import (  # noqa: E402
    MethodSpec, QRConfig, register_method, sign_fix_qr, sign_fix_r)


def default_nblocks(m: int, n: int) -> int:
    """Largest divisor of m in [2, 8] scaled by aspect (legacy heuristic:
    deep enough trees for tall inputs, always an exact row partition)."""
    nb = max(2, min(8, m // max(n, 1)))
    while m % nb != 0:
        nb -= 1
    return max(nb, 1)


def _resolve_tsqr(m: int, n: int, cfg: QRConfig, *, dtype=None,
                  explain=None) -> QRConfig:
    del dtype  # tree shape is element-width independent
    nb = cfg.nblocks if cfg.nblocks is not None else default_nblocks(m, n)
    if m % nb != 0:
        raise ValueError(f"m={m} not divisible by nblocks={nb}")
    if explain is not None and cfg.nblocks is None:
        from repro.core.plan import RouteDecision

        explain.append(RouteDecision(
            "tsqr_nblocks", "resolved",
            f"nblocks={nb} (largest divisor of m={m} in [2, 8] scaled "
            f"by aspect) — merge tree depth {(nb - 1).bit_length()}"))
    return cfg.replace(nblocks=nb)


def _solve_tsqr(a: Array, cfg: QRConfig):
    from repro.observability import metrics as _obs_metrics

    _obs_metrics.counter("tsqr.solves", nblocks=cfg.nblocks,
                         mode=cfg.mode).inc()
    _obs_metrics.gauge("tsqr.tree_depth", nblocks=cfg.nblocks).set(
        (cfg.nblocks - 1).bit_length())
    qr_block = min(cfg.block, a.shape[1])
    if cfg.mode == "r":
        r = tsqr_r(a, nblocks=cfg.nblocks, qr_block=qr_block,
                   use_kernel=bool(cfg.use_kernel))
        return sign_fix_r(r) if cfg.sign_fix else r
    q, r = tsqr_qr(a, nblocks=cfg.nblocks, refine=cfg.refine, qr_block=qr_block,
                   use_kernel=bool(cfg.use_kernel))
    return sign_fix_qr(q, r) if cfg.sign_fix else (q, r)


def _vmem_tsqr(m: int, n: int, cfg: QRConfig) -> int:
    """Leaf working set: one (m/nblocks, min(block, n)) panel in VMEM."""
    from repro.kernels import ops

    nb = cfg.nblocks if cfg.nblocks is not None else default_nblocks(m, n)
    return ops.vmem_bytes_mht_panel(m // nb, min(cfg.block, n))


register_method(MethodSpec(
    name="tsqr",
    solve=_solve_tsqr,
    resolve=_resolve_tsqr,
    supports_full_q=False,
    min_aspect=4.0,
    kernel_backed=True,
    vmem_bytes=_vmem_tsqr,
    description="tall-skinny tree QR (single device; sharded via shard_map)",
))
