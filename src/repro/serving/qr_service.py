"""QR-as-a-service: shape-bucketed batched factorization serving.

The engine factors one matrix per dispatch; production traffic is many
concurrent heterogeneous ``(m, n, dtype, mode)`` requests.  The tiled
DAG's tasks are independent across matrices exactly as they are across
tiles, so throughput comes from keeping the accelerator saturated with
macro-op work: :class:`QRService` buckets submissions by padded shape
class (:mod:`repro.serving.bucketing`), zero-pads and stacks each
bucket, and factors it in ONE dispatch through
:func:`repro.core.engine.factor_tiles_batched` — on the megakernel path
that is literally one ``pallas_call`` per bucket, batch axis on the
grid, one task table shared across the batch.

The pipeline per :meth:`QRService.flush`:

    requests -> bucketize -> (plan cache: BucketKey x batch -> compiled
    executable) -> stage bucket i+1's host->device transfer while bucket
    i computes (donated input buffers) -> unpad + scatter results back

**Compiled-plan cache.**  Plans are AOT-compiled
(``jax.jit(...).lower(...).compile()``) and kept in an LRU keyed on
``(BucketKey, padded_batch)``; hits, misses, evictions, and compiles are
exposed via :meth:`QRService.stats`, so a steady-state stream (warmed
cache) performs ZERO recompilations — asserted in
tests/test_qr_service.py, measured by benchmarks/bench_qr_serving.py.
The LRU is additionally keyed on the active measured tuning cache's
fingerprint (:func:`repro.tuning.cache.active_cache_info`): bucket
executables bake in tuned dispatch-mode routing, so installing a fresh
sweep invalidates every cached plan (``plan_invalidations`` counter) and
they recompile lazily under the new measurements.

Zero padding is numerically free (padded rows/cols factor to
exactly-zero reflectors), and the batched engine is bitwise-equal per
slice to independent single-matrix runs, so serving answers are the
answers the per-request path would have produced.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax

from repro.observability import metrics as _metrics
from repro.observability import trace as _trace
from repro.serving.bucketing import (
    BucketKey, BucketingPolicy, bucketize, pad_batch)

Array = jax.Array

__all__ = ["QRRequest", "QRResult", "QRService"]

# Distinguishes each QRService instance's series in the process-global
# metrics registry, so a fresh service starts from zero counts.
_SERVICE_IDS = itertools.count()


@dataclasses.dataclass(frozen=True)
class QRRequest:
    """One queued factorization: the payload plus its bucket identity."""

    rid: int
    a: np.ndarray
    mode: str
    t_submit: float = 0.0      # monotonic clock at submit (queue-wait base)

    @property
    def shape(self) -> Tuple[int, int]:
        return self.a.shape  # type: ignore[return-value]

    @property
    def dtype(self):
        return self.a.dtype


@dataclasses.dataclass(frozen=True)
class QRResult:
    """Unpadded per-request answer; ``q`` is None for mode="r"."""

    rid: int
    q: Optional[Array]
    r: Array


def _tuning_fingerprint() -> Tuple:
    """Identity of the active measured tuning cache (source + contents
    summary).  Compiled bucket plans bake in tuned routing decisions
    (dispatch mode per shape class), so a cache refresh — a new sweep
    installed via ``set_active_cache`` or ``$REPRO_TUNING_CACHE`` — must
    invalidate them; the plan LRU is keyed on this fingerprint."""
    from repro.tuning import cache as _tcache

    info = _tcache.active_cache_info()
    return (info["source"], info["entries"], tuple(info["classes"]))


@dataclasses.dataclass(frozen=True)
class _BucketPlan:
    """One AOT-compiled bucket executable (the plan-cache value)."""

    key: BucketKey
    batch: int                 # padded batch the executable expects
    grid: Tuple[int, int]      # (p, q) tile grid
    nb: int
    dispatch_mode: Optional[str]
    fn: object                 # jax compiled executable


def _solve_bucket(stacked: Array, *, p: int, q: int, nb: int, mode: str,
                  use_kernel: bool, interpret: bool,
                  dispatch_mode: Optional[str]):
    """The traced bucket program: one batched engine dispatch for the
    whole stack via the shared :func:`repro.core.tilegraph
    ._factor_stack_padded` lowering (the same program the optimizer's
    shape-class dispatch lowers through).  Runs on PADDED shapes and
    returns FULL padded factors (the donated staged buffer can alias an
    output); per-request unpadding happens host-side."""
    from repro.core.tilegraph import _factor_stack_padded

    return _factor_stack_padded(stacked, p=p, q=q, nb=nb, mode=mode,
                                use_kernel=use_kernel, interpret=interpret,
                                dispatch_mode=dispatch_mode)


class QRService:
    """Batched QR serving: submit heterogeneous requests, get per-request
    factors back from shape-bucketed single-dispatch execution.

        service = QRService()                       # auto kernel policy
        rid = service.submit(a, mode="reduced")     # queue
        out = service.flush()[rid]                  # bucket + dispatch
        results = service.submit_many(arrays)       # pipelined stream

    Parameters
    ----------
    policy:        bucketing policy (tile size, waste cap, max batch).
    use_kernel:    engine Pallas lowering — None resolves like the
                   planner (kernel on TPU, jnp oracle elsewhere).
    dispatch_mode: engine kernel lowering per bucket; None lets the
                   engine's budget rule pick (megakernel when the shared
                   task table + batched working set fit).
    cache_size:    max resident compiled bucket plans (LRU).
    """

    def __init__(self, *, policy: Optional[BucketingPolicy] = None,
                 use_kernel: Optional[bool] = None,
                 dispatch_mode: Optional[str] = None,
                 interpret: Optional[bool] = None,
                 cache_size: int = 32):
        if cache_size < 1:
            raise ValueError(f"cache_size must be >= 1, got {cache_size}")
        self.policy = BucketingPolicy() if policy is None else policy
        self.use_kernel = (jax.default_backend() == "tpu"
                           if use_kernel is None else bool(use_kernel))
        self.dispatch_mode = dispatch_mode
        self.interpret = interpret
        self.cache_size = cache_size
        self._plans: "collections.OrderedDict[Tuple[BucketKey, int], _BucketPlan]" \
            = collections.OrderedDict()
        self._pending: List[QRRequest] = []
        self._tuning_fp = _tuning_fingerprint()
        self._next_rid = 0
        # Counters live in the process-global metrics registry under this
        # instance's ``service`` label; stats() is a view over them.
        self._sid = f"qr{next(_SERVICE_IDS)}"

    # ---------------------------------------------------- metrics plumbing

    def _count(self, name: str, amount: int = 1) -> None:
        _metrics.counter(f"serving.{name}", service=self._sid).inc(amount)

    def _count_value(self, name: str) -> int:
        return int(_metrics.counter_value(f"serving.{name}", service=self._sid))

    def _observe(self, name: str, value: float, **labels: object) -> None:
        _metrics.histogram(f"serving.{name}", service=self._sid,
                           **labels).observe(value)

    # ------------------------------------------------------------ intake

    def submit(self, a, mode: str = "reduced") -> int:
        """Queue one matrix; returns the request id :meth:`flush` keys
        results on.  The array is copied to host memory at submit time
        (the service owns staging; donation consumes staged buffers)."""
        arr = np.asarray(a)
        if arr.ndim != 2:
            raise ValueError(f"expected one matrix, got shape {arr.shape}")
        if mode not in ("reduced", "r"):
            raise ValueError(
                f"serving modes are 'reduced' and 'r', got {mode!r}")
        rid = self._next_rid
        self._next_rid += 1
        self._pending.append(QRRequest(rid=rid, a=arr, mode=mode,
                                       t_submit=time.monotonic()))
        self._count("requests")
        return rid

    def submit_many(self, arrays: Sequence, mode: str = "reduced"
                    ) -> List[QRResult]:
        """Submit a homogeneous-mode stream and flush it; results come
        back in submission order.  Buckets are dispatched back-to-back
        with the NEXT bucket's host->device transfer staged while the
        current one computes (see :meth:`flush`)."""
        rids = [self.submit(a, mode=mode) for a in arrays]
        results = self.flush()
        return [results[rid] for rid in rids]

    # --------------------------------------------------------- plan cache

    def _plan_for(self, key: BucketKey, batch: int) -> _BucketPlan:
        fp = _tuning_fingerprint()
        if fp != self._tuning_fp:
            # Tuning-cache refresh: every cached executable may have been
            # built under routing the new measurements contradict — drop
            # them all (they recompile lazily on next use).
            self._tuning_fp = fp
            if self._plans:
                self._count("plan_invalidations")
                self._count("cache_evictions", len(self._plans))
                self._plans.clear()
        cache_key = (key, batch)
        plan = self._plans.get(cache_key)
        if plan is not None:
            self._plans.move_to_end(cache_key)
            self._count("cache_hits")
            return plan
        self._count("cache_misses")
        plan = self._build_plan(key, batch)
        self._plans[cache_key] = plan
        if len(self._plans) > self.cache_size:
            self._plans.popitem(last=False)
            self._count("cache_evictions")
        return plan

    def _build_plan(self, key: BucketKey, batch: int) -> _BucketPlan:
        """AOT-compile one bucket executable.  The ONLY site that
        compiles — ``stats()["compiles"]`` counts exactly these, which is
        what makes the steady-state zero-recompilation claim testable."""
        from repro.core import engine
        from repro.kernels import macro_ops

        nb = min(self.policy.tile, key.m, key.n)
        p, q = -(-key.m // nb), -(-key.n // nb)
        itemsize = np.dtype(key.dtype).itemsize
        dispatch_mode = self.dispatch_mode
        if self.use_kernel and dispatch_mode is None:
            # Measured tuning entries (same pow2-ish shape classes as the
            # bucket edges) take precedence over the engine's budget
            # rule — this is what the fingerprint invalidation protects.
            from repro.tuning import cache as _tcache

            entry = _tcache.active_cache().lookup(
                backend=jax.default_backend(), m=key.m, n=key.n,
                dtype=np.dtype(key.dtype))
            if (entry is not None and entry.best.use_kernel
                    and entry.best.dispatch_mode is not None):
                dispatch_mode = entry.best.dispatch_mode
            else:
                dispatch_mode = engine.resolve_dispatch_mode(p, q, nb,
                                                             itemsize)
        interpret = (macro_ops.default_interpret()
                     if self.interpret is None else self.interpret)
        fn = jax.jit(
            functools.partial(
                _solve_bucket, p=p, q=q, nb=nb, mode=key.mode,
                use_kernel=self.use_kernel, interpret=interpret,
                dispatch_mode=dispatch_mode),
            donate_argnums=(0,))
        shape = jax.ShapeDtypeStruct((batch, key.m, key.n),
                                     np.dtype(key.dtype))
        t0 = time.monotonic()
        compiled = fn.lower(shape).compile()
        self._count("compiles")
        self._observe("compile_seconds", time.monotonic() - t0)
        return _BucketPlan(key=key, batch=batch, grid=(p, q), nb=nb,
                           dispatch_mode=dispatch_mode if self.use_kernel
                           else None, fn=compiled)

    # ---------------------------------------------------------- execution

    def _chunks(self) -> List[Tuple[BucketKey, List[QRRequest]]]:
        """Bucketize pending requests and split buckets into
        max_batch-sized dispatch chunks (submission order preserved)."""
        reqs, self._pending = self._pending, []
        out: List[Tuple[BucketKey, List[QRRequest]]] = []
        for key, rs in bucketize(reqs, self.policy).items():
            for i in range(0, len(rs), self.policy.max_batch):
                out.append((key, rs[i:i + self.policy.max_batch]))
        return out

    def _stage(self, key: BucketKey, chunk: List[QRRequest],
               batch: int) -> Array:
        """Zero-pad and stack one chunk, then start its host->device
        transfer.  Unfilled batch slots stay zero — a zero matrix
        factors to zero reflectors, so padding slots are compute waste
        only, priced by the fill-ratio stat, never a correctness risk."""
        buf = np.zeros((batch, key.m, key.n), np.dtype(key.dtype))
        for s, req in enumerate(chunk):
            m, n = req.shape
            buf[s, :m, :n] = req.a
        return jax.device_put(buf)

    def flush(self) -> Dict[int, QRResult]:
        """Execute every pending request; returns ``{rid: QRResult}``.

        Software pipeline over dispatch chunks: while chunk i's batched
        factorization computes (async dispatch), chunk i+1's stacked
        buffer is already staging host->device; each staged buffer is
        donated into its executable (compiled with ``donate_argnums``),
        so steady state holds one in-flight compute and one in-flight
        transfer, not a growing buffer population."""
        with _trace.span("serving.bucketize", service=self._sid):
            work = self._chunks()
        if not work:
            return {}
        with _trace.span("serving.plan", service=self._sid,
                         chunks=len(work)):
            plans = [self._plan_for(
                key, pad_batch(len(chunk), max_batch=self.policy.max_batch))
                for key, chunk in work]
        staged = self._stage(work[0][0], work[0][1], plans[0].batch)
        outs = []
        for i, (plan, (key, chunk)) in enumerate(zip(plans, work)):
            nxt = (self._stage(work[i + 1][0], work[i + 1][1],
                               plans[i + 1].batch)
                   if i + 1 < len(work) else None)
            with _trace.span("serving.dispatch", service=self._sid,
                             bucket=f"{key.m}x{key.n}", batch=plan.batch,
                             fill=len(chunk)):
                outs.append(plan.fn(staged))  # async; donates staged buffer
            self._count("dispatches")
            self._count("matrices_served", len(chunk))
            self._count("padded_slots", plan.batch - len(chunk))
            now = time.monotonic()
            for req in chunk:
                self._observe("queue_wait_seconds", now - req.t_submit)
            self._observe("bucket_fill", len(chunk) / plan.batch)
            real = sum(m * n for m, n in (r.shape for r in chunk))
            waste = 1.0 - real / (plan.batch * key.m * key.n)
            self._observe("padding_waste", waste, bucket=f"{key.m}x{key.n}")
            staged = nxt
        results: Dict[int, QRResult] = {}
        with _trace.span("serving.unpad", service=self._sid) as sp:
            for (key, chunk), out in zip(work, outs):
                sp.sync(out)
                now = time.monotonic()
                for s, req in enumerate(chunk):
                    m, n = req.shape
                    k = min(m, n)
                    if key.mode == "r":
                        q_mat, r_mat = None, out[0][s, :k, :n]
                    else:
                        q_mat, r_mat = out[0][s, :m, :k], out[1][s, :k, :n]
                    results[req.rid] = QRResult(rid=req.rid, q=q_mat, r=r_mat)
                    self._observe("latency_seconds", now - req.t_submit)
        return results

    # -------------------------------------------------------------- stats

    def stats(self) -> Dict[str, object]:
        """Serving counters: cache behavior, dispatch economy, padding
        waste.  ``bucket_fill_ratio`` is matrices served over batch slots
        dispatched (1.0 = every slot carried a real request);
        ``cache_hit_rate`` is plan-cache hits over lookups.

        Counters are a view over this instance's ``serving.*`` series in
        the process-global metrics registry (``service=<id>`` label)."""
        served = self._count_value("matrices_served")
        padded = self._count_value("padded_slots")
        hits = self._count_value("cache_hits")
        slots = served + padded
        lookups = hits + self._count_value("cache_misses")
        return dict(
            requests=self._count_value("requests"),
            matrices_served=served,
            dispatches=self._count_value("dispatches"),
            compiles=self._count_value("compiles"),
            cache_hits=hits,
            cache_misses=self._count_value("cache_misses"),
            cache_evictions=self._count_value("cache_evictions"),
            plan_invalidations=self._count_value("plan_invalidations"),
            plans_cached=len(self._plans),
            padded_slots=padded,
            bucket_fill_ratio=(served / slots) if slots else 1.0,
            cache_hit_rate=(hits / lookups) if lookups else 0.0,
        )
