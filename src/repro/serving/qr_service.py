"""QR-as-a-service: shape-bucketed batched factorization serving.

The engine factors one matrix per dispatch; production traffic is many
concurrent heterogeneous ``(m, n, dtype, mode)`` requests.  The tiled
DAG's tasks are independent across matrices exactly as they are across
tiles, so throughput comes from keeping the accelerator saturated with
macro-op work: :class:`QRService` buckets submissions by padded shape
class (:mod:`repro.serving.bucketing`), zero-pads and stacks each
bucket, and factors it in ONE dispatch through
:func:`repro.core.engine.factor_tiles_batched` — on the megakernel path
that is literally one ``pallas_call`` per bucket, batch axis on the
grid, one task table shared across the batch.

The pipeline per :meth:`QRService.flush`:

    requests -> admission -> bucketize -> (plan cache: BucketKey x batch
    -> compiled executable) -> stage bucket i+1's host->device transfer
    while bucket i computes (donated input buffers) -> sync + health
    check -> unpad + scatter results back

**Compiled-plan cache.**  Plans are AOT-compiled
(``jax.jit(...).lower(...).compile()``) and kept in an LRU keyed on
``(BucketKey, padded_batch, rung)``; hits, misses, evictions, and
compiles are exposed via :meth:`QRService.stats`, so a steady-state
stream (warmed cache) performs ZERO recompilations — asserted in
tests/test_qr_service.py, measured by benchmarks/bench_qr_serving.py.
The LRU is additionally keyed on the active measured tuning cache's
fingerprint (:func:`repro.tuning.cache.active_cache_info`): bucket
executables bake in tuned dispatch-mode routing, so installing a fresh
sweep invalidates every cached plan (``plan_invalidations`` counter) and
they recompile lazily under the new measurements.

**Failure hardening** (:mod:`repro.robustness`).  Three lines of
defense, each named and counted:

  * *Admission* — :meth:`submit` runs the finite/shape/dtype guard
    (``admission`` policy); a rejected payload is **quarantined** (its
    :class:`QRResult` carries ``error="quarantined:<reason>"``) instead
    of poisoning the padded bucket it would have shared.
  * *Verification* — with the ``verify`` knob on (``$REPRO_VERIFY``
    default), every synced bucket is health-checked **per slice**
    (residual + orthogonality against the conformance tolerance); only
    the failing slices re-solve, the healthy bucket-mates ship as-is.
  * *Escalation* — a failed AOT compile, dispatch, or health check
    walks the degradation ladder megakernel -> wavefront -> oracle ->
    lapack (:mod:`repro.robustness.escalate`), recording
    ``robustness.escalations{from, to, reason}``.  A bucket that
    escalates ``breaker_threshold`` times trips its **circuit
    breaker**: its compiled plans are evicted and the bucket pins to
    the lapack fallback until the tuning fingerprint changes.

Flush is failure-atomic: if an exception does escape (escalation
disabled, or a non-recoverable error), every request that has not been
resolved into a result is restored to the pending queue before the
exception propagates — no request is silently dropped.

Zero padding is numerically free (padded rows/cols factor to
exactly-zero reflectors), and the batched engine is bitwise-equal per
slice to independent single-matrix runs, so serving answers are the
answers the per-request path would have produced.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import itertools
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

import jax

from repro.observability import metrics as _metrics
from repro.observability import trace as _trace
from repro.robustness import escalate as _escalate
from repro.robustness import guards as _guards
from repro.robustness import inject as _inject
from repro.robustness import verify as _verify
from repro.serving.bucketing import (
    BucketKey, BucketingPolicy, bucketize, pad_batch)

Array = jax.Array

__all__ = ["QRRequest", "QRResult", "QRService"]

# Distinguishes each QRService instance's series in the process-global
# metrics registry, so a fresh service starts from zero counts.
_SERVICE_IDS = itertools.count()


@dataclasses.dataclass(frozen=True)
class QRRequest:
    """One queued factorization: the payload plus its bucket identity."""

    rid: int
    a: np.ndarray
    mode: str
    t_submit: float = 0.0      # monotonic clock at submit (queue-wait base)

    @property
    def shape(self) -> Tuple[int, int]:
        return self.a.shape  # type: ignore[return-value]

    @property
    def dtype(self):
        return self.a.dtype


@dataclasses.dataclass(frozen=True)
class QRResult:
    """Unpadded per-request answer; ``q`` is None for mode="r".

    ``error`` is None for a healthy result; a quarantined or
    unrecoverable request carries the named reason
    (``"quarantined:nonfinite_input"``, ``"escalation_exhausted"``,
    ...) and ``q``/``r`` may be None."""

    rid: int
    q: Optional[Array]
    r: Optional[Array]
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None


def _tuning_fingerprint() -> Tuple:
    """Identity of the active measured tuning cache (source + contents
    summary).  Compiled bucket plans bake in tuned routing decisions
    (dispatch mode per shape class), so a cache refresh — a new sweep
    installed via ``set_active_cache`` or ``$REPRO_TUNING_CACHE`` — must
    invalidate them; the plan LRU is keyed on this fingerprint."""
    from repro.tuning import cache as _tcache

    info = _tcache.active_cache_info()
    return (info["source"], info["entries"], tuple(info["classes"]))


@dataclasses.dataclass(frozen=True)
class _BucketPlan:
    """One AOT-compiled bucket executable (the plan-cache value)."""

    key: BucketKey
    batch: int                 # padded batch the executable expects
    grid: Tuple[int, int]      # (p, q) tile grid
    nb: int
    dispatch_mode: Optional[str]
    rung: str                  # ladder rung this plan executes at
    fn: object                 # jax compiled executable


def _solve_bucket(stacked: Array, *, p: int, q: int, nb: int, mode: str,
                  use_kernel: bool, interpret: bool,
                  dispatch_mode: Optional[str]):
    """The traced bucket program: one batched engine dispatch for the
    whole stack via the shared :func:`repro.core.tilegraph
    ._factor_stack_padded` lowering (the same program the optimizer's
    shape-class dispatch lowers through).  Runs on PADDED shapes and
    returns FULL padded factors (the donated staged buffer can alias an
    output); per-request unpadding happens host-side."""
    from repro.core.tilegraph import _factor_stack_padded

    return _factor_stack_padded(stacked, p=p, q=q, nb=nb, mode=mode,
                                use_kernel=use_kernel, interpret=interpret,
                                dispatch_mode=dispatch_mode)


class QRService:
    """Batched QR serving: submit heterogeneous requests, get per-request
    factors back from shape-bucketed single-dispatch execution.

        service = QRService()                       # auto kernel policy
        rid = service.submit(a, mode="reduced")     # queue
        out = service.flush()[rid]                  # bucket + dispatch
        results = service.submit_many(arrays)       # pipelined stream

    Parameters
    ----------
    policy:        bucketing policy (tile size, waste cap, max batch).
    use_kernel:    engine Pallas lowering — None resolves like the
                   planner (kernel on TPU, jnp oracle elsewhere).
    dispatch_mode: engine kernel lowering per bucket; None lets the
                   engine's budget rule pick (megakernel when the shared
                   task table + batched working set fit).
    cache_size:    max resident compiled bucket plans (LRU).
    admission:     input guard run at submit (None disables; default:
                   finite 2-D float — :mod:`repro.robustness.guards`).
    verify:        post-dispatch per-slice health checks — True/False
                   force, None defers to ``$REPRO_VERIFY``.
    escalate:      walk the degradation ladder on failures (False keeps
                   the raise-through behavior; flush stays atomic).
    breaker_threshold: escalations a bucket tolerates before its
                   circuit breaker opens (plans evicted, bucket pinned
                   to the lapack fallback until the tuning fingerprint
                   changes).
    """

    def __init__(self, *, policy: Optional[BucketingPolicy] = None,
                 use_kernel: Optional[bool] = None,
                 dispatch_mode: Optional[str] = None,
                 interpret: Optional[bool] = None,
                 cache_size: int = 32,
                 admission: Optional[_guards.AdmissionPolicy] =
                 _guards.DEFAULT_ADMISSION,
                 verify: Optional[bool] = None,
                 escalate: bool = True,
                 breaker_threshold: int = 3):
        if cache_size < 1:
            raise ValueError(f"cache_size must be >= 1, got {cache_size}")
        if breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got {breaker_threshold}")
        self.policy = BucketingPolicy() if policy is None else policy
        self.use_kernel = (jax.default_backend() == "tpu"
                           if use_kernel is None else bool(use_kernel))
        self.dispatch_mode = dispatch_mode
        self.interpret = interpret
        self.cache_size = cache_size
        self.admission = admission
        self.verify = verify
        self.escalate = escalate
        self.breaker_threshold = breaker_threshold
        self._plans: "collections.OrderedDict[Tuple[BucketKey, int, str], _BucketPlan]" \
            = collections.OrderedDict()
        self._pending: List[QRRequest] = []
        self._quarantined: Dict[int, str] = {}    # rid -> named reason
        self._esc_counts: Dict[BucketKey, int] = {}
        self._breaker_open: Set[BucketKey] = set()
        self.escalations: List[_escalate.Escalation] = []
        self._tuning_fp = _tuning_fingerprint()
        self._next_rid = 0
        # Counters live in the process-global metrics registry under this
        # instance's ``service`` label; stats() is a view over them.
        self._sid = f"qr{next(_SERVICE_IDS)}"

    # ---------------------------------------------------- metrics plumbing

    def _count(self, name: str, amount: int = 1) -> None:
        _metrics.counter(f"serving.{name}", service=self._sid).inc(amount)

    def _count_value(self, name: str) -> int:
        return int(_metrics.counter_value(f"serving.{name}", service=self._sid))

    def _observe(self, name: str, value: float, **labels: object) -> None:
        _metrics.histogram(f"serving.{name}", service=self._sid,
                           **labels).observe(value)

    def _verify_on(self) -> bool:
        return _verify.verify_enabled(self.verify)

    # ------------------------------------------------------------ intake

    def submit(self, a, mode: str = "reduced") -> int:
        """Queue one matrix; returns the request id :meth:`flush` keys
        results on.  The array is copied to host memory at submit time
        (the service owns staging; donation consumes staged buffers).

        Admission runs here — a payload the guard rejects is
        quarantined (``flush()`` returns an error-carrying
        :class:`QRResult` for it) rather than stacked into a bucket
        where its NaNs would contaminate every bucket-mate."""
        arr = np.asarray(a)
        if arr.ndim != 2:
            raise ValueError(f"expected one matrix, got shape {arr.shape}")
        if mode not in ("reduced", "r"):
            raise ValueError(
                f"serving modes are 'reduced' and 'r', got {mode!r}")
        rid = self._next_rid
        self._next_rid += 1
        self._count("requests")
        if _inject.enabled():
            arr = _inject.corrupt_input(
                arr, f"{arr.shape[0]}x{arr.shape[1]}")
        if self.admission is not None:
            try:
                _guards.admit(arr, policy=self.admission)
            except _guards.AdmissionError as e:
                self._quarantined[rid] = e.reason
                self._count("quarantined")
                _metrics.counter("robustness.quarantined",
                                 reason=e.reason).inc()
                return rid
        self._pending.append(QRRequest(rid=rid, a=arr, mode=mode,
                                       t_submit=time.monotonic()))
        return rid

    def submit_many(self, arrays: Sequence, mode: str = "reduced"
                    ) -> List[QRResult]:
        """Submit a homogeneous-mode stream and flush it; results come
        back in submission order.  Buckets are dispatched back-to-back
        with the NEXT bucket's host->device transfer staged while the
        current one computes (see :meth:`flush`)."""
        rids = [self.submit(a, mode=mode) for a in arrays]
        results = self.flush()
        return [results[rid] for rid in rids]

    # --------------------------------------------------------- plan cache

    def _check_tuning(self) -> None:
        """Tuning-cache refresh detection: every cached executable may
        have been built under routing the new measurements contradict —
        drop them all (they recompile lazily on next use).  An open
        circuit breaker also resets: the new measurements may route the
        bucket around whatever kept failing."""
        fp = _tuning_fingerprint()
        if fp == self._tuning_fp:
            return
        self._tuning_fp = fp
        if self._plans:
            self._count("plan_invalidations")
            self._count("cache_evictions", len(self._plans))
            self._plans.clear()
        if self._breaker_open or self._esc_counts:
            self._count("breaker_resets", len(self._breaker_open) or 1)
            self._breaker_open.clear()
            self._esc_counts.clear()

    def _initial_rung(self, key: BucketKey) -> str:
        """The ladder rung a fresh bucket plan starts at: the tuned /
        budget-resolved dispatch mode on the kernel path, "oracle" on
        the jnp path."""
        if not self.use_kernel:
            return "oracle"
        if self.dispatch_mode is not None:
            return self.dispatch_mode
        from repro.core import engine
        from repro.tuning import cache as _tcache

        nb = min(self.policy.tile, key.m, key.n)
        p, q = -(-key.m // nb), -(-key.n // nb)
        # Measured tuning entries (same pow2-ish shape classes as the
        # bucket edges) take precedence over the engine's budget rule —
        # this is what the fingerprint invalidation protects.
        entry = _tcache.active_cache().lookup(
            backend=jax.default_backend(), m=key.m, n=key.n,
            dtype=np.dtype(key.dtype))
        if (entry is not None and entry.best.use_kernel
                and entry.best.dispatch_mode is not None):
            return entry.best.dispatch_mode
        return engine.resolve_dispatch_mode(
            p, q, nb, np.dtype(key.dtype).itemsize)

    def _plan_for(self, key: BucketKey, batch: int, *,
                  rung: str) -> _BucketPlan:
        self._check_tuning()
        cache_key = (key, batch, rung)
        plan = self._plans.get(cache_key)
        if plan is not None:
            self._plans.move_to_end(cache_key)
            self._count("cache_hits")
            return plan
        self._count("cache_misses")
        plan = self._build_plan(key, batch, rung=rung)
        self._plans[cache_key] = plan
        if len(self._plans) > self.cache_size:
            self._plans.popitem(last=False)
            self._count("cache_evictions")
        return plan

    def _build_plan(self, key: BucketKey, batch: int, *,
                    rung: str) -> _BucketPlan:
        """AOT-compile one bucket executable at ``rung``.  The ONLY site
        that compiles — ``stats()["compiles"]`` counts exactly these,
        which is what makes the steady-state zero-recompilation claim
        testable."""
        from repro.kernels import macro_ops

        _inject.check("compile", f"{key.m}x{key.n}:{rung}")
        use_kernel = rung in ("megakernel", "wavefront")
        dispatch_mode = rung if use_kernel else None
        nb = min(self.policy.tile, key.m, key.n)
        p, q = -(-key.m // nb), -(-key.n // nb)
        interpret = (macro_ops.default_interpret()
                     if self.interpret is None else self.interpret)
        fn = jax.jit(
            functools.partial(
                _solve_bucket, p=p, q=q, nb=nb, mode=key.mode,
                use_kernel=use_kernel, interpret=interpret,
                dispatch_mode=dispatch_mode),
            donate_argnums=(0,))
        shape = jax.ShapeDtypeStruct((batch, key.m, key.n),
                                     np.dtype(key.dtype))
        t0 = time.monotonic()
        compiled = fn.lower(shape).compile()
        self._count("compiles")
        self._observe("compile_seconds", time.monotonic() - t0)
        return _BucketPlan(key=key, batch=batch, grid=(p, q), nb=nb,
                           dispatch_mode=dispatch_mode, rung=rung,
                           fn=compiled)

    def _plan_with_escalation(
            self, key: BucketKey, batch: int
            ) -> Tuple[Optional[_BucketPlan], str]:
        """Resolve a bucket's plan, walking the ladder on compile
        failures.  Returns ``(plan, rung)``; ``plan=None`` means the
        lapack rung (per-request fallback, nothing to compile)."""
        if key in self._breaker_open:
            self._count("breaker_pinned_dispatches")
            return None, "lapack"
        rung = self._initial_rung(key)
        while True:
            try:
                return self._plan_for(key, batch, rung=rung), rung
            except Exception as e:  # noqa: BLE001 — every rung failure degrades
                if not self.escalate:
                    raise
                below = _escalate.ladder_below(rung)
                nxt = below[0] if below else "lapack"
                self._record_escalation(key, _escalate.record(
                    rung, nxt, _escalate.classify(e, "compile"), str(e)))
                if nxt == "lapack":
                    return None, "lapack"
                rung = nxt

    # ------------------------------------------------- failure machinery

    def _record_escalation(self, key: BucketKey,
                           esc: _escalate.Escalation) -> None:
        self.escalations.append(esc)
        del self.escalations[:-200]            # bounded history
        self._count("escalations")
        self._esc_counts[key] = self._esc_counts.get(key, 0) + 1
        if (self._esc_counts[key] >= self.breaker_threshold
                and key not in self._breaker_open):
            self._breaker_open.add(key)
            self._count("breaker_trips")
            _metrics.counter("robustness.breaker_open",
                             bucket=f"{key.m}x{key.n}").inc()
            stale = [ck for ck in self._plans if ck[0] == key]
            for ck in stale:
                del self._plans[ck]
            if stale:
                self._count("cache_evictions", len(stale))
            self.escalations.append(_escalate.Escalation(
                rung_from=esc.rung_to, rung_to="lapack",
                rule="breaker_open",
                reason=f"bucket {key.m}x{key.n} escalated "
                       f"{self._esc_counts[key]} times "
                       f"(threshold {self.breaker_threshold}); pinned to "
                       f"lapack until the tuning fingerprint changes"))

    def _recover_request(self, req: QRRequest, key: BucketKey,
                         start: str) -> QRResult:
        """Re-solve ONE request below ``start`` on its raw, unpadded
        payload (the per-slice recovery path)."""
        try:
            q, r, rung, escs = _escalate.solve_below(
                req.a, mode=key.mode, start=start,
                verify=self._verify_on(), tag=f"{key.m}x{key.n}")
        except _escalate.EscalationExhausted as e:
            for esc in e.escalations:
                self._record_escalation(key, esc)
            return QRResult(rid=req.rid, q=None, r=None,
                            error="escalation_exhausted")
        for esc in escs:
            self._record_escalation(key, esc)
        return QRResult(rid=req.rid, q=None if key.mode == "r" else q,
                        r=r)

    def _lapack_chunk(self, key: BucketKey, chunk: List[QRRequest]
                      ) -> Dict[int, QRResult]:
        """The breaker-pinned / bottom-rung chunk path: per-request
        ``jnp.linalg.qr`` on the raw payloads — no padding, no
        compiled plan, nothing left to fail but the input itself."""
        out: Dict[int, QRResult] = {}
        for req in chunk:
            q, r = _escalate.lapack_qr(req.a, key.mode)
            out[req.rid] = QRResult(rid=req.rid, q=q, r=r)
        return out

    # ---------------------------------------------------------- execution

    def _chunks(self) -> List[Tuple[BucketKey, List[QRRequest]]]:
        """Bucketize pending requests and split buckets into
        max_batch-sized dispatch chunks (submission order preserved)."""
        reqs, self._pending = self._pending, []
        out: List[Tuple[BucketKey, List[QRRequest]]] = []
        for key, rs in bucketize(reqs, self.policy).items():
            for i in range(0, len(rs), self.policy.max_batch):
                out.append((key, rs[i:i + self.policy.max_batch]))
        return out

    def _stage(self, key: BucketKey, chunk: List[QRRequest],
               batch: int) -> Array:
        """Zero-pad and stack one chunk, then start its host->device
        transfer.  Unfilled batch slots stay zero — a zero matrix
        factors to zero reflectors, so padding slots are compute waste
        only, priced by the fill-ratio stat, never a correctness risk."""
        buf = np.zeros((batch, key.m, key.n), np.dtype(key.dtype))
        for s, req in enumerate(chunk):
            m, n = req.shape
            buf[s, :m, :n] = req.a
        return jax.device_put(buf)

    def flush(self) -> Dict[int, QRResult]:
        """Execute every pending request; returns ``{rid: QRResult}``.

        Software pipeline over dispatch chunks: while chunk i's batched
        factorization computes (async dispatch), chunk i+1's stacked
        buffer is already staging host->device; each staged buffer is
        donated into its executable (compiled with ``donate_argnums``),
        so steady state holds one in-flight compute and one in-flight
        transfer, not a growing buffer population.  Health checks and
        escalations happen at sync time, after every dispatch has been
        issued — a failing slice never stalls the healthy pipeline.

        Failure-atomic: if an exception escapes (escalation disabled or
        non-recoverable), every request not yet resolved to a result is
        restored to the pending queue before the exception propagates."""
        self._check_tuning()
        with _trace.span("serving.bucketize", service=self._sid):
            work = self._chunks()
        results: Dict[int, QRResult] = {}
        try:
            if work:
                self._flush_work(work, results)
        except BaseException:
            done = set(results)
            self._pending = [req for _, chunk in work for req in chunk
                             if req.rid not in done] + self._pending
            raise
        for rid, reason in self._quarantined.items():
            results[rid] = QRResult(rid=rid, q=None, r=None,
                                    error=f"quarantined:{reason}")
        self._quarantined.clear()
        return results

    def _flush_work(self, work, results: Dict[int, QRResult]) -> None:
        with _trace.span("serving.plan", service=self._sid,
                         chunks=len(work)):
            planned = [self._plan_with_escalation(
                key, pad_batch(len(chunk), max_batch=self.policy.max_batch))
                for key, chunk in work]
        verify_on = self._verify_on()
        kernel_chunks = [i for i, (plan, _) in enumerate(planned)
                        if plan is not None]
        staged: Dict[int, Array] = {}
        if kernel_chunks:
            i0 = kernel_chunks[0]
            staged[i0] = self._stage(work[i0][0], work[i0][1],
                                     planned[i0][0].batch)
        outs: Dict[int, object] = {}
        for pos, i in enumerate(kernel_chunks):
            plan, rung = planned[i]
            key, chunk = work[i]
            if pos + 1 < len(kernel_chunks):
                j = kernel_chunks[pos + 1]
                staged[j] = self._stage(work[j][0], work[j][1],
                                        planned[j][0].batch)
            tag = f"{key.m}x{key.n}:{rung}"
            with _trace.span("serving.dispatch", service=self._sid,
                             bucket=f"{key.m}x{key.n}", batch=plan.batch,
                             fill=len(chunk), rung=rung):
                try:
                    _inject.sleep(tag)
                    _inject.check("dispatch", tag)
                    out = plan.fn(staged.pop(i))  # async; donates buffer
                    outs[i] = _inject.corrupt_output(out, tag)
                except Exception as e:  # noqa: BLE001
                    if not self.escalate:
                        raise
                    # Dispatch raised before results existed: the whole
                    # chunk recovers per request below this rung.
                    self._record_escalation(key, _escalate.record(
                        rung, "per-request", _escalate.classify(
                            e, "dispatch"), str(e)))
                    staged.pop(i, None)
                    for req in chunk:
                        results[req.rid] = self._recover_request(
                            req, key, rung)
                    planned[i] = (None, "recovered")
                    continue
            self._count("dispatches")
            self._count("matrices_served", len(chunk))
            self._count("padded_slots", plan.batch - len(chunk))
            now = time.monotonic()
            for req in chunk:
                self._observe("queue_wait_seconds", now - req.t_submit)
            self._observe("bucket_fill", len(chunk) / plan.batch)
            real = sum(m * n for m, n in (r.shape for r in chunk))
            waste = 1.0 - real / (plan.batch * key.m * key.n)
            self._observe("padding_waste", waste, bucket=f"{key.m}x{key.n}")
        with _trace.span("serving.unpad", service=self._sid) as sp:
            for i, (key, chunk) in enumerate(work):
                plan, rung = planned[i]
                if rung == "recovered":
                    continue
                if plan is None:               # breaker-pinned / lapack
                    results.update(self._lapack_chunk(key, chunk))
                    self._count("dispatches")
                    self._count("matrices_served", len(chunk))
                    continue
                out = outs[i]
                try:
                    sp.sync(out)
                except Exception as e:  # noqa: BLE001 — deferred runtime error
                    if not self.escalate:
                        raise
                    self._record_escalation(key, _escalate.record(
                        rung, "per-request",
                        _escalate.classify(e, "dispatch"), str(e)))
                    for req in chunk:
                        results[req.rid] = self._recover_request(
                            req, key, rung)
                    continue
                bad: Set[int] = set()
                if verify_on:
                    bad = self._verify_chunk(key, chunk, out, rung)
                now = time.monotonic()
                for s, req in enumerate(chunk):
                    if s in bad:
                        results[req.rid] = self._recover_request(
                            req, key, rung)
                        continue
                    m, n = req.shape
                    k = min(m, n)
                    if key.mode == "r":
                        q_mat, r_mat = None, out[0][s, :k, :n]
                    else:
                        q_mat, r_mat = out[0][s, :m, :k], out[1][s, :k, :n]
                    results[req.rid] = QRResult(rid=req.rid, q=q_mat,
                                                r=r_mat)
                    self._observe("latency_seconds", now - req.t_submit)

    def _verify_chunk(self, key: BucketKey, chunk: List[QRRequest],
                      out, rung: str) -> Set[int]:
        """Per-slice health check of one synced bucket: ONE vmapped
        stats program over the padded stack, host-side verdicts.  A
        failing slice is recorded (and escalated by the caller) alone —
        its bucket-mates are unaffected."""
        a_stack = np.zeros((out[0].shape[0], key.m, key.n),
                           np.dtype(key.dtype))
        for s, req in enumerate(chunk):
            m, n = req.shape
            a_stack[s, :m, :n] = req.a
        kp = min(key.m, key.n)   # factors come back fully padded
        with _trace.span("serving.verify", service=self._sid,
                         bucket=f"{key.m}x{key.n}"):
            if key.mode == "r":
                reports = _verify.check_batch(
                    a_stack, None, out[0][:, :kp, :key.n])
            else:
                reports = _verify.check_batch(
                    a_stack, out[0][:, :, :kp], out[1][:, :kp, :key.n])
        bad: Set[int] = set()
        for s in range(len(chunk)):
            rep = reports[s]
            if rep.ok:
                continue
            bad.add(s)
            self._count("health_check_failures")
            self._record_escalation(key, _escalate.record(
                rung, "per-request", "health_check_failed",
                f"slice {s} ({chunk[s].shape[0]}x{chunk[s].shape[1]}): "
                f"{rep.reason} residual={rep.residual:.3e} "
                f"defect={rep.ortho_defect:.3e} tol={rep.tol:.3e}"))
        return bad

    # -------------------------------------------------------------- stats

    def stats(self) -> Dict[str, object]:
        """Serving counters: cache behavior, dispatch economy, padding
        waste, failure hardening.  ``bucket_fill_ratio`` is matrices
        served over batch slots dispatched (1.0 = every slot carried a
        real request); ``cache_hit_rate`` is plan-cache hits over
        lookups; ``breaker_open`` counts buckets currently pinned to the
        fallback path.

        Counters are a view over this instance's ``serving.*`` series in
        the process-global metrics registry (``service=<id>`` label)."""
        served = self._count_value("matrices_served")
        padded = self._count_value("padded_slots")
        hits = self._count_value("cache_hits")
        slots = served + padded
        lookups = hits + self._count_value("cache_misses")
        return dict(
            requests=self._count_value("requests"),
            matrices_served=served,
            dispatches=self._count_value("dispatches"),
            compiles=self._count_value("compiles"),
            cache_hits=hits,
            cache_misses=self._count_value("cache_misses"),
            cache_evictions=self._count_value("cache_evictions"),
            plan_invalidations=self._count_value("plan_invalidations"),
            plans_cached=len(self._plans),
            padded_slots=padded,
            bucket_fill_ratio=(served / slots) if slots else 1.0,
            cache_hit_rate=(hits / lookups) if lookups else 0.0,
            quarantined=self._count_value("quarantined"),
            escalations=self._count_value("escalations"),
            health_check_failures=self._count_value(
                "health_check_failures"),
            breaker_trips=self._count_value("breaker_trips"),
            breaker_open=len(self._breaker_open),
        )
