"""Serving engine: batched prefill + decode with greedy/temperature sampling.

``serve_step`` (one new token against a full-length cache) is the function
the decode_32k / long_500k dry-run cells lower.  The engine wraps it with
cache management for actual generation (examples/serve_lm.py):

    engine = ServeEngine(params, cfg, batch=8, max_len=1024)
    out = engine.generate(prompt_tokens, steps=64)

Batched requests decode in lock-step with per-request lengths (a length
mask keeps ragged prompts correct); prefill pads to the batch maximum.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward_decode, forward_prefill, init_caches

Array = jax.Array

__all__ = ["ServeEngine", "serve_step"]


def serve_step(params, tokens: Array, cfg: ModelConfig, caches, pos: Array):
    """One decode step: (B,1) token ids + caches -> (B,1,V) logits + caches.

    This is the exact callable the decode dry-run cells lower+compile."""
    return forward_decode(params, tokens, cfg, caches, pos)


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, batch: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, t, c, pos: serve_step(p, t, cfg, c, pos))
        self._prefill = jax.jit(lambda p, b: forward_prefill(p, b, cfg))

    def _pad_caches(self, caches, prompt_len: int):
        """Extend prefill KV caches to max_len rings."""
        out = []
        for entry in caches:
            if "k" in entry:
                pad = self.max_len - entry["k"].shape[2]
                f = lambda a: jnp.pad(
                    a, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                out.append({"k": f(entry["k"]), "v": f(entry["v"])})
            else:
                out.append(entry)
        return tuple(out)

    def _sample(self, logits: Array) -> Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / self.temperature, axis=-1)

    def generate(self, prompt_tokens, steps: int,
                 prompt_embeds: Optional[Array] = None) -> Array:
        """prompt_tokens: (B, S0) int32. Returns (B, steps) generated ids."""
        b, s0 = prompt_tokens.shape
        assert b == self.batch and s0 + steps <= self.max_len
        batch = ({"embeds": prompt_embeds} if self.cfg.embedding_input
                 and prompt_embeds is not None
                 else {"tokens": jnp.asarray(prompt_tokens)})
        logits, caches = self._prefill(self.params, batch)   # (B, 1, V)
        caches = self._pad_caches(caches, s0)
        tok = self._sample(logits[:, 0])[:, None].astype(jnp.int32)
        out = [tok]
        pos = jnp.int32(s0)
        for _ in range(steps - 1):
            logits, caches = self._decode(self.params, tok, caches, pos)
            tok = self._sample(logits[:, 0])[:, None].astype(jnp.int32)
            out.append(tok)
            pos = pos + 1
        return jnp.concatenate(out, axis=1)
