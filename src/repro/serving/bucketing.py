"""Shape bucketing for the QR serving layer.

Production QR traffic is thousands of concurrent heterogeneous
``(m, n, dtype, mode)`` requests; the engine wants few, large, statically
shaped dispatches.  This module maps each request to a **bucket** — a
padded shape class — so requests sharing a bucket can be zero-padded,
stacked, and factored in one batched dispatch
(:func:`repro.core.engine.factor_tiles_batched`).  Zero padding is
numerically free for QR: padded rows/columns factor to exactly-zero
reflector entries, so the unpadded ``Q``/``R`` slices of the padded
factorization ARE the factorization of the original matrix (the same
invariant ``tiled_qr`` already relies on for non-multiple-of-tile
shapes).

Bucket edges are **pow2-ish** — per dimension, the candidate edges are
``tile * 2^k`` and ``tile * 3 * 2^(k-1)`` (ratio <= 4/3 between
consecutive edges) — so the number of distinct buckets a traffic mix can
produce stays logarithmic in the shape range, which is what keeps the
compiled-plan cache small and steady-state serving compile-free.  A
configurable **waste cap** bounds the padding cost: when the pow2-ish
edge would pad more than ``max_waste`` of the padded extent, the
dimension falls back to the next tile multiple instead (tile granularity
is the floor — every edge must be a tile multiple for the tile-grid
engine).  Batch sizes are padded to pow2 so plan shapes stay finite
there too.

Every request lands in exactly ONE bucket (``bucket_key`` is a pure
function of the request), and the cap is honored whenever it is
achievable at tile granularity — both property-tested in
tests/test_qr_service.py.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

__all__ = [
    "BucketKey",
    "BucketingPolicy",
    "bucket_key",
    "bucketize",
    "group_shape_classes",
    "pad_batch",
    "pad_dim",
    "pow2ish_edges",
]


def pow2ish_edges(tile: int, hi: int) -> Tuple[int, ...]:
    """Ascending pow2-ish edge candidates covering ``[tile, >= hi]``:
    ``tile * {1, 2, 3, 4, 6, 8, 12, 16, ...}`` — the multipliers are
    ``2^k`` and ``3 * 2^(k-1)``, so every edge is a tile multiple and
    consecutive ratios are <= 2 (and <= 1.5 from the third edge on)."""
    if tile < 1:
        raise ValueError(f"tile must be >= 1, got {tile}")
    mults: List[int] = [1, 2, 3]
    while tile * mults[-1] < hi:
        mults.append(2 * mults[-2])
    return tuple(tile * c for c in mults)


def pad_dim(d: int, *, tile: int, max_waste: float) -> int:
    """Bucketed extent of one dimension: the smallest pow2-ish edge
    >= ``d``, unless that edge would waste more than ``max_waste`` of the
    padded extent — then the next tile multiple (the finest granularity
    the tile-grid engine admits).  Always a tile multiple >= ``d`` and
    >= ``tile``; monotone in ``d``."""
    if d < 1:
        raise ValueError(f"dimension must be >= 1, got {d}")
    d = max(d, 1)
    for e in pow2ish_edges(tile, d):
        if e >= d:
            break
    tiled_up = -(-d // tile) * tile
    if (e - d) / e > max_waste:
        return tiled_up
    return e


@dataclasses.dataclass(frozen=True)
class BucketingPolicy:
    """How requests map to buckets.

    tile:       engine tile size (``QRConfig.block`` of the bucketed
                plan) — every padded extent is a multiple of it.
    max_waste:  per-dimension padding cap (fraction of the padded
                extent); pow2-ish edges exceeding it fall back to tile
                granularity.  Honored whenever achievable at tile
                granularity (tiny dims floor at one tile).
    max_batch:  largest bucket batch one dispatch may carry; larger
                groups split into max_batch-sized chunks.
    """

    tile: int = 32
    max_waste: float = 0.25
    max_batch: int = 64

    def __post_init__(self):
        if self.tile < 1:
            raise ValueError(f"tile must be >= 1, got {self.tile}")
        if not 0.0 <= self.max_waste < 1.0:
            raise ValueError(
                f"max_waste must be in [0, 1), got {self.max_waste}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")


@dataclasses.dataclass(frozen=True)
class BucketKey:
    """A padded shape class — everything a compiled bucket plan is
    specialized on.  Hashable: the plan-cache key is (BucketKey, batch)."""

    m: int
    n: int
    dtype: str
    mode: str

    def __post_init__(self):
        if self.mode not in ("reduced", "r"):
            raise ValueError(
                f"serving modes are 'reduced' and 'r', got {self.mode!r}")


def bucket_key(m: int, n: int, dtype, mode: str,
               policy: BucketingPolicy) -> BucketKey:
    """The ONE bucket a ``(m, n, dtype, mode)`` request lands in."""
    import numpy as np

    return BucketKey(
        m=pad_dim(m, tile=policy.tile, max_waste=policy.max_waste),
        n=pad_dim(n, tile=policy.tile, max_waste=policy.max_waste),
        dtype=str(np.dtype(dtype)),
        mode=mode,
    )


def pad_batch(b: int, *, max_batch: int) -> int:
    """Padded batch size: next power of two, capped at ``max_batch`` —
    keeps the number of distinct compiled (bucket, batch) plans
    logarithmic in the arrival rate."""
    if b < 1:
        raise ValueError(f"batch must be >= 1, got {b}")
    p = 1
    while p < b:
        p *= 2
    return min(p, max_batch)


def group_shape_classes(shapes: Sequence[Tuple], policy: BucketingPolicy,
                        *, mode: str = "reduced"
                        ) -> Dict[BucketKey, List[int]]:
    """Group ``(m, n, dtype)`` shape triples into padded shape classes,
    returning the member indices of each class (input order preserved
    within a class) — the reusable core of request bucketing, shared by
    the serving intake (:func:`bucketize` over request objects) and the
    optimizer's batched orthogonalization
    (:mod:`repro.optim.batched_ortho`, which groups the 2-D momentum
    matrices of one update step the same way the tuning cache keys shape
    classes, so measured entries apply to optimizer dispatches too)."""
    grouped = bucketize(list(enumerate(shapes)), policy,
                        key_fn=lambda t: (t[1][0], t[1][1], t[1][2], mode))
    return {key: [i for i, _ in members] for key, members in grouped.items()}


def bucketize(requests: Sequence, policy: BucketingPolicy,
              key_fn=None) -> Dict[BucketKey, List]:
    """Group requests by bucket, preserving submission order within each
    bucket.  ``key_fn(req) -> (m, n, dtype, mode)`` defaults to reading
    ``req.shape`` / ``req.dtype`` / ``req.mode`` (QRRequest duck type)."""
    if key_fn is None:
        key_fn = lambda r: (*r.shape, r.dtype, r.mode)  # noqa: E731
    out: Dict[BucketKey, List] = {}
    for req in requests:
        m, n, dtype, mode = key_fn(req)
        out.setdefault(bucket_key(m, n, dtype, mode, policy), []).append(req)
    return out
