"""Serving substrate."""

from repro.serving.engine import ServeEngine, serve_step

__all__ = ["ServeEngine", "serve_step"]
