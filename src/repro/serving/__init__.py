"""Serving layer: autoregressive LM decode (ServeEngine) and batched
QR-as-a-service (QRService) — two consumers of the same compiled-plan
discipline: bucket dynamic traffic into a small set of static shapes,
cache the compiled executables, keep steady state compile-free."""

from repro.serving.bucketing import (
    BucketKey, BucketingPolicy, bucket_key, bucketize, group_shape_classes,
    pad_batch, pad_dim, pow2ish_edges)
from repro.serving.engine import ServeEngine, serve_step
from repro.serving.qr_service import QRRequest, QRResult, QRService

__all__ = [
    "BucketKey",
    "BucketingPolicy",
    "QRRequest",
    "QRResult",
    "QRService",
    "ServeEngine",
    "bucket_key",
    "bucketize",
    "group_shape_classes",
    "pad_batch",
    "pad_dim",
    "pow2ish_edges",
    "serve_step",
]
